"""Beyond-paper: shared-prefix KV reuse — the radix prefix cache and
``prefix_aware`` routing, swept over prefix share x routing policy x
both cost regimes.

Protocol: ``cluster_stress_config`` traffic with RAG-scale prompts
(``PROMPT_SCALE`` x the terse corpus counts) where every request is
front-loaded with a tenant system prompt of ``SHARED_PREFIX_TOKENS``
tokens drawn from ``PREFIX_GROUPS_PER_TENANT`` groups per tenant tier
(the dominant structure of real multi-tenant chat/RAG traffic). All
arms run the iteration-level step engine with the per-replica radix
prefix cache enabled (``ClusterConfig.prefix_cache``); the prefix-share
sweep includes 0 (no shareable prefix), which must reproduce the PR-3
step-engine numbers exactly — the benchmark checks that against a
cache-off baseline and reports ``share0_matches_baseline``.

``PREFIX_CACHE_PAGES`` is deliberately sized BELOW the full group
population at the highest prefix share: whether routing concentrates a
group's stream (stable residency) or sprays it across replicas (LRU
thrash) is then visible in the hit-rate/eviction counters, not just in
latency. What to expect:

* at prefix share 0 every policy is a wash (and bit-identical to the
  cache-off step engine);
* at moderate share, every replica can hold every group — the policies
  converge on hit rate and the win is only the avoided cold misses;
* at high share (>= ~50% of prompt tokens) the population no longer
  fits per replica: ``prefix_aware`` partitions groups onto replicas
  and keeps hit rates high where ``least_loaded`` thrashes — fewer
  prefill tokens actually computed, lower TTFT P50, fewer evictions.

``--json`` output carries per-arm hit rate, saved prefill tokens, and
evicted pages (the ``prefix_cache`` block of ``ClusterMetrics``), so
per-PR trajectories of cache effectiveness stay attributable.

Smoke mode: set ``BENCH_SMOKE=1`` to shrink the sweep to a single
seed / tiny request count (used by the CI benchmark smoke step).
"""

from __future__ import annotations

import os

from repro.cluster import ClusterConfig, ClusterSimulator
from repro.serving.cost_model import L4_MAX_DRIVEN, L4_QWEN_1_8B
from repro.workload.generator import (GeneratorConfig, WorkloadGenerator,
                                      cluster_stress_config)

from .common import fmt_table, mean, save_json

N_REPLICAS = 4
SEEDS = (1, 2)
TOTAL_REQUESTS = 600
#: prompt scale: corpus prompts are 3-32 tokens; x8 models RAG traffic
#: (~25-250 prompt tokens) on top of which the shared prefix rides.
PROMPT_SCALE = 8.0
#: shared system-prompt sizes swept (tokens; 0 = no shareable prefix).
#: 256 ~= a chat system prompt; 1024 ~= a heavy RAG/agent template.
SHARED_PREFIX_TOKENS = (0, 256, 1024)
PREFIX_GROUPS_PER_TENANT = 4          # x3 tenant tiers = 12 groups
#: per-replica cache budget in KV pages of 128 tokens. 32 pages hold
#: all 12 groups at 256 shared tokens (24 pages) but only 4 of 12 at
#: 1024 (96 pages needed) — the regime where placement must partition.
PREFIX_CACHE_PAGES = 32
ROUTINGS = ("least_loaded", "prefix_aware")
REGIMES = {"batch_walk": L4_MAX_DRIVEN, "sum_dominated": L4_QWEN_1_8B}
#: per-iteration chunked-prefill budget (tokens): prefill starts at the
#: cached boundary, so a hit shrinks the chunk stream, not just one sum.
CHUNK_PREFILL_TOKENS = 2048

_SMOKE = os.environ.get("BENCH_SMOKE", "").strip().lower() \
    not in ("", "0", "false", "no")

# --- engine arm: the same question over real ServingEngine replicas ---
# Engine-scale constants (smoke models bucket prompts at 64 tokens, so
# the shared prefix and the page size shrink with them; the *shape* of
# the experiment — cache budget below the group population, prefix_aware
# vs least_loaded — is identical to the simulator sweep above).
ENGINE_REPLICAS = 2
ENGINE_REQUESTS = 120                 # 48 under BENCH_SMOKE
ENGINE_SHARED_TOKENS = 16             # 2 pages of 8 on the device pool
ENGINE_PAGE_SIZE = 8
#: per-replica residency budget in device pages: 12 groups x 2 pages =
#: 24 pages of population vs 16 budget — placement must partition.
ENGINE_CACHE_PAGES = 16
ENGINE_CHUNK_TOKENS = 16


def _protocol() -> dict:
    """Effective sweep constants (shrunk under BENCH_SMOKE)."""
    if _SMOKE:
        return {"seeds": (1,), "total": 150, "n_replicas": 2,
                "shares": (0, 1024), "engine_total": 48}
    return {"seeds": SEEDS, "total": TOTAL_REQUESTS,
            "n_replicas": N_REPLICAS, "shares": SHARED_PREFIX_TOKENS,
            "engine_total": ENGINE_REQUESTS}


def _run_engine_arm(proto: dict) -> dict:
    """prefix_aware vs least_loaded over real JAX engines: N paged
    ``ServingEngine`` replicas with the radix prefix cache and chunked
    prefill on, driven through ``EngineClusterDriver``. Arrivals are
    interleaved with engine steps (one step per arrival, then drain)
    so routing probes a *live* cache — the measured-residency signal
    ``prefix_aware`` follows. Hit rates aggregate each engine's own
    tree counters; TTFT comes from the engine-stamped ``prefill_end``
    in step units."""
    import jax

    from repro.cluster.driver import make_engine_cluster
    from repro.configs import smoke_config
    from repro.models.registry import get_api
    from repro.serving.engine import EngineConfig
    from repro.serving.metrics import percentile

    cfg = smoke_config("smollm-135m")
    params = get_api(cfg).init(cfg, jax.random.PRNGKey(0))
    out = {}
    for routing in ROUTINGS:
        driver = make_engine_cluster(
            cfg, params, ENGINE_REPLICAS, routing=routing,
            engine_config=EngineConfig(
                n_slots=4, max_len=96, prompt_buckets=(64,),
                paged=True, page_size=ENGINE_PAGE_SIZE,
                chunk_prefill_tokens=ENGINE_CHUNK_TOKENS,
                prefix_cache=True,
                prefix_cache_pages=ENGINE_CACHE_PAGES))
        gen = WorkloadGenerator(GeneratorConfig(
            total_requests=proto["engine_total"],
            calibration_requests=proto["engine_total"],
            max_tokens=24, seed=proto["seeds"][0],
            shared_prefix_tokens=ENGINE_SHARED_TOKENS,
            prefix_groups_per_tenant=PREFIX_GROUPS_PER_TENANT))
        now = 0.0
        for _, r in gen.plan(seed=proto["seeds"][0]).calibration:
            r.arrival_time = now
            driver.submit(r, now)
            driver.step(now)
            now += 1.0
        m = driver.run_until_drained(max_steps=20_000)
        stats = [rep.prefix_cache_stats() for rep in driver.replicas]
        hits = sum(s["hits"] for s in stats)
        misses = sum(s["misses"] for s in stats)
        done = [r for rep in driver.replicas for r in rep.sched.completed]
        out[routing] = {
            "n_completed": m.n_completed,
            "hit_rate": hits / max(hits + misses, 1),
            "saved_tokens": sum(s["tokens_saved"] for s in stats),
            "evicted_pages": sum(s["evicted_pages"] for s in stats),
            "ttft_p50_steps": percentile(
                [r.ttft for r in done if r.ttft is not None], 50),
        }
    pa, ll = out["prefix_aware"], out["least_loaded"]
    out["prefix_aware_beats_least_loaded"] = {
        "hit_rate": pa["hit_rate"] > ll["hit_rate"],
        "ttft_p50": pa["ttft_p50_steps"] <= ll["ttft_p50_steps"],
    }
    return out


def _run_one(routing: str, shared: int, cost_model, proto: dict,
             seed: int, cache: bool = True):
    gen = WorkloadGenerator(cluster_stress_config(
        proto["n_replicas"], seed=seed, total_requests=proto["total"],
        prompt_tokens_scale=PROMPT_SCALE,
        shared_prefix_tokens=shared,
        prefix_groups_per_tenant=PREFIX_GROUPS_PER_TENANT))
    sim = ClusterSimulator(
        plan=gen.plan(seed=seed),
        config=ClusterConfig(
            n_replicas=proto["n_replicas"], routing=routing,
            step_engine=True, chunk_prefill_tokens=CHUNK_PREFILL_TOKENS,
            prefix_cache=cache, prefix_cache_pages=PREFIX_CACHE_PAGES,
            seed=seed),
        cost_model=cost_model)
    return sim.run()


def _collect(routing: str, shared: int, cost_model, proto: dict,
             cache: bool = True) -> dict:
    acc = {k: [] for k in ("ttft_p50", "ttft_p99", "e2e_p50", "e2e_p99",
                           "inter_token_p50", "hit_rate", "saved_tokens",
                           "evicted_pages", "n_completed")}
    for seed in proto["seeds"]:
        m = _run_one(routing, shared, cost_model, proto, seed, cache=cache)
        acc["ttft_p50"].append(m.ttft.p50)
        acc["ttft_p99"].append(m.ttft.p99)
        acc["e2e_p50"].append(m.run.e2e.p50)
        acc["e2e_p99"].append(m.run.e2e.p99)
        acc["inter_token_p50"].append(m.inter_token.p50)
        acc["hit_rate"].append(m.prefix_cache.get("hit_rate", 0.0))
        acc["saved_tokens"].append(m.prefix_cache.get("tokens_saved", 0))
        acc["evicted_pages"].append(m.prefix_cache.get("evicted_pages", 0))
        acc["n_completed"].append(m.run.n_completed)
    return {k: mean(v) for k, v in acc.items()}


def run() -> dict:
    proto = _protocol()
    out = {"smoke": _SMOKE, "protocol": {
        "seeds": list(proto["seeds"]), "total_requests": proto["total"],
        "n_replicas": proto["n_replicas"],
        "shared_prefix_tokens": list(proto["shares"]),
        "prefix_groups_per_tenant": PREFIX_GROUPS_PER_TENANT,
        "prefix_cache_pages": PREFIX_CACHE_PAGES,
        "engine": {"n_replicas": ENGINE_REPLICAS,
                   "total_requests": proto["engine_total"],
                   "shared_prefix_tokens": ENGINE_SHARED_TOKENS,
                   "page_size": ENGINE_PAGE_SIZE,
                   "prefix_cache_pages": ENGINE_CACHE_PAGES,
                   "chunk_prefill_tokens": ENGINE_CHUNK_TOKENS}},
        "sweep": {}}
    for regime, cost in REGIMES.items():
        rows = {}
        for shared in proto["shares"]:
            for routing in ROUTINGS:
                rows[f"{routing}[{shared}]"] = _collect(
                    routing, shared, cost, proto)
        out["sweep"][regime] = rows

    # prefix share 0 must reproduce the cache-off step engine (PR-3
    # numbers) bit-for-bit: the cache sees no shareable prefix, takes
    # no action, and perturbs nothing (locked by tests too)
    out["share0_matches_baseline"] = {}
    for regime, cost in REGIMES.items():
        with_cache = _run_one("least_loaded", 0, cost, proto,
                              proto["seeds"][0], cache=True)
        without = _run_one("least_loaded", 0, cost, proto,
                           proto["seeds"][0], cache=False)
        out["share0_matches_baseline"][regime] = \
            with_cache.as_dict() == without.as_dict()

    # engine arm: the same comparison over real JAX ServingEngine
    # replicas (chunked prefill + page-donation radix cache on device)
    try:
        out["engine"] = _run_engine_arm(proto)
    except ImportError as e:          # pragma: no cover - jax-less hosts
        out["engine"] = {"skipped": str(e)}

    # headline: prefix_aware vs least_loaded at the highest share
    # (acceptance bar: less prefill-token work AND lower TTFT P50 at
    # >= 50% shared-prefix share)
    top = max(proto["shares"])
    out["prefix_aware_vs_least_loaded"] = {}
    for regime, rows in out["sweep"].items():
        ll, pa = rows[f"least_loaded[{top}]"], rows[f"prefix_aware[{top}]"]
        out["prefix_aware_vs_least_loaded"][regime] = {
            "shared_prefix_tokens": top,
            "hit_rate": {"least_loaded": ll["hit_rate"],
                         "prefix_aware": pa["hit_rate"]},
            "saved_tokens_ratio": pa["saved_tokens"]
            / max(ll["saved_tokens"], 1),
            "ttft_p50_reduction_pct": 100.0
            * (1 - pa["ttft_p50"] / max(ll["ttft_p50"], 1e-9)),
            "e2e_p50_reduction_pct": 100.0
            * (1 - pa["e2e_p50"] / max(ll["e2e_p50"], 1e-9)),
        }

    save_json("prefix_cache", out)
    return out


def report(out: dict) -> str:
    rows = []
    for regime, per_mode in out["sweep"].items():
        for mode, r in per_mode.items():
            rows.append([regime, mode,
                         f"{r['ttft_p50']:.2f}", f"{r['e2e_p50']:.2f}",
                         f"{r['e2e_p99']:.2f}", f"{r['hit_rate']:.2f}",
                         int(r["saved_tokens"]), int(r["evicted_pages"]),
                         int(r["n_completed"])])
    s = fmt_table(
        ["regime", "routing[prefix]", "TTFT50", "e2e50", "e2e99",
         "hit", "saved_tok", "evict", "done"],
        rows,
        "Shared-prefix KV reuse: radix cache + routing policy sweep "
        f"({'SMOKE, ' if out['smoke'] else ''}"
        f"{len(out['protocol']['seeds'])}-seed avg; cache budget "
        f"{out['protocol']['prefix_cache_pages']} pages/replica)")
    for regime, ok in out["share0_matches_baseline"].items():
        s += (f"\n{regime}: share-0 reproduces cache-off step engine: "
              f"{'YES' if ok else 'NO (regression!)'}")
    for regime, d in out["prefix_aware_vs_least_loaded"].items():
        s += (f"\n{regime}: prefix_aware vs least_loaded at "
              f"{d['shared_prefix_tokens']} shared tokens: hit rate "
              f"{d['hit_rate']['prefix_aware']:.2f} vs "
              f"{d['hit_rate']['least_loaded']:.2f}, saved-token ratio "
              f"{d['saved_tokens_ratio']:.2f}x, TTFT P50 "
              f"{d['ttft_p50_reduction_pct']:+.0f}%, e2e P50 "
              f"{d['e2e_p50_reduction_pct']:+.0f}%")
    eng = out.get("engine", {})
    if "skipped" in eng:
        s += f"\nengine arm skipped: {eng['skipped']}"
    else:
        for routing in ROUTINGS:
            r = eng[routing]
            s += (f"\nengine[{routing}]: hit {r['hit_rate']:.2f}, "
                  f"saved {r['saved_tokens']} tok, TTFT P50 "
                  f"{r['ttft_p50_steps']:.0f} steps, "
                  f"done {r['n_completed']}")
        wins = eng["prefix_aware_beats_least_loaded"]
        s += (f"\nengine: prefix_aware beats least_loaded: "
              f"hit_rate={'YES' if wins['hit_rate'] else 'NO'}, "
              f"ttft_p50={'YES' if wins['ttft_p50'] else 'NO'}")
    return s
