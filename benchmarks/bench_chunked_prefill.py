"""Beyond-paper: iteration-level execution core — chunked-prefill
continuous batching on unified replicas vs ``pd_disaggregated`` vs the
legacy atomic-batch path, swept across chunk budgets and both cost
regimes.

Protocol: `cluster_stress_config` traffic with RAG/agent-scale prompts
(``PROMPT_SCALE`` x the terse corpus counts), 4 replicas, both
service-time regimes — batch-walk (``L4_MAX_DRIVEN``) and sum-dominated
(``L4_QWEN_1_8B``). Two seeds averaged; bit-deterministic per seed.

What to expect: the step engine answers the ROADMAP follow-up "chunked
prefill on unified replicas — the intra-replica alternative to
disaggregation" head-to-head. Continuous batching collapses unified
TTFT (requests no longer wait for the whole batch to drain — P50
typically 100-400x below legacy-atomic, far past the 2x acceptance
bar) and beats the atomic path on e2e too (freed slots refill instead
of walking to the batch's longest member). Chunk budgets show a
U-shape: below ``~c_decode_max / c_prefill`` tokens the extra
per-iteration walk overhead outweighs the peer-prefill wait it saves
(see the TTFT-monotonicity test in tests/test_step_engine.py). The
P/D arm runs the same step engine (so the comparison isolates
disaggregation itself; the atomic P/D baseline is bench_pd_disagg's
job): a dedicated prefill pool still wins the TTFT *tail* — P99 stays
flat where chunked-unified's inherits queueing spikes — but pays a
~1.2-1.4x e2e premium for the smaller decode pool and KV handoff;
chunked unified needs no KV transfer or role-split pool to operate.

Smoke mode: set ``BENCH_SMOKE=1`` to shrink the sweep to a single
seed / tiny request count (used by the CI benchmark smoke step).
"""

from __future__ import annotations

import os

from repro.cluster import ClusterConfig, ClusterSimulator
from repro.serving.cost_model import L4_MAX_DRIVEN, L4_QWEN_1_8B
from repro.workload.generator import WorkloadGenerator, cluster_stress_config

from .common import fmt_table, mean, save_json

N_REPLICAS = 4
SEEDS = (1, 2)
TOTAL_REQUESTS = 600
#: prompt scale: corpus prompts are 3-32 tokens; x16 models RAG/agent
#: traffic (~50-500 prompt tokens) where prefill chunking has teeth.
PROMPT_SCALE = 16.0
#: per-iteration prefill token budgets swept for the chunked modes
#: (None = unbounded: a joining prompt prefills in one iteration).
CHUNK_BUDGETS = (None, 2048, 512)
REGIMES = {"batch_walk": L4_MAX_DRIVEN, "sum_dominated": L4_QWEN_1_8B}
#: unified modes route least_loaded — the same load measure
#: pd_disaggregated's decode stage uses, isolating the execution model.
UNIFIED_ROUTING = "least_loaded"

_SMOKE = os.environ.get("BENCH_SMOKE", "").strip().lower() \
    not in ("", "0", "false", "no")


def _protocol() -> dict:
    """Effective sweep constants (shrunk under BENCH_SMOKE)."""
    if _SMOKE:
        return {"seeds": (1,), "total": 120, "budgets": (None, 512),
                "n_replicas": 2}
    return {"seeds": SEEDS, "total": TOTAL_REQUESTS,
            "budgets": CHUNK_BUDGETS, "n_replicas": N_REPLICAS}


def _mode_config(mode: str, n: int, seed: int, chunk) -> ClusterConfig:
    if mode == "legacy_atomic":
        return ClusterConfig(n_replicas=n, routing=UNIFIED_ROUTING,
                             seed=seed)
    if mode == "chunked_unified":
        return ClusterConfig(n_replicas=n, routing=UNIFIED_ROUTING,
                             step_engine=True, chunk_prefill_tokens=chunk,
                             seed=seed)
    # P/D runs the SAME iteration-level engine as the chunked arms
    # (handoffs at iteration boundaries) so the comparison isolates
    # disaggregation itself, not atomic-vs-continuous execution; the
    # atomic P/D baseline lives in bench_pd_disagg.
    return ClusterConfig(n_replicas=n, routing="pd_disaggregated",
                         step_engine=True, seed=seed)


def _collect(mode: str, cost_model, proto: dict, chunk=None) -> dict:
    acc = {k: [] for k in ("ttft_p50", "ttft_p99", "e2e_p50", "e2e_p99",
                           "queue_wait_p50", "n_completed")}
    for seed in proto["seeds"]:
        gen = WorkloadGenerator(cluster_stress_config(
            proto["n_replicas"], seed=seed, total_requests=proto["total"],
            prompt_tokens_scale=PROMPT_SCALE))
        sim = ClusterSimulator(
            plan=gen.plan(seed=seed),
            config=_mode_config(mode, proto["n_replicas"], seed, chunk),
            cost_model=cost_model)
        m = sim.run()
        acc["ttft_p50"].append(m.ttft.p50)
        acc["ttft_p99"].append(m.ttft.p99)
        acc["e2e_p50"].append(m.run.e2e.p50)
        acc["e2e_p99"].append(m.run.e2e.p99)
        acc["queue_wait_p50"].append(m.run.queue_wait.p50)
        acc["n_completed"].append(m.run.n_completed)
    return {k: mean(v) for k, v in acc.items()}


def _label(mode: str, chunk) -> str:
    if mode != "chunked_unified":
        return mode
    return f"chunked_unified[{'inf' if chunk is None else chunk}]"


def run() -> dict:
    proto = _protocol()
    out = {"smoke": _SMOKE, "protocol": {
        "seeds": list(proto["seeds"]), "total_requests": proto["total"],
        "n_replicas": proto["n_replicas"],
        "chunk_budgets": [b if b is not None else "inf"
                          for b in proto["budgets"]]},
        "sweep": {}}
    for regime, cost in REGIMES.items():
        rows = {}
        rows["legacy_atomic"] = _collect("legacy_atomic", cost, proto)
        for chunk in proto["budgets"]:
            rows[_label("chunked_unified", chunk)] = _collect(
                "chunked_unified", cost, proto, chunk=chunk)
        rows["pd_disaggregated"] = _collect("pd_disaggregated", cost, proto)
        out["sweep"][regime] = rows

    # headline: best chunked-unified budget vs legacy-atomic TTFT
    # (acceptance bar: >= 2x better P50 under the stress workload)
    out["ttft_speedup_vs_atomic"] = {}
    for regime, rows in out["sweep"].items():
        legacy = rows["legacy_atomic"]
        chunked = {k: v for k, v in rows.items()
                   if k.startswith("chunked_unified")}
        best_key = min(chunked, key=lambda k: chunked[k]["ttft_p50"])
        best = chunked[best_key]
        out["ttft_speedup_vs_atomic"][regime] = {
            "best_mode": best_key,
            "p50_speedup_x": legacy["ttft_p50"] / max(best["ttft_p50"], 1e-9),
            "p99_speedup_x": legacy["ttft_p99"] / max(best["ttft_p99"], 1e-9),
            "e2e_p99_ratio": best["e2e_p99"] / max(legacy["e2e_p99"], 1e-9),
        }

    save_json("chunked_prefill", out)
    return out


def report(out: dict) -> str:
    rows = []
    for regime, per_mode in out["sweep"].items():
        for mode, r in per_mode.items():
            rows.append([regime, mode,
                         f"{r['ttft_p50']:.2f}", f"{r['ttft_p99']:.2f}",
                         f"{r['e2e_p50']:.2f}", f"{r['e2e_p99']:.2f}",
                         int(r["n_completed"])])
    s = fmt_table(
        ["regime", "mode", "TTFT50", "TTFT99", "e2e50", "e2e99", "done"],
        rows,
        "Chunked-prefill continuous batching vs P/D vs atomic "
        f"({'SMOKE, ' if out['smoke'] else ''}"
        f"{len(out['protocol']['seeds'])}-seed avg; legacy-atomic TTFT "
        "is batch-atomic e2e by construction)")
    for regime, d in out["ttft_speedup_vs_atomic"].items():
        s += (f"\n{regime}: {d['best_mode']} vs legacy_atomic: TTFT P50 "
              f"{d['p50_speedup_x']:.1f}x, P99 {d['p99_speedup_x']:.1f}x "
              f"better; e2e P99 ratio {d['e2e_p99_ratio']:.2f}x")
    return s
