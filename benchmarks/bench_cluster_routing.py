"""Beyond-paper: cluster serving layer — routing-policy x replica-count
sweep under the heterogeneous stress workload, plus rate-limited
admission shedding and an elastic-autoscaling trace.

Protocol: `cluster_stress_config` traffic (arrival rate scaled to the
replica count, heavy-tailed category mix), batch-walk ("max-driven")
cost regime — the execution-model end where batch composition matters
(see cost_model.L4_MAX_DRIVEN; under the sum-dominated regime routing
is a near-wash and we report that too). Two seeds averaged; every run
is bit-deterministic given its seed.
"""

from __future__ import annotations

from repro.cluster import (AdmissionConfig, Autoscaler, AutoscalerConfig,
                           ClusterConfig, ClusterSimulator, GlobalAdmission)
from repro.core.request import TenantTier
from repro.serving.cost_model import L4_MAX_DRIVEN, L4_QWEN_1_8B
from repro.workload.generator import WorkloadGenerator, cluster_stress_config

from .common import fmt_table, mean, save_json

ROUTINGS = ("round_robin", "least_loaded", "drift_aware", "tenant_affinity")
REPLICA_COUNTS = (2, 4, 8)
SEEDS = (1, 2)


def _run_cluster(routing: str, n: int, seed: int, *,
                 cost_model=L4_MAX_DRIVEN, admission=None, autoscaler=None,
                 n_replicas=None):
    gen = WorkloadGenerator(cluster_stress_config(n, seed=seed))
    sim = ClusterSimulator(
        plan=gen.plan(seed=seed),
        config=ClusterConfig(n_replicas=n_replicas or n, routing=routing,
                             seed=seed),
        cost_model=cost_model,
        admission=admission,
        autoscaler=autoscaler)
    return sim, sim.run()


def _tight_admission() -> GlobalAdmission:
    """Buckets sized to bite during the stress burst (per-tier sheds)."""
    return GlobalAdmission(AdmissionConfig(
        bucket_capacity={TenantTier.PREMIUM: 60_000.0,
                         TenantTier.STANDARD: 40_000.0,
                         TenantTier.BATCH: 20_000.0},
        refill_rate={TenantTier.PREMIUM: 2_500.0,
                     TenantTier.STANDARD: 1_500.0,
                     TenantTier.BATCH: 800.0},
        max_cluster_token_mass=400_000.0))


def run() -> dict:
    out = {"sweep": {}}
    # 1) routing x replica-count sweep (unbounded admission: pure latency)
    for n in REPLICA_COUNTS:
        out["sweep"][n] = {}
        for routing in ROUTINGS:
            p50s, p99s, fairs, utils = [], [], [], []
            for seed in SEEDS:
                _, m = _run_cluster(routing, n, seed)
                p50s.append(m.run.e2e.p50)
                p99s.append(m.run.e2e.p99)
                fairs.append(m.run.fairness)
                utils.append(mean([r.utilization for r in m.replicas]))
            out["sweep"][n][routing] = {
                "p50": mean(p50s), "p99": mean(p99s),
                "fairness": mean(fairs), "shed_rate": 0.0,
                "replica_util": mean(utils),
            }
    rr4 = out["sweep"][4]["round_robin"]
    da4 = out["sweep"][4]["drift_aware"]
    out["drift_vs_rr_at_4"] = {
        "p50_reduction_pct": 100 * (1 - da4["p50"] / rr4["p50"]),
        "p99_reduction_pct": 100 * (1 - da4["p99"] / rr4["p99"]),
    }

    # 2) rate-limited admission: shed accounting per tier (4 replicas)
    out["admission"] = {}
    for routing in ("round_robin", "drift_aware"):
        sheds, p99s = [], []
        per_tier = None
        for seed in SEEDS:
            _, m = _run_cluster(routing, 4, seed,
                                admission=_tight_admission())
            sheds.append(m.shed_rate)
            p99s.append(m.run.e2e.p99)
            per_tier = m.shed["shed_rate_per_tier"]
        out["admission"][routing] = {
            "shed_rate": mean(sheds), "p99": mean(p99s),
            "shed_rate_per_tier_last_seed": per_tier,
        }

    # 3) sum-dominated regime honesty check (routing is a near-wash there)
    out["sum_regime_4"] = {}
    for routing in ("round_robin", "drift_aware"):
        p50s, p99s = [], []
        for seed in SEEDS:
            _, m = _run_cluster(routing, 4, seed, cost_model=L4_QWEN_1_8B)
            p50s.append(m.run.e2e.p50)
            p99s.append(m.run.e2e.p99)
        out["sum_regime_4"][routing] = {"p50": mean(p50s), "p99": mean(p99s)}

    # 4) elastic autoscaling: start at 2, let the burst grow the pool
    sim, m = _run_cluster(
        "drift_aware", 4, 1, n_replicas=2,
        autoscaler=Autoscaler(AutoscalerConfig(
            min_replicas=2, max_replicas=8,
            up_queue_mass_per_replica=15_000.0,
            down_queue_mass_per_replica=2_000.0,
            cooldown=10.0, startup_delay=5.0)))
    out["autoscale"] = {
        "n_start": 2, "n_end": m.n_replicas_end,
        "events": [(round(e["time"], 1), e["action"]) for e in m.scale_events],
        "p99": m.run.e2e.p99,
        "n_completed": m.run.n_completed,
    }

    # 5) replica failure mid-stress: reroute, no work lost
    sim, m = _run_cluster("drift_aware", 4, 1)
    base_completed = m.run.n_completed
    gen = WorkloadGenerator(cluster_stress_config(4, seed=1))
    sim_f = ClusterSimulator(
        plan=gen.plan(seed=1),
        config=ClusterConfig(n_replicas=4, routing="drift_aware", seed=1,
                             fail_events=((20.0, 0),), repair_time=25.0),
        cost_model=L4_MAX_DRIVEN)
    m_f = sim_f.run()
    out["failure"] = {
        "n_completed_clean": base_completed,
        "n_completed_with_failure": m_f.run.n_completed,
        "n_rerouted": m_f.n_rerouted,
        "n_failed_dispatches": m_f.run.n_failed_dispatches,
        "p99_clean": m.run.e2e.p99, "p99_with_failure": m_f.run.e2e.p99,
    }

    save_json("cluster_routing", out)
    return out


def report(out: dict) -> str:
    rows = []
    for n, per_routing in out["sweep"].items():
        for routing, r in per_routing.items():
            rows.append([n, routing, f"{r['p50']:.1f}", f"{r['p99']:.1f}",
                         f"{r['fairness']:.3f}", f"{r['replica_util']:.2f}"])
    s = fmt_table(
        ["replicas", "routing", "P50(s)", "P99(s)", "jain", "util"],
        rows, "Cluster routing sweep (max-driven regime, 2-seed avg)")
    d = out["drift_vs_rr_at_4"]
    s += (f"\ndrift_aware vs round_robin @4 replicas: "
          f"P50 -{d['p50_reduction_pct']:.0f}%, "
          f"P99 -{d['p99_reduction_pct']:.0f}%")
    a = out["admission"]
    s += ("\nrate-limited admission @4: "
          f"shed {100 * a['round_robin']['shed_rate']:.1f}% (rr) vs "
          f"{100 * a['drift_aware']['shed_rate']:.1f}% (drift), "
          f"P99 {a['round_robin']['p99']:.1f}s vs "
          f"{a['drift_aware']['p99']:.1f}s")
    sr = out["sum_regime_4"]
    s += ("\nsum-dominated regime @4 (honesty check): P99 "
          f"{sr['round_robin']['p99']:.1f}s (rr) vs "
          f"{sr['drift_aware']['p99']:.1f}s (drift) — near-wash, "
          "as documented")
    au = out["autoscale"]
    s += (f"\nautoscale 2->{au['n_end']} replicas, events {au['events']}, "
          f"{au['n_completed']} completed")
    f = out["failure"]
    s += (f"\nreplica failure: {f['n_completed_with_failure']}/"
          f"{f['n_completed_clean']} completed, {f['n_rerouted']} rerouted, "
          f"P99 {f['p99_clean']:.1f}s -> {f['p99_with_failure']:.1f}s")
    return s
