"""Beyond-paper: fault-tolerance and straggler-mitigation benchmarks
(DESIGN.md §7) — worker failure mid-experiment, hedged dispatch, and
elastic multi-worker scaling. Not a paper table; required for the
1000+-node operating envelope."""

from __future__ import annotations

from repro.serving.simulator import SimConfig

from .common import SEEDS, fmt_table, mean, run_experiment, save_json


def run() -> dict:
    out = {}
    # 1) failure injection: one worker dies during each burst
    base, fail = [], []
    retries, lost = [], []
    for seed in SEEDS:
        _, _, m0 = run_experiment("sjf", bias=True, seed=seed)
        base.append(m0.e2e.p99)
        sched, _, m1 = run_experiment(
            "sjf", bias=True, seed=seed,
            sim_config=SimConfig(seed=seed, fail_times=(30.0, 400.0),
                                 repair_time=45.0))
        fail.append(m1.e2e.p99)
        retries.append(m1.n_failed_dispatches)
        lost.append(3000 - m1.n_completed)
    out["failure"] = {
        "p99_clean": mean(base), "p99_with_failures": mean(fail),
        "p99_penalty_pct": 100 * (mean(fail) / mean(base) - 1),
        "requests_retried": mean(retries), "requests_lost": mean(lost),
    }
    # 2) straggler mitigation
    slow, mit = [], []
    for seed in SEEDS:
        _, _, a = run_experiment(
            "fifo", bias=True, seed=seed,
            sim_config=SimConfig(seed=seed, n_workers=4,
                                 straggler_worker=3, straggler_after=10.0,
                                 straggler_factor=6.0))
        _, _, b = run_experiment(
            "fifo", bias=True, seed=seed,
            sim_config=SimConfig(seed=seed, n_workers=4,
                                 straggler_worker=3, straggler_after=10.0,
                                 straggler_factor=6.0,
                                 mitigate_stragglers=True))
        slow.append(a.e2e.p99)
        mit.append(b.e2e.p99)
    out["straggler"] = {
        "p99_unmitigated": mean(slow), "p99_mitigated": mean(mit),
        "improvement_pct": 100 * (1 - mean(mit) / mean(slow)),
    }
    # 2b) hedged dispatch (speculative batch re-execution)
    hedge_p99, hedges, wins = [], [], []
    for seed in SEEDS:
        _, sim, h = run_experiment(
            "fifo", bias=True, seed=seed,
            sim_config=SimConfig(seed=seed, n_workers=4,
                                 straggler_worker=3, straggler_after=10.0,
                                 straggler_factor=6.0,
                                 hedge=True, hedge_factor=2.0))
        hedge_p99.append(h.e2e.p99)
        hedges.append(sim.n_hedges)
        wins.append(sim.n_hedge_wins)
    out["hedging"] = {
        "p99_hedged": mean(hedge_p99),
        "improvement_vs_unmitigated_pct":
            100 * (1 - mean(hedge_p99) / mean(slow)),
        "hedges_issued": mean(hedges), "hedge_wins": mean(wins),
    }
    # 3) elastic scaling: throughput vs workers
    scale = {}
    for n in (1, 2, 4, 8):
        _, _, m = run_experiment(
            "fifo", bias=True, seed=1,
            sim_config=SimConfig(seed=1, n_workers=n))
        scale[n] = {"throughput_rps": m.throughput_rps,
                    "makespan_s": m.makespan}
    out["scaling"] = scale
    save_json("fault_tolerance", out)
    return out


def report(out: dict) -> str:
    f, s = out["failure"], out["straggler"]
    rows = [
        ["failure: P99 clean -> with 2 failures",
         f"{f['p99_clean']:.0f}s -> {f['p99_with_failures']:.0f}s "
         f"(+{f['p99_penalty_pct']:.1f}%)"],
        ["failure: retried / lost",
         f"{f['requests_retried']:.0f} / {f['requests_lost']:.0f}"],
        ["straggler: P99 unmitigated -> mitigated",
         f"{s['p99_unmitigated']:.0f}s -> {s['p99_mitigated']:.0f}s "
         f"(-{s['improvement_pct']:.1f}%)"],
        ["hedging: P99 with speculative re-execution",
         f"{out['hedging']['p99_hedged']:.0f}s "
         f"(-{out['hedging']['improvement_vs_unmitigated_pct']:.1f}%, "
         f"{out['hedging']['hedges_issued']:.0f} hedges, "
         f"{out['hedging']['hedge_wins']:.0f} wins)"],
    ]
    for n, v in out["scaling"].items():
        rows.append([f"scaling: {n} worker(s)",
                     f"{v['throughput_rps']:.2f} rps, "
                     f"makespan {v['makespan_s']:.0f}s"])
    return fmt_table(["scenario", "result"], rows,
                     "Beyond-paper: fault tolerance / stragglers / scaling")
