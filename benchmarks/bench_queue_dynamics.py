"""Paper Fig 6: tenant queue-depth evolution. Validates the two
buildup phases (calibration burst, stress burst) and the per-policy
drain signatures."""

from __future__ import annotations

from .common import POLICIES, fmt_table, run_experiment, save_json


def _phases(depths, boundary):
    """Peak depth in each phase from (t, prem, std, batch) samples."""
    pre = [(p + s + b) for t, p, s, b in depths if t < boundary]
    post = [(p + s + b) for t, p, s, b in depths if t >= boundary]
    return (max(pre) if pre else 0, max(post) if post else 0)


def run() -> dict:
    out = {}
    for policy in POLICIES:
        sched, sim, m = run_experiment(policy, bias=True, seed=1)
        hist = sched.queues.depth_history
        peak_cal, peak_stress = _phases(hist, sim.phase_boundary)
        # drain-order signature: completion time of the last request per
        # tenant shows which queue empties first
        last_done = {}
        for t in ("premium", "standard", "batch"):
            times = [r.completion_time for r in sched.completed
                     if r.tenant.label == t]
            last_done[t] = max(times)
        out[policy] = {
            "peak_depth_calibration": peak_cal,
            "peak_depth_stress": peak_stress,
            "two_phases": bool(peak_cal > 50 and peak_stress > peak_cal),
            "phase_boundary_s": sim.phase_boundary,
            "makespan_s": m.makespan,
            "last_completion_by_tenant": last_done,
            "n_depth_samples": len(hist),
        }
    save_json("queue_dynamics", out)
    return out


def report(out: dict) -> str:
    rows = []
    for p in POLICIES:
        r = out[p]
        ld = r["last_completion_by_tenant"]
        order = sorted(ld, key=ld.get)
        rows.append([p, r["peak_depth_calibration"],
                     r["peak_depth_stress"],
                     "yes" if r["two_phases"] else "NO",
                     "<".join(order)])
    tbl = fmt_table(
        ["scheduler", "peak(cal)", "peak(stress)", "two-phases",
         "drain order"], rows,
        "Fig 6: queue dynamics (two buildup phases + drain signatures)")
    tbl += ("\npaper: both phases visible; Priority/Aging drain premium "
            "first and batch last; FIFO uniform; SJF by size")
    return tbl
