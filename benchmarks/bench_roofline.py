"""Roofline summary: reads the dry-run + probe artifacts under
results/ and prints the full per-(arch x shape) roofline table
(deliverable g). The numbers are produced by
``repro.launch.dryrun`` / ``repro.launch.roofline``; this bench
aggregates and sanity-checks them."""

from __future__ import annotations

import glob
import json
import os

from .common import fmt_table, save_json

ROOT = os.path.join(os.path.dirname(__file__), "..", "results")


def _load(pattern):
    out = {}
    for path in sorted(glob.glob(os.path.join(ROOT, pattern))):
        with open(path) as f:
            rec = json.load(f)
        out[(rec["arch"], rec["shape"], rec.get("mesh", "16x16"),
             rec.get("variant", ""))] = rec
    return out


def run() -> dict:
    dry = _load("dryrun/*.json")
    roof = _load("roofline/*.json")
    cells = []
    for (arch, shape, mesh, variant), rec in sorted(roof.items()):
        if variant or rec.get("status") != "ok":
            continue
        r = rec["roofline"]
        dr = dry.get((arch, shape, "16x16", ""), {})
        cells.append({
            "arch": arch, "shape": shape,
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"],
            "bottleneck": r["bottleneck"],
            "step_lb_s": r["step_time_lower_bound_s"],
            "roofline_fraction": r["roofline_fraction"],
            "useful_flops_ratio": r["useful_flops_ratio"],
            "compile_ok_single": dr.get("status") == "ok",
            "compile_ok_multi": dry.get(
                (arch, shape, "2x16x16", ""), {}).get("status") == "ok",
        })
    summary = {
        "n_cells": len(cells),
        "n_compile_ok_both_meshes": sum(
            1 for c in cells if c["compile_ok_single"]
            and c["compile_ok_multi"]),
        "bottleneck_histogram": {},
        "cells": cells,
    }
    for c in cells:
        b = c["bottleneck"]
        summary["bottleneck_histogram"][b] = \
            summary["bottleneck_histogram"].get(b, 0) + 1
    save_json("roofline_summary", summary)
    return summary


def report(out: dict) -> str:
    rows = []
    for c in out["cells"]:
        rows.append([c["arch"], c["shape"],
                     f"{c['compute_s']:.4f}", f"{c['memory_s']:.4f}",
                     f"{c['collective_s']:.4f}", c["bottleneck"],
                     f"{c['roofline_fraction']:.2f}",
                     f"{c['useful_flops_ratio']:.2f}"])
    tbl = fmt_table(
        ["arch", "shape", "compute(s)", "memory(s)", "coll(s)",
         "bound", "frac", "useful"],
        rows, "Roofline terms per cell (16x16 mesh, per-device)")
    tbl += (f"\ncells: {out['n_cells']}, compile-ok on both meshes: "
            f"{out['n_compile_ok_both_meshes']}, bottlenecks: "
            f"{out['bottleneck_histogram']}")
    return tbl
