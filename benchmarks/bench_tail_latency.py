"""Paper Table III (P95/P99 +- sigma) and Table IV (wait + percentiles),
3-run averages across all five schedulers."""

from __future__ import annotations

from .common import POLICIES, SEEDS, fmt_table, mean, run_experiment, \
    save_json, std

PAPER_T3 = {  # scheduler -> (P95, s95, P99, s99)
    "fifo": (592.957, 6.686, 630.205, 1.502),
    "priority": (599.760, 1.738, 633.684, 1.792),
    "weighted": (595.601, 2.465, 631.305, 2.715),
    "sjf": (491.480, 3.995, 526.363, 5.028),
    "aging": (611.968, 2.472, 644.645, 4.905),
}
PAPER_T4 = {  # scheduler -> (wait, P50, P95, P99)
    "fifo": (238.8, 184.7, 593.0, 630.2),
    "priority": (239.2, 197.8, 599.8, 633.7),
    "weighted": (241.0, 192.8, 595.6, 631.3),
    "aging": (245.0, 196.3, 612.0, 644.6),
    "sjf": (149.5, 106.9, 491.5, 526.4),
}


def run() -> dict:
    out = {}
    for policy in POLICIES:
        p50s, p95s, p99s, waits = [], [], [], []
        for seed in SEEDS:
            _, _, m = run_experiment(policy, bias=True, seed=seed)
            p50s.append(m.e2e.p50)
            p95s.append(m.e2e.p95)
            p99s.append(m.e2e.p99)
            waits.append(m.queue_wait.mean)
        out[policy] = {
            "wait_mean": mean(waits),
            "p50": mean(p50s), "p95": mean(p95s), "p99": mean(p99s),
            "p95_std": std(p95s), "p99_std": std(p99s),
        }
    # alternative max-driven regime (see cost_model.L4_MAX_DRIVEN): the
    # execution-model end that reproduces the paper's SJF P99 reduction
    from repro.serving.cost_model import L4_MAX_DRIVEN
    alt = {}
    for policy in ("fifo", "sjf"):
        p99s, p50s = [], []
        for seed in SEEDS:
            _, _, m = run_experiment(policy, bias=True, seed=seed,
                                     cost_model=L4_MAX_DRIVEN)
            p99s.append(m.e2e.p99)
            p50s.append(m.e2e.p50)
        alt[policy] = {"p50": mean(p50s), "p99": mean(p99s)}
    out["max_driven_regime"] = {
        **alt,
        "sjf_p99_reduction_pct":
            100 * (1 - alt["sjf"]["p99"] / alt["fifo"]["p99"]),
    }

    fifo, sjf = out["fifo"], out["sjf"]
    out["sjf_vs_fifo"] = {
        "p50_reduction_pct": 100 * (1 - sjf["p50"] / fifo["p50"]),
        "p95_reduction_pct": 100 * (1 - sjf["p95"] / fifo["p95"]),
        "p99_reduction_pct": 100 * (1 - sjf["p99"] / fifo["p99"]),
        "wait_reduction_pct": 100 * (1 - sjf["wait_mean"] / fifo["wait_mean"]),
        "paper": {"p50": 42.0, "p95": 17.0, "p99": 16.0},
    }
    save_json("tail_latency", out)
    return out


def report(out: dict) -> str:
    rows = []
    for p in POLICIES:
        r = out[p]
        pp = PAPER_T4[p]
        rows.append([p, f"{r['wait_mean']:.1f}", f"{r['p50']:.1f}",
                     f"{r['p95']:.1f}+-{r['p95_std']:.1f}",
                     f"{r['p99']:.1f}+-{r['p99_std']:.1f}",
                     f"{pp[0]:.0f}/{pp[1]:.0f}/{pp[2]:.0f}/{pp[3]:.0f}"])
    s = out["sjf_vs_fifo"]
    tbl = fmt_table(
        ["scheduler", "wait(s)", "P50", "P95", "P99",
         "paper(w/50/95/99)"], rows,
        "Tables III-IV: tail latency across schedulers (3-run avg)")
    tbl += ("\nSJF vs FIFO: P50 -{p50_reduction_pct:.0f}% (paper -42%), "
            "P95 -{p95_reduction_pct:.0f}% (paper -17%), "
            "P99 -{p99_reduction_pct:.0f}% (paper -16%), "
            "wait -{wait_reduction_pct:.0f}%"
            .format(**s))
    md = out["max_driven_regime"]
    tbl += ("\nmax-driven regime: FIFO P99 {f:.0f}s, SJF P99 {j:.0f}s -> "
            "SJF P99 -{r:.0f}% (paper -16%; P50 overshoots, see "
            "EXPERIMENTS.md residual note)").format(
                f=md["fifo"]["p99"], j=md["sjf"]["p99"],
                r=md["sjf_p99_reduction_pct"])
    return tbl
