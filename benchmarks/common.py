"""Shared experiment driver for all paper-table benchmarks.

One *experiment run* = the paper's protocol (Sec. III-B): 3000 requests
(1000 calibration + 2000 stress bursts), batch capacity 32, batch wait
0.01 s, one L4-calibrated worker, a given scheduling policy and BIAS
setting. Three seeds reproduce the paper's 3-run averaging.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Tuple

from repro.core.drift import ErrorStats, error_reduction
from repro.core.estimator import DriftConfig
from repro.core.scheduler import DriftScheduler
from repro.serving.cost_model import L4_QWEN_1_8B
from repro.serving.metrics import RunMetrics
from repro.serving.simulator import SimConfig, WorkerSimulator
from repro.workload.generator import GeneratorConfig, WorkloadGenerator

POLICIES = ("fifo", "priority", "weighted", "sjf", "aging")
SEEDS = (1, 2, 3)                      # paper: three independent runs
RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "benchmarks")

_cache: Dict[tuple, tuple] = {}


def run_experiment(policy: str, *, bias: bool = True, seed: int = 1,
                   sim_config: Optional[SimConfig] = None,
                   total_requests: int = 3000,
                   cost_model=None,
                   ) -> Tuple[DriftScheduler, WorkerSimulator, RunMetrics]:
    """One full paper-protocol run (memoised per process)."""
    key = (policy, bias, seed, total_requests,
           id(sim_config) if sim_config is not None else None,
           getattr(cost_model, "name", None))
    if key in _cache:
        return _cache[key]
    gen = WorkloadGenerator(GeneratorConfig(
        total_requests=total_requests,
        calibration_requests=total_requests // 3,
        seed=seed))
    plan = gen.plan(seed=seed)
    sched = DriftScheduler(policy=policy,
                           config=DriftConfig(bias_enabled=bias))
    sim = WorkerSimulator(sched, plan, sim_config or SimConfig(seed=seed),
                           cost_model=cost_model or L4_QWEN_1_8B)
    metrics = sim.run()
    _cache[key] = (sched, sim, metrics)
    return _cache[key]


def mean(xs: List[float]) -> float:
    return sum(xs) / len(xs) if xs else float("nan")


def std(xs: List[float]) -> float:
    if len(xs) < 2:
        return 0.0
    m = mean(xs)
    return (sum((x - m) ** 2 for x in xs) / (len(xs) - 1)) ** 0.5


def sanitize_json(obj):
    """Replace non-finite floats (NaN/inf) with None so the output is
    *strict* JSON — Python's json module would otherwise emit bare
    ``NaN`` literals (e.g. empty LatencyStats percentiles), which jq,
    JavaScript, and most non-Python consumers reject wholesale.

    Dataclasses and numpy/JAX scalars are unpacked *before* the float
    check: previously they fell through to ``json.dump(default=str)``,
    which silently stringified their NaNs into ``"nan"`` — a value that
    parses as a string and poisons any numeric consumer downstream."""
    import dataclasses
    import math
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else None
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        as_dict = getattr(obj, "as_dict", None)
        return sanitize_json(as_dict() if callable(as_dict)
                             else dataclasses.asdict(obj))
    if type(obj).__module__.split(".")[0] in ("numpy", "jax", "jaxlib"):
        if hasattr(obj, "tolist"):      # ndarray/scalar -> python types
            return sanitize_json(obj.tolist())
    if isinstance(obj, dict):
        return {k: sanitize_json(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [sanitize_json(v) for v in obj]
    return obj


def save_json(name: str, payload: dict) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(sanitize_json(payload), f, indent=1)
    return path


def fmt_table(headers: List[str], rows: List[List], title: str = "") -> str:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows))
              for i, h in enumerate(headers)]
    out = []
    if title:
        out.append(title)
    out.append("  ".join(str(h).ljust(w) for h, w in zip(headers, widths)))
    out.append("  ".join("-" * w for w in widths))
    for r in rows:
        out.append("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))
    return "\n".join(out)
