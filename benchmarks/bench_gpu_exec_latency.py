"""Paper Fig 9 + Sec IV-I: GPU execution latency across schedulers
(policy-~invariant) and GPU utilization / memory plateau.

Also reports wall-clock micro-latency for the two fused engine
kernels (chunked-prefill attention and batched paged-decode) against
their unfused dispatch patterns — the per-iteration launch savings
that the engine's per-chunk prefill and single-call decode step buy.
Off-TPU this times the XLA reference path, so treat the rows as a
dispatch-count trend, not device kernel time.
"""

from __future__ import annotations

import time

from .common import POLICIES, SEEDS, fmt_table, mean, run_experiment, \
    save_json


def _time_ms(fn, *args, reps: int = 5) -> float:
    """Steady-state latency of a jitted callable, min over reps."""
    import jax
    fn = jax.jit(fn)
    jax.block_until_ready(fn(*args))        # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return 1e3 * best


def _kernel_micro_latency() -> dict:
    """Fused vs unfused dispatch for the two engine kernels.

    * decode: one ``batched_paged_decode_attention`` call covering the
      whole decode set vs B single-sequence ``paged_decode_attention``
      dispatches (the pre-batching engine inner loop).
    * prefill: per-chunk ``chunked_prefill_attention`` slabs (the
      engine's interleavable unit) vs one whole-prompt flash call —
      the price of chunking, paid back in slot-level interleaving.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    B, H, Hk, D = 8, 8, 4, 64
    page, pps = 16, 8                        # 128-token pool rows
    L, chunk = 128, 32
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)
    n_pages = B * pps
    k_pages = jax.random.normal(ks[0], (n_pages, page, Hk, D))
    v_pages = jax.random.normal(ks[1], (n_pages, page, Hk, D))
    table = jnp.arange(n_pages, dtype=jnp.int32).reshape(B, pps)
    seq_lens = jnp.full((B,), 100, dtype=jnp.int32)
    qd = jax.random.normal(ks[2], (B, H, D))
    k_new = jax.random.normal(ks[3], (B, Hk, D))
    v_new = jax.random.normal(ks[4], (B, Hk, D))

    def decode_batched(q, kp, vp, tab, lens, kn, vn):
        return ops.batched_paged_decode_attention(
            q, kp, vp, tab, lens, kn, vn, impl="reference")

    def decode_per_seq(q, kp, vp, tab, lens):
        return jnp.concatenate([
            ops.paged_decode_attention(
                q[i:i + 1], kp, vp, tab[i:i + 1], lens[i:i + 1],
                impl="reference")
            for i in range(B)])

    qp = jax.random.normal(ks[5], (B, L, H, D))
    kf = jax.random.normal(ks[6], (B, L, Hk, D))
    vf = jax.random.normal(ks[7], (B, L, Hk, D))
    kv_lens = jnp.full((B,), L, dtype=jnp.int32)

    def prefill_single_shot(q, k, v):
        return ops.attention(q, k, v, causal=True, impl="reference")

    def prefill_chunked(q, kp, vp, tab):
        outs = []
        for off in range(0, L, chunk):
            lens = jnp.full((B,), off + chunk, dtype=jnp.int32)
            offs = jnp.full((B,), off, dtype=jnp.int32)
            outs.append(ops.chunked_prefill_attention(
                q[:, off:off + chunk], kp, vp, tab, offs, lens,
                impl="reference"))
        return jnp.concatenate(outs, axis=1)

    out = {
        "shapes": {"B": B, "H": H, "Hk": Hk, "D": D, "page_size": page,
                   "pages_per_seq": pps, "prompt_len": L, "chunk": chunk},
        "decode_batched_ms": _time_ms(
            decode_batched, qd, k_pages, v_pages, table, seq_lens,
            k_new, v_new),
        "decode_per_seq_loop_ms": _time_ms(
            decode_per_seq, qd, k_pages, v_pages, table, seq_lens),
        "prefill_single_shot_ms": _time_ms(
            prefill_single_shot, qp, kf, vf),
        "prefill_chunked_ms": _time_ms(
            prefill_chunked, qp, k_pages, v_pages, table),
    }
    out["decode_batched_speedup"] = (
        out["decode_per_seq_loop_ms"] / max(out["decode_batched_ms"], 1e-9))
    out["prefill_chunk_overhead_x"] = (
        out["prefill_chunked_ms"] / max(out["prefill_single_shot_ms"], 1e-9))
    return out


def run() -> dict:
    out = {}
    for policy in POLICIES:
        p50s, p95s, p99s, utils, mems = [], [], [], [], []
        for seed in SEEDS:
            sched, sim, m = run_experiment(policy, bias=True, seed=seed)
            p50s.append(m.gpu_exec.p50)
            p95s.append(m.gpu_exec.p95)
            p99s.append(m.gpu_exec.p99)
            utils.append(m.gpu_utilization)
            busy = [t.gpu_mem_gb for t in sim.telemetry if t.gpu_util > 0.5]
            mems.append(mean(busy))
        out[policy] = {"p50": mean(p50s), "p95": mean(p95s),
                       "p99": mean(p99s), "gpu_util": mean(utils),
                       "gpu_mem_gb": mean(mems)}
    p50s = [out[p]["p50"] for p in POLICIES if p != "sjf"]
    out["invariance"] = {
        "non_sjf_p50_spread_pct":
            100 * (max(p50s) - min(p50s)) / mean(p50s),
        "paper": "FIFO/Priority/Weighted/Aging ~10.5s P50, ~11.3s P99; "
                 "SJF slightly lower",
    }
    out["kernels"] = _kernel_micro_latency()
    save_json("gpu_exec_latency", out)
    return out


def report(out: dict) -> str:
    rows = []
    for p in POLICIES:
        r = out[p]
        rows.append([p, f"{r['p50']:.2f}", f"{r['p95']:.2f}",
                     f"{r['p99']:.2f}", f"{100*r['gpu_util']:.0f}%",
                     f"{r['gpu_mem_gb']:.1f}"])
    tbl = fmt_table(
        ["scheduler", "P50(s)", "P95(s)", "P99(s)", "util", "mem(GB)"],
        rows, "Fig 9 / Sec IV-I: GPU execution latency + utilization")
    tbl += (f"\nnon-SJF P50 spread: "
            f"{out['invariance']['non_sjf_p50_spread_pct']:.1f}% "
            "(paper: execution cost ~policy-invariant; queue effects "
            "dominate e2e)")
    k = out["kernels"]
    krows = [
        ["paged decode (B=8)", f"{k['decode_per_seq_loop_ms']:.2f}",
         f"{k['decode_batched_ms']:.2f}",
         f"loop/batched {k['decode_batched_speedup']:.2f}x "
         "(1 dispatch vs B on device)"],
        ["prefill (128 tok)", f"{k['prefill_single_shot_ms']:.2f}",
         f"{k['prefill_chunked_ms']:.2f}",
         f"chunked/single {k['prefill_chunk_overhead_x']:.2f}x "
         "(chunk unit buys interleaving)"],
    ]
    tbl += "\n" + fmt_table(
        ["kernel", "unfused(ms)", "fused/chunked(ms)", "ratio"],
        krows, "Engine kernel micro-latency (per-iteration dispatch; "
               "reference path off-TPU — a dispatch-count trend, not "
               "device kernel time)")
    return tbl
