"""Paper Fig 9 + Sec IV-I: GPU execution latency across schedulers
(policy-~invariant) and GPU utilization / memory plateau."""

from __future__ import annotations

from .common import POLICIES, SEEDS, fmt_table, mean, run_experiment, \
    save_json


def run() -> dict:
    out = {}
    for policy in POLICIES:
        p50s, p95s, p99s, utils, mems = [], [], [], [], []
        for seed in SEEDS:
            sched, sim, m = run_experiment(policy, bias=True, seed=seed)
            p50s.append(m.gpu_exec.p50)
            p95s.append(m.gpu_exec.p95)
            p99s.append(m.gpu_exec.p99)
            utils.append(m.gpu_utilization)
            busy = [t.gpu_mem_gb for t in sim.telemetry if t.gpu_util > 0.5]
            mems.append(mean(busy))
        out[policy] = {"p50": mean(p50s), "p95": mean(p95s),
                       "p99": mean(p99s), "gpu_util": mean(utils),
                       "gpu_mem_gb": mean(mems)}
    p50s = [out[p]["p50"] for p in POLICIES if p != "sjf"]
    out["invariance"] = {
        "non_sjf_p50_spread_pct":
            100 * (max(p50s) - min(p50s)) / mean(p50s),
        "paper": "FIFO/Priority/Weighted/Aging ~10.5s P50, ~11.3s P99; "
                 "SJF slightly lower",
    }
    save_json("gpu_exec_latency", out)
    return out


def report(out: dict) -> str:
    rows = []
    for p in POLICIES:
        r = out[p]
        rows.append([p, f"{r['p50']:.2f}", f"{r['p95']:.2f}",
                     f"{r['p99']:.2f}", f"{100*r['gpu_util']:.0f}%",
                     f"{r['gpu_mem_gb']:.1f}"])
    tbl = fmt_table(
        ["scheduler", "P50(s)", "P95(s)", "P99(s)", "util", "mem(GB)"],
        rows, "Fig 9 / Sec IV-I: GPU execution latency + utilization")
    tbl += (f"\nnon-SJF P50 spread: "
            f"{out['invariance']['non_sjf_p50_spread_pct']:.1f}% "
            "(paper: execution cost ~policy-invariant; queue effects "
            "dominate e2e)")
    return tbl
