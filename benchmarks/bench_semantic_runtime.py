"""Paper Fig 4 / Table I: semantic workload category -> runtime
scheduling class mapping. Validates that report splits medium/long and
that the mapping is policy-independent."""

from __future__ import annotations

from collections import Counter

from .common import POLICIES, fmt_table, run_experiment, save_json


def run() -> dict:
    out = {}
    for policy in POLICIES:
        sched, _, _ = run_experiment(policy, bias=True, seed=1)
        dist = Counter()
        for rec in sched.admission.log:
            dist[(rec.category, rec.job_class)] += 1
        out[policy] = {
            cat: {jc: dist.get((cat, jc), 0)
                  for jc in ("short", "medium", "long")}
            for cat in ("short_qa", "summary", "technical", "report")
        }
    save_json("semantic_runtime", out)
    return out


def report(out: dict) -> str:
    rows = []
    for cat in ("short_qa", "summary", "technical", "report"):
        for policy in ("fifo", "sjf"):
            d = out[policy][cat]
            rows.append([cat, policy, d["short"], d["medium"], d["long"]])
    tbl = fmt_table(["semantic", "policy", "short", "medium", "long"],
                    rows, "Fig 4: semantic -> runtime class (counts)")
    tbl += ("\npaper: short_qa->short; summary->medium; technical->"
            "medium/long; report->medium/long; ~policy-independent")
    return tbl
