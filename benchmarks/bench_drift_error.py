"""Paper Table VII: MAE/RMSE estimation-error reduction from adaptive
runtime token-drift compensation (BIAS=OFF vs BIAS=ON), per scheduler,
3-run averages."""

from __future__ import annotations

from repro.core.drift import error_reduction

from .common import POLICIES, SEEDS, fmt_table, mean, run_experiment, \
    save_json

PAPER = {  # scheduler -> (MAE reduction %, RMSE reduction %)
    "fifo": (39.51, 41.40),
    "priority": (39.62, 41.36),
    "weighted": (38.33, 41.10),
    "sjf": (36.82, 37.18),
    "aging": (39.74, 41.40),
}


def run() -> dict:
    out = {}
    for policy in POLICIES:
        maes, rmses = [], []
        for seed in SEEDS:
            s_off, _, _ = run_experiment(policy, bias=False, seed=seed)
            s_on, _, _ = run_experiment(policy, bias=True, seed=seed)
            red = error_reduction(s_off.drift.stats(), s_on.drift.stats())
            maes.append(red["mae_reduction_pct"])
            rmses.append(red["rmse_reduction_pct"])
        out[policy] = {"mae_reduction_pct": mean(maes),
                       "rmse_reduction_pct": mean(rmses)}
    out["average"] = {
        "mae_reduction_pct": mean([out[p]["mae_reduction_pct"]
                                   for p in POLICIES]),
        "rmse_reduction_pct": mean([out[p]["rmse_reduction_pct"]
                                    for p in POLICIES]),
        "paper": {"mae": 38.80, "rmse": 40.49},
    }
    save_json("drift_error", out)
    return out


def report(out: dict) -> str:
    rows = []
    for p in POLICIES:
        r = out[p]
        rows.append([p, f"{r['mae_reduction_pct']:.1f}%",
                     f"{r['rmse_reduction_pct']:.1f}%",
                     f"{PAPER[p][0]:.1f}% / {PAPER[p][1]:.1f}%"])
    a = out["average"]
    rows.append(["AVERAGE", f"{a['mae_reduction_pct']:.1f}%",
                 f"{a['rmse_reduction_pct']:.1f}%", "38.8% / 40.5%"])
    return fmt_table(["scheduler", "MAE red.", "RMSE red.", "paper"],
                     rows,
                     "Table VII: estimation-error reduction (3-run avg)")
