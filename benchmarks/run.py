"""Benchmark harness entry point: one module per paper table/figure
plus the beyond-paper fault-tolerance, cluster-routing, and
P/D-disaggregation suites and the roofline summary.

    PYTHONPATH=src python -m benchmarks.run [--list] [--only NAME]
                                            [--json PATH] [--trace PATH]

``--list`` prints the available benchmark keys together with each
module's config constants and exits. ``--only`` substring-filters the
keys and errors out (listing them) when nothing matches. ``--json
PATH`` additionally writes every executed benchmark's raw result dict
(plus wall time, failure status, the benchmark's config constants, and
the repo git SHA) to one machine-readable JSON file (``-`` for stdout),
so per-PR perf trajectories stay attributable across PRs.

``--trace PATH`` installs the process-global trace recorder
(``repro.obs``) before any benchmark builds a simulator or engine, so
every arm executed by this invocation emits lifecycle events; on exit
the recording is exported as a Chrome-trace-event (Perfetto-loadable)
file at PATH. Summarize it with ``python -m repro.obs.report PATH``.
Tracing is counter-sampled and RNG-free: traced results are
bit-identical to untraced ones (locked by ``tests/test_obs.py``).
With ``--json`` the trace path, sampling strides, event counts, and
the streaming telemetry/SLO snapshots land in ``_meta.trace``.
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time

from . import (bench_bias_convergence, bench_chunked_prefill,
               bench_cluster_routing, bench_drift_error,
               bench_fault_tolerance, bench_gpu_exec_latency,
               bench_pd_disagg, bench_prefix_cache, bench_queue_dynamics,
               bench_roofline, bench_semantic_runtime, bench_tail_latency,
               bench_tenant_qos, bench_vector_scale, bench_wait_by_class)

BENCHES = [
    ("bias_convergence (Fig 5)", bench_bias_convergence),
    ("semantic_runtime (Fig 4 / Table I)", bench_semantic_runtime),
    ("drift_error (Table VII)", bench_drift_error),
    ("tail_latency (Tables III-IV)", bench_tail_latency),
    ("tenant_qos (Table V)", bench_tenant_qos),
    ("wait_by_class (Table VI)", bench_wait_by_class),
    ("queue_dynamics (Fig 6)", bench_queue_dynamics),
    ("gpu_exec_latency (Fig 9)", bench_gpu_exec_latency),
    ("fault_tolerance (beyond-paper)", bench_fault_tolerance),
    ("cluster_routing (beyond-paper)", bench_cluster_routing),
    ("pd_disagg (beyond-paper)", bench_pd_disagg),
    ("chunked_prefill (beyond-paper)", bench_chunked_prefill),
    ("prefix_cache (beyond-paper)", bench_prefix_cache),
    ("vector_scale (beyond-paper)", bench_vector_scale),
    ("roofline (deliverable g)", bench_roofline),
]


def git_sha() -> str:
    """Current repo HEAD (+ a '-dirty' marker), or 'unknown' outside a
    work tree — recorded so BENCH_*.json trajectories are attributable
    to the PR that produced them."""
    try:
        sha = subprocess.check_output(
            ["git", "rev-parse", "HEAD"], stderr=subprocess.DEVNULL,
            text=True).strip()
        dirty = subprocess.run(
            ["git", "diff", "--quiet", "HEAD"],
            stderr=subprocess.DEVNULL).returncode != 0
        return sha + ("-dirty" if dirty else "")
    except Exception:
        return "unknown"


def bench_config(mod) -> dict:
    """A benchmark module's protocol constants (public module-level
    UPPERCASE values of plain-data type): the knobs that, together with
    the git SHA, make a recorded result reproducible."""
    out = {}
    for k, v in vars(mod).items():
        if not k.isupper() or k.startswith("_"):
            continue
        if isinstance(v, (int, float, str, bool, tuple, list)):
            out[k] = v
        elif isinstance(v, dict):
            out[k] = {str(kk): str(vv) for kk, vv in v.items()}
    return out


def list_benches() -> str:
    """Human-readable inventory: every benchmark key plus the config
    constants that parameterise it (what ``--only`` matches against)."""
    lines = ["available benchmarks (--only matches substrings):"]
    for name, mod in BENCHES:
        lines.append(f"  {name}")
        cfg = bench_config(mod)
        for k in sorted(cfg):
            lines.append(f"      {k} = {cfg[k]!r}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--list", action="store_true",
                    help="print available benchmark keys and their "
                         "config constants, then exit")
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark name "
                         "(see --list); unknown filters are an error")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write all executed benchmark results to PATH "
                         "as machine-readable JSON ('-' for stdout)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record lifecycle traces for every executed "
                         "benchmark and export a Chrome-trace-event "
                         "(Perfetto) file to PATH")
    args = ap.parse_args(argv)

    if args.list:
        print(list_benches())
        return 0
    selected = [(name, mod) for name, mod in BENCHES
                if not args.only or args.only in name]
    if not selected:
        print(f"error: --only {args.only!r} matches no benchmark\n",
              file=sys.stderr)
        print(list_benches(), file=sys.stderr)
        return 2

    # with --json - the JSON document owns stdout (machine-readable
    # contract); the human-readable progress/report stream moves to
    # stderr so `... --json - | jq .` just works
    log = sys.stderr if args.json == "-" else sys.stdout
    failures = 0
    results = {"_meta": {"git_sha": git_sha(),
                         "argv": list(argv) if argv is not None
                         else sys.argv[1:]}}

    recorder = series = slo = None
    if args.trace:
        # install the process-global recorder BEFORE any benchmark
        # constructs a simulator/engine (components resolve it at
        # construction time); observers stream every emission
        # pre-sampling, so their aggregates are exact
        from repro.obs import (SeriesBank, SloMonitor, TraceRecorder,
                               set_recorder)
        series = SeriesBank()
        slo = SloMonitor()
        recorder = TraceRecorder(observers=(series, slo))
        set_recorder(recorder)

    for name, mod in selected:
        t0 = time.time()
        print(f"\n=== {name} ===", flush=True, file=log)
        try:
            out = mod.run()
            print(mod.report(out), file=log)
            dt = time.time() - t0
            print(f"[done in {dt:.1f}s]", file=log)
            results[name] = {"ok": True, "wall_s": dt,
                             "git_sha": results["_meta"]["git_sha"],
                             "config": bench_config(mod), "result": out}
        except Exception as e:  # keep the harness going
            failures += 1
            import traceback
            print(f"[FAILED] {type(e).__name__}: {e}", file=log)
            traceback.print_exc()
            results[name] = {"ok": False, "wall_s": time.time() - t0,
                             "git_sha": results["_meta"]["git_sha"],
                             "config": bench_config(mod),
                             "error": f"{type(e).__name__}: {e}"}
    if recorder is not None:
        from repro.obs import set_recorder, write_chrome_trace
        set_recorder(None)             # in-process hygiene (tests)
        stats = recorder.stats()
        write_chrome_trace(args.trace, recorder.events(),
                           recorder_stats=stats)
        now = recorder.last_ts
        results["_meta"]["trace"] = {
            "path": args.trace,
            "events_emitted": stats["emitted"],
            "events_recorded": stats["recorded"],
            "dropped_overflow": stats["dropped_overflow"],
            "sample_every": stats["sample_every"],
            "by_kind": stats["by_kind"],
            "segments": stats["segments"],
            "series": series.snapshot(now),
            "slo": slo.status(now),
        }
        print(f"\n[trace -> {args.trace}: {stats['recorded']} events "
              f"recorded of {stats['emitted']} emitted; summarize with "
              f"`python -m repro.obs.report {args.trace}`]", file=log)

    if args.json:
        from .common import sanitize_json
        # allow_nan=False backstops sanitize_json: a NaN that somehow
        # survives is a loud error, never a bare-NaN literal; default=str
        # still catches exotic non-JSON types (after sanitize_json has
        # already unpacked dataclasses/numpy, so it can no longer
        # stringify a NaN into "nan")
        if args.json == "-":
            json.dump(sanitize_json(results), sys.stdout, indent=1,
                      allow_nan=False, default=str)
            print()
        else:
            with open(args.json, "w") as f:
                json.dump(sanitize_json(results), f, indent=1,
                          allow_nan=False, default=str)
            print(f"\n[json results -> {args.json}]", file=log)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
