"""Benchmark harness entry point: one module per paper table/figure
plus the beyond-paper fault-tolerance suite and the roofline summary.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]
"""

from __future__ import annotations

import argparse
import sys
import time

from . import (bench_bias_convergence, bench_drift_error,
               bench_fault_tolerance, bench_gpu_exec_latency,
               bench_queue_dynamics, bench_roofline,
               bench_semantic_runtime, bench_tail_latency,
               bench_tenant_qos, bench_wait_by_class)

BENCHES = [
    ("bias_convergence (Fig 5)", bench_bias_convergence),
    ("semantic_runtime (Fig 4 / Table I)", bench_semantic_runtime),
    ("drift_error (Table VII)", bench_drift_error),
    ("tail_latency (Tables III-IV)", bench_tail_latency),
    ("tenant_qos (Table V)", bench_tenant_qos),
    ("wait_by_class (Table VI)", bench_wait_by_class),
    ("queue_dynamics (Fig 6)", bench_queue_dynamics),
    ("gpu_exec_latency (Fig 9)", bench_gpu_exec_latency),
    ("fault_tolerance (beyond-paper)", bench_fault_tolerance),
    ("roofline (deliverable g)", bench_roofline),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark name")
    args = ap.parse_args(argv)

    failures = 0
    for name, mod in BENCHES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        print(f"\n=== {name} ===", flush=True)
        try:
            out = mod.run()
            print(mod.report(out))
            print(f"[done in {time.time() - t0:.1f}s]")
        except Exception as e:  # keep the harness going
            failures += 1
            import traceback
            print(f"[FAILED] {type(e).__name__}: {e}")
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
