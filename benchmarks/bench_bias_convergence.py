"""Paper Fig 5: adaptive bias convergence per workload category, per
scheduler. Validates the published 0.79-0.84 convergence band and the
stability of learned values through the stress phase."""

from __future__ import annotations

from .common import POLICIES, fmt_table, mean, run_experiment, save_json


def run() -> dict:
    out = {}
    for policy in POLICIES:
        sched, sim, _ = run_experiment(policy, bias=True, seed=1)
        final = sched.bias_store.snapshot()
        # stability: bias range within the stress phase (after boundary)
        hist = sched.bias_store.history
        boundary_step = None
        for snap in hist:
            if snap.time >= sim.phase_boundary:
                boundary_step = snap.step
                break
        stress = [s for s in hist if boundary_step and s.step >= boundary_step]
        drift_in_stress = {}
        for cat in final:
            vals = [s.bias for s in stress if s.category == cat]
            drift_in_stress[cat] = (max(vals) - min(vals)) if vals else 0.0
        out[policy] = {
            "final_bias": final,
            "stress_phase_range": drift_in_stress,
            "trajectory_len": len(hist),
        }
    allb = [b for p in POLICIES for b in out[p]["final_bias"].values()]
    out["band"] = {"min": min(allb), "max": max(allb),
                   "paper_band": [0.79, 0.84]}
    save_json("bias_convergence", out)
    return out


def report(out: dict) -> str:
    rows = []
    for p in POLICIES:
        f = out[p]["final_bias"]
        r = out[p]["stress_phase_range"]
        rows.append([p] + [f"{f[c]:.3f} (+-{r[c]:.3f})" for c in
                           ("short_qa", "summary", "technical", "report")])
    tbl = fmt_table(["scheduler", "short_qa", "summary", "technical",
                     "report"], rows,
                    "Fig 5: learned bias (final value, stress-phase range)")
    b = out["band"]
    tbl += (f"\nband: [{b['min']:.3f}, {b['max']:.3f}]  "
            f"paper: [0.79, 0.84]")
    return tbl
