"""Paper Table VI: average queue waiting time by runtime workload class
(short / medium / long) across schedulers."""

from __future__ import annotations

from .common import POLICIES, SEEDS, fmt_table, mean, run_experiment, \
    save_json

PAPER = {
    "fifo": (166.89, 258.21, 258.04),
    "priority": (168.64, 276.74, 81.20),
    "weighted": (168.05, 265.49, 164.95),
    "sjf": (2.87, 163.18, 396.59),
    "aging": (168.65, 282.63, 83.83),
}


def run() -> dict:
    out = {}
    for policy in POLICIES:
        acc = {c: [] for c in ("short", "medium", "long")}
        for seed in SEEDS:
            _, _, m = run_experiment(policy, bias=True, seed=seed)
            for c in acc:
                acc[c].append(m.per_class_wait[c])
        out[policy] = {c: mean(v) for c, v in acc.items()}
    save_json("wait_by_class", out)
    return out


def report(out: dict) -> str:
    rows = []
    for p in POLICIES:
        r = out[p]
        pp = PAPER[p]
        rows.append([p, f"{r['short']:.1f}", f"{r['medium']:.1f}",
                     f"{r['long']:.1f}",
                     f"{pp[0]:.0f} / {pp[1]:.0f} / {pp[2]:.0f}"])
    return fmt_table(["scheduler", "short(s)", "medium(s)", "long(s)",
                      "paper(s/m/l)"], rows,
                     "Table VI: queue wait by runtime class (3-run avg)")
