"""Paper Table V: per-tenant end-to-end latency + queue wait across
schedulers — the fairness / QoS-differentiation trade-off matrix."""

from __future__ import annotations

from .common import POLICIES, SEEDS, fmt_table, mean, run_experiment, \
    save_json

PAPER = {  # (scheduler, tenant) -> (latency, wait)
    ("fifo", "premium"): (248.23, 238.04),
    ("fifo", "standard"): (249.25, 238.93),
    ("fifo", "batch"): (252.97, 242.77),
    ("priority", "premium"): (77.32, 67.18),
    ("priority", "standard"): (252.80, 242.63),
    ("priority", "batch"): (426.72, 416.57),
    ("weighted", "premium"): (158.45, 148.25),
    ("weighted", "standard"): (255.02, 244.82),
    ("weighted", "batch"): (333.05, 322.90),
    ("sjf", "premium"): (226.60, 218.10),
    ("sjf", "standard"): (157.52, 149.38),
    ("sjf", "batch"): (94.91, 87.07),
    ("aging", "premium"): (76.39, 66.26),
    ("aging", "standard"): (256.07, 245.99),
    ("aging", "batch"): (433.00, 422.87),
}


def run() -> dict:
    out = {}
    for policy in POLICIES:
        acc = {t: {"lat": [], "wait": []} for t in
               ("premium", "standard", "batch")}
        fair = []
        for seed in SEEDS:
            _, _, m = run_experiment(policy, bias=True, seed=seed)
            for t in acc:
                acc[t]["lat"].append(m.per_tenant[t]["latency"]["mean"])
                acc[t]["wait"].append(m.per_tenant[t]["queue_wait"]["mean"])
            fair.append(m.fairness)
        out[policy] = {
            t: {"latency": mean(v["lat"]), "queue_wait": mean(v["wait"])}
            for t, v in acc.items()
        }
        out[policy]["jain_fairness"] = mean(fair)
    save_json("tenant_qos", out)
    return out


def report(out: dict) -> str:
    rows = []
    for p in POLICIES:
        for t in ("premium", "standard", "batch"):
            r = out[p][t]
            pl, pw = PAPER[(p, t)]
            rows.append([p, t, f"{r['latency']:.1f}", f"{r['queue_wait']:.1f}",
                         f"{pl:.0f} / {pw:.0f}"])
        rows.append([p, "jain-idx", f"{out[p]['jain_fairness']:.3f}", "", ""])
    return fmt_table(
        ["scheduler", "tenant", "latency(s)", "wait(s)", "paper(lat/wait)"],
        rows, "Table V: tenant-level QoS (3-run avg)")
