"""Beyond-paper: the flat-array simulator core vs the object-engine
oracle at sweep scale — wall-clock, requests/second, and tail-latency
stability across seeds.

Protocol: the *deterministic sweep regime* the vector core is designed
for (``repro.serving.vector_sim``): single L4-calibrated worker, batch
capacity 32, fifo, step engine with frozen batch membership
(``continuous_joins=False``) so pure-decode runs collapse into
batch-drain epochs, zero service-time jitter, and strided
telemetry/depth sampling. Both engines run the SAME requests: the plan
is drawn once per (size, seed) with ``VectorPlan.generate`` and the
object arm consumes ``to_arrival_plan()`` of that exact plan — so the
speedup column compares identical event trajectories, and the bench
cross-checks makespan/completion equality on every co-run size.

Why this regime for the headline: per-iteration jitter draws and
per-boundary continuous joins are sequential rng/queue semantics that
*any* bit-exact engine must replay one by one — the parity suite
(tests/test_vector_parity.py) locks those arms bit-for-bit at small N,
while this bench measures the regime where the array core's epoch
collapse has leverage. The acceptance bar is the ``speedup_at_headline``
figure: >= 20x object requests/second at 10^5 requests.

Smoke mode: ``BENCH_SMOKE=1`` drops the 10^5/10^6 sizes and runs one
seed (CI's benchmark smoke step).
"""

from __future__ import annotations

import dataclasses
import os
import time

from repro.core.scheduler import DriftScheduler
from repro.obs.stats import percentile
from repro.serving.cost_model import L4_QWEN_1_8B
from repro.serving.simulator import SimConfig, make_worker_simulator
from repro.workload.generator import GeneratorConfig, VectorPlan

from .common import fmt_table, save_json

#: request counts swept on BOTH engines (object oracle included)
SIZES = (1_000, 10_000, 100_000)
#: request counts swept on the vector core only (the object engine
#: would need ~8 minutes at 10^6; the 10^5 co-run anchors the ratio)
VECTOR_ONLY_SIZES = (1_000_000,)
#: the co-run size whose object/vector ratio is the headline figure
HEADLINE_SIZE = 100_000
SEEDS = (1, 2, 3)                 # tail-stability sweep
STABILITY_N = 10_000
BATCH_CAPACITY = 32
POLICY = "fifo"
#: telemetry/depth sampling stride in the sweep regime (documented
#: divergence knob: stride > 1 subsamples diagnostics, it never
#: changes scheduling)
SAMPLE_STRIDE = 64

_SMOKE = os.environ.get("BENCH_SMOKE", "").strip().lower() \
    not in ("", "0", "false", "no")

#: zero service-time jitter: the deterministic sweep regime (jitter()
#: returns 1.0 without consuming rng state, so this is exactly the
#: object engine's trajectory with sigma = 0, not an approximation)
_ZERO_JITTER = dataclasses.replace(L4_QWEN_1_8B, jitter_sigma=0.0)


def _protocol() -> dict:
    if _SMOKE:
        return {"sizes": (1_000, 10_000), "vector_only": (),
                "headline": 10_000, "seeds": (1,), "stability_n": 4_000}
    return {"sizes": SIZES, "vector_only": VECTOR_ONLY_SIZES,
            "headline": HEADLINE_SIZE, "seeds": SEEDS,
            "stability_n": STABILITY_N}


def _sim_config(backend: str) -> SimConfig:
    return SimConfig(step_engine=True, n_workers=1,
                     batch_capacity=BATCH_CAPACITY, seed=1,
                     continuous_joins=False,
                     telemetry_stride=SAMPLE_STRIDE,
                     depth_stride=SAMPLE_STRIDE, backend=backend)


def _plan(n: int, seed: int) -> VectorPlan:
    return VectorPlan.generate(
        GeneratorConfig(total_requests=n, calibration_requests=n // 3,
                        seed=seed), seed=seed)


def _run_vector(vp: VectorPlan):
    t0 = time.perf_counter()
    sim = make_worker_simulator(DriftScheduler(policy=POLICY), vp,
                                _sim_config("vector"), _ZERO_JITTER)
    metrics = sim.run()
    return time.perf_counter() - t0, metrics, sim

def _run_object(vp: VectorPlan):
    # the honest same-input oracle arm: fresh Request objects carrying
    # this plan's req_ids and draws
    plan = vp.to_arrival_plan()
    t0 = time.perf_counter()
    sim = make_worker_simulator(DriftScheduler(policy=POLICY), plan,
                                _sim_config("object"), _ZERO_JITTER)
    metrics = sim.run()
    return time.perf_counter() - t0, metrics, sim


def run() -> dict:
    proto = _protocol()
    out = {"smoke": _SMOKE,
           "protocol": {"sizes": list(proto["sizes"]),
                        "vector_only": list(proto["vector_only"]),
                        "headline_size": proto["headline"],
                        "seeds": list(proto["seeds"]),
                        "stability_n": proto["stability_n"],
                        "policy": POLICY,
                        "batch_capacity": BATCH_CAPACITY,
                        "sample_stride": SAMPLE_STRIDE,
                        "jitter_sigma": 0.0,
                        "continuous_joins": False},
           "scale": [], "stability": {}}

    for n in proto["sizes"]:
        vp = _plan(n, seed=7)
        tv, mv, _ = _run_vector(vp)
        to, mo, _ = _run_object(vp)
        out["scale"].append({
            "n": n,
            "vector_wall_s": tv, "vector_rps": n / tv,
            "object_wall_s": to, "object_rps": n / to,
            "speedup_x": to / tv,
            "trajectory_match": (mo.makespan == mv.makespan
                                 and mo.n_completed == mv.n_completed
                                 and mo.e2e.p99 == mv.e2e.p99),
        })
    for n in proto["vector_only"]:
        vp = _plan(n, seed=7)
        tv, mv, _ = _run_vector(vp)
        out["scale"].append({
            "n": n,
            "vector_wall_s": tv, "vector_rps": n / tv,
            "object_wall_s": None, "object_rps": None,
            "speedup_x": None, "trajectory_match": None,
        })

    # tail stability: per-seed e2e P99/P99.9 from the state columns
    # (different seeds = different workload draws; the spread shows the
    # tail statistic is workload-stable, not a single-draw artifact)
    p99s, p999s = [], []
    for seed in proto["seeds"]:
        vp = _plan(proto["stability_n"], seed=seed)
        _, _, sim = _run_vector(vp)
        e2e = (sim.state.completion - sim.state.arrival).tolist()
        p99s.append(percentile(e2e, 99.0))
        p999s.append(percentile(e2e, 99.9))
    out["stability"] = {
        "p99_per_seed": p99s, "p999_per_seed": p999s,
        "p99_spread_rel": ((max(p99s) - min(p99s))
                           / (sum(p99s) / len(p99s))),
        "p999_spread_rel": ((max(p999s) - min(p999s))
                            / (sum(p999s) / len(p999s))),
    }

    head = next(r for r in out["scale"] if r["n"] == proto["headline"])
    out["speedup_at_headline"] = {
        "n": head["n"], "speedup_x": head["speedup_x"],
        "object_rps": head["object_rps"],
        "vector_rps": head["vector_rps"],
        "meets_20x": head["speedup_x"] >= 20.0,
    }

    save_json("vector_scale", out)
    return out


def report(out: dict) -> str:
    rows = []
    for r in out["scale"]:
        rows.append([
            f"{r['n']:,}",
            f"{r['vector_rps']:,.0f}", f"{r['vector_wall_s']:.2f}",
            "-" if r["object_rps"] is None else f"{r['object_rps']:,.0f}",
            "-" if r["object_wall_s"] is None
            else f"{r['object_wall_s']:.2f}",
            "-" if r["speedup_x"] is None else f"{r['speedup_x']:.1f}x",
            {True: "yes", False: "NO", None: "-"}[r["trajectory_match"]],
        ])
    s = fmt_table(
        ["requests", "vec rps", "vec s", "obj rps", "obj s",
         "speedup", "traj match"],
        rows,
        "Vector core vs object oracle, deterministic sweep regime "
        f"({'SMOKE' if out['smoke'] else 'full'}; same plan both arms)")
    st = out["stability"]
    s += ("\ntail stability over seeds "
          f"{out['protocol']['seeds']} at n={out['protocol']['stability_n']}: "
          f"e2e P99 spread {100 * st['p99_spread_rel']:.1f}% "
          f"(per-seed {['%.1f' % v for v in st['p99_per_seed']]}), "
          f"P99.9 spread {100 * st['p999_spread_rel']:.1f}%")
    h = out["speedup_at_headline"]
    s += (f"\nheadline: {h['speedup_x']:.1f}x object throughput at "
          f"n={h['n']:,} ({h['object_rps']:,.0f} -> "
          f"{h['vector_rps']:,.0f} simulated requests/s; "
          f"acceptance >= 20x: {'MET' if h['meets_20x'] else 'NOT MET'})")
    return s
