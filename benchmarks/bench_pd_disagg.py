"""Beyond-paper: prefill/decode disaggregation — unified vs
``pd_disaggregated`` vs ``pd_disaggregated`` + work stealing, swept
across replica counts and both cost regimes.

Protocol: `cluster_stress_config` traffic (rates scaled to replica
count, heavy-tailed category mix), both service-time regimes —
batch-walk (``L4_MAX_DRIVEN``: batch time walks to its longest member,
where batch composition matters most) and sum-dominated
(``L4_QWEN_1_8B``: batch time ~ total tokens). Two seeds averaged;
bit-deterministic per seed.

What to expect: disaggregation collapses TTFT (prefill no longer waits
behind decode batches — the head-of-line effect of arXiv 2602.02987)
while e2e tails pay for the smaller decode pool plus the modeled KV
transfer; the gap narrows as the pool grows. Work stealing is a
drain-phase mechanism: it fires on imbalance (failure/repair, uneven
tails), so the sweep also includes a decode-replica failure scenario
where stolen work is the recovery path.

The *engine arm* reruns the headline comparison over real JAX
``ServingEngine`` replicas via ``EngineClusterDriver``: a prefill
engine hands each finished prompt's actual KV pages to a decode
engine (fused chunked-prefill + batched paged-decode kernels under
the hood), vs the same engines unified under least_loaded. Times are
in lockstep engine iterations (``dt`` steps), so only intra-arm
comparisons are meaningful.

Smoke mode: set ``BENCH_SMOKE=1`` to shrink the sweep to a single
seed / replica count and a smaller engine workload (used by the CI
benchmark smoke step).
"""

from __future__ import annotations

import os

from repro.cluster import ClusterConfig, ClusterSimulator
from repro.serving.cost_model import L4_MAX_DRIVEN, L4_QWEN_1_8B
from repro.workload.generator import (GeneratorConfig, WorkloadGenerator,
                                      cluster_stress_config)

from .common import fmt_table, mean, save_json

MODES = ("unified", "pd_disaggregated", "pd_steal")
REPLICA_COUNTS = (4, 8)
SEEDS = (1, 2)
REGIMES = {"batch_walk": L4_MAX_DRIVEN, "sum_dominated": L4_QWEN_1_8B}
#: unified baseline routes with least_loaded — the same load measure
#: pd_disaggregated uses for its decode stage, so the comparison
#: isolates disaggregation itself, not the load metric.
UNIFIED_ROUTING = "least_loaded"
FAIL_EVENTS = ((20.0, 2),)           # decode-replica failure scenario
REPAIR_TIME = 25.0

# --- engine arm: the same question over real ServingEngine replicas ---
ENGINE_REPLICAS = 3                  # P/D split: 1 prefill + 2 decode
ENGINE_REQUESTS = 48                 # 24 under BENCH_SMOKE
ENGINE_SLOTS = 2                     # scarce slots: decode clogs unified
ENGINE_CHUNK_TOKENS = 16

_SMOKE = os.environ.get("BENCH_SMOKE", "").strip().lower() \
    not in ("", "0", "false", "no")


def _protocol() -> dict:
    """Effective sweep constants (shrunk under BENCH_SMOKE)."""
    if _SMOKE:
        return {"seeds": (1,), "replica_counts": (4,),
                "regimes": {"batch_walk": L4_MAX_DRIVEN},
                "engine_total": 24}
    return {"seeds": SEEDS, "replica_counts": REPLICA_COUNTS,
            "regimes": REGIMES, "engine_total": ENGINE_REQUESTS}


def _mode_config(mode: str, n: int, seed: int, **extra) -> ClusterConfig:
    if mode == "unified":
        return ClusterConfig(n_replicas=n, routing=UNIFIED_ROUTING,
                             seed=seed, **extra)
    return ClusterConfig(n_replicas=n, routing="pd_disaggregated",
                         work_stealing=(mode == "pd_steal"),
                         steal_min_depth=2, seed=seed, **extra)


def _run(mode: str, n: int, seed: int, cost_model, **extra):
    gen = WorkloadGenerator(cluster_stress_config(n, seed=seed))
    sim = ClusterSimulator(plan=gen.plan(seed=seed),
                           config=_mode_config(mode, n, seed, **extra),
                           cost_model=cost_model)
    return sim, sim.run()


def _collect(mode: str, n: int, cost_model, seeds, **extra) -> dict:
    acc = {k: [] for k in ("ttft_p50", "ttft_p99", "decode_p50",
                           "decode_p99", "e2e_p50", "e2e_p99",
                           "n_handoffs", "n_stolen", "n_completed")}
    for seed in seeds:
        _, m = _run(mode, n, seed, cost_model, **extra)
        acc["ttft_p50"].append(m.ttft.p50)
        acc["ttft_p99"].append(m.ttft.p99)
        acc["decode_p50"].append(m.decode.p50)
        acc["decode_p99"].append(m.decode.p99)
        acc["e2e_p50"].append(m.run.e2e.p50)
        acc["e2e_p99"].append(m.run.e2e.p99)
        acc["n_handoffs"].append(m.n_handoffs)
        acc["n_stolen"].append(m.n_stolen)
        acc["n_completed"].append(m.run.n_completed)
    return {k: mean(v) for k, v in acc.items()}


def _run_engine_arm(proto: dict) -> dict:
    """pd_disaggregated vs unified least_loaded over real JAX engines:
    ``ENGINE_REPLICAS`` paged ``ServingEngine`` replicas driven through
    ``EngineClusterDriver``, with the P/D arm moving each prompt's
    actual KV pages from the prefill engine to a decode engine.
    Arrivals outpace the decode drain (one request per lockstep
    iteration against decode targets of ~24 steps), so unified slots
    clog with decode and late prompts queue behind them; the prefill
    engine recycles its slots at first token. TTFT is the
    engine-stamped ``prefill_end`` in step units."""
    import jax

    from repro.cluster.driver import make_engine_cluster
    from repro.configs import smoke_config
    from repro.models.registry import get_api
    from repro.serving.engine import EngineConfig
    from repro.serving.metrics import percentile

    cfg = smoke_config("smollm-135m")
    params = get_api(cfg).init(cfg, jax.random.PRNGKey(0))
    seed = proto["seeds"][0]
    out = {}
    for mode in ("unified", "pd_disaggregated"):
        driver = make_engine_cluster(
            cfg, params, ENGINE_REPLICAS,
            routing=UNIFIED_ROUTING if mode == "unified"
            else "pd_disaggregated",
            n_prefill_replicas=1 if mode == "pd_disaggregated" else None,
            engine_config=EngineConfig(
                n_slots=ENGINE_SLOTS, max_len=96, prompt_buckets=(64,),
                paged=True, page_size=8,
                chunk_prefill_tokens=ENGINE_CHUNK_TOKENS))
        gen = WorkloadGenerator(GeneratorConfig(
            total_requests=proto["engine_total"],
            calibration_requests=proto["engine_total"],
            max_tokens=24, seed=seed))
        now = 0.0
        for _, r in gen.plan(seed=seed).calibration:
            r.arrival_time = now
            driver.submit(r, now)
            driver.step(now)
            now += 1.0
        m = driver.run_until_drained(max_steps=20_000)
        done = [r for rep in driver.replicas for r in rep.sched.completed]
        ttfts = [r.ttft for r in done if r.ttft is not None]
        e2es = [r.e2e_latency for r in done if r.e2e_latency is not None]
        out[mode] = {
            "n_completed": m.n_completed,
            "n_handoffs": driver.n_handoffs,
            "ttft_p50_steps": percentile(ttfts, 50),
            "ttft_p99_steps": percentile(ttfts, 99),
            "e2e_p99_steps": percentile(e2es, 99),
        }
    pd, uni = out["pd_disaggregated"], out["unified"]
    out["pd_beats_unified_ttft_p50"] = (
        pd["ttft_p50_steps"] < uni["ttft_p50_steps"])
    out["ttft_p50_reduction_pct"] = 100 * (
        1 - pd["ttft_p50_steps"] / max(uni["ttft_p50_steps"], 1e-9))
    return out


def run() -> dict:
    proto = _protocol()
    out = {"smoke": _SMOKE, "sweep": {}}
    # 1) mode x replica-count sweep, both regimes
    for regime, cost in proto["regimes"].items():
        out["sweep"][regime] = {}
        for n in proto["replica_counts"]:
            out["sweep"][regime][n] = {
                mode: _collect(mode, n, cost, proto["seeds"])
                for mode in MODES}

    # headline: TTFT reduction from disaggregation at 4 replicas
    out["ttft_reduction_at_4"] = {}
    for regime in proto["regimes"]:
        uni = out["sweep"][regime][4]["unified"]
        pd = out["sweep"][regime][4]["pd_disaggregated"]
        out["ttft_reduction_at_4"][regime] = {
            "p50_reduction_pct": 100 * (1 - pd["ttft_p50"] / uni["ttft_p50"]),
            "p99_reduction_pct": 100 * (1 - pd["ttft_p99"] / uni["ttft_p99"]),
            "e2e_p99_ratio": pd["e2e_p99"] / uni["e2e_p99"],
        }

    # 2) failure-drain scenario: a decode replica dies mid-run; work
    # stealing is the recovery path for the post-repair imbalance
    out["failure_drain"] = {}
    for mode in ("pd_disaggregated", "pd_steal"):
        p99s, stolen, rerouted, completed = [], [], [], []
        for seed in proto["seeds"]:
            _, m = _run(mode, 4, seed, L4_MAX_DRIVEN,
                        fail_events=FAIL_EVENTS, repair_time=REPAIR_TIME)
            p99s.append(m.run.e2e.p99)
            stolen.append(m.n_stolen)
            rerouted.append(m.n_rerouted)
            completed.append(m.run.n_completed)
        out["failure_drain"][mode] = {
            "p99": mean(p99s), "n_stolen": mean(stolen),
            "n_rerouted": mean(rerouted), "n_completed": mean(completed)}

    # 3) engine arm: the headline comparison on real ServingEngines
    out["engine"] = _run_engine_arm(proto)

    save_json("pd_disagg", out)
    return out


def report(out: dict) -> str:
    rows = []
    for regime, per_n in out["sweep"].items():
        for n, per_mode in per_n.items():
            for mode, r in per_mode.items():
                rows.append([
                    regime, n, mode,
                    f"{r['ttft_p50']:.1f}", f"{r['ttft_p99']:.1f}",
                    "-" if r["decode_p50"] != r["decode_p50"]
                    else f"{r['decode_p50']:.1f}",
                    "-" if r["decode_p99"] != r["decode_p99"]
                    else f"{r['decode_p99']:.1f}",
                    f"{r['e2e_p50']:.1f}", f"{r['e2e_p99']:.1f}",
                    int(r["n_stolen"])])
    s = fmt_table(
        ["regime", "replicas", "mode", "TTFT50", "TTFT99",
         "dec50", "dec99", "e2e50", "e2e99", "stolen"],
        rows, "P/D disaggregation sweep (2-seed avg; unified TTFT is "
              "batch-atomic e2e by construction)")
    for regime, d in out["ttft_reduction_at_4"].items():
        s += (f"\n{regime}: pd vs unified @4 replicas: TTFT P50 "
              f"-{d['p50_reduction_pct']:.0f}%, P99 "
              f"-{d['p99_reduction_pct']:.0f}%, e2e P99 ratio "
              f"{d['e2e_p99_ratio']:.2f}x")
    f = out["failure_drain"]
    s += ("\nfailure drain @4 (decode replica dies at t=20): P99 "
          f"{f['pd_disaggregated']['p99']:.1f}s (no steal, "
          f"{f['pd_disaggregated']['n_rerouted']:.0f} rerouted) vs "
          f"{f['pd_steal']['p99']:.1f}s with stealing "
          f"({f['pd_steal']['n_stolen']:.0f} stolen)")
    e = out["engine"]
    pd, uni = e["pd_disaggregated"], e["unified"]
    s += ("\nengine arm (real ServingEngines, step units): pd TTFT P50 "
          f"{pd['ttft_p50_steps']:.1f} vs unified "
          f"{uni['ttft_p50_steps']:.1f} "
          f"(-{e['ttft_p50_reduction_pct']:.0f}%, "
          f"{int(pd['n_handoffs'])} KV handoffs, "
          f"pd_beats_unified_ttft_p50={e['pd_beats_unified_ttft_p50']})")
    return s
