import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline term extraction via structural depth probes (deliverable g).

XLA's HloCostAnalysis counts a while-loop body once, so the scan-based
full-depth compile undercounts per-layer work by ~n_layers. Rather than
unroll the full stack (108 s compile for the *smallest* arch), we
compile *unrolled reduced-depth* probes and solve the structural cost
model exactly:

    dense / moe / vlm / ssm:  f(k) = fixed + k * layer
                              probes k in {1, 2}
    encdec:                   f(d, e) = fixed + d * dec + e * enc
                              probes {(1,1), (2,1), (1,2)}
    hybrid (attn_every=A):    f(k) = fixed + k * mamba + ceil(k/A) * shared
                              probes k in {1, 2, A+1}

Layer stacks are homogeneous, so the extrapolation to full depth is
exact up to XLA fusion noise (validated against a full unroll of
smollm train_4k — see EXPERIMENTS.md §Roofline methodology).

Every extrapolated quantity (FLOPs, HBM bytes, per-type collective
bytes/ops) is per device on the single-pod 16x16 mesh.
"""

import argparse
import json
import math
import sys
import time
import traceback
from typing import Dict, List, Tuple

import jax

from ..configs import ARCHS, SHAPES, get_config, shape_applicable
from .. import xla_scan as nn_layers
from ..models.config import ModelConfig
from . import dryrun as dr
from .analysis import count_collective_ops, parse_collective_bytes, \
    summarize_cell
from .mesh import make_production_mesh

# quantities extrapolated through the structural model
_KEYS = ("flops", "bytes", "transcendentals", "io_bytes",
         "coll_all-reduce", "coll_all-gather", "coll_reduce-scatter",
         "coll_all-to-all", "coll_collective-permute", "coll_total",
         "ops_all-reduce", "ops_all-gather", "ops_reduce-scatter",
         "ops_all-to-all", "ops_collective-permute")


def _measure(cfg: ModelConfig, shape_name: str, mesh, **lower_kw) -> Dict[str, float]:
    """Compile one unrolled probe and extract raw per-device quantities."""
    nn_layers.set_scan_unroll(True)
    try:
        with mesh:
            lowered, _ = dr.lower_cell(cfg, shape_name, mesh, **lower_kw)
            compiled = lowered.compile()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        cb = parse_collective_bytes(hlo)
        co = count_collective_ops(hlo)
        io_bytes = 0.0
        try:
            ma = compiled.memory_analysis()
            if ma is not None:
                io_bytes = float(
                    getattr(ma, "argument_size_in_bytes", 0)
                    + getattr(ma, "output_size_in_bytes", 0))
        except Exception:
            pass
    finally:
        nn_layers.set_scan_unroll(False)
    out = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "transcendentals": float(cost.get("transcendentals", 0.0)),
        "io_bytes": io_bytes,
        "coll_total": float(cb["total"]),
    }
    for k, v in cb.items():
        if k != "total":
            out[f"coll_{k}"] = float(v)
    for k, v in co.items():
        out[f"ops_{k}"] = float(v)
    return out


def _combine(points: List[Tuple[Dict[str, float], Dict[str, float]]],
             full_counts: Dict[str, float]) -> Dict[str, float]:
    """Solve  f(probe) = fixed + sum_c counts[c] * unit_c  exactly.

    ``points`` = [(counts, measured)], with len(points) = n_units + 1.
    ``full_counts`` = structural counts at full depth.
    """
    units = sorted({c for counts, _ in points for c in counts})
    import numpy as np
    A = np.array([[1.0] + [counts.get(u, 0.0) for u in units]
                  for counts, _ in points])
    out = {}
    for key in _KEYS:
        b = np.array([m.get(key, 0.0) for _, m in points])
        try:
            coef, *_ = np.linalg.lstsq(A, b, rcond=None)
        except np.linalg.LinAlgError:
            out[key] = float(b[-1])
            continue
        val = coef[0] + sum(coef[1 + i] * full_counts.get(u, 0.0)
                            for i, u in enumerate(units))
        out[key] = float(max(val, 0.0))
    return out


def _probe_plan(cfg: ModelConfig):
    """[(probe_cfg, counts)], full_counts."""
    if cfg.family == "encdec":
        pts = [(cfg.replace(n_layers=d, n_enc_layers=e),
                {"dec": d, "enc": e})
               for d, e in ((1, 1), (2, 1), (1, 2))]
        return pts, {"dec": cfg.n_layers, "enc": cfg.n_enc_layers}
    if cfg.family == "hybrid":
        A = cfg.attn_every

        def counts(k):
            return {"mamba": k, "shared": math.ceil(k / A)}
        ks = (1, 2, A + 1)
        pts = [(cfg.replace(n_layers=k), counts(k)) for k in ks]
        return pts, counts(cfg.n_layers)
    pts = [(cfg.replace(n_layers=k), {"layer": k}) for k in (1, 2)]
    return pts, {"layer": cfg.n_layers}


def run_cell(arch: str, shape_name: str, *, out_dir: str,
             force: bool = False, variant: str = "",
             cfg_overrides: Dict = None,
             **lower_kw) -> Dict:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = SHAPES[shape_name]
    cell = f"{arch}__{shape_name}" + (f"__{variant}" if variant else "")
    path = os.path.join(out_dir, cell + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    record: Dict = {"arch": arch, "shape": shape_name, "mesh": "16x16",
                    "kind": shape.kind, "variant": variant}
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        record.update({"status": "skipped", "reason": reason})
        dr._save(path, record)
        return record

    mesh = make_production_mesh(multi_pod=False)
    try:
        t0 = time.time()
        plan, full_counts = _probe_plan(cfg)
        points = []
        for pcfg, counts in plan:
            points.append((counts, _measure(pcfg, shape_name, mesh,
                                            **lower_kw)))
        extrap = _combine(points, full_counts)
        coll = {k.replace("coll_", ""): v for k, v in extrap.items()
                if k.startswith("coll_")}
        summary = summarize_cell(
            cfg, shape.kind,
            shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1),
            mesh.devices.size,
            {"flops": extrap["flops"], "bytes accessed": extrap["bytes"]},
            coll, io_bytes=extrap.get("io_bytes", 0.0))
        record.update({
            "status": "ok",
            "n_chips": int(mesh.devices.size),
            "probe_s": round(time.time() - t0, 1),
            "probes": [{"counts": c, **m} for c, m in points],
            "extrapolated": extrap,
            "roofline": summary,
        })
    except Exception as e:
        record.update({"status": "error",
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]})
    dr._save(path, record)
    return record


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--out", default="results/roofline")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    archs = ARCHS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    failures = 0
    for arch in archs:
        for shape in shapes:
            rec = run_cell(arch, shape, out_dir=args.out, force=args.force)
            status = rec["status"]
            extra = ""
            if status == "ok":
                r = rec["roofline"]
                extra = (f" {r['bottleneck']}-bound"
                         f" t>={r['step_time_lower_bound_s']:.4f}s"
                         f" frac={r['roofline_fraction']:.2f}")
            elif status == "error":
                failures += 1
                extra = " " + rec["error"][:120]
            print(f"[{status:7s}] roofline {arch} x {shape}{extra}",
                  flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
