"""Serving launcher: the paper's full experiment protocol.

    PYTHONPATH=src python -m repro.launch.serve --policy sjf --bias on
    PYTHONPATH=src python -m repro.launch.serve --engine jax \
        --arch smollm-135m --requests 24

``--engine sim`` (default) runs the discrete-event cluster simulator
with the L4-calibrated cost model — the configuration every paper table
uses. ``--engine jax`` runs the real continuous-batching JAX engine on
the reduced model (CPU container), same scheduler state machine.
"""

from __future__ import annotations

import argparse
import json
import sys

from ..configs import ARCHS, smoke_config
from ..core.estimator import DriftConfig
from ..core.scheduler import DriftScheduler
from ..serving.simulator import SimConfig, WorkerSimulator
from ..workload.generator import GeneratorConfig, WorkloadGenerator


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--engine", default="sim", choices=["sim", "jax"])
    ap.add_argument("--policy", default="fifo",
                    choices=["fifo", "priority", "weighted", "sjf", "aging"])
    ap.add_argument("--bias", default="on", choices=["on", "off"])
    ap.add_argument("--requests", type=int, default=3000)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--arch", default="smollm-135m", choices=ARCHS)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--fail-at", type=float, default=None,
                    help="inject a worker failure at this time (s)")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)

    sched = DriftScheduler(
        policy=args.policy,
        config=DriftConfig(bias_enabled=args.bias == "on"))

    if args.engine == "sim":
        gen = WorkloadGenerator(GeneratorConfig(
            total_requests=args.requests,
            calibration_requests=args.requests // 3,
            seed=args.seed))
        plan = gen.plan(seed=args.seed)
        sim_cfg = SimConfig(
            seed=args.seed, n_workers=args.workers,
            fail_times=(args.fail_at,) if args.fail_at else ())
        sim = WorkerSimulator(sched, plan, sim_cfg)
        metrics = sim.run()
    else:
        import jax
        from ..models.registry import get_api
        from ..serving.engine import EngineConfig, ServingEngine
        cfg = smoke_config(args.arch)
        api = get_api(cfg)
        params = api.init(cfg, jax.random.PRNGKey(0))
        eng = ServingEngine(cfg, params, sched,
                            EngineConfig(n_slots=8, max_len=128,
                                         prompt_buckets=(16, 32)))
        gen = WorkloadGenerator(GeneratorConfig(
            total_requests=args.requests,
            calibration_requests=args.requests,
            max_tokens=64, seed=args.seed))
        for t, r in gen.plan(seed=args.seed).calibration:
            sched.submit(r, t)
        metrics = eng.run_until_drained()

    out = metrics.as_dict()
    out["learned_bias"] = sched.bias_store.snapshot()
    if args.json:
        print(json.dumps(out, indent=1, default=float))
    else:
        print(f"policy={args.policy} bias={args.bias} "
              f"completed={metrics.n_completed}")
        print(f"e2e    P50={metrics.e2e.p50:8.2f}s "
              f"P95={metrics.e2e.p95:8.2f}s P99={metrics.e2e.p99:8.2f}s")
        print(f"wait   mean={metrics.queue_wait.mean:7.2f}s")
        print(f"exec   P50={metrics.gpu_exec.p50:8.2f}s "
              f"util={metrics.gpu_utilization:.0%}")
        for t, v in metrics.per_tenant.items():
            print(f"tenant {t:9s} latency={v['latency']['mean']:7.1f}s "
                  f"wait={v['queue_wait']['mean']:7.1f}s")
        print("learned bias:", {k: round(v, 3)
                                for k, v in out["learned_bias"].items()})
    return 0


if __name__ == "__main__":
    sys.exit(main())
