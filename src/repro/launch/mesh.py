"""Production mesh construction (a FUNCTION — importing this module never
touches jax device state).

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model) — the pod
axis carries pure data parallelism across the inter-pod (DCN) links.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / elastic re-scale use smaller ones)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


# TPU v5e hardware constants used by the roofline analysis.
HW = {
    "peak_flops_bf16": 197e12,   # FLOP/s per chip
    "hbm_bw": 819e9,             # bytes/s per chip
    "ici_bw": 50e9,              # bytes/s per link (~ per-chip collective bw)
    "hbm_bytes": 16 * 2**30,     # 16 GiB HBM per v5e chip
}
