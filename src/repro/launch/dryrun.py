import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input shape x mesh) cell: build the step
function (train_step / prefill_step / serve_step), attach the cell's
shardings to ShapeDtypeStruct stand-ins, ``jax.jit(...).lower()``,
``.compile()``, and record ``memory_analysis()`` + ``cost_analysis()``
plus the HLO collective inventory into results/dryrun/<cell>.json.

The 512 placeholder host devices exist ONLY here (the two lines above
run before any other import, since jax locks the device count on first
init). Smoke tests and benches see 1 device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch all --shape all --mesh both --out results/dryrun
"""

import argparse
import json
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..configs import ARCHS, SHAPES, cells_for, get_config, input_specs, \
    shape_applicable
from ..distributed.optimizer import Optimizer, OptimizerConfig
from ..models.config import ModelConfig
from ..models.registry import abstract_params, get_api
from ..models.steps import make_prefill_step, make_serve_step, make_train_step
from . import cell_shardings as cs
from .analysis import count_collective_ops, parse_collective_bytes, \
    summarize_cell
from .mesh import make_production_mesh


def _cell_id(arch: str, shape: str, multi_pod: bool) -> str:
    return f"{arch}__{shape}__{'multipod' if multi_pod else 'pod'}"


def lower_cell(cfg: ModelConfig, shape_name: str, mesh, *,
               donate: bool = True, attn_impl: str = "auto",
               remat: bool = True, param_mode: Optional[str] = None,
               batch_mode: str = "default",
               rules_mode: Optional[str] = None):
    """Build + lower one cell. Returns (lowered, meta).

    ``param_mode``: train | serve | replicated | serve-2d (see
    cell_shardings.params_shardings_for). ``batch_mode``: default |
    dp-all (batch over the model axis too; activation constraints switch
    to the pure-DP rule set)."""
    from ..distributed.sharding import logical_mode
    shape = SHAPES[shape_name]
    specs = input_specs(cfg, shape)
    aparams = abstract_params(cfg)
    meta: Dict = {"kind": shape.kind,
                  "variant_knobs": {"param_mode": param_mode,
                                    "batch_mode": batch_mode,
                                    "rules_mode": rules_mode,
                                    "remat": remat}}
    rules = rules_mode or ("dp-all" if batch_mode == "dp-all" else "default")

    if shape.kind == "train":
        p_shard, policy = cs.params_shardings_for(
            cfg, mesh, aparams,
            mode=param_mode or "train")
        opt = Optimizer(OptimizerConfig())
        aopt = jax.eval_shape(opt.init, aparams)
        o_shard = opt.state_shardings(aparams, mesh)
        b_shard = cs.train_batch_shardings(mesh, specs, mode=batch_mode)
        step = make_train_step(cfg, opt, remat=remat, attn_impl=attn_impl)
        args = (cs.attach(aparams, p_shard),
                cs.attach(aopt, o_shard),
                cs.attach(specs, b_shard))
        jitted = jax.jit(step, donate_argnums=(0, 1) if donate else ())
        with logical_mode(rules):
            lowered = jitted.lower(*args)
        meta["param_policy"] = policy
        meta["n_tokens"] = shape.global_batch * shape.seq_len
        return lowered, meta

    p_shard, policy = cs.params_shardings_for(
        cfg, mesh, aparams, mode=param_mode or "serve")
    meta["param_policy"] = policy

    if shape.kind == "prefill":
        b_shard = cs.train_batch_shardings(mesh, specs, mode=batch_mode)
        step = make_prefill_step(cfg, max_len=shape.seq_len,
                                 attn_impl=attn_impl)
        rng = jax.ShapeDtypeStruct((2,), jnp.uint32)
        args = (cs.attach(aparams, p_shard),
                cs.attach(specs, b_shard),
                rng)
        with logical_mode(rules):
            lowered = jax.jit(step).lower(*args)
        meta["n_tokens"] = shape.global_batch * shape.seq_len
        return lowered, meta

    # decode
    c_shard = cs.cache_shardings(cfg, mesh, specs["cache"])
    step = make_serve_step(cfg, attn_impl=attn_impl)
    args = (cs.attach(aparams, p_shard),
            cs.attach(specs["cache"], c_shard),
            cs.attach(specs["tokens"], cs.token_sharding(
                mesh, shape.global_batch)),
            specs["pos"],
            specs["rng"])
    jitted = jax.jit(step, donate_argnums=(1,) if donate else ())
    with logical_mode(rules):
        lowered = jitted.lower(*args)
    meta["n_tokens"] = shape.global_batch  # one new token per sequence
    return lowered, meta


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str, force: bool = False,
             save_hlo: bool = False, variant: str = "",
             **lower_kw) -> Dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    cell = _cell_id(arch, shape_name, multi_pod) + (
        f"__{variant}" if variant else "")
    path = os.path.join(out_dir, cell + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    record: Dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind, "variant": variant,
    }
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        record.update({"status": "skipped", "reason": reason})
        _save(path, record)
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    try:
        with mesh:
            lowered, meta = lower_cell(cfg, shape_name, mesh, **lower_kw)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            cost = compiled.cost_analysis() or {}
            try:
                mem = compiled.memory_analysis()
                mem_info = {
                    k: int(getattr(mem, k))
                    for k in ("argument_size_in_bytes",
                              "output_size_in_bytes",
                              "temp_size_in_bytes",
                              "generated_code_size_in_bytes",
                              "alias_size_in_bytes")
                    if hasattr(mem, k)
                } if mem is not None else {}
            except Exception as e:  # CPU backend may not implement it
                mem_info = {"error": str(e)}

            hlo = compiled.as_text()
            coll_bytes = parse_collective_bytes(hlo)
            coll_ops = count_collective_ops(hlo)
            if save_hlo:
                with open(os.path.join(out_dir, cell + ".hlo.txt"), "w") as f:
                    f.write(hlo)

        io_bytes = float(mem_info.get("argument_size_in_bytes", 0)
                         + mem_info.get("output_size_in_bytes", 0)) \
            if "error" not in mem_info else 0.0
        summary = summarize_cell(cfg, meta["kind"], meta["n_tokens"],
                                 n_chips, cost, coll_bytes,
                                 io_bytes=io_bytes)
        record.update({
            "status": "ok",
            "n_chips": int(n_chips),
            "param_policy": meta.get("param_policy"),
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "cost_analysis": {k: float(v) for k, v in cost.items()
                              if isinstance(v, (int, float))},
            "memory_analysis": mem_info,
            "collective_bytes": coll_bytes,
            "collective_ops": coll_ops,
            "roofline": summary,
        })
    except Exception as e:
        record.update({
            "status": "error",
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        })
    _save(path, record)
    return record


def _save(path: str, record: Dict) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help=f"one of {ARCHS} or 'all' or comma list")
    ap.add_argument("--shape", default="all",
                    help=f"one of {list(SHAPES)} or 'all' or comma list")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args(argv)

    archs = ARCHS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                rec = run_cell(arch, shape, multi_pod=multi,
                               out_dir=args.out, force=args.force,
                               save_hlo=args.save_hlo)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" bottleneck={r['bottleneck']}"
                             f" t>={r['step_time_lower_bound_s']:.4f}s"
                             f" compile={rec['compile_s']:.0f}s")
                elif status == "error":
                    failures += 1
                    extra = " " + rec["error"][:120]
                print(f"[{status:7s}] {arch} x {shape} x "
                      f"{'2x16x16' if multi else '16x16'}{extra}",
                      flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
