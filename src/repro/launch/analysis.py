"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Sources:
* ``compiled.cost_analysis()``  -> HLO FLOPs + HBM bytes (per device —
  the compiled module IS the per-device SPMD program);
* ``compiled.as_text()``        -> collective ops; we sum the result
  operand sizes of every all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute (also per device).

Roofline terms (seconds), per the hardware constants in mesh.HW:

    compute    = flops_per_dev / peak_flops
    memory     = bytes_per_dev / hbm_bw
    collective = coll_bytes_per_dev / ici_bw

(equivalent to the total-work formulation total / (chips * rate) since
total = per_dev * chips).
"""

from __future__ import annotations

import math
import re
from typing import Dict, Optional, Tuple

from ..models.config import ModelConfig
from .mesh import HW

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
    "s4": 1, "u4": 1,  # rounded up
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter",
                "all-to-all", "collective-permute")

# one result shape, e.g. f32[8,128]{1,0} or bf16[2,4096]
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-operand bytes of every collective op (per device).

    Handles sync ops and async ``-start``/``-done`` pairs (the ``-done``
    line repeats the shape, so only ``-start`` and plain forms count).
    """
    out: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        _, _, rhs = line.partition("=")
        m = re.search(
            r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|"
            r"collective-permute)(-start)?\(", rhs)
        if not m:
            continue
        if re.search(r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|"
                     r"collective-permute)-done\(", rhs):
            continue
        kind = m.group(1)
        # result shape(s) sit between '=' and the op name, e.g.
        #   %all-gather.39 = f32[576,3,4]{2,1,0} all-gather(%x), ...
        # (-start forms carry an (in, out) tuple -> halve)
        total = 0
        for dt, dims in _SHAPE_RE.findall(rhs[:m.start()]):
            total += _shape_bytes(dt, dims)
        if m.group(2):
            total //= 2
        out[kind] += total
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


def count_collective_ops(hlo_text: str) -> Dict[str, int]:
    out: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for c in _COLLECTIVES:
        out[c] = len(re.findall(rf"\b{c}(-start)?\(", hlo_text))
    return out


# ---------------------------------------------------------------------------

def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float,
                   io_bytes_per_dev: float = 0.0) -> Dict[str, float]:
    """``bytes_per_dev`` is HloCostAnalysis 'bytes accessed' — an UPPER
    bound on HBM traffic (the CPU backend fuses less than TPU, so many
    counted operands would stay in VMEM/registers on the target).
    ``io_bytes_per_dev`` (argument+output buffer sizes) is the matching
    LOWER bound: every input/output must cross HBM at least once. The
    reported memory term uses the upper bound (conservative); both are
    recorded."""
    compute = flops_per_dev / HW["peak_flops_bf16"]
    memory = bytes_per_dev / HW["hbm_bw"]
    memory_io = io_bytes_per_dev / HW["hbm_bw"]
    collective = coll_bytes_per_dev / HW["ici_bw"]
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom.replace("_s", "")
    terms["memory_io_lower_s"] = memory_io
    total = max(compute, memory, collective)
    terms["step_time_lower_bound_s"] = total
    terms["roofline_fraction"] = compute / total if total > 0 else 0.0
    # optimistic fraction if TPU fusion removes all intermediate traffic
    best = max(compute, memory_io, collective)
    terms["roofline_fraction_optimistic"] = (compute / best
                                             if best > 0 else 0.0)
    return terms


def model_flops(cfg: ModelConfig, kind: str, tokens: int) -> float:
    """6·N·D (train) / 2·N·D (inference) on active params."""
    n_active = cfg.active_param_count()
    per_token = 6.0 * n_active if kind == "train" else 2.0 * n_active
    return per_token * tokens


def summarize_cell(cfg: ModelConfig, kind: str, n_tokens: int,
                   n_chips: int, cost: dict, coll: Dict[str, int],
                   io_bytes: float = 0.0) -> Dict[str, float]:
    flops = float(cost.get("flops", 0.0))
    b_out = float(cost.get("bytes accessed", 0.0))
    terms = roofline_terms(flops, b_out, float(coll.get("total", 0)),
                           io_bytes)
    mf = model_flops(cfg, kind, n_tokens)
    hlo_total = flops * n_chips
    terms.update({
        "hlo_flops_per_dev": flops,
        "hlo_bytes_per_dev": b_out,
        "coll_bytes_per_dev": float(coll.get("total", 0)),
        "model_flops_total": mf,
        "useful_flops_ratio": (mf / hlo_total) if hlo_total > 0 else 0.0,
    })
    return terms
