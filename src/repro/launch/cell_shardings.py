"""Per-cell sharding assignment: in/out sharding pytrees for every
(arch x shape x mesh) dry-run cell.

Decisions (DESIGN.md §4):

* params — `param_shardings` rules (TP on heads / d_ff / experts /
  vocab); training and big-arch serving additionally spread each
  param's largest free dim over the data axis (FSDP/weight-gathered
  serving) so nothing replicated outgrows HBM;
* batch inputs — batch dim over (pod, data) when divisible;
* KV caches — batch on (pod, data); kv-heads on model when divisible,
  else the *sequence* dim on model (flash-decoding-style partitioning:
  per-shard partial softmax stats are combined by tiny all-reduces),
  else replicated;
* SSM states — batch on data, ssm-heads on model;
* scalars / rng / lens — replicated.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed.sharding import param_shardings
from ..models.config import ModelConfig

# replicated-param bytes above this threshold switch serving to
# weight-gathered (params also sharded over data) mode
SERVE_GATHER_THRESHOLD = 4 * 2**30  # 4 GiB / device


def _axes(mesh) -> Dict[str, int]:
    return dict(mesh.shape)


def batch_axes(mesh, batch: int) -> Tuple[str, ...]:
    """Largest (pod, data) prefix that divides the batch."""
    sizes = _axes(mesh)
    chosen, total = [], 1
    for ax in ("pod", "data"):
        if ax in sizes and batch % (total * sizes[ax]) == 0:
            chosen.append(ax)
            total *= sizes[ax]
    return tuple(chosen)


def batch_part(mesh, batch: int):
    axes = batch_axes(mesh, batch)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def token_sharding(mesh, batch: int) -> NamedSharding:
    return NamedSharding(mesh, P(batch_part(mesh, batch)))


def all_axes_batch_part(mesh, batch: int):
    """Batch over EVERY mesh axis (pure-DP layout for small models)."""
    sizes = _axes(mesh)
    chosen, total = [], 1
    for ax in ("pod", "data", "model"):
        if ax in sizes and batch % (total * sizes[ax]) == 0:
            chosen.append(ax)
            total *= sizes[ax]
    if not chosen:
        return None
    return tuple(chosen) if len(chosen) > 1 else chosen[0]


def train_batch_shardings(mesh, batch_specs: Dict, *,
                          mode: str = "default") -> Dict:
    """tokens/labels [B, L] (+ modality stubs [B, T, d]).

    mode 'dp-all' spreads the batch over the model axis too — the
    pure-data-parallel layout for models too small to tensor-shard."""
    part_fn = all_axes_batch_part if mode == "dp-all" else batch_part
    out = {}
    for name, sds in batch_specs.items():
        b = sds.shape[0]
        spec = [part_fn(mesh, b)] + [None] * (len(sds.shape) - 1)
        out[name] = NamedSharding(mesh, P(*spec))
    return out


def _kv_spec(mesh, shape) -> P:
    """[L, B, S, Hk, hd] (self/cross KV cache)."""
    sizes = _axes(mesh)
    model = sizes.get("model", 1)
    _, B, S, Hk, _ = shape
    bp = batch_part(mesh, B)
    if Hk % model == 0:
        return P(None, bp, None, "model", None)
    if S % model == 0:
        return P(None, bp, "model", None, None)
    return P(None, bp, None, None, None)


def cache_shardings(cfg: ModelConfig, mesh, cache_tree) -> Dict:
    """NamedShardings for a decode-cache pytree (by leaf name)."""
    sizes = _axes(mesh)
    model = sizes.get("model", 1)

    def leaf_spec(path, sds):
        name = str(getattr(path[-1], "key", path[-1]))
        shp = sds.shape
        if name in ("k", "v", "attn_k", "attn_v", "cross_k", "cross_v"):
            return _kv_spec(mesh, shp)
        if name in ("k_scale", "v_scale"):    # [L, B, S, Hk] int8 scales
            full = _kv_spec(mesh, tuple(shp) + (0,))
            return P(*tuple(full)[:4])
        if name == "conv":            # [L, B, k-1, C]
            bp = batch_part(mesh, shp[1])
            cp = "model" if shp[3] % model == 0 else None
            return P(None, bp, None, cp)
        if name == "ssm":             # [L, B, H, P, N]
            bp = batch_part(mesh, shp[1])
            hp = "model" if shp[2] % model == 0 else None
            return P(None, bp, hp, None, None)
        if name == "lens":            # [B]
            return P(batch_part(mesh, shp[0]))
        return P(*([None] * len(shp)))

    return jax.tree_util.tree_map_with_path(
        lambda path, sds: NamedSharding(mesh, leaf_spec(path, sds)),
        cache_tree)


def _params_2d(cfg: ModelConfig, mesh, abstract_params) -> Dict:
    """Weight-stationary 2D sharding for serving big models: every large
    matrix is sharded over BOTH mesh axes (d_model rows on data, heads /
    d_ff columns on model), so no per-step weight all-gather is needed —
    contractions over sharded dims lower to small ACTIVATION all-reduces
    instead. The serving fix for the weight-gathered decode bottleneck
    (EXPERIMENTS.md §Perf, grok decode hillclimb)."""
    sizes = _axes(mesh)
    d_ax = sizes.get("data", 1)
    m_ax = sizes.get("model", 1)

    def ok(dim, ax_size):
        return ax_size > 1 and dim % ax_size == 0

    def spec_for(path: str, shape) -> P:
        def two_d(rows_i, cols_i, rank):
            spec = [None] * rank
            if ok(shape[rows_i], d_ax):
                spec[rows_i] = "data"
            if ok(shape[cols_i], m_ax):
                spec[cols_i] = "model"
            return P(*spec)

        if path.endswith(("embed/table", "lm_head/table")):
            return two_d(1, 0, 2)            # [V@model, d@data]
        if path.endswith("attn/wq"):
            # [d, H, hd]: d on data; heads on model else head_dim
            spec = [None, None, None]
            if ok(shape[0], d_ax):
                spec[0] = "data"
            if ok(shape[-2], m_ax):
                spec[-2] = "model"
            elif ok(shape[-1], m_ax):
                spec[-1] = "model"
            return P(*spec)
        if path.endswith(("attn/wk", "attn/wv")):
            spec = [None] * len(shape)
            if ok(shape[-3], d_ax):
                spec[-3] = "data"
            if ok(shape[-2], m_ax):
                spec[-2] = "model"
            elif ok(shape[-1], m_ax):
                spec[-1] = "model"
            return P(*spec)
        if path.endswith("attn/wo"):
            spec = [None] * len(shape)
            if ok(shape[-3], m_ax):
                spec[-3] = "model"
            if ok(shape[-1], d_ax):
                spec[-1] = "data"
            return P(*spec)
        if path.endswith(("mlp/w1", "mlp/w3", "ssm/in_proj")):
            spec = [None] * len(shape)
            if ok(shape[-2], d_ax):
                spec[-2] = "data"
            if ok(shape[-1], m_ax):
                spec[-1] = "model"
            return P(*spec)
        if path.endswith(("mlp/w2", "ssm/out_proj")):
            spec = [None] * len(shape)
            if ok(shape[-2], m_ax):
                spec[-2] = "model"
            if ok(shape[-1], d_ax):
                spec[-1] = "data"
            return P(*spec)
        if path.endswith(("moe/w1", "moe/w3")):
            spec = [None] * len(shape)
            if ok(shape[-2], d_ax):
                spec[-2] = "data"
            if ok(shape[-1], m_ax):
                spec[-1] = "model"
            return P(*spec)
        if path.endswith("moe/w2"):
            spec = [None] * len(shape)
            if ok(shape[-2], m_ax):
                spec[-2] = "model"
            if ok(shape[-1], d_ax):
                spec[-1] = "data"
            return P(*spec)
        return P(*([None] * len(shape)))

    flat = jax.tree_util.tree_flatten_with_path(abstract_params)[0]

    def path_str(kp):
        return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)

    return jax.tree_util.tree_map_with_path(
        lambda kp, sds: NamedSharding(mesh, spec_for(path_str(kp),
                                                     sds.shape)),
        abstract_params)


def params_shardings_for(cfg: ModelConfig, mesh, abstract_params, *,
                         mode: str) -> Tuple[Dict, str]:
    """(sharding pytree, policy description).
    mode: 'train' | 'serve' | 'replicated' | 'serve-2d'."""
    if mode == "replicated":
        return replicated(mesh, abstract_params), \
            "replicated (pure data parallelism)"
    if mode == "serve-2d":
        return _params_2d(cfg, mesh, abstract_params), \
            "weight-stationary 2D (d on data, heads/ff on model)"
    if mode == "train":
        shard, _ = param_shardings(abstract_params, mesh, zero_axis="data")
        return shard, "fsdp (model-TP + data-sharded params, ZeRO)"
    # serve: replicate over data unless the replicated size would blow HBM
    tp_only, _ = param_shardings(abstract_params, mesh)
    sizes = _axes(mesh)
    model = sizes.get("model", 1)

    def bytes_under(shard_tree):
        total = 0
        for sds, sh in zip(jax.tree_util.tree_leaves(abstract_params),
                           jax.tree_util.tree_leaves(shard_tree)):
            n = int(np.prod(sds.shape)) * sds.dtype.itemsize
            spec = sh.spec
            denom = 1
            for part in spec:
                for ax in ((part,) if isinstance(part, str) else (part or ())):
                    denom *= sizes.get(ax, 1)
            total += n // max(denom, 1)
        return total

    if bytes_under(tp_only) <= SERVE_GATHER_THRESHOLD:
        return tp_only, "tp-only (params replicated over data)"
    gathered, _ = param_shardings(abstract_params, mesh, zero_axis="data")
    return gathered, "weight-gathered (params sharded over data+model)"


def replicated(mesh, tree):
    return jax.tree_util.tree_map(
        lambda sds: NamedSharding(mesh, P(*([None] * len(sds.shape)))), tree)


def attach(specs, shardings):
    """Attach shardings to ShapeDtypeStructs (jit infers in_shardings)."""
    return jax.tree_util.tree_map(
        lambda sds, sh: jax.ShapeDtypeStruct(sds.shape, sds.dtype, sharding=sh),
        specs, shardings)
