"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --preset smoke --steps 50 --dataset synthetic

On this CPU container, real training runs the reduced (smoke) preset;
the full configs are exercised via --compile-only (lower+compile on the
production mesh — the same path as the dry-run). Checkpoint/restart is
on by default: interrupt and relaunch with --resume.
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, get_config, smoke_config
from ..data.pipeline import attach_modality_stubs, make_dataset
from ..distributed.checkpoint import CheckpointManager
from ..distributed.optimizer import Optimizer, OptimizerConfig
from ..models.registry import get_api
from ..models.steps import make_train_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-135m", choices=ARCHS)
    ap.add_argument("--preset", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--dataset", default="synthetic",
                    choices=["synthetic", "corpus"])
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compile-only", action="store_true",
                    help="lower+compile the production-mesh train step "
                         "instead of executing (CPU container)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    if args.compile_only:
        from .dryrun import run_cell  # sets XLA_FLAGS on import
        rec = run_cell(args.arch, "train_4k", multi_pod=False,
                       out_dir="results/dryrun")
        print(rec["status"], rec.get("roofline", rec.get("error")))
        return 0 if rec["status"] == "ok" else 1

    cfg = smoke_config(args.arch) if args.preset == "smoke" \
        else get_config(args.arch)
    api = get_api(cfg)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"dataset={args.dataset} steps={args.steps}")

    params = api.init(cfg, jax.random.PRNGKey(0))
    opt = Optimizer(OptimizerConfig(lr=args.lr, warmup_steps=10,
                                    decay_steps=max(args.steps, 100)))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt))
    ds = make_dataset(args.dataset, cfg, args.seq, args.batch)

    start = 0
    ckpt = None
    if args.checkpoint_dir:
        ckpt = CheckpointManager(args.checkpoint_dir)
        if args.resume and ckpt.latest_step() is not None:
            start, state, _ = ckpt.restore(
                {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            print(f"resumed from step {start}")

    rng = np.random.default_rng(0)
    t0 = time.time()
    for step in range(start, args.steps):
        batch = attach_modality_stubs(cfg, ds.batch(step), rng)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss={float(m['loss']):.4f} "
                  f"gnorm={float(m['grad_norm']):.3f} "
                  f"lr={float(m['lr']):.2e} "
                  f"({(time.time()-t0)/(step-start+1):.2f}s/step)",
                  flush=True)
        if ckpt and (step + 1) % args.checkpoint_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state})
    if ckpt:
        ckpt.save(args.steps, {"params": params, "opt": opt_state})
        ckpt.wait()
    return 0


if __name__ == "__main__":
    sys.exit(main())
