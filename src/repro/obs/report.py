"""Summarize a Chrome-trace file produced by ``benchmarks/run.py
--trace`` (or :func:`repro.obs.timeline.write_chrome_trace`).

    PYTHONPATH=src python -m repro.obs.report TRACE.json

Validates the file against the trace-event structural contract first
(:func:`~repro.obs.timeline.validate_chrome_trace`) and exits non-zero
on any violation — this CLI is the CI gate for exported traces. On a
valid file it prints track/slice/counter/flow inventories, slice-
duration percentiles per slice name, and the embedded recorder stats
(events emitted per kind, sampling strides, ring overflow), ending
with a ``trace OK`` line the CI grep guard keys on.
"""

from __future__ import annotations

import argparse
import json
import sys
from collections import Counter, defaultdict
from typing import Dict, List

from .stats import percentile
from .timeline import validate_chrome_trace

_US = 1_000_000.0


def summarize(doc: dict) -> str:
    evs = doc.get("traceEvents", [])
    by_ph = Counter(e.get("ph") for e in evs)
    tracks = {(e.get("pid"), e.get("tid")) for e in evs
              if e.get("ph") != "M"}
    pids = {e.get("pid") for e in evs}
    lines = [
        f"events: {len(evs)}  "
        f"(slices={by_ph.get('X', 0)} instants={by_ph.get('i', 0)} "
        f"counters={by_ph.get('C', 0)} "
        f"flows={by_ph.get('s', 0)}+{by_ph.get('f', 0)} "
        f"metadata={by_ph.get('M', 0)})",
        f"tracks: {len(tracks)} across {len(pids)} process groups",
    ]

    durs: Dict[str, List[float]] = defaultdict(list)
    for e in evs:
        if e.get("ph") == "X":
            name = e.get("cat") or e.get("name", "?")
            durs[name].append(float(e.get("dur", 0.0)) / _US)
    for name in sorted(durs):
        xs = durs[name]
        lines.append(
            f"  {name}: n={len(xs)} "
            f"mean={sum(xs) / len(xs):.3f}s "
            f"p50={percentile(xs, 50):.3f}s "
            f"p95={percentile(xs, 95):.3f}s "
            f"max={max(xs):.3f}s")

    counters = Counter(e.get("name") for e in evs if e.get("ph") == "C")
    if counters:
        lines.append("counters: " + ", ".join(
            f"{n} ({c} samples)" for n, c in sorted(counters.items())))
    instants = Counter(e.get("name") for e in evs if e.get("ph") == "i")
    if instants:
        lines.append("markers: " + ", ".join(
            f"{n}={c}" for n, c in sorted(instants.items())))

    rec = (doc.get("otherData") or {}).get("recorder")
    if rec:
        lines.append(
            f"recorder: emitted={rec.get('emitted')} "
            f"recorded={rec.get('recorded')} "
            f"overflow_dropped={rec.get('dropped_overflow')}")
        by_kind = rec.get("by_kind") or {}
        if by_kind:
            lines.append("  by kind: " + ", ".join(
                f"{k}={v}" for k, v in sorted(by_kind.items())))
        strides = {k: v for k, v in (rec.get("sample_every")
                                     or {}).items() if v != 1}
        if strides:
            lines.append("  sampled: " + ", ".join(
                f"{k} 1:{v}" for k, v in sorted(strides.items())))
        segs = rec.get("segments") or []
        if segs:
            lines.append(f"  segments: {len(segs)} "
                         f"({', '.join(segs[:6])}"
                         f"{', ...' if len(segs) > 6 else ''})")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="Chrome trace-event JSON file "
                                  "(benchmarks/run.py --trace output)")
    args = ap.parse_args(argv)
    try:
        with open(args.trace) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: cannot load {args.trace}: {e}", file=sys.stderr)
        return 2
    problems = validate_chrome_trace(doc)
    if problems:
        print(f"INVALID trace ({len(problems)} problems):",
              file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    print(f"trace: {args.trace}")
    print(summarize(doc))
    print(f"trace OK: {args.trace} is a valid Chrome trace-event file")
    return 0


if __name__ == "__main__":
    sys.exit(main())
