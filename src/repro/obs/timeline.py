"""Chrome-trace-event (Perfetto-loadable) export of a recorded trace.

Converts :class:`~repro.obs.events.TraceEvent` streams into the JSON
object format chrome://tracing and https://ui.perfetto.dev load
directly (see ``docs/observability.md`` for the walkthrough):

* one **process** (pid) per (segment, replica) pair — each benchmark
  arm gets its own process group, each replica its own track set, plus
  a cluster-scope track for front-door events;
* one **thread** (tid) per request inside its replica's process, so a
  request's lifetime renders as a horizontal slice;
* ``X`` complete slices: request lifetime (arrive -> complete/shed)
  and, when a TTFT anchor exists, the decode span (first_token ->
  complete);
* ``i`` instants: shed / steal / preempt / prefix_evict / scale /
  fail / repair markers;
* ``s``/``f`` flow pairs: P/D KV handoffs and stolen-work
  re-transfers draw arrows from source to destination replica;
* ``C`` counters: gauge events (queue depth per tier, slot occupancy,
  free pages, ...) render as counter tracks.

Timestamps are micro­seconds (the format's unit); the simulation's
seconds are scaled by 1e6. :func:`validate_chrome_trace` checks the
structural contract (required keys, non-negative durations, per-track
monotone ``ts``, balanced flows) and is what the CI smoke step and the
report CLI run against every exported file.
"""

from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence, Tuple

from . import events as ev

_US = 1_000_000.0   # trace-event ts unit: microseconds

#: per-request event kinds rendered as instant markers
_REQ_INSTANTS = (ev.SHED, ev.STEAL, ev.PREEMPT, ev.PREFIX_HIT,
                 ev.PREFIX_MISS, ev.HANDOFF)
#: scope-level kinds rendered as instant markers on the track's row 0
_SCOPE_INSTANTS = (ev.SCALE_UP, ev.SCALE_DOWN, ev.REPLICA_FAIL,
                   ev.REPLICA_RECOVER, ev.WORKER_FAIL, ev.WORKER_REPAIR,
                   ev.PREFIX_EVICT)


class _Tracks:
    """pid registry: (seg, rid) -> pid, with process_name metadata."""

    def __init__(self, segments: Sequence[str]) -> None:
        self._pids: Dict[Tuple[int, Optional[int]], int] = {}
        self._segments = list(segments)
        self.metadata: List[dict] = []

    def pid(self, seg: int, rid: Optional[int]) -> int:
        key = (seg, rid)
        if key not in self._pids:
            pid = len(self._pids) + 1
            self._pids[key] = pid
            label = (self._segments[seg - 1]
                     if 1 <= seg <= len(self._segments) else f"seg{seg}")
            where = "cluster" if rid is None else f"replica{rid}"
            self.metadata.append({
                "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                "ts": 0, "args": {"name": f"{label}/{where}"}})
            # replica tracks after the cluster track, stable within a
            # segment: sort_index mirrors rid
            self.metadata.append({
                "name": "process_sort_index", "ph": "M", "pid": pid,
                "tid": 0, "ts": 0,
                "args": {"sort_index": seg * 1000
                         + (-1 if rid is None else rid)}})
        return self._pids[key]


def to_chrome_trace(events: Sequence, *,
                    recorder_stats: Optional[dict] = None) -> dict:
    """Build the Chrome trace-event JSON object for ``events``
    (oldest-first :class:`TraceEvent` list, e.g. ``recorder.events()``)."""
    segments = (recorder_stats or {}).get("segments", [])
    tracks = _Tracks(segments)
    out: List[dict] = []
    flow_seq = 0

    # group per (seg, req_id) to build lifetime/decode slices and
    # pair handoff flows
    chains: Dict[Tuple[int, int], List] = {}
    for e in events:
        if e.req_id is not None:
            chains.setdefault((e.seg, e.req_id), []).append(e)

    for (seg, req_id), chain in chains.items():
        # the request's home track: where it last executed
        rid = next((e.rid for e in reversed(chain) if e.rid is not None),
                   None)
        pid = tracks.pid(seg, rid)
        first, last = chain[0], chain[-1]
        terminal = last.kind in (ev.COMPLETE, ev.SHED)
        if terminal and last.ts >= first.ts:
            args = {"kind": "lifetime", "tenant": first.tenant
                    or last.tenant or "?"}
            for k in ("observed", "e2e", "ttft", "reason"):
                if k in last.data and last.data[k] is not None:
                    args[k] = last.data[k]
            out.append({
                "name": f"req {req_id} ({args['tenant']})",
                "cat": "request", "ph": "X",
                "ts": first.ts * _US,
                "dur": max(last.ts - first.ts, 0.0) * _US,
                "pid": pid, "tid": req_id, "args": args})
        ft = next((e for e in chain if e.kind == ev.FIRST_TOKEN), None)
        if ft is not None and terminal and last.kind == ev.COMPLETE:
            out.append({
                "name": "decode", "cat": "phase", "ph": "X",
                "ts": ft.ts * _US,
                "dur": max(last.ts - ft.ts, 0.0) * _US,
                "pid": tracks.pid(seg, ft.rid if ft.rid is not None
                                  else rid),
                "tid": req_id, "args": {}})
        # flows: each handoff 'out' pairs with the next 'in'
        pending_out = None
        for e in chain:
            if e.kind != ev.HANDOFF:
                continue
            edge = e.data.get("edge")
            if edge == "out":
                pending_out = e
            elif edge == "in" and pending_out is not None:
                flow_seq += 1
                base = {"name": "handoff", "cat": "kv_transfer",
                        "id": flow_seq}
                out.append(dict(base, ph="s",
                                ts=pending_out.ts * _US,
                                pid=tracks.pid(seg, pending_out.rid),
                                tid=req_id))
                out.append(dict(base, ph="f", bp="e", ts=e.ts * _US,
                                pid=tracks.pid(seg, e.rid), tid=req_id))
                pending_out = None

    for e in events:
        pid = tracks.pid(e.seg, e.rid)
        ts = e.ts * _US
        if e.kind == ev.GAUGE:
            out.append({"name": e.data["name"], "cat": "gauge",
                        "ph": "C", "ts": ts, "pid": pid, "tid": 0,
                        "args": {"value": e.data["value"]}})
        elif e.req_id is not None and e.kind in _REQ_INSTANTS:
            out.append({"name": e.kind, "cat": "marker", "ph": "i",
                        "s": "t", "ts": ts, "pid": pid,
                        "tid": e.req_id, "args": dict(e.data)})
        elif e.req_id is None and e.kind in _SCOPE_INSTANTS:
            out.append({"name": e.kind, "cat": "marker", "ph": "i",
                        "s": "p", "ts": ts, "pid": pid, "tid": 0,
                        "args": dict(e.data)})

    out.sort(key=lambda d: (d["ts"], d["pid"], d["tid"]))
    doc = {"traceEvents": tracks.metadata + out,
           "displayTimeUnit": "ms"}
    if recorder_stats is not None:
        doc["otherData"] = {"recorder": recorder_stats}
    return doc


def write_chrome_trace(path: str, events: Sequence, *,
                       recorder_stats: Optional[dict] = None) -> dict:
    """Export ``events`` to ``path`` and return the written document.
    ``allow_nan=False`` makes any non-finite payload a loud error —
    a trace file that Perfetto rejects must never be written quietly."""
    doc = to_chrome_trace(events, recorder_stats=recorder_stats)
    with open(path, "w") as f:
        json.dump(doc, f, indent=None, separators=(",", ":"),
                  allow_nan=False)
    return doc


# --- structural validation ---------------------------------------------
_REQUIRED_KEYS = ("name", "ph", "ts", "pid", "tid")


def validate_chrome_trace(doc: dict, *, max_problems: int = 20) -> List[str]:
    """Check a trace document against the Chrome trace-event contract:
    required keys on every event, numeric non-negative ``dur`` on X
    slices, monotone ``ts`` per (pid, tid) track, balanced s/f flow
    pairs. Returns human-readable problems (empty = valid)."""
    problems: List[str] = []

    def bad(msg: str) -> None:
        if len(problems) < max_problems:
            problems.append(msg)

    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents missing or not a list"]
    last_ts: Dict[Tuple[int, int], float] = {}
    flows: Dict[object, int] = {}
    for i, e in enumerate(evs):
        if not isinstance(e, dict):
            bad(f"event {i} is not an object")
            continue
        missing = [k for k in _REQUIRED_KEYS if k not in e]
        if missing:
            bad(f"event {i} ({e.get('name')!r}) missing keys {missing}")
            continue
        if not isinstance(e["ts"], (int, float)):
            bad(f"event {i} ts is not numeric")
            continue
        ph = e["ph"]
        if ph == "M":
            continue
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                bad(f"event {i} ({e['name']!r}) X slice with bad dur "
                    f"{dur!r}")
        if ph == "s":
            flows[e.get("id")] = flows.get(e.get("id"), 0) + 1
        elif ph == "f":
            flows[e.get("id")] = flows.get(e.get("id"), 0) - 1
        key = (e["pid"], e["tid"])
        if e["ts"] < last_ts.get(key, float("-inf")):
            bad(f"event {i} ({e['name']!r}) ts {e['ts']} regressed on "
                f"track pid={e['pid']} tid={e['tid']}")
        last_ts[key] = e["ts"]
    unbalanced = {k: v for k, v in flows.items() if v != 0}
    if unbalanced:
        bad(f"unbalanced flow pairs (id -> s minus f): {unbalanced}")
    return problems
