"""Per-tenant-tier SLO targets + multi-window burn-rate monitors.

SRE-style burn-rate alerting over the trace stream: each tenant tier
declares latency targets (:class:`SloTarget` — TTFT and e2e thresholds
plus an attainment fraction), and :class:`SloMonitor` watches
``complete`` events through two (configurable) trailing windows.

**Burn rate** = (fraction of requests violating the threshold inside
the window) / (error budget), where error budget = 1 - attainment.
Burn 1.0 means the tier is consuming its budget exactly as fast as the
SLO allows; 6.0 means six times too fast. A tier's state is:

* ``page`` — *every* window burns >= ``page_burn`` (the classic
  multi-window AND: the short window proves it's happening *now*, the
  long window proves it's not a blip);
* ``warn`` — every window burns >= ``warn_burn``;
* ``ok``   — otherwise (including "no data yet": an idle tier has
  burned nothing).

This PR is report-only: :meth:`SloMonitor.status` is a pure probe the
router/admission *may* consume later (ROADMAP items 3/5); nothing here
mutates scheduling state. Timestamps are simulated seconds, same
clock as the rest of the trace.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Mapping, Optional, Sequence, Tuple

from . import events as ev
from .series import SlidingWindow

#: monitored latency metrics (keys into SloTarget thresholds)
METRICS = ("ttft", "e2e")


@dataclass(frozen=True)
class SloTarget:
    """Latency thresholds (simulated seconds) + attainment fraction:
    "``attainment`` of requests must see ttft <= ``ttft`` and e2e <=
    ``e2e``"."""

    ttft: float
    e2e: float
    attainment: float = 0.95

    def __post_init__(self) -> None:
        if not 0.0 < self.attainment < 1.0:
            raise ValueError(
                f"attainment must be in (0, 1), got {self.attainment}")

    def threshold(self, metric: str) -> float:
        if metric == "ttft":
            return self.ttft
        if metric == "e2e":
            return self.e2e
        raise ValueError(f"unknown SLO metric {metric!r}")

    @property
    def error_budget(self) -> float:
        return 1.0 - self.attainment


#: illustrative per-tier defaults for the L4-calibrated simulations —
#: premium pays for tight first-token + completion bounds, batch only
#: for eventual completion. Override per experiment.
DEFAULT_TARGETS: Dict[str, SloTarget] = {
    "premium": SloTarget(ttft=2.0, e2e=60.0, attainment=0.95),
    "standard": SloTarget(ttft=5.0, e2e=120.0, attainment=0.90),
    "batch": SloTarget(ttft=30.0, e2e=600.0, attainment=0.80),
}


class _MetricWindow:
    """Violation bookkeeping for one (tier, metric) over the trailing
    windows: total observations + violations per window."""

    def __init__(self, windows: Sequence[float]) -> None:
        self.seen = {w: SlidingWindow(w) for w in windows}
        self.violated = {w: SlidingWindow(w) for w in windows}

    def observe(self, ts: float, value: float, threshold: float) -> None:
        for w in self.seen.values():
            w.add(ts)
        if value > threshold:
            for w in self.violated.values():
                w.add(ts)

    def violation_fraction(self, window: float, now: float) -> float:
        n = self.seen[window].count(now)
        if n == 0:
            return 0.0
        return self.violated[window].count(now) / n


class SloMonitor:
    """Multi-window burn-rate monitor; attach as a recorder observer.

    Consumes ``complete`` events (their ``ttft`` / ``e2e`` payloads);
    requests with no TTFT anchor (atomic-batch runs) simply don't
    feed the ttft metric. ``windows`` are trailing spans in simulated
    seconds, shortest first by convention.
    """

    def __init__(self, targets: Optional[Mapping[str, SloTarget]] = None,
                 windows: Tuple[float, float] = (60.0, 600.0),
                 warn_burn: float = 1.0, page_burn: float = 6.0) -> None:
        if not windows:
            raise ValueError("need at least one window")
        self.targets = dict(targets if targets is not None
                            else DEFAULT_TARGETS)
        self.windows = tuple(windows)
        self.warn_burn = warn_burn
        self.page_burn = page_burn
        self._state: Dict[tuple, _MetricWindow] = {}
        self.last_ts = 0.0

    # ------------------------------------------------------------------
    def on_event(self, event) -> None:
        if event.ts > self.last_ts:
            self.last_ts = event.ts
        if event.kind != ev.COMPLETE or event.tenant is None:
            return
        self.observe(event.tenant, event.ts,
                     ttft=event.data.get("ttft"),
                     e2e=event.data.get("e2e"))

    def observe(self, tier: str, ts: float, *,
                ttft: Optional[float] = None,
                e2e: Optional[float] = None) -> None:
        target = self.targets.get(tier)
        if target is None:
            return
        for metric, value in (("ttft", ttft), ("e2e", e2e)):
            if value is None:
                continue
            key = (tier, metric)
            mw = self._state.get(key)
            if mw is None:
                mw = self._state[key] = _MetricWindow(self.windows)
            mw.observe(ts, value, target.threshold(metric))

    # ------------------------------------------------------------------
    def burn_rates(self, tier: str, metric: str,
                   now: Optional[float] = None) -> Dict[float, float]:
        """window -> burn rate (violation fraction / error budget);
        zeros when the tier/metric has no observations."""
        now = self.last_ts if now is None else now
        target = self.targets[tier]
        mw = self._state.get((tier, metric))
        if mw is None:
            return {w: 0.0 for w in self.windows}
        budget = max(target.error_budget, 1e-9)
        return {w: mw.violation_fraction(w, now) / budget
                for w in self.windows}

    def _verdict(self, burns: Dict[float, float]) -> str:
        vals = list(burns.values())
        if vals and all(b >= self.page_burn for b in vals):
            return "page"
        if vals and all(b >= self.warn_burn for b in vals):
            return "warn"
        return "ok"

    def status(self, now: Optional[float] = None) -> dict:
        """Pure probe: per-tier, per-metric burn rates + verdicts, plus
        a per-tier rollup (worst metric wins). JSON-ready."""
        now = self.last_ts if now is None else now
        rank = {"ok": 0, "warn": 1, "page": 2}
        out: dict = {}
        for tier in self.targets:
            metrics = {}
            worst = "ok"
            for metric in METRICS:
                burns = self.burn_rates(tier, metric, now)
                verdict = self._verdict(burns)
                mw = self._state.get((tier, metric))
                metrics[metric] = {
                    "burn": {f"{int(w)}s": b for w, b in burns.items()},
                    "state": verdict,
                    "n": (mw.seen[self.windows[0]].count(now)
                          if mw is not None else 0),
                }
                if rank[verdict] > rank[worst]:
                    worst = verdict
            out[tier] = {"state": worst, "metrics": metrics}
        return out
