"""Observability: lifecycle tracing, streaming telemetry, SLO monitors.

The instrument layer every execution surface emits into (DESIGN: the
paper's central claim is that *runtime observation* should drive
scheduling; this package is what makes runtime state observable):

* :mod:`events`   — typed lifecycle events + the bounded ring-buffer
  :class:`TraceRecorder` (counter-strided sampling, zero-overhead
  :class:`NullRecorder` default);
* :mod:`stats`    — exact percentile/Jain/LatencyStats helpers shared
  with ``serving.metrics`` (which re-exports them);
* :mod:`series`   — streaming windowed aggregates (P² quantiles,
  sliding-window rates, gauges);
* :mod:`slo`      — per-tenant-tier SLO targets with multi-window
  burn-rate monitors (report-only probes);
* :mod:`timeline` — Chrome-trace-event (Perfetto) export + structural
  validation;
* :mod:`report`   — ``python -m repro.obs.report`` trace summary CLI.

**Recorder plumbing.** Components accept an explicit ``trace=``
recorder; when omitted they resolve the process-global recorder at
construction time (:func:`get_recorder`, default the no-op
:data:`NULL_RECORDER`). ``benchmarks/run.py --trace`` installs a live
recorder via :func:`set_recorder` before any benchmark constructs a
simulator/engine, which is how a whole benchmark run gets traced
without threading a parameter through every layer.

**Determinism.** Tracing never touches a simulation RNG (sampling is
counter-strided) and never changes control flow, so traced runs are
bit-identical to untraced runs on the same seed — locked by
``tests/test_obs.py``.
"""

from __future__ import annotations

from .events import (DEFAULT_SAMPLE_EVERY, EVENT_KINDS, NULL_RECORDER,
                     NullRecorder, TraceEvent, TraceRecorder,
                     validate_lifecycles)
from .series import P2Quantile, SeriesBank, SlidingWindow, StreamSummary
from .slo import DEFAULT_TARGETS, SloMonitor, SloTarget
from .stats import LatencyStats, jain_index, percentile
from .timeline import (to_chrome_trace, validate_chrome_trace,
                       write_chrome_trace)

_active = NULL_RECORDER


def get_recorder():
    """The process-global recorder (the no-op sentinel by default)."""
    return _active


def set_recorder(recorder):
    """Install ``recorder`` as the process-global default (None resets
    to the no-op sentinel). Returns the installed recorder. Components
    resolve the global at *construction* time — install before building
    the simulators/engines that should emit into it."""
    global _active
    _active = recorder if recorder is not None else NULL_RECORDER
    return _active


def resolve_recorder(trace):
    """Constructor helper: an explicit recorder wins; None falls back
    to the process-global one."""
    return trace if trace is not None else _active


__all__ = [
    "DEFAULT_SAMPLE_EVERY", "DEFAULT_TARGETS", "EVENT_KINDS",
    "LatencyStats", "NULL_RECORDER", "NullRecorder", "P2Quantile",
    "SeriesBank", "SlidingWindow", "SloMonitor", "SloTarget",
    "StreamSummary", "TraceEvent", "TraceRecorder", "get_recorder",
    "jain_index", "percentile", "resolve_recorder", "set_recorder",
    "to_chrome_trace", "validate_chrome_trace", "validate_lifecycles",
    "write_chrome_trace",
]
