"""Streaming windowed telemetry: P² quantiles, sliding windows, gauges.

The online counterpart of the exact end-of-run aggregation in
:mod:`repro.serving.metrics`: everything here is incremental (O(1)
memory per stream), which is what a feedback controller — proactive
scaling, SLO-aware admission — can actually consume *during* a run.

* :class:`P2Quantile` — Jain & Chlamtac's P² algorithm (1985): one
  streaming quantile from five markers, no sample storage. Exact for
  n <= 5 (falls back to linear interpolation over the stored seed
  values); for larger n the classic parabolic marker update applies.
  Accuracy is distribution-dependent; on the unimodal latency
  distributions here the estimate tracks the exact percentile to
  within a few percent of the sample range (bounds locked by
  ``tests/test_obs.py``, documented in ``docs/observability.md``).
* :class:`StreamSummary` — n / mean / min / max + P² p50/p95/p99 for
  one latency metric; ``as_dict()`` mirrors ``LatencyStats`` keys.
* :class:`SlidingWindow` — time-windowed (ts, value) pairs with O(1)
  amortised trim; rate / mean / sum over the trailing window.
* :class:`SeriesBank` — a :class:`~repro.obs.events.TraceRecorder`
  observer wiring trace events into the above: TTFT / e2e /
  inter-token streams, drift MAE window, prefix hit-rate window,
  arrival & shed rates, and last-value gauges (queue depth per tier,
  slot occupancy, free pages, ...).
"""

from __future__ import annotations

import math
from collections import deque
from typing import Dict, List, Optional

from . import events as ev
from .stats import percentile


class P2Quantile:
    """Single streaming quantile via the P² algorithm.

    Five markers track (min, p/2, p, (1+p)/2, max); marker heights
    adjust by a piecewise-parabolic prediction as observations arrive.
    ``add`` is O(1); ``value`` is O(1) after the first five samples.
    """

    def __init__(self, p: float) -> None:
        if not 0.0 < p < 1.0:
            raise ValueError(f"quantile must be in (0, 1), got {p}")
        self.p = p
        self.n = 0
        self._seed: List[float] = []       # first five observations
        self._q: List[float] = []          # marker heights
        self._pos: List[float] = []        # marker positions (1-based)
        self._want: List[float] = []       # desired positions
        self._dn = [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0]

    def add(self, x: float) -> None:
        self.n += 1
        if self.n <= 5:
            self._seed.append(x)
            if self.n == 5:
                self._seed.sort()
                self._q = list(self._seed)
                self._pos = [1.0, 2.0, 3.0, 4.0, 5.0]
                self._want = [1.0 + 4.0 * d for d in self._dn]
            return
        q, pos = self._q, self._pos
        # cell k: which marker interval x falls in; extremes clamp
        if x < q[0]:
            q[0] = x
            k = 0
        elif x >= q[4]:
            q[4] = x
            k = 3
        else:
            k = 0
            while k < 3 and x >= q[k + 1]:
                k += 1
        for i in range(k + 1, 5):
            pos[i] += 1.0
        for i in range(5):
            self._want[i] += self._dn[i]
        # adjust interior markers toward their desired positions
        for i in (1, 2, 3):
            d = self._want[i] - pos[i]
            if (d >= 1.0 and pos[i + 1] - pos[i] > 1.0) or \
                    (d <= -1.0 and pos[i - 1] - pos[i] < -1.0):
                sign = 1.0 if d >= 1.0 else -1.0
                cand = self._parabolic(i, sign)
                if q[i - 1] < cand < q[i + 1]:
                    q[i] = cand
                else:
                    q[i] = self._linear(i, sign)
                pos[i] += sign

    def _parabolic(self, i: int, d: float) -> float:
        q, n = self._q, self._pos
        return q[i] + d / (n[i + 1] - n[i - 1]) * (
            (n[i] - n[i - 1] + d) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
            + (n[i + 1] - n[i] - d) * (q[i] - q[i - 1]) / (n[i] - n[i - 1]))

    def _linear(self, i: int, d: float) -> float:
        q, n = self._q, self._pos
        j = i + int(d)
        return q[i] + d * (q[j] - q[i]) / (n[j] - n[i])

    def value(self) -> float:
        """Current estimate (NaN when empty; exact for n <= 5)."""
        if self.n == 0:
            return float("nan")
        if self.n <= 5:
            return percentile(self._seed, self.p * 100.0)
        return self._q[2]


class StreamSummary:
    """Streaming n/mean/min/max + P² p50/p95/p99 for one metric."""

    QUANTILES = (0.50, 0.95, 0.99)

    def __init__(self) -> None:
        self.n = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._q = {p: P2Quantile(p) for p in self.QUANTILES}

    def add(self, x: float) -> None:
        self.n += 1
        self.total += x
        self.min = min(self.min, x)
        self.max = max(self.max, x)
        for q in self._q.values():
            q.add(x)

    def quantile(self, p: float) -> float:
        return self._q[p].value()

    def as_dict(self) -> dict:
        if self.n == 0:
            return {"n": 0, "mean": float("nan"), "p50": float("nan"),
                    "p95": float("nan"), "p99": float("nan")}
        return {"n": self.n, "mean": self.total / self.n,
                "p50": self._q[0.50].value(),
                "p95": self._q[0.95].value(),
                "p99": self._q[0.99].value(),
                "min": self.min, "max": self.max}


class SlidingWindow:
    """(ts, value) pairs over a trailing time window."""

    def __init__(self, window: float) -> None:
        if window <= 0:
            raise ValueError(f"window must be > 0, got {window}")
        self.window = window
        self._buf: deque = deque()
        self._sum = 0.0

    def add(self, ts: float, value: float = 1.0) -> None:
        self._buf.append((ts, value))
        self._sum += value
        self.trim(ts)

    def trim(self, now: float) -> None:
        cutoff = now - self.window
        buf = self._buf
        while buf and buf[0][0] < cutoff:
            self._sum -= buf.popleft()[1]

    def count(self, now: float) -> int:
        self.trim(now)
        return len(self._buf)

    def sum(self, now: float) -> float:
        self.trim(now)
        return self._sum

    def mean(self, now: float) -> float:
        self.trim(now)
        return self._sum / len(self._buf) if self._buf else float("nan")

    def rate(self, now: float) -> float:
        """Events per second over the trailing window."""
        return self.count(now) / self.window


class SeriesBank:
    """Recorder observer: trace events -> streaming aggregates.

    Attach via ``TraceRecorder(observers=[bank])`` (or
    ``add_observer``); observers see every emission pre-sampling, so
    these aggregates are exact regardless of ring thinning.
    """

    def __init__(self, window: float = 60.0) -> None:
        self.window = window
        self.ttft = StreamSummary()
        self.e2e = StreamSummary()
        self.inter_token = StreamSummary()
        self.drift_abs_error = SlidingWindow(window)   # -> windowed MAE
        self.prefix_hits = SlidingWindow(window)
        self.prefix_misses = SlidingWindow(window)
        self.arrivals = SlidingWindow(window)
        self.sheds = SlidingWindow(window)
        self.completions = SlidingWindow(window)
        # gauge name -> (ts, last value); per-tier queue depth, slot
        # occupancy, free pages etc. arrive through GAUGE events
        self.gauges: Dict[str, tuple] = {}
        self.last_ts = 0.0

    def on_event(self, event) -> None:
        k = event.kind
        ts = event.ts
        if ts > self.last_ts:
            self.last_ts = ts
        if k == ev.COMPLETE:
            d = event.data
            if d.get("e2e") is not None:
                self.e2e.add(d["e2e"])
            if d.get("ttft") is not None:
                self.ttft.add(d["ttft"])
            if d.get("inter_token") is not None:
                self.inter_token.add(d["inter_token"])
            self.completions.add(ts)
        elif k == ev.ARRIVE:
            self.arrivals.add(ts)
        elif k == ev.SHED:
            self.sheds.add(ts)
        elif k == ev.DRIFT:
            self.drift_abs_error.add(ts, event.data.get("abs_error", 0.0))
        elif k == ev.PREFIX_HIT:
            self.prefix_hits.add(ts)
        elif k == ev.PREFIX_MISS:
            self.prefix_misses.add(ts)
        elif k == ev.GAUGE:
            self.gauges[event.data["name"]] = (ts, event.data["value"])

    # ------------------------------------------------------------------
    def prefix_hit_rate(self, now: Optional[float] = None) -> float:
        now = self.last_ts if now is None else now
        h = self.prefix_hits.count(now)
        m = self.prefix_misses.count(now)
        return h / (h + m) if h + m else float("nan")

    def drift_mae(self, now: Optional[float] = None) -> float:
        now = self.last_ts if now is None else now
        return self.drift_abs_error.mean(now)

    def snapshot(self, now: Optional[float] = None) -> dict:
        """Point-in-time view of every stream (JSON-ready; NaN for
        empty streams — sanitized to null by the benchmark writer)."""
        now = self.last_ts if now is None else now
        return {
            "ts": now,
            "window_s": self.window,
            "ttft": self.ttft.as_dict(),
            "e2e": self.e2e.as_dict(),
            "inter_token": self.inter_token.as_dict(),
            "windowed": {
                "arrival_rate": self.arrivals.rate(now),
                "shed_rate": self.sheds.rate(now),
                "completion_rate": self.completions.rate(now),
                "drift_mae": self.drift_mae(now),
                "prefix_hit_rate": self.prefix_hit_rate(now),
            },
            "gauges": {name: {"ts": t, "value": v}
                       for name, (t, v) in sorted(self.gauges.items())},
        }
