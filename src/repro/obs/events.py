"""Typed request-lifecycle trace events + the bounded ring recorder.

The observability substrate every execution layer emits into:

* :class:`TraceEvent` — one timestamped, typed event (``kind`` from
  :data:`EVENT_KINDS`), optionally bound to a request / replica /
  tenant, carrying a small ``data`` payload.
* :class:`TraceRecorder` — bounded ring buffer with per-kind stride
  sampling. Sampling is **counter-based** (every Nth emission of a
  kind), never RNG-based: tracing must not touch any simulation RNG,
  which is what keeps traced runs bit-identical to untraced ones.
  Observers (:class:`~repro.obs.series.SeriesBank`,
  :class:`~repro.obs.slo.SloMonitor`) see every emission *before*
  sampling, so streaming aggregates are exact even when the ring keeps
  only every 32nd ``decode_step``.
* :class:`NullRecorder` — the zero-overhead default. Its class-level
  ``enabled = False`` is the single attribute check hot paths pay when
  tracing is off (``if self.trace.enabled: ...``).

Emission ordering contract: components emit events in causal order at
the simulated timestamp they happen, so for any one request the event
sequence is non-decreasing in ``ts`` and :func:`validate_lifecycles`
can check chains without re-sorting.
"""

from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence

# --- event taxonomy ----------------------------------------------------
ARRIVE = "arrive"                 # request hits the front door
ADMIT = "admit"                   # admission accepted (est priced)
SHED = "shed"                     # admission rejected (data: reason)
ROUTE = "route"                   # router placed it (data: stage)
PREFILL_CHUNK = "prefill_chunk"   # one prompt chunk consumed (data: tokens)
FIRST_TOKEN = "first_token"       # honest TTFT anchor (data: ttft)
DECODE_STEP = "decode_step"       # one decode token (ring-sampled)
HANDOFF = "handoff"               # P/D KV transfer (data: edge=out|in)
STEAL = "steal"                   # work stealing moved it (victim/thief)
PREFIX_HIT = "prefix_hit"         # joined with resident prefix pages
PREFIX_MISS = "prefix_miss"       # shareable prefix, nothing resident
PREFIX_EVICT = "prefix_evict"     # LRU eviction freed pages (data: pages)
PREEMPT = "preempt"               # failure aborted in-flight work
COMPLETE = "complete"             # retired (data: observed, e2e, ttft)
SCALE_UP = "scale_up"             # autoscaler decision
SCALE_DOWN = "scale_down"
REPLICA_FAIL = "replica_fail"     # whole replica left the pool
REPLICA_RECOVER = "replica_recover"
WORKER_FAIL = "worker_fail"       # one worker inside a replica died
WORKER_REPAIR = "worker_repair"
DRIFT = "drift"                   # drift sample (data: abs_error, phase)
GAUGE = "gauge"                   # sampled scalar (data: name, value)

EVENT_KINDS = frozenset({
    ARRIVE, ADMIT, SHED, ROUTE, PREFILL_CHUNK, FIRST_TOKEN, DECODE_STEP,
    HANDOFF, STEAL, PREFIX_HIT, PREFIX_MISS, PREFIX_EVICT, PREEMPT,
    COMPLETE, SCALE_UP, SCALE_DOWN, REPLICA_FAIL, REPLICA_RECOVER,
    WORKER_FAIL, WORKER_REPAIR, DRIFT, GAUGE,
})

#: kinds that fire once per decoded token / control tick — the only
#: ones worth thinning by default. Everything else records 1:1.
DEFAULT_SAMPLE_EVERY: Dict[str, int] = {DECODE_STEP: 32, GAUGE: 8}


@dataclass
class TraceEvent:
    """One recorded lifecycle event. ``seq`` is the global emission
    index (pre-sampling, so gaps reveal what the ring thinned out);
    ``seg`` groups events by run segment (see
    :meth:`TraceRecorder.begin_segment`)."""

    seq: int
    ts: float
    kind: str
    req_id: Optional[int] = None
    rid: Optional[int] = None      # replica id (None = cluster scope)
    tenant: Optional[str] = None   # tenant tier label
    seg: int = 0
    data: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        out = {"seq": self.seq, "ts": self.ts, "kind": self.kind,
               "seg": self.seg}
        if self.req_id is not None:
            out["req_id"] = self.req_id
        if self.rid is not None:
            out["rid"] = self.rid
        if self.tenant is not None:
            out["tenant"] = self.tenant
        if self.data:
            out["data"] = dict(self.data)
        return out


class NullRecorder:
    """Tracing-off sentinel: hot paths check ``enabled`` once and skip
    every emission. All methods are harmless no-ops so accidental calls
    on the sentinel cannot crash an untraced run."""

    enabled = False

    def emit(self, ts: float, kind: str, **kw) -> None:
        pass

    def begin_segment(self, label: str) -> int:
        return 0

    def add_observer(self, observer) -> None:
        pass

    def events(self) -> List[TraceEvent]:
        return []

    def stats(self) -> dict:
        return {"emitted": 0, "recorded": 0, "dropped_overflow": 0,
                "by_kind": {}, "sample_every": {}, "segments": []}


NULL_RECORDER = NullRecorder()


class TraceRecorder:
    """Bounded ring of :class:`TraceEvent` with per-kind stride sampling.

    ``capacity`` bounds memory (oldest events drop first);
    ``sample_every`` maps kind -> stride N (record every Nth emission,
    deterministic counter — the first emission of a kind always
    records). Kinds absent from the map record 1:1; pass explicit ``1``
    strides to force full fidelity for the thinned defaults
    (:data:`DEFAULT_SAMPLE_EVERY`).

    Observers receive *every* emission (pre-sampling) via
    ``observer.on_event(ev)`` — streaming aggregates must not be
    subject to ring thinning or overflow.
    """

    enabled = True

    def __init__(self, capacity: int = 500_000,
                 sample_every: Optional[Dict[str, int]] = None,
                 observers: Iterable = ()) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.sample_every = dict(DEFAULT_SAMPLE_EVERY)
        if sample_every:
            for k, n in sample_every.items():
                if k not in EVENT_KINDS:
                    raise ValueError(f"unknown event kind {k!r}")
                if n < 1:
                    raise ValueError(f"sample_every[{k!r}] must be >= 1")
                self.sample_every[k] = int(n)
        self._ring: deque = deque(maxlen=capacity)
        self._observers: List = list(observers)
        self._seq = itertools.count()
        self._emitted: Dict[str, int] = {}
        self._recorded: Dict[str, int] = {}
        self._seg = 0
        self._segments: List[str] = []
        self.last_ts = 0.0

    # ------------------------------------------------------------------
    def add_observer(self, observer) -> None:
        self._observers.append(observer)

    def begin_segment(self, label: str) -> int:
        """Start a new run segment (one benchmark arm / one ``run()``).
        Events emitted afterwards carry the new segment index, which
        the timeline exporter maps to separate Perfetto process
        groups so sequential runs don't interleave on one track."""
        self._seg += 1
        self._segments.append(label)
        return self._seg

    def emit(self, ts: float, kind: str, *, req_id: Optional[int] = None,
             rid: Optional[int] = None, tenant: Optional[str] = None,
             **data) -> None:
        """Record one event (subject to per-kind stride sampling);
        observers always see it first."""
        if kind not in EVENT_KINDS:
            raise ValueError(f"unknown event kind {kind!r}")
        ev = TraceEvent(seq=next(self._seq), ts=ts, kind=kind,
                        req_id=req_id, rid=rid, tenant=tenant,
                        seg=self._seg, data=data)
        if ts > self.last_ts:
            self.last_ts = ts
        for ob in self._observers:
            ob.on_event(ev)
        n = self._emitted.get(kind, 0)
        self._emitted[kind] = n + 1
        stride = self.sample_every.get(kind, 1)
        if n % stride == 0:
            self._ring.append(ev)
            self._recorded[kind] = self._recorded.get(kind, 0) + 1

    # ------------------------------------------------------------------
    def events(self) -> List[TraceEvent]:
        """Ring contents, oldest first (post-sampling, post-overflow)."""
        return list(self._ring)

    def stats(self) -> dict:
        emitted = sum(self._emitted.values())
        recorded = sum(self._recorded.values())
        return {
            "emitted": emitted,
            "recorded": recorded,
            # sampled-in events the ring later overwrote (capacity)
            "dropped_overflow": recorded - len(self._ring),
            "by_kind": dict(sorted(self._emitted.items())),
            "sample_every": dict(self.sample_every),
            "segments": list(self._segments),
        }


# --- lifecycle validation ---------------------------------------------
#: kinds that terminate a request's chain
_TERMINAL = (COMPLETE, SHED)
#: per-request kinds that may only appear between admit and terminal
_EXEC_KINDS = (PREFILL_CHUNK, FIRST_TOKEN, DECODE_STEP, PREFIX_HIT,
               PREFIX_MISS)


def validate_lifecycles(events: Sequence[TraceEvent], *,
                        require_route: Optional[bool] = None,
                        require_terminal: bool = True) -> List[str]:
    """Check every request's event chain is a well-formed lifecycle.

    Returns a list of human-readable violations (empty = valid). The
    accepted grammar (events in emission order)::

        arrive -> [admit -> [route] -> exec*] -> (complete | shed)

    where ``exec*`` is any interleaving of prefill_chunk / first_token /
    decode_step / prefix_* / handoff / steal / preempt / route
    (reroutes), subject to:

    * the chain starts with ``arrive``;
    * nothing follows a terminal (``complete`` / ``shed``);
    * ``complete`` requires a prior ``admit``;
    * with ``require_route`` (default: auto — required iff any route
      event exists in the stream) a completed chain needs >= 1
      ``route`` before its first exec event;
    * ``first_token`` precedes ``complete``; ``prefill_chunk`` never
      follows ``first_token`` unless a ``preempt`` or ``handoff``
      intervened (re-prefill after failure is legal);
    * timestamps are non-decreasing along the chain.

    Run this against a full-fidelity recorder (stride-1 sampling, no
    ring overflow) — a thinned ring legitimately lacks links.
    ``require_terminal=False`` permits unterminated chains (runs
    stopped by ``max_time`` with work still queued).
    """
    chains: Dict[int, List[TraceEvent]] = {}
    any_route = False
    for ev in events:
        if ev.kind == ROUTE:
            any_route = True
        if ev.req_id is not None:
            chains.setdefault(ev.req_id, []).append(ev)
    if require_route is None:
        require_route = any_route

    problems: List[str] = []
    for req_id, chain in chains.items():
        kinds = [e.kind for e in chain]

        def bad(msg: str) -> None:
            problems.append(f"req {req_id}: {msg} (chain: {kinds})")

        if kinds[0] != ARRIVE:
            bad(f"chain starts with {kinds[0]!r}, not 'arrive'")
        for a, b in zip(chain, chain[1:]):
            if b.ts < a.ts:
                bad(f"ts regressed {a.ts} -> {b.ts} at {b.kind!r}")
                break
        terminals = [i for i, k in enumerate(kinds) if k in _TERMINAL]
        if not terminals:
            if require_terminal:
                bad("no terminal complete/shed")
            continue
        t = terminals[0]
        if len(terminals) > 1 or t != len(kinds) - 1:
            bad(f"events after terminal {kinds[t]!r}")
        if kinds[t] == COMPLETE:
            if ADMIT not in kinds[:t]:
                bad("complete without admit")
            exec_idx = [i for i, k in enumerate(kinds)
                        if k in _EXEC_KINDS]
            if require_route:
                first_route = kinds.index(ROUTE) if ROUTE in kinds else None
                if first_route is None:
                    bad("complete without route")
                elif exec_idx and first_route > exec_idx[0]:
                    bad("execution before first route")
            ft = [i for i, k in enumerate(kinds) if k == FIRST_TOKEN]
            for i, k in enumerate(kinds):
                if k == PREFILL_CHUNK and ft and i > ft[0]:
                    # legal only after a preempt/handoff reset re-ran
                    # prefill; otherwise the chain is out of order
                    between = kinds[ft[0]:i]
                    if PREEMPT not in between and HANDOFF not in between:
                        bad("prefill_chunk after first_token without "
                            "preempt/handoff")
                        break
    return problems
