"""Shared exact statistics helpers (percentiles, fairness, batches).

These used to live in ``repro.serving.metrics``; they are the exact
(store-everything) counterparts of the streaming estimators in
:mod:`repro.obs.series` and are shared by run-level metrics, cluster
metrics, and the trace report CLI. ``repro.serving.metrics`` re-exports
them so existing imports keep working.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence


def percentile(values: Sequence[float], p: float) -> float:
    """Linear-interpolation percentile (numpy 'linear' method)."""
    xs = sorted(values)
    if not xs:
        return float("nan")
    if len(xs) == 1:
        return xs[0]
    rank = (p / 100.0) * (len(xs) - 1)
    lo = int(math.floor(rank))
    hi = min(lo + 1, len(xs) - 1)
    frac = rank - lo
    return xs[lo] * (1 - frac) + xs[hi] * frac


def jain_index(values: Sequence[float]) -> float:
    xs = [v for v in values if v is not None]
    if not xs:
        return float("nan")
    s = sum(xs)
    s2 = sum(v * v for v in xs)
    return (s * s) / (len(xs) * s2) if s2 > 0 else 1.0


@dataclass
class LatencyStats:
    n: int = 0
    mean: float = float("nan")
    p50: float = float("nan")
    p95: float = float("nan")
    p99: float = float("nan")

    @classmethod
    def of(cls, values: Sequence[float]) -> "LatencyStats":
        vals = [v for v in values if v is not None]
        if not vals:
            return cls()
        return cls(n=len(vals), mean=sum(vals) / len(vals),
                   p50=percentile(vals, 50), p95=percentile(vals, 95),
                   p99=percentile(vals, 99))

    def as_dict(self) -> dict:
        return {"n": self.n, "mean": self.mean, "p50": self.p50,
                "p95": self.p95, "p99": self.p99}
