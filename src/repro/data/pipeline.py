"""Deterministic, shardable LM data pipelines.

Two sources:

* :class:`SyntheticLMDataset` — procedurally generated token streams
  with learnable structure (affine next-token rule mixed with repeated
  motifs). Loss visibly decreases within tens of steps, which makes the
  end-to-end training example / convergence tests meaningful without
  shipping a corpus.
* :class:`CorpusTextDataset` — byte-level tokenisation of the workload
  corpus prompts (the paper's own text), packed into fixed-length
  sequences.

Both are stateless-indexable: ``batch(step, rank, n_ranks)`` returns
the same arrays for the same coordinates — exactly what a restarted or
elastically re-scaled data-parallel trainer needs (no iterator state in
checkpoints; the step counter is the state).
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from ..models.config import ModelConfig


def _seed_for(tag: str, step: int, rank: int) -> int:
    h = hashlib.sha256(f"{tag}:{step}:{rank}".encode()).digest()
    return int.from_bytes(h[:8], "big") % (2**63)


@dataclass(frozen=True)
class SyntheticLMDataset:
    vocab: int
    seq_len: int
    batch_per_rank: int
    motif_len: int = 16
    tag: str = "synthetic-lm"

    def batch(self, step: int, rank: int = 0, n_ranks: int = 1) -> Dict:
        rng = np.random.default_rng(_seed_for(self.tag, step, rank))
        B, L, V = self.batch_per_rank, self.seq_len, self.vocab
        # affine progressions: x_{t+1} = (x_t + delta) % V, per row
        start = rng.integers(0, V, (B, 1))
        delta = rng.integers(1, 7, (B, 1))
        seq = (start + delta * np.arange(L + 1)[None, :]) % V
        # overwrite random windows with repeated motifs (copy task)
        motif = rng.integers(0, V, (B, self.motif_len))
        for b in range(B):
            at = rng.integers(0, max(L - 2 * self.motif_len, 1))
            seq[b, at:at + self.motif_len] = motif[b]
            seq[b, at + self.motif_len:at + 2 * self.motif_len] = motif[b]
        tokens = seq[:, :-1].astype(np.int32)
        labels = seq[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}


@dataclass(frozen=True)
class CorpusTextDataset:
    vocab: int
    seq_len: int
    batch_per_rank: int
    tag: str = "corpus-text"

    def _bytes(self) -> np.ndarray:
        from ..workload.corpus import build_corpus
        text = "\n".join(p.text for p in build_corpus().prompts)
        arr = np.frombuffer(text.encode(), dtype=np.uint8).astype(np.int32)
        return arr % self.vocab

    def batch(self, step: int, rank: int = 0, n_ranks: int = 1) -> Dict:
        stream = self._bytes()
        B, L = self.batch_per_rank, self.seq_len
        need = B * (L + 1)
        offset = (_seed_for(self.tag, step, rank) % max(
            len(stream) - need, 1))
        flat = np.take(stream, np.arange(offset, offset + need),
                       mode="wrap")
        seq = flat.reshape(B, L + 1)
        return {"tokens": seq[:, :-1].copy(), "labels": seq[:, 1:].copy()}


def make_dataset(name: str, cfg: ModelConfig, seq_len: int,
                 batch_per_rank: int):
    if name == "synthetic":
        return SyntheticLMDataset(cfg.vocab, seq_len, batch_per_rank)
    if name == "corpus":
        return CorpusTextDataset(cfg.vocab, seq_len, batch_per_rank)
    raise ValueError(f"unknown dataset {name!r}")


def attach_modality_stubs(cfg: ModelConfig, batch: Dict,
                          rng: Optional[np.random.Generator] = None) -> Dict:
    """Add the stub frontend inputs the vlm/encdec families expect."""
    rng = rng or np.random.default_rng(0)
    B = batch["tokens"].shape[0]
    if cfg.family == "vlm":
        batch["patches"] = (0.02 * rng.standard_normal(
            (B, cfg.prefix_len, cfg.d_model))).astype(np.float32)
    if cfg.family == "encdec":
        batch["frames"] = (0.02 * rng.standard_normal(
            (B, cfg.enc_seq, cfg.d_model))).astype(np.float32)
    return batch
