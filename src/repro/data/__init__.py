"""Data pipeline for the training path."""

from .pipeline import CorpusTextDataset, SyntheticLMDataset, make_dataset

__all__ = ["CorpusTextDataset", "SyntheticLMDataset", "make_dataset"]
