"""Distribution substrate: divisibility-aware sharding rules, ZeRO-1
optimizer with optional int8 gradient compression, checkpointing, and
fault-tolerance machinery (heartbeats, elastic re-mesh, hedging)."""

from .sharding import (
    constrain,
    logical_to_spec,
    param_shardings,
    batch_spec,
)

__all__ = ["constrain", "logical_to_spec", "param_shardings", "batch_spec"]
