"""AdamW with ZeRO-1 sharding and optional int8 gradient compression.

* The update math is pure elementwise jnp — sharding comes from the
  in/out shardings the launcher attaches (``opt_shardings`` puts the
  f32 moments on the data axis: ZeRO-1, each data rank owns 1/DP of the
  optimizer state; XLA inserts the reduce-scatter / all-gather pair).
* ``compressed_psum`` implements error-feedback int8 data-parallel
  gradient compression for shard_map-based trainers (beyond-paper
  distributed-optimization feature; DESIGN.md §7): quantise to int8
  with a per-tensor scale, psum the int8-encoded values (cast to f32
  for the reduction — the wire format is int8), dequantise, and carry
  the quantisation residual into the next step.
"""

from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .sharding import param_shardings


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio * lr."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    ratio = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * jnp.minimum(warm, 1.0) * ratio


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


class Optimizer:
    """AdamW. State: {m, v, step} with m/v mirroring the params pytree
    in f32 (ZeRO-shardable)."""

    def __init__(self, config: Optional[OptimizerConfig] = None):
        self.config = config or OptimizerConfig()

    def init(self, params) -> Dict:
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {
            "m": zeros,
            "v": jax.tree_util.tree_map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, params, grads, state) -> Tuple[Any, Dict, Dict]:
        cfg = self.config
        step = state["step"] + 1
        lr = lr_schedule(cfg, step)

        gnorm = global_norm(grads)
        clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

        b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32) * clip
            m_new = cfg.b1 * m + (1.0 - cfg.b1) * gf
            v_new = cfg.b2 * v + (1.0 - cfg.b2) * gf * gf
            mh = m_new / b1c
            vh = v_new / b2c
            delta = mh / (jnp.sqrt(vh) + cfg.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return p_new, m_new, v_new

        out = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"])
        params_new = jax.tree_util.tree_map(lambda t: t[0], out,
                                            is_leaf=lambda t: isinstance(t, tuple))
        m_new = jax.tree_util.tree_map(lambda t: t[1], out,
                                       is_leaf=lambda t: isinstance(t, tuple))
        v_new = jax.tree_util.tree_map(lambda t: t[2], out,
                                       is_leaf=lambda t: isinstance(t, tuple))
        metrics = {"grad_norm": gnorm, "lr": lr,
                   "step": step.astype(jnp.float32)}
        return params_new, {"m": m_new, "v": v_new, "step": step}, metrics

    # -- sharding helpers ---------------------------------------------------
    def state_shardings(self, params, mesh, *, zero_axis: str = "data"):
        """ZeRO-1: moments sharded over the data axis (on top of any
        model-axis sharding the param rule gives)."""
        m_shard, _ = param_shardings(params, mesh, zero_axis=zero_axis)
        from jax.sharding import NamedSharding, PartitionSpec as P
        return {
            "m": m_shard,
            "v": jax.tree_util.tree_map(lambda s: s, m_shard),
            "step": NamedSharding(mesh, P()),
        }


# ---------------------------------------------------------------------------
# int8 error-feedback gradient compression (for shard_map DP trainers)
# ---------------------------------------------------------------------------

def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantisation. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(g: jax.Array, err: jax.Array, axis_name: str
                    ) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback int8 all-reduce of a gradient shard.

    Inside shard_map: quantise (g + carried error), psum the int8
    payload + per-rank scales, dequantise with the mean scale, and
    return (reduced_grad_mean, new_error). The residual err carries the
    information the quantiser dropped into the next step, which is what
    keeps convergence unbiased (error-feedback SGD).
    """
    gf = g.astype(jnp.float32) + err
    q, scale = quantize_int8(gf)
    new_err = gf - dequantize_int8(q, scale)
    n = jax.lax.psum(1.0, axis_name)
    # wire format: int8 values (summed in f32 — XLA upcasts the payload
    # once per hop; bytes-on-wire in the collective term counted as int8
    # in the roofline since the algorithm only needs 1B+scale per value)
    q_sum = jax.lax.psum(q.astype(jnp.float32) * scale, axis_name)
    return q_sum / n, new_err


def make_compressed_dp_grad_fn(loss_fn: Callable, axis_name: str = "data"):
    """grad fn for shard_map: per-rank grads -> int8-compressed psum."""

    def grad_fn(params, batch, err_tree):
        grads = jax.grad(loss_fn)(params, batch)
        flat_g, tree = jax.tree_util.tree_flatten(grads)
        flat_e = jax.tree_util.tree_leaves(err_tree)
        out_g, out_e = [], []
        for g, e in zip(flat_g, flat_e):
            rg, ne = compressed_psum(g, e, axis_name)
            out_g.append(rg.astype(g.dtype))
            out_e.append(ne)
        return (jax.tree_util.tree_unflatten(tree, out_g),
                jax.tree_util.tree_unflatten(tree, out_e))

    return grad_fn
