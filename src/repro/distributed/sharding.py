"""Divisibility-aware sharding rules (DESIGN.md §4).

Two mechanisms:

* **Activation constraints** — :func:`constrain` annotates intermediate
  values with logical axes ("batch", "model", "expert", ...) resolved
  against the mesh *currently in context*. Resolution is
  divisibility-aware: a logical axis whose dimension does not divide the
  mesh axis silently falls back to replication (e.g. smollm's 9 heads on
  a 16-way model axis). Outside a mesh context it is a no-op, so the
  same model code runs single-device smoke tests and 512-device
  dry-runs.

* **Parameter shardings** — :func:`param_shardings` maps a params pytree
  (by path) to NamedShardings using the same logical rules, for
  jit in_shardings. Stacked-layer params ([L, ...]) keep dim 0
  unsharded.

Logical axis -> mesh axes:
    batch   -> ("pod", "data")   (whichever exist in the mesh)
    data    -> ("data",)
    model   -> ("model",)        tensor-parallel dimension
    expert  -> ("model",)        MoE expert parallelism
    zero    -> ("data",)         optimizer-state / ZeRO-1 sharding
"""

from __future__ import annotations

import re
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

LOGICAL_RULES = {
    "batch": ("pod", "data"),
    "data": ("data",),
    "model": ("model",),
    "expert": ("model",),
    "residual": ("model",),   # d dim of the between-layer carry
    "zero": ("data",),
    None: (),
}

# pure-data-parallel rule set: small models spread the batch over the
# model axis too and keep every tensor dimension unsharded (the
# EXPERIMENTS.md §Perf 'dp-all' layout)
DP_ALL_RULES = {
    "batch": ("pod", "data", "model"),
    "data": ("data",),
    "model": (),
    "expert": (),
    "residual": (),
    "zero": ("data",),
    None: (),
}

# Megatron-style: residual stream replicated on d between layers; the
# block-internal heads/d_ff stay model-sharded, so each block costs one
# row-parallel all-reduce instead of a resharding cycle (§Perf)
MEGATRON_RULES = dict(LOGICAL_RULES, residual=())

_RULE_SETS = {"default": LOGICAL_RULES, "dp-all": DP_ALL_RULES,
              "megatron": MEGATRON_RULES}
_active_rules = LOGICAL_RULES


def set_logical_mode(mode: str) -> None:
    global _active_rules
    _active_rules = _RULE_SETS[mode]


class logical_mode:
    """Context manager: swap the activation-constraint rule set while
    tracing/lowering a variant layout."""

    def __init__(self, mode: str):
        self.mode = mode

    def __enter__(self):
        self.prev = _active_rules
        set_logical_mode(self.mode)

    def __exit__(self, *exc):
        global _active_rules
        _active_rules = self.prev


def _current_mesh() -> Optional[Mesh]:
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty:
            # fall back to the physical mesh context manager
            from jax.interpreters import pxla
            env_mesh = pxla.thread_resources.env.physical_mesh
            return None if env_mesh.empty else env_mesh
        return mesh
    except Exception:
        return None


def logical_to_spec(
    logical_axes: Sequence[Optional[str]],
    dims: Sequence[int],
    mesh,
) -> P:
    """Resolve logical axes to a PartitionSpec, dropping any mesh axis
    that does not divide the corresponding dimension."""
    axis_sizes = dict(mesh.shape)
    spec = []
    used = set()
    for logical, dim in zip(logical_axes, dims):
        mesh_axes = _active_rules.get(logical, ())
        chosen = []
        total = 1
        for ax in mesh_axes:
            if ax not in axis_sizes or ax in used:
                continue
            size = axis_sizes[ax]
            if dim % (total * size) == 0:
                chosen.append(ax)
                total *= size
        for ax in chosen:
            used.add(ax)
        if not chosen:
            spec.append(None)
        elif len(chosen) == 1:
            spec.append(chosen[0])
        else:
            spec.append(tuple(chosen))
    return P(*spec)


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint against the ambient mesh (no-op without)."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(f"{len(logical_axes)} axes for rank-{x.ndim} value")
    spec = logical_to_spec(logical_axes, x.shape, mesh)
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except ValueError:
        # abstract mesh from context: constraint via spec directly
        return jax.lax.with_sharding_constraint(x, spec)


def batch_spec(mesh) -> P:
    """Input-batch sharding: batch dim over (pod, data)."""
    axes = [a for a in ("pod", "data") if a in dict(mesh.shape)]
    return P(tuple(axes) if len(axes) > 1 else (axes[0] if axes else None))


# ---------------------------------------------------------------------------
# Parameter sharding rules, by path regex. First match wins.
# Conventions: stacked layer params have a leading L dim (rule specs are
# for the *trailing* dims; leading dims padded with None).
# ---------------------------------------------------------------------------

# (pattern, logical axes for trailing dims)
PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    # embeddings / unembedding: vocab on model
    (r"(embed|lm_head)/table$", ("model", None)),
    # attention projections: wq [d, H, hd] heads on model; wkv [d, Hk, hd]
    # kv-heads on model if divisible else head_dim on model (rule resolution
    # handles the fallback by trying 'model' on the hd axis).
    (r"attn/wq$", (None, "model", None)),
    (r"attn/wk$", (None, "model", "model_fallback")),
    (r"attn/wv$", (None, "model", "model_fallback")),
    (r"attn/wo$", ("model", None, None)),
    # dense MLP: d_ff on model
    (r"mlp/w(1|3)$", (None, "model")),
    (r"mlp/w2$", ("model", None)),
    # MoE: experts on model when divisible (expert parallelism); router repl.
    (r"moe/w(1|3)$", ("expert", None, "model_fallback")),
    (r"moe/w2$", ("expert", "model_fallback", None)),
    (r"moe/router$", (None, None)),
    # Mamba2 split projections: the d_inner-sized z/x columns on model
    # (head-aligned); the small B/C/dt projections stay replicated
    (r"ssm/w_(z|x)$", (None, "model")),
    (r"ssm/out_proj$", ("model", None)),
    # norms / scalars / conv / everything else: replicated
)


def _rule_for(path: str):
    for pat, axes in PARAM_RULES:
        if re.search(pat, path):
            return axes
    return None


def _spec_for_param(path: str, shape: Tuple[int, ...], mesh) -> P:
    axes = _rule_for(path)
    if axes is None:
        return P()
    # pad leading dims (layer stacking) with None
    n_trail = len(axes)
    if len(shape) < n_trail:
        # rule longer than rank (unstacked edge case): trim from the left
        axes = axes[len(axes) - len(shape):]
        n_trail = len(axes)
    full = [None] * (len(shape) - n_trail) + list(axes)

    axis_sizes = dict(mesh.shape)
    model_size = axis_sizes.get("model", 1)
    resolved = []
    used = set()
    for logical, dim in zip(full, shape):
        if logical == "model_fallback":
            # only shard if the *primary* model-axis slot upstream failed
            # and this dim divides
            if "model" not in used and dim % model_size == 0 and "model" in axis_sizes:
                resolved.append("model")
                used.add("model")
            else:
                resolved.append(None)
        elif logical in ("model", "expert"):
            if "model" not in used and "model" in axis_sizes and dim % model_size == 0:
                resolved.append("model")
                used.add("model")
            else:
                resolved.append(None)
        else:
            resolved.append(None)
    return P(*resolved)


def param_shardings(params, mesh, *, zero_axis: Optional[str] = None):
    """NamedShardings for a params pytree.

    ``zero_axis``: additionally shard the *largest* divisible dim of each
    param over the data axis (ZeRO-3-style fully-sharded params) — used
    for the huge MoE configs where replicated-over-data params would not
    fit HBM."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]

    def path_str(kp):
        return "/".join(
            getattr(k, "key", getattr(k, "idx", None)).__str__() for k in kp
        )

    out = {}
    for kp, leaf in flat:
        path = path_str(kp)
        spec = _spec_for_param(path, leaf.shape, mesh)
        if zero_axis is not None and zero_axis in dict(mesh.shape):
            spec = _add_zero_axis(spec, leaf.shape, mesh, zero_axis)
        out[path] = NamedSharding(mesh, spec)

    def map_fn(kp, leaf):
        return out[path_str(kp)]

    return jax.tree_util.tree_map_with_path(map_fn, params), out


def _add_zero_axis(spec: P, shape: Tuple[int, ...], mesh, zero_axis: str) -> P:
    """Add the data axis onto the largest still-unsharded divisible dim."""
    axis_sizes = dict(mesh.shape)
    zsize = axis_sizes[zero_axis]
    parts = list(spec) + [None] * (len(shape) - len(spec))
    best, best_dim = None, 0
    for i, (p, dim) in enumerate(zip(parts, shape)):
        if p is None and dim % zsize == 0 and dim > best_dim:
            best, best_dim = i, dim
        elif p is not None and not isinstance(p, tuple):
            # existing sharding: can we append zero axis on the same dim?
            shard = dim // axis_sizes.get(p, 1)
            if shard % zsize == 0 and dim > best_dim:
                pass  # prefer a clean dim first; handled only if none found
    if best is not None:
        parts[best] = zero_axis
        return P(*parts)
    # fall back: stack onto an already-sharded dim if divisible
    for i, (p, dim) in enumerate(zip(parts, shape)):
        if p is not None and not isinstance(p, tuple):
            shard = dim // axis_sizes.get(p, 1)
            if shard % zsize == 0:
                parts[i] = (p, zero_axis)
                return P(*parts)
    return P(*parts)
