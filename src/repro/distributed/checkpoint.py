"""Checkpoint / restart (fault tolerance, DESIGN.md §7).

Snapshot = {model params, optimizer state, scheduler state (bias store,
queues, policy cursor), metadata}. Layout:

    <dir>/step_<N>/
        manifest.json        # step, timestamp, tree structure, digests
        arrays.npz           # flattened pytree leaves (path-keyed)
        scheduler.json       # DriftScheduler.state_dict()

Writes are crash-safe (tmp dir + atomic rename) and optionally async
(double-buffered: at most one in-flight writer; the next save waits).
Restore picks the newest complete manifest, so a crash mid-write falls
back to the previous snapshot.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        out[path] = np.asarray(leaf)
    return out


def _unflatten_like(template, arrays: Dict[str, np.ndarray]):
    flat = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for kp, leaf in flat[0]:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        if path not in arrays:
            raise KeyError(f"checkpoint missing array {path!r}")
        arr = arrays[path]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"checkpoint shape mismatch at {path}: "
                f"{arr.shape} vs {leaf.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(flat[1], leaves)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 2, async_write: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_write = async_write
        self._writer: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, state: Dict[str, Any],
             scheduler_state: Optional[dict] = None,
             metadata: Optional[dict] = None) -> str:
        """state: pytree dict (e.g. {"params": ..., "opt": ...})."""
        self.wait()  # double-buffer: at most one in-flight write
        arrays = _flatten(state)
        sched = dict(scheduler_state or {})
        meta = {
            "step": int(step),
            "time": time.time(),
            "n_arrays": len(arrays),
            **(metadata or {}),
        }

        def _write():
            final = os.path.join(self.directory, f"step_{step:08d}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            with open(os.path.join(tmp, "scheduler.json"), "w") as f:
                json.dump(sched, f)
            # manifest last: its presence marks the snapshot complete
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if self.async_write:
            self._writer = threading.Thread(target=_write, daemon=True)
            self._writer.start()
        else:
            _write()
        return os.path.join(self.directory, f"step_{step:08d}")

    def wait(self) -> None:
        if self._writer is not None:
            self._writer.join()
            self._writer = None

    def _gc(self) -> None:
        steps = self.list_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def list_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                manifest = os.path.join(self.directory, name, "manifest.json")
                if os.path.exists(manifest):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: Optional[int] = None
                ) -> Tuple[int, Any, dict]:
        """Returns (step, state, scheduler_state). ``template`` is a
        pytree of arrays or ShapeDtypeStructs with the target structure."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step:08d}")
        with np.load(os.path.join(d, "arrays.npz")) as z:
            arrays = {k: z[k] for k in z.files}
        state = _unflatten_like(template, arrays)
        with open(os.path.join(d, "scheduler.json")) as f:
            sched = json.load(f)
        return step, state, sched
