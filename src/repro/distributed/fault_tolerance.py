"""Fault-tolerance machinery for 1000+-node operation (DESIGN.md §7).

Host-side, execution-agnostic components — the discrete-event simulator
injects failures through them and the real engine wires them to wall
clocks:

* :class:`HeartbeatMonitor`   — dead-worker detection by heartbeat age;
* :class:`StragglerDetector`  — per-worker EWMA slowdown detection plus
  the hedged-dispatch decision rule (re-issue a request elsewhere when
  its wait exceeds the tail of the expected distribution);
* :func:`elastic_plan`        — given the surviving chip count, the
  largest runnable (data, model) re-mesh and the re-sharding actions
  (re-lower on the smaller data axis; ZeRO state re-sharded).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


class HeartbeatMonitor:
    """Workers ping; anything silent for ``timeout`` seconds is dead."""

    def __init__(self, timeout: float = 15.0):
        self.timeout = timeout
        self._last: Dict[int, float] = {}
        self._dead: set = set()

    def beat(self, worker_id: int, now: float) -> None:
        self._last[worker_id] = now
        self._dead.discard(worker_id)

    def dead_workers(self, now: float) -> List[int]:
        newly = [w for w, t in self._last.items()
                 if w not in self._dead and now - t > self.timeout]
        self._dead.update(newly)
        return newly

    def alive(self, now: float) -> List[int]:
        return [w for w, t in self._last.items()
                if w not in self._dead and now - t <= self.timeout]


@dataclass
class WorkerStats:
    ewma: float = 0.0
    n: int = 0


class StragglerDetector:
    """EWMA per-worker step time; flags workers slower than
    ``threshold`` x the fleet median (straggler mitigation)."""

    def __init__(self, alpha: float = 0.2, threshold: float = 1.8):
        self.alpha = alpha
        self.threshold = threshold
        self.stats: Dict[int, WorkerStats] = {}

    def observe(self, worker_id: int, step_time: float) -> None:
        s = self.stats.setdefault(worker_id, WorkerStats())
        if s.n == 0:
            s.ewma = step_time
        else:
            s.ewma = (1 - self.alpha) * s.ewma + self.alpha * step_time
        s.n += 1

    @staticmethod
    def _median(vals: List[float]) -> float:
        vals = sorted(vals)
        if not vals:
            return 0.0
        mid = len(vals) // 2
        return (vals[mid] if len(vals) % 2 else
                0.5 * (vals[mid - 1] + vals[mid]))

    def fleet_median(self) -> float:
        return self._median([s.ewma for s in self.stats.values() if s.n > 0])

    def stragglers(self) -> List[int]:
        """Leave-one-out comparison: a worker is a straggler when it is
        ``threshold`` x slower than the median of the *other* workers
        (the pooled median would mask the straggler in small fleets)."""
        out = []
        for w, s in self.stats.items():
            if s.n < 3:
                continue
            others = [t.ewma for ww, t in self.stats.items()
                      if ww != w and t.n > 0]
            med = self._median(others)
            if med > 0 and s.ewma > self.threshold * med:
                out.append(w)
        return out

    # -- hedged dispatch -----------------------------------------------
    def should_hedge(self, wait_time: float, p99_expected: float) -> bool:
        """Re-issue a request to a second worker when its queue wait has
        exceeded the expected P99 (Dean & Barroso hedging rule)."""
        return p99_expected > 0 and wait_time > p99_expected


# ---------------------------------------------------------------------------
# elastic re-scale
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]
    dropped_chips: int
    actions: Tuple[str, ...]


def elastic_plan(n_chips: int, *, model_parallel: int = 16,
                 prefer_pods: bool = True) -> ElasticPlan:
    """Largest runnable mesh after failures.

    Keeps the model axis intact (TP degree is fixed by the weight
    sharding) and shrinks the data axis — the standard elastic-DP
    recovery. If fewer than one TP group survives, reduce TP to the
    largest power-of-two that fits.
    """
    actions = []
    tp = model_parallel
    if n_chips < tp:
        while tp > 1 and n_chips < tp:
            tp //= 2
        actions.append(f"reduce TP to {tp} (re-shard params)")
    dp = n_chips // tp
    if dp == 0:
        raise ValueError(f"cannot build a mesh from {n_chips} chips")
    used = dp * tp
    dropped = n_chips - used
    if dropped:
        actions.append(f"idle {dropped} chips (non-rectangular remainder)")
    actions.append(f"re-lower train/serve step on ({dp}, {tp}) mesh")
    actions.append("re-shard ZeRO optimizer state over the new data axis")
    actions.append("re-queue in-flight requests (at-most-once dispatch)")
    return ElasticPlan(
        mesh_shape=(dp, tp),
        mesh_axes=("data", "model"),
        dropped_chips=dropped,
        actions=tuple(actions),
    )
