"""Shared scan wrapper so analysis tooling can force full unrolling.

XLA's HloCostAnalysis counts a while-loop body ONCE (trip count is
opaque to it), so the roofline probes unroll every structured loop —
layer stacks *and* the blockwise kernel-reference scans (chunked
attention KV blocks, SSD chunk recurrence) — to measure true
FLOPs/bytes/collectives. Production lowering keeps rolled loops
(compile time, code size).
"""

from __future__ import annotations

import jax

_UNROLL = False


def set_scan_unroll(flag: bool) -> None:
    global _UNROLL
    _UNROLL = bool(flag)


def scan_unroll_enabled() -> bool:
    return _UNROLL


def scan(body, init, xs, **kwargs):
    if _UNROLL:
        kwargs["unroll"] = True
    return jax.lax.scan(body, init, xs, **kwargs)
