"""API-gateway admission path (Sec. II-A, Fig. 1).

The paper fronts the system with a FastAPI gateway; here the gateway is
an in-process component (the serving engine and simulator call it
directly) with the identical pipeline:

    raw request -> workload analysis (estimate + classify, Eq. 1-4)
                -> tenant queue assignment (Sec. II-E)

Prompt length is measured in whitespace-delimited units — the same
computationally-inexpensive proxy the paper uses for output length
(Sec. II-C1). ``count_tokens`` is the single place this proxy lives so
swapping in a real tokenizer (the paper's stated future work) is a
one-line change.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import List, Optional

from .estimator import AdaptiveTokenEstimator
from .queues import TenantQueueManager
from .request import Category, Request, RequestState, TenantTier


def count_tokens(text: str) -> int:
    """Whitespace-delimited word count (paper Sec. II-C1 proxy)."""
    return len(text.split())


@dataclass
class AdmissionRecord:
    """Per-admission log row (metrics pipeline, Sec. II-I)."""

    req_id: int
    time: float
    tenant: str
    category: str
    job_class: str
    t_budget: float
    bias_used: float


class AdmissionController:
    """Applies the workload-analysis layer and routes into tenant queues."""

    def __init__(self, estimator: AdaptiveTokenEstimator,
                 queues: TenantQueueManager) -> None:
        self.estimator = estimator
        self.queues = queues
        self._seq = itertools.count()
        self.log: List[AdmissionRecord] = []

    def admit(self, req: Request, now: float) -> Request:
        if req.prompt_tokens <= 0 and req.prompt:
            req.prompt_tokens = count_tokens(req.prompt)
        req.arrival_time = now
        req.seq = next(self._seq)
        # expected_cached_tokens is the resident-prefix overlap the
        # router observed on this replica at placement (0 without a
        # prefix cache): the budget prices only the uncached suffix
        req.estimate = self.estimator.estimate(
            req.category, req.tenant, req.prompt_tokens,
            cached_tokens=req.expected_cached_tokens,
        )
        self.queues.enqueue(req, now)
        self.log.append(AdmissionRecord(
            req_id=req.req_id, time=now, tenant=req.tenant.label,
            category=req.category.value, job_class=req.estimate.job_class.value,
            t_budget=req.estimate.t_budget, bias_used=req.estimate.bias,
        ))
        return req

    def readmit(self, req: Request, now: float) -> Request:
        """Fault-tolerance path: a request whose worker died is re-queued
        at the head of its tenant queue. The original estimate is kept —
        re-admission must be idempotent w.r.t. the learned bias (no
        double feedback; feedback only fires on completion)."""
        req.reset_for_retry()
        self.queues.enqueue(req, now, front=True)
        return req
