"""Adaptive token-budget estimation with runtime drift compensation.

Implements the paper's Eq. 1-2 (admission-time estimate) and Eq. 5-6
(EMA bias update):

    T_budget           = T_input + T_estimated_output                (1)
    T_estimated_output = T_base * B_runtime * S_tenant * F_input     (2)
    B_new              = (1 - alpha) * B_old + alpha * B_measured    (5)
    B_measured         = T_actual / T_base                           (6)

``B_runtime`` is tracked *per semantic workload category* (Sec. II-J,
Fig. 5: one bias curve per category, all initialised at 1.0). The
estimator is a pure host-side component — it runs at admission time on
the CPU, off the accelerator critical path, exactly as in the paper.
"""

from __future__ import annotations

import math
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Tuple

from .request import Category, Estimate, JobClass, TenantTier


@dataclass(frozen=True)
class DriftConfig:
    """All estimator constants. Paper-unspecified values are documented
    in DESIGN.md §2 and chosen to reproduce the published bias band."""

    # T_base — baseline workload token estimate per semantic category.
    base_estimates: Mapping[Category, float] = field(
        default_factory=lambda: {
            Category.SHORT_QA: 64.0,
            Category.SUMMARY: 288.0,
            Category.TECHNICAL: 416.0,
            Category.REPORT: 600.0,
        }
    )
    # S_tenant — tenant-aware safety scaling (premium over-provisions).
    tenant_safety: Mapping[TenantTier, float] = field(
        default_factory=lambda: {
            TenantTier.PREMIUM: 1.15,
            TenantTier.STANDARD: 1.05,
            TenantTier.BATCH: 1.0,
        }
    )
    # EMA learning rate (Eq. 5).
    ema_alpha: float = 0.10
    # BIAS=ON / BIAS=OFF switch (Sec. III-B).
    bias_enabled: bool = True
    bias_init: float = 1.0
    # Clamp on B_measured so a single pathological request cannot wreck
    # the estimate (robustness; not in the paper but harmless).
    bias_clip: Tuple[float, float] = (0.1, 4.0)
    # F_input — prompt-complexity scaling: log-scaled around a reference
    # prompt length, clipped. Longer prompts historically elicit longer
    # answers (Sec. II-C1). The reference sits below typical prompt
    # lengths so static estimation systematically over-provisions —
    # the paper's observed direction of runtime token drift.
    f_input_ref_tokens: float = 6.0
    f_input_log_slope: float = 0.10
    f_input_clip: Tuple[float, float] = (0.90, 1.40)
    # Runtime classification thresholds (Eq. 3).
    short_threshold: float = 128.0
    long_threshold: float = 512.0


@dataclass
class BiasSnapshot:
    """One point of the per-category bias trajectory (for Fig. 5)."""

    step: int
    time: float
    category: str
    bias: float


class BiasStore:
    """Per-category adaptive bias factors with EMA updates.

    Thread-safe: the real serving engine completes requests from worker
    threads while admission happens on the gateway thread.
    """

    def __init__(self, config: DriftConfig):
        self.config = config
        self._bias: Dict[Category, float] = {
            c: config.bias_init for c in Category
        }
        self._updates: Dict[Category, int] = {c: 0 for c in Category}
        self._lock = threading.Lock()
        self.history: List[BiasSnapshot] = []
        self._step = 0

    def get(self, category: Category) -> float:
        if not self.config.bias_enabled:
            return self.config.bias_init
        with self._lock:
            return self._bias[category]

    def update(self, category: Category, t_actual: float, now: float = 0.0) -> float:
        """Eq. 5-6. Returns the new bias. No-op under BIAS=OFF (the paper
        still *measures* drift under BIAS=OFF, it just never corrects)."""
        cfg = self.config
        t_base = cfg.base_estimates[category]
        lo, hi = cfg.bias_clip
        b_measured = min(max(t_actual / t_base, lo), hi)
        with self._lock:
            if cfg.bias_enabled:
                b_old = self._bias[category]
                b_new = (1.0 - cfg.ema_alpha) * b_old + cfg.ema_alpha * b_measured
                self._bias[category] = b_new
            else:
                b_new = self._bias[category]
            self._updates[category] += 1
            self._step += 1
            self.history.append(
                BiasSnapshot(self._step, now, category.value, b_new)
            )
            return b_new

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            return {c.value: b for c, b in self._bias.items()}

    def update_counts(self) -> Dict[str, int]:
        with self._lock:
            return {c.value: n for c, n in self._updates.items()}

    # --- checkpoint/restore (fault tolerance) -------------------------
    def state_dict(self) -> dict:
        with self._lock:
            return {
                "bias": {c.value: b for c, b in self._bias.items()},
                "updates": {c.value: n for c, n in self._updates.items()},
                "step": self._step,
            }

    def load_state_dict(self, state: dict) -> None:
        with self._lock:
            for c in Category:
                if c.value in state.get("bias", {}):
                    self._bias[c] = float(state["bias"][c.value])
                if c.value in state.get("updates", {}):
                    self._updates[c] = int(state["updates"][c.value])
            self._step = int(state.get("step", self._step))


class AdaptiveTokenEstimator:
    """The workload-analysis layer estimator (Sec. II-C1, Algorithm 2)."""

    def __init__(self, config: Optional[DriftConfig] = None,
                 bias_store: Optional[BiasStore] = None):
        self.config = config or DriftConfig()
        self.bias_store = bias_store or BiasStore(self.config)

    # -- Eq. 2 factor helpers ------------------------------------------
    def f_input(self, prompt_tokens: int) -> float:
        cfg = self.config
        ratio = max(float(prompt_tokens), 1.0) / cfg.f_input_ref_tokens
        raw = 1.0 + cfg.f_input_log_slope * math.log2(ratio)
        lo, hi = cfg.f_input_clip
        return min(max(raw, lo), hi)

    def classify_budget(self, t_budget: float) -> JobClass:
        """Eq. 3-4: runtime scheduling class from the estimated budget."""
        cfg = self.config
        if t_budget <= cfg.short_threshold:
            return JobClass.SHORT
        if t_budget <= cfg.long_threshold:
            return JobClass.MEDIUM
        return JobClass.LONG

    # -- Algorithm 2 ----------------------------------------------------
    def estimate(self, category: Category, tenant: TenantTier,
                 prompt_tokens: int, cached_tokens: int = 0) -> Estimate:
        """Admission-time estimate (Eq. 1-2). ``cached_tokens`` is the
        prompt prefix expected to be resident in the target replica's
        KV cache (set by prefix-aware placement): those tokens cost no
        prefill work, so the budget prices only the uncached suffix.
        ``F_input`` still reads the FULL prompt — output length depends
        on what the model sees, not on what was re-computed — so cache
        hits change the work estimate, never the output estimate."""
        cfg = self.config
        t_base = cfg.base_estimates[category]
        bias = self.bias_store.get(category)
        safety = cfg.tenant_safety[tenant]
        f_in = self.f_input(prompt_tokens)
        est_out = t_base * bias * safety * f_in              # Eq. 2
        cached = min(max(int(cached_tokens), 0), int(prompt_tokens))
        t_budget = float(prompt_tokens - cached) + est_out   # Eq. 1
        return Estimate(
            t_base=t_base,
            bias=bias,
            safety=safety,
            f_input=f_in,
            est_output_tokens=est_out,
            t_budget=t_budget,
            job_class=self.classify_budget(t_budget),
            cached_tokens=cached,
        )

    # -- Sec. II-J feedback ---------------------------------------------
    def feedback(self, category: Category, observed_output_tokens: float,
                 now: float = 0.0) -> float:
        return self.bias_store.update(category, observed_output_tokens, now)
