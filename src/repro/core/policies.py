"""Scheduling policies (Sec. II-F).

Five policies, all selecting from the :class:`TenantQueueManager`:

* :class:`FifoPolicy`          — strict global arrival order (Sec. II-F1)
* :class:`PriorityPolicy`      — tenant-tier precedence, FIFO within tier,
  score = priority_score * 1e12 + arrival_time (Sec. II-F2)
* :class:`SjfPolicy`           — smallest estimated token budget first
  (Sec. II-F3); directly consumes the adaptive estimator's budgets.
* :class:`WeightedPolicy`      — cyclic dispatch over a Premium:Standard:
  Batch ratio (Sec. II-F4; ratio redacted in the paper, default 5:3:2,
  see DESIGN.md §2)
* :class:`AgingPriorityPolicy` — priority score decays with queue waiting
  time so long-waiting requests eventually execute (Sec. II-F5)

Every policy implements ``select(manager, now) -> Optional[Request]``,
removing and returning the chosen request. Selection is deterministic:
ties break on the monotone admission sequence number.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from .queues import TenantQueueManager
from .request import Request, TenantTier

PRIORITY_SCALE = 1e12  # paper: score = priority_score * 10^12 + arrival_time


class SchedulingPolicy:
    """Base class. Subclasses override :meth:`select`."""

    name: str = "base"

    def select(self, manager: TenantQueueManager, now: float) -> Optional[Request]:
        raise NotImplementedError

    # Policies are stateless unless noted; Weighted keeps a cycle cursor.
    def state_dict(self) -> dict:
        return {}

    def load_state_dict(self, state: dict) -> None:
        pass

    # ------------------------------------------------------------------
    @staticmethod
    def _pop_head_min(
        manager: TenantQueueManager,
        key_fn,
    ) -> Optional[Request]:
        """Pop the queue-head request minimising ``key_fn`` across the
        three tenant queues (used when FIFO-within-tier is preserved)."""
        best_tier, best_key = None, None
        for tier, q in manager.queues.items():
            head = q.peek()
            if head is None:
                continue
            key = key_fn(head)
            if best_key is None or key < best_key:
                best_key, best_tier = key, tier
        if best_tier is None:
            return None
        return manager.queues[best_tier].pop()

    @staticmethod
    def _pop_scan_min(
        manager: TenantQueueManager,
        key_fn,
    ) -> Optional[Request]:
        """Pop the request minimising ``key_fn`` over *all* queued
        requests (needed when in-tier order is not score order, e.g. SJF
        and Aging). O(depth) per dispatch — exact Redis-zset semantics."""
        best_req, best_key = None, None
        for req in manager.all_requests():
            key = key_fn(req)
            if best_key is None or key < best_key:
                best_key, best_req = key, req
        if best_req is None:
            return None
        manager.queues[best_req.tenant]._q.remove(best_req)  # O(n) removal
        return best_req


class FifoPolicy(SchedulingPolicy):
    """Strict arrival order, tenant-blind (Sec. II-F1)."""

    name = "fifo"

    def select(self, manager: TenantQueueManager, now: float) -> Optional[Request]:
        # Global FIFO == min admission sequence across per-tenant heads
        # (each tenant queue is itself in arrival order).
        return self._pop_head_min(manager, lambda r: (r.seq,))


class PriorityPolicy(SchedulingPolicy):
    """Premium > Standard > Batch; FIFO within tier (Sec. II-F2)."""

    name = "priority"

    def select(self, manager: TenantQueueManager, now: float) -> Optional[Request]:
        return self._pop_head_min(
            manager,
            lambda r: (int(r.tenant) * PRIORITY_SCALE + r.arrival_time, r.seq),
        )


class SjfPolicy(SchedulingPolicy):
    """Shortest (estimated) job first (Sec. II-F3).

    Sensitive by construction to the adaptive token estimator: the key is
    the admission-time ``t_budget`` (Eq. 1), so drift compensation
    directly changes dispatch order.
    """

    name = "sjf"

    def select(self, manager: TenantQueueManager, now: float) -> Optional[Request]:
        return self._pop_scan_min(manager, lambda r: (r.t_budget, r.seq))


class WeightedPolicy(SchedulingPolicy):
    """Cyclic weighted dispatch across tenant classes (Sec. II-F4).

    The paper redacts the Premium:Standard:Batch ratio; we default to
    5:3:2 (DESIGN.md §2). The cursor advances through an expanded cycle
    pattern; empty classes are skipped so capacity is never idled.
    """

    name = "weighted"

    def __init__(self, ratio: Sequence[int] = (5, 3, 2)) -> None:
        if len(ratio) != len(TenantTier):
            raise ValueError("ratio must have one entry per tenant tier")
        self.ratio = tuple(int(x) for x in ratio)
        self._pattern: List[TenantTier] = []
        for tier, weight in zip(TenantTier, self.ratio):
            self._pattern.extend([tier] * weight)
        self._cursor = 0

    def select(self, manager: TenantQueueManager, now: float) -> Optional[Request]:
        if manager.is_empty():
            return None
        n = len(self._pattern)
        for step in range(n):
            tier = self._pattern[(self._cursor + step) % n]
            req = manager.queues[tier].pop()
            if req is not None:
                self._cursor = (self._cursor + step + 1) % n
                return req
        return None  # unreachable: manager not empty

    def state_dict(self) -> dict:
        return {"cursor": self._cursor, "ratio": list(self.ratio)}

    def load_state_dict(self, state: dict) -> None:
        self._cursor = int(state.get("cursor", 0))


class AgingPriorityPolicy(SchedulingPolicy):
    """Priority with starvation mitigation (Sec. II-F5).

    Effective score = tier * aging_threshold - waiting_time. Waiting time
    progressively reduces the score, so a Batch request that has waited
    longer than ``2 * aging_threshold`` seconds outranks a fresh Premium
    request. The default threshold keeps behaviour close to strict
    Priority (paper Tables III/V: Aging ~= Priority for tenant QoS, with
    slightly higher tail latency from periodic promotions).
    """

    name = "aging"

    def __init__(self, aging_threshold: float = 240.0, aging_rate: float = 1.0) -> None:
        self.aging_threshold = float(aging_threshold)
        self.aging_rate = float(aging_rate)

    def select(self, manager: TenantQueueManager, now: float) -> Optional[Request]:
        def score(r: Request):
            wait = now - r.enqueue_time
            return (int(r.tenant) * self.aging_threshold - self.aging_rate * wait,
                    r.seq)

        return self._pop_scan_min(manager, score)


POLICIES: Dict[str, type] = {
    p.name: p
    for p in (FifoPolicy, PriorityPolicy, SjfPolicy, WeightedPolicy, AgingPriorityPolicy)
}


def make_policy(name: str, **kwargs) -> SchedulingPolicy:
    try:
        cls = POLICIES[name.lower()]
    except KeyError:
        raise ValueError(
            f"unknown policy {name!r}; available: {sorted(POLICIES)}"
        ) from None
    return cls(**kwargs)
