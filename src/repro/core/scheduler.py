"""The DriftSched scheduling engine (Fig. 1, Sec. II-F/II-J).

Ties together the admission controller (workload analysis), the tenant
queue manager, the active scheduling policy, and the runtime-feedback
loop:

    submit()   -> admission (estimate Eq. 1-2, classify Eq. 3-4, enqueue)
    dispatch() -> policy.select() pops the next request for the worker
    complete() -> drift record + EMA bias update (Eq. 5-6)
    fail()     -> fault-tolerance re-admission (head of tenant queue)

The engine is execution-agnostic: the discrete-event simulator and the
real JAX continuous-batching engine both drive it through this exact
interface, so the scheduling state machine under test is identical in
both. The whole scheduler state (bias store, queues, policy cursor,
admission sequence) is checkpointable for restart.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional

from .admission import AdmissionController
from .drift import DriftSample, DriftTracker
from .estimator import AdaptiveTokenEstimator, DriftConfig
from .policies import SchedulingPolicy, make_policy
from .queues import TenantQueueManager
from .request import Request, RequestState


class DriftScheduler:
    """QoS-aware scheduler with runtime token-drift compensation."""

    def __init__(self, policy: str | SchedulingPolicy = "fifo",
                 config: Optional[DriftConfig] = None,
                 estimator: Optional[AdaptiveTokenEstimator] = None,
                 max_new_per_step: Optional[int] = None,
                 **policy_kwargs) -> None:
        """``estimator`` may be shared across schedulers: the cluster
        layer hands every replica the same AdaptiveTokenEstimator so
        drift feedback from any replica calibrates them all.

        ``max_new_per_step`` caps how many queued requests
        :meth:`dispatch_step` admits at one iteration boundary of a
        continuous-batching executor (None = fill every free slot).
        Sarathi-style chunked prefill bounds the per-iteration prefill
        *token* budget in the executor; this knob bounds per-iteration
        *admissions*, limiting how much prefill work can pile into one
        iteration in the first place."""
        if estimator is not None and config is not None \
                and estimator.config is not config:
            raise ValueError("pass either a shared estimator or a config, "
                             "not two disagreeing ones")
        self.estimator = estimator or AdaptiveTokenEstimator(
            config or DriftConfig())
        self.config = self.estimator.config
        self.queues = TenantQueueManager()
        self.admission = AdmissionController(self.estimator, self.queues)
        self.policy: SchedulingPolicy = (
            policy if isinstance(policy, SchedulingPolicy)
            else make_policy(policy, **policy_kwargs)
        )
        if max_new_per_step is not None and max_new_per_step < 1:
            raise ValueError(
                f"max_new_per_step must be >= 1 or None, got {max_new_per_step}")
        self.max_new_per_step = max_new_per_step
        self.drift = DriftTracker()
        self.completed: List[Request] = []
        self.dispatched = 0
        # Which serving phase this scheduler's completions observe
        # ("unified", or "decode" on a P/D decode replica — the phase
        # that actually sees the final output length). Used to attribute
        # drift feedback; prefill replicas never call complete().
        self.feedback_phase = "unified"
        # per-phase count of bias-feedback events (at-most-once audit)
        self.phase_feedback_counts: Dict[str, int] = {}

    # --- lifecycle ------------------------------------------------------
    def submit(self, req: Request, now: float) -> Request:
        return self.admission.admit(req, now)

    def dispatch(self, now: float) -> Optional[Request]:
        req = self.policy.select(self.queues, now)
        if req is None:
            return None
        req.dispatch_time = now
        req.state = RequestState.DISPATCHED
        self.dispatched += 1
        return req

    def dispatch_batch(self, now: float, max_n: int) -> List[Request]:
        """Fill up to ``max_n`` slots (batch formation, Sec. III-B)."""
        out: List[Request] = []
        for _ in range(max_n):
            req = self.dispatch(now)
            if req is None:
                break
            out.append(req)
        return out

    def dispatch_step(self, now: float, free_slots: int) -> List[Request]:
        """Slot-granular admission for iteration-level executors: fill
        at most ``free_slots`` freed decode slots, further capped by the
        ``max_new_per_step`` admission knob. Delegates to
        :meth:`dispatch_batch` so the per-request dispatch contract
        (policy selection, state transition, dispatch count) is
        identical on both execution paths."""
        cap = free_slots
        if self.max_new_per_step is not None:
            cap = min(cap, self.max_new_per_step)
        return self.dispatch_batch(now, max(cap, 0))

    def complete(self, req: Request, observed_tokens: int, now: float,
                 phase: Optional[str] = None) -> DriftSample:
        """Runtime feedback (Sec. II-J): record drift, update bias.

        ``phase`` attributes the observation to the serving phase that
        produced it ("unified" single-stage serving, "decode" on a P/D
        decode replica); defaults to this scheduler's
        :attr:`feedback_phase`. Attribution matters for the at-most-once
        contract: in disaggregated serving only the phase that observes
        the final output length (decode) may feed the bias EMA —
        a prefill pass observes no output drift and must stay silent.
        """
        phase = phase or self.feedback_phase
        req.mark_completed(observed_tokens, now)
        sample = self.drift.record(req, now, phase=phase)
        self.estimator.feedback(req.category, float(observed_tokens), now)
        self.phase_feedback_counts[phase] = \
            self.phase_feedback_counts.get(phase, 0) + 1
        self.completed.append(req)
        return sample

    def fail(self, req: Request, now: float) -> Request:
        """Worker failure: re-queue at the head, estimate preserved, no
        bias feedback (at-most-once feedback per completed request)."""
        req.state = RequestState.FAILED
        return self.admission.readmit(req, now)

    # --- introspection ---------------------------------------------------
    @property
    def bias_store(self):
        return self.estimator.bias_store

    def queue_depth(self) -> int:
        return self.queues.depth()

    # --- checkpoint/restore ----------------------------------------------
    def state_dict(self) -> dict:
        return {
            "policy": self.policy.name,
            "policy_state": self.policy.state_dict(),
            "bias": self.bias_store.state_dict(),
            "dispatched": self.dispatched,
            "queued_req_ids": [r.req_id for r in self.queues.all_requests()],
        }

    def load_state_dict(self, state: dict,
                        requests: Optional[Mapping[int, Request]] = None) -> None:
        """Restore scheduler state. ``requests`` maps ``req_id`` to the
        live :class:`Request` objects for any queued-at-checkpoint
        requests (queues hold object references, so the checkpoint only
        records ids); without it a checkpoint with a non-empty queue is
        refused rather than silently dropping the queue."""
        if state.get("policy") != self.policy.name:
            raise ValueError(
                f"checkpoint policy {state.get('policy')!r} != {self.policy.name!r}"
            )
        # validate everything before mutating anything: a caller that
        # catches a restore error must be left with its original state
        queued_ids = list(state.get("queued_req_ids", []))
        if queued_ids and requests is None:
            raise ValueError(
                f"checkpoint has {len(queued_ids)} queued requests; pass "
                "a `requests` registry (req_id -> Request) to restore them")
        missing = [i for i in queued_ids if i not in (requests or {})]
        if missing:
            raise KeyError(f"request registry missing req_ids {missing}")
        self.policy.load_state_dict(state.get("policy_state", {}))
        self.bias_store.load_state_dict(state.get("bias", {}))
        self.dispatched = int(state.get("dispatched", 0))
        # the queue must mirror the checkpoint either way — drop any
        # stale queued requests even when the checkpoint queue is empty
        self.queues.drain()
        for rid in queued_ids:
            req = requests[rid]
            # preserve the original enqueue timestamp: restore must not
            # reset aging/FIFO order
            self.queues.enqueue(req, req.enqueue_time)
