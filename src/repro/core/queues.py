"""Tenant-aware queue management (Sec. II-E).

The paper backs its queues with Redis data structures (lists for FIFO
order, sorted sets for scored policies). This module reimplements those
semantics as deterministic in-memory structures so experiments are
reproducible bit-for-bit:

* :class:`FifoQueue`      — Redis list  (RPUSH / LPOP)
* :class:`ScoredQueue`    — Redis zset  (ZADD / ZPOPMIN), min-heap backed
* :class:`TenantQueueManager` — the three tenant service queues
  (Premium / Standard / Batch), each holding heterogeneous short /
  medium / long workloads.

Queue assignment depends on the workload classification produced by the
adaptive token-estimation layer, so improvements in drift compensation
directly influence queue composition (Sec. II-E, last paragraph).
"""

from __future__ import annotations

import heapq
import itertools
from collections import deque
from typing import Callable, Dict, Iterable, List, Optional

from .request import Request, RequestState, TenantTier


class FifoQueue:
    """Redis-list semantics: strict arrival order."""

    def __init__(self) -> None:
        self._q: deque[Request] = deque()

    def push(self, req: Request) -> None:
        self._q.append(req)

    def push_front(self, req: Request) -> None:
        """Re-queue at the head (used for failure retries so a retried
        request does not lose its place)."""
        self._q.appendleft(req)

    def pop(self) -> Optional[Request]:
        return self._q.popleft() if self._q else None

    def peek(self) -> Optional[Request]:
        return self._q[0] if self._q else None

    def __len__(self) -> int:
        return len(self._q)

    def __iter__(self):
        return iter(self._q)


class ScoredQueue:
    """Redis sorted-set semantics (ZADD / ZPOPMIN) on a binary heap.

    Scores may be recomputed lazily (aging): :meth:`pop_min_rescored`
    accepts a scoring function evaluated against *current* time, which
    re-scores the whole heap. For the queue sizes in the paper's
    experiments (<= a few thousand entries) this is cheap and keeps the
    semantics exact rather than approximating aging with stale scores.
    """

    _tie = itertools.count()

    def __init__(self) -> None:
        self._heap: List[tuple] = []

    def push(self, score: float, req: Request) -> None:
        heapq.heappush(self._heap, (score, next(self._tie), req))

    def pop_min(self) -> Optional[Request]:
        if not self._heap:
            return None
        return heapq.heappop(self._heap)[2]

    def peek_score(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def pop_min_rescored(self, score_fn: Callable[[Request], float]) -> Optional[Request]:
        if not self._heap:
            return None
        best_i, best_key = 0, None
        for i, (_, tie, req) in enumerate(self._heap):
            key = (score_fn(req), tie)
            if best_key is None or key < best_key:
                best_key, best_i = key, i
        # remove index best_i from the heap
        last = self._heap.pop()
        if best_i < len(self._heap):
            removed = self._heap[best_i]
            self._heap[best_i] = last
            heapq.heapify(self._heap)
            return removed[2]
        return last[2]

    def __len__(self) -> int:
        return len(self._heap)

    def __iter__(self):
        return (entry[2] for entry in self._heap)


class TenantQueueManager:
    """Three independent tenant queues (Sec. II-E).

    Internally each tenant queue preserves FIFO arrival order; scheduling
    policies impose their own selection order on top (Sec. II-F). The
    manager also tracks queue-depth history for Fig. 6 reproduction.
    """

    def __init__(self) -> None:
        self.queues: Dict[TenantTier, FifoQueue] = {
            t: FifoQueue() for t in TenantTier
        }
        # (time, depth_premium, depth_standard, depth_batch) samples
        self.depth_history: List[tuple] = []

    # ------------------------------------------------------------------
    def enqueue(self, req: Request, now: float, *, front: bool = False) -> None:
        req.enqueue_time = now
        req.state = RequestState.QUEUED
        if front:
            self.queues[req.tenant].push_front(req)
        else:
            self.queues[req.tenant].push(req)

    def depth(self, tenant: Optional[TenantTier] = None) -> int:
        if tenant is not None:
            return len(self.queues[tenant])
        return sum(len(q) for q in self.queues.values())

    def depths(self) -> Dict[TenantTier, int]:
        return {t: len(q) for t, q in self.queues.items()}

    def record_depth(self, now: float) -> None:
        d = self.depths()
        self.depth_history.append(
            (now, d[TenantTier.PREMIUM], d[TenantTier.STANDARD], d[TenantTier.BATCH])
        )

    def all_requests(self) -> Iterable[Request]:
        for q in self.queues.values():
            yield from q

    def is_empty(self) -> bool:
        return self.depth() == 0

    # --- checkpoint/restore (fault tolerance) -------------------------
    def drain(self) -> List[Request]:
        """Remove and return every queued request (used when re-meshing
        or restoring from checkpoint)."""
        out: List[Request] = []
        for q in self.queues.values():
            while True:
                r = q.pop()
                if r is None:
                    break
                out.append(r)
        return out
