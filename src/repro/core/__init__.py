"""DriftSched core — the paper's contribution.

Adaptive QoS-aware scheduling under runtime token drift: admission-time
token-budget estimation (Eq. 1-2), runtime job classification (Eq. 3-4),
EMA drift compensation (Eq. 5-6), tenant queues, and the five evaluated
scheduling policies (FIFO, Priority, Weighted, SJF, Aging Priority).
"""

from .admission import AdmissionController, count_tokens
from .drift import DriftSample, DriftTracker, ErrorStats, error_reduction
from .estimator import AdaptiveTokenEstimator, BiasStore, DriftConfig
from .policies import (
    POLICIES,
    AgingPriorityPolicy,
    FifoPolicy,
    PriorityPolicy,
    SchedulingPolicy,
    SjfPolicy,
    WeightedPolicy,
    make_policy,
)
from .queues import FifoQueue, ScoredQueue, TenantQueueManager
from .request import (
    Category,
    Estimate,
    JobClass,
    Request,
    RequestState,
    TenantTier,
)
from .scheduler import DriftScheduler

__all__ = [
    "AdaptiveTokenEstimator", "AdmissionController", "AgingPriorityPolicy",
    "BiasStore", "Category", "DriftConfig", "DriftSample", "DriftScheduler",
    "DriftTracker", "ErrorStats", "Estimate", "FifoPolicy", "FifoQueue",
    "JobClass", "POLICIES", "PriorityPolicy", "Request", "RequestState",
    "SchedulingPolicy", "ScoredQueue", "SjfPolicy", "TenantQueueManager",
    "TenantTier", "WeightedPolicy", "count_tokens", "error_reduction",
    "make_policy",
]
