"""Request model and lifecycle for DriftSched.

A :class:`Request` carries everything the paper's pipeline needs:

* identity + tenant tier + semantic workload category (Sec. II-B/II-D),
* the admission-time estimate fields filled in by the adaptive token
  estimator (Eq. 1-2) and the runtime classifier (Eq. 3-4),
* lifecycle timestamps used by the metrics pipeline (Sec. II-I) to
  separate queueing latency from GPU execution latency,
* the observed output length fed back into the drift compensator
  (Eq. 5-6).
"""

from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Optional


class TenantTier(enum.IntEnum):
    """Service tiers (Sec. II-B). Lower value = higher priority."""

    PREMIUM = 0
    STANDARD = 1
    BATCH = 2

    @property
    def label(self) -> str:
        return self.name.lower()


class Category(enum.Enum):
    """Semantic workload categories (Sec. II-D)."""

    SHORT_QA = "short_qa"
    SUMMARY = "summary"
    TECHNICAL = "technical"
    REPORT = "report"


class JobClass(enum.Enum):
    """Runtime scheduling classes (Eq. 3-4)."""

    SHORT = "short"
    MEDIUM = "medium"
    LONG = "long"


class RequestState(enum.Enum):
    CREATED = "created"
    QUEUED = "queued"
    DISPATCHED = "dispatched"
    EXECUTING = "executing"
    COMPLETED = "completed"
    FAILED = "failed"       # worker failure; will be re-queued
    CANCELLED = "cancelled"


_REQ_IDS = itertools.count()


@dataclass
class Estimate:
    """Admission-time estimate produced by the adaptive token estimator."""

    t_base: float            # baseline workload token estimate (per category)
    bias: float              # B_runtime used for this estimate
    safety: float            # S_tenant
    f_input: float           # prompt-complexity scaling
    est_output_tokens: float  # T_base * B * S * F        (Eq. 2)
    t_budget: float           # T_input - cached + est_out (Eq. 1, with
    #                           the prefix-cache discount; 0 when the
    #                           placement saw no resident overlap)
    job_class: JobClass       # runtime scheduling class   (Eq. 4)
    cached_tokens: int = 0    # resident-prefix tokens priced out of
    #                           T_input at estimation time


@dataclass
class Request:
    tenant: TenantTier
    category: Category
    prompt: str = ""
    prompt_tokens: int = 0           # T_input
    max_tokens: int = 1024           # user-configured generation cap
    # --- shared-prefix identity (radix KV cache) ---
    # The first ``shared_prefix_tokens`` of the prompt are a shared
    # population prefix (tenant system prompt / RAG template) identified
    # by ``prefix_group`` (any hashable; the generator uses
    # (tenant_label, group_idx)). None/0 = no shareable prefix.
    prefix_group: Optional[tuple] = None
    shared_prefix_tokens: int = 0
    # Ground-truth output length. Hidden from the scheduler; consumed by
    # the simulator / engine which "generates" this many tokens (clipped
    # by max_tokens). The real JAX engine ignores it and samples to EOS.
    true_output_tokens: int = 0

    req_id: int = field(default_factory=lambda: next(_REQ_IDS))
    # --- lifecycle timestamps (simulated or wall-clock seconds) ---
    arrival_time: float = 0.0        # submitted to the API gateway
    enqueue_time: float = 0.0        # entered a tenant queue
    dispatch_time: Optional[float] = None   # selected by the policy
    exec_start: Optional[float] = None      # worker began the batch
    exec_end: Optional[float] = None        # worker finished the batch
    completion_time: Optional[float] = None

    state: RequestState = RequestState.CREATED
    estimate: Optional[Estimate] = None
    observed_output_tokens: Optional[int] = None
    worker_id: Optional[int] = None
    retries: int = 0                 # re-dispatches after worker failure

    # --- phase-disaggregated lifecycle (cluster P/D serving) ---
    # All timestamps in the same simulated/wall-clock seconds as above.
    # Unset (None) on the unified path, where one batch covers both
    # phases and ``exec_end`` is the only completion anchor.
    prefill_end: Optional[float] = None     # prefill phase finished (TTFT)
    handoff_time: Optional[float] = None    # KV landed on the decode replica
    prefill_rid: Optional[int] = None       # replica that ran prefill
    decode_rid: Optional[int] = None        # replica that ran decode
    n_steals: int = 0                # times moved by cross-replica stealing
    # --- prefix-cache accounting (set by router / step engine) ---
    # expected: the resident overlap the router observed on the chosen
    # replica at placement (prices the admission budget); realized: the
    # hit actually taken when prefill started (eviction/invalidation
    # may land it below the expectation — drift analyses separate the
    # two, see core.drift.DriftSample).
    expected_cached_tokens: int = 0
    cached_prompt_tokens: int = 0

    # monotone admission sequence number, assigned by the scheduler; used
    # for FIFO / tie-breaking so ordering is fully deterministic.
    seq: int = -1

    # ------------------------------------------------------------------
    @property
    def job_class(self) -> Optional[JobClass]:
        return self.estimate.job_class if self.estimate else None

    @property
    def t_budget(self) -> float:
        if self.estimate is None:
            raise ValueError(f"request {self.req_id} has no estimate yet")
        return self.estimate.t_budget

    @property
    def queue_wait(self) -> Optional[float]:
        if self.dispatch_time is None:
            return None
        return self.dispatch_time - self.arrival_time

    @property
    def e2e_latency(self) -> Optional[float]:
        if self.completion_time is None:
            return None
        return self.completion_time - self.arrival_time

    @property
    def gpu_latency(self) -> Optional[float]:
        """Worker-side execution latency (batch granularity, Sec. IV-J)."""
        if self.exec_end is None or self.exec_start is None:
            return None
        return self.exec_end - self.exec_start

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token, seconds since arrival.

        On the P/D-disaggregated path this is the end of the prefill
        phase (the first output token exists once prefill has run). On
        the unified path the cost model is batch-atomic — the first
        token is only observable at batch end — so TTFT degrades to the
        batch completion time. That asymmetry is the honest one: it is
        exactly the TTFT head-of-line damage disaggregation removes.
        """
        end = self.prefill_end if self.prefill_end is not None else self.exec_end
        if end is None:
            return None
        return end - self.arrival_time

    @property
    def decode_latency(self) -> Optional[float]:
        """Decode-phase latency, seconds. On the P/D path: KV arrival
        on the decode replica to completion (decode queueing + decode
        execution). On a unified replica running the step engine: first
        token (``prefill_end``) to completion — pure decode execution.
        None on the legacy atomic unified path, where the batch-atomic
        cost model cannot split the two phases."""
        if self.completion_time is None:
            return None
        anchor = (self.handoff_time if self.handoff_time is not None
                  else self.prefill_end)
        if anchor is None:
            return None
        return self.completion_time - anchor

    @property
    def inter_token_latency(self) -> Optional[float]:
        """Mean inter-token gap, seconds: the decode span divided over
        the ``observed - 1`` gaps after the first token. Includes decode
        queueing on the P/D path (the gap a client actually sees).
        None until completion, on single-token outputs, and on the
        legacy atomic unified path (no first-token anchor)."""
        if self.observed_output_tokens is None \
                or self.observed_output_tokens <= 1:
            return None
        span = self.decode_latency
        if span is None:
            return None
        return span / (self.observed_output_tokens - 1)

    @property
    def kv_transfer_latency(self) -> Optional[float]:
        """Modeled prefill→decode KV-transfer time, seconds (includes
        any re-targeting retries). None outside the P/D path."""
        if self.handoff_time is None or self.prefill_end is None:
            return None
        return self.handoff_time - self.prefill_end

    def mark_completed(self, observed_tokens: int, now: float) -> None:
        self.observed_output_tokens = int(observed_tokens)
        self.completion_time = now
        self.state = RequestState.COMPLETED

    def reset_for_retry(self) -> None:
        """Re-queue after a worker failure (fault tolerance path)."""
        self.retries += 1
        self.dispatch_time = None
        self.exec_start = None
        self.exec_end = None
        self.worker_id = None
        # any prefix-cache hit died with the worker's KV pool; the
        # retry re-probes whatever cache its next replica holds
        self.cached_prompt_tokens = 0
        self.state = RequestState.QUEUED

    def reset_for_reprefill(self) -> None:
        """Re-run the prefill phase from scratch.

        Used when the KV produced by a finished prefill is lost before
        decode completes — the prefill replica died mid-transfer, or the
        decode replica holding the pages failed. The admission estimate
        is deliberately kept (at-most-once feedback: nothing was
        observed yet, so nothing may be re-priced)."""
        self.reset_for_retry()
        self.prefill_end = None
        self.handoff_time = None
        self.prefill_rid = None
        self.decode_rid = None
