"""Request model and lifecycle for DriftSched.

A :class:`Request` carries everything the paper's pipeline needs:

* identity + tenant tier + semantic workload category (Sec. II-B/II-D),
* the admission-time estimate fields filled in by the adaptive token
  estimator (Eq. 1-2) and the runtime classifier (Eq. 3-4),
* lifecycle timestamps used by the metrics pipeline (Sec. II-I) to
  separate queueing latency from GPU execution latency,
* the observed output length fed back into the drift compensator
  (Eq. 5-6).
"""

from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Optional


class TenantTier(enum.IntEnum):
    """Service tiers (Sec. II-B). Lower value = higher priority."""

    PREMIUM = 0
    STANDARD = 1
    BATCH = 2

    @property
    def label(self) -> str:
        return self.name.lower()


class Category(enum.Enum):
    """Semantic workload categories (Sec. II-D)."""

    SHORT_QA = "short_qa"
    SUMMARY = "summary"
    TECHNICAL = "technical"
    REPORT = "report"


class JobClass(enum.Enum):
    """Runtime scheduling classes (Eq. 3-4)."""

    SHORT = "short"
    MEDIUM = "medium"
    LONG = "long"


class RequestState(enum.Enum):
    CREATED = "created"
    QUEUED = "queued"
    DISPATCHED = "dispatched"
    EXECUTING = "executing"
    COMPLETED = "completed"
    FAILED = "failed"       # worker failure; will be re-queued
    CANCELLED = "cancelled"


_REQ_IDS = itertools.count()


@dataclass
class Estimate:
    """Admission-time estimate produced by the adaptive token estimator."""

    t_base: float            # baseline workload token estimate (per category)
    bias: float              # B_runtime used for this estimate
    safety: float            # S_tenant
    f_input: float           # prompt-complexity scaling
    est_output_tokens: float  # T_base * B * S * F        (Eq. 2)
    t_budget: float           # T_input + est_output       (Eq. 1)
    job_class: JobClass       # runtime scheduling class   (Eq. 4)


@dataclass
class Request:
    tenant: TenantTier
    category: Category
    prompt: str = ""
    prompt_tokens: int = 0           # T_input
    max_tokens: int = 1024           # user-configured generation cap
    # Ground-truth output length. Hidden from the scheduler; consumed by
    # the simulator / engine which "generates" this many tokens (clipped
    # by max_tokens). The real JAX engine ignores it and samples to EOS.
    true_output_tokens: int = 0

    req_id: int = field(default_factory=lambda: next(_REQ_IDS))
    # --- lifecycle timestamps (simulated or wall-clock seconds) ---
    arrival_time: float = 0.0        # submitted to the API gateway
    enqueue_time: float = 0.0        # entered a tenant queue
    dispatch_time: Optional[float] = None   # selected by the policy
    exec_start: Optional[float] = None      # worker began the batch
    exec_end: Optional[float] = None        # worker finished the batch
    completion_time: Optional[float] = None

    state: RequestState = RequestState.CREATED
    estimate: Optional[Estimate] = None
    observed_output_tokens: Optional[int] = None
    worker_id: Optional[int] = None
    retries: int = 0                 # re-dispatches after worker failure

    # monotone admission sequence number, assigned by the scheduler; used
    # for FIFO / tie-breaking so ordering is fully deterministic.
    seq: int = -1

    # ------------------------------------------------------------------
    @property
    def job_class(self) -> Optional[JobClass]:
        return self.estimate.job_class if self.estimate else None

    @property
    def t_budget(self) -> float:
        if self.estimate is None:
            raise ValueError(f"request {self.req_id} has no estimate yet")
        return self.estimate.t_budget

    @property
    def queue_wait(self) -> Optional[float]:
        if self.dispatch_time is None:
            return None
        return self.dispatch_time - self.arrival_time

    @property
    def e2e_latency(self) -> Optional[float]:
        if self.completion_time is None:
            return None
        return self.completion_time - self.arrival_time

    @property
    def gpu_latency(self) -> Optional[float]:
        """Worker-side execution latency (batch granularity, Sec. IV-J)."""
        if self.exec_end is None or self.exec_start is None:
            return None
        return self.exec_end - self.exec_start

    def mark_completed(self, observed_tokens: int, now: float) -> None:
        self.observed_output_tokens = int(observed_tokens)
        self.completion_time = now
        self.state = RequestState.COMPLETED

    def reset_for_retry(self) -> None:
        """Re-queue after a worker failure (fault tolerance path)."""
        self.retries += 1
        self.dispatch_time = None
        self.exec_start = None
        self.exec_end = None
        self.worker_id = None
        self.state = RequestState.QUEUED
