"""Runtime token-drift measurement (Sec. II-J, Table VII).

Tracks (estimated_output, observed_output) pairs for every completed
request and computes the estimation-error metrics the paper reports:

* MAE  = mean |est - obs|
* RMSE = sqrt(mean (est - obs)^2)

Errors are tracked overall and per semantic category, and as a running
time-series so Fig. 8 (estimated vs observed under BIAS=OFF/ON) can be
re-created. The BIAS=OFF vs BIAS=ON *reduction* percentages of Table VII
are computed by :func:`error_reduction` over two runs' metrics.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .request import Category, Request


@dataclass
class DriftSample:
    """One completed request's estimation record."""

    time: float
    category: str
    estimated_output: float
    observed_output: float
    t_budget: float
    prompt_tokens: int
    # serving phase that observed the output length ("unified", or
    # "decode" when a P/D decode replica saw the request finish)
    phase: str = "unified"
    # prefix-cache attribution: the overlap priced into t_budget at
    # placement vs the hit actually taken at prefill. The bias EMA is
    # cache-neutral by construction — feedback is observed OUTPUT
    # tokens (Eq. 6), which a cached prefill does not change — so cache
    # luck can never masquerade as systematic output drift; these
    # fields exist so budget-error analyses can split the hit/miss
    # populations (and audit expectation-vs-realization) instead of
    # averaging cache fortune into the drift numbers.
    expected_cached_tokens: int = 0
    cached_tokens: int = 0

    @property
    def cache_hit(self) -> bool:
        return self.cached_tokens > 0

    @property
    def error(self) -> float:
        return self.estimated_output - self.observed_output

    @property
    def abs_error(self) -> float:
        return abs(self.error)


@dataclass
class ErrorStats:
    n: int = 0
    mae: float = 0.0
    rmse: float = 0.0
    mean_error: float = 0.0  # signed: >0 means over-estimation

    def as_dict(self) -> dict:
        return {
            "n": self.n, "mae": self.mae, "rmse": self.rmse,
            "mean_error": self.mean_error,
        }


class DriftTracker:
    """Accumulates drift samples during an experiment run.

    Owners that trace (worker simulators, serving engines) attach a
    live recorder as ``self.trace`` (plus their replica id as
    ``self.trace_rid``) so every drift sample also lands in the
    lifecycle trace — this is the drift-MAE stream the observability
    layer's sliding windows consume."""

    def __init__(self) -> None:
        self.samples: List[DriftSample] = []
        # observability hooks: no-op sentinel unless an owner attaches
        # a live recorder (imported lazily to keep core dependency-lean)
        from ..obs.events import NULL_RECORDER
        self.trace = NULL_RECORDER
        self.trace_rid: Optional[int] = None

    def record(self, req: Request, now: float,
               phase: str = "unified") -> DriftSample:
        if req.estimate is None or req.observed_output_tokens is None:
            raise ValueError(f"request {req.req_id} incomplete for drift record")
        s = DriftSample(
            time=now,
            category=req.category.value,
            estimated_output=req.estimate.est_output_tokens,
            observed_output=float(req.observed_output_tokens),
            t_budget=req.estimate.t_budget,
            prompt_tokens=req.prompt_tokens,
            phase=phase,
            expected_cached_tokens=req.estimate.cached_tokens,
            cached_tokens=req.cached_prompt_tokens,
        )
        self.samples.append(s)
        if self.trace.enabled:
            from ..obs import events as _tr
            self.trace.emit(now, _tr.DRIFT, req_id=req.req_id,
                            rid=self.trace_rid, tenant=req.tenant.label,
                            category=s.category, phase=phase,
                            estimated=s.estimated_output,
                            observed=s.observed_output,
                            abs_error=s.abs_error)
        return s

    # ------------------------------------------------------------------
    def stats(self, category: Optional[Category] = None,
              after: float = -math.inf, before: float = math.inf) -> ErrorStats:
        cat = category.value if category is not None else None
        sel = [s for s in self.samples
               if (cat is None or s.category == cat) and after <= s.time < before]
        if not sel:
            return ErrorStats()
        n = len(sel)
        mae = sum(s.abs_error for s in sel) / n
        rmse = math.sqrt(sum(s.error ** 2 for s in sel) / n)
        mean_err = sum(s.error for s in sel) / n
        return ErrorStats(n=n, mae=mae, rmse=rmse, mean_error=mean_err)

    def per_category(self) -> Dict[str, ErrorStats]:
        return {c.value: self.stats(c) for c in Category}

    def per_cache_outcome(self) -> Dict[str, ErrorStats]:
        """Estimation error split by prefix-cache outcome, so cache
        luck is inspectable instead of averaged into the drift numbers
        (output-bias calibration itself is cache-neutral: Eq. 6 feeds
        on observed output tokens only)."""
        def _stats(sel: List[DriftSample]) -> ErrorStats:
            if not sel:
                return ErrorStats()
            n = len(sel)
            return ErrorStats(
                n=n,
                mae=sum(s.abs_error for s in sel) / n,
                rmse=math.sqrt(sum(s.error ** 2 for s in sel) / n),
                mean_error=sum(s.error for s in sel) / n)
        return {"hit": _stats([s for s in self.samples if s.cache_hit]),
                "miss": _stats([s for s in self.samples
                                if not s.cache_hit])}

    def misclassification_rate(self, classify_fn) -> float:
        """Fraction of requests whose *runtime* class (from the observed
        budget: prompt + observed output) differs from the admission-time
        class (Fig. 2's misclassification phenomenon)."""
        if not self.samples:
            return 0.0
        wrong = 0
        for s in self.samples:
            predicted = classify_fn(s.t_budget)
            actual = classify_fn(s.prompt_tokens + s.observed_output)
            if predicted != actual:
                wrong += 1
        return wrong / len(self.samples)


def error_reduction(off: ErrorStats, on: ErrorStats) -> Dict[str, float]:
    """Table VII: percentage reduction BIAS=OFF -> BIAS=ON."""

    def pct(a: float, b: float) -> float:
        return 100.0 * (a - b) / a if a > 0 else 0.0

    return {
        "mae_reduction_pct": pct(off.mae, on.mae),
        "rmse_reduction_pct": pct(off.rmse, on.rmse),
    }
