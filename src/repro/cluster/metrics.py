"""Cluster-level metrics: per-run aggregation across N replicas.

Wraps the single-run :func:`repro.serving.metrics.summarize_run` (same
latency/fairness definitions, so cluster numbers are directly
comparable with the paper tables) and adds the cluster-only dimensions:
shed accounting per tier, per-replica utilization and routing share,
and the autoscaler's action trail.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from ..core.request import Request
from ..serving.metrics import LatencyStats, RunMetrics, summarize_run
from .admission import GlobalAdmission
from .autoscaler import Autoscaler


@dataclass
class ReplicaStats:
    """Per-replica run accounting: role, routing share, completions,
    busy seconds, utilization (busy_time / makespan), and the P/D
    handoff + work-stealing flow counters."""

    rid: int
    state: str
    n_routed: int
    n_completed: int
    busy_time: float
    utilization: float               # busy_time / makespan
    role: str = "unified"
    n_handoffs_in: int = 0
    n_handoffs_out: int = 0
    n_stolen_in: int = 0
    n_stolen_away: int = 0
    # shared-prefix KV cache counters (all zero when disabled):
    # request-granular hits/misses, prefill tokens served from cache,
    # LRU-evicted pages, failure-driven cache wipes
    n_prefix_hits: int = 0
    n_prefix_misses: int = 0
    prefix_tokens_saved: int = 0
    prefix_evicted_pages: int = 0
    prefix_invalidations: int = 0

    def as_dict(self) -> dict:
        """JSON-ready flat dict (benchmark --json capture)."""
        return {"rid": self.rid, "state": self.state, "role": self.role,
                "n_routed": self.n_routed, "n_completed": self.n_completed,
                "busy_time": self.busy_time, "utilization": self.utilization,
                "n_handoffs_in": self.n_handoffs_in,
                "n_handoffs_out": self.n_handoffs_out,
                "n_stolen_in": self.n_stolen_in,
                "n_stolen_away": self.n_stolen_away,
                "n_prefix_hits": self.n_prefix_hits,
                "n_prefix_misses": self.n_prefix_misses,
                "prefix_tokens_saved": self.prefix_tokens_saved,
                "prefix_evicted_pages": self.prefix_evicted_pages,
                "prefix_invalidations": self.prefix_invalidations}


@dataclass
class ClusterMetrics:
    """One cluster run: the familiar RunMetrics plus cluster extras.

    The per-phase breakdown (all in seconds since arrival):

    * ``ttft`` — time to first token. Real (end of the prefill phase)
      on the P/D path, and real on unified replicas running the
      iteration-level step engine (``ClusterConfig.step_engine``: the
      iteration that emitted the request's first token). Only on the
      legacy atomic path does the cost model observe the first token at
      batch end, degrading unified TTFT to e2e — that asymmetry *is*
      the head-of-line effect both disaggregation and chunked-prefill
      continuous batching remove (compare them head-to-head with
      ``benchmarks.bench_chunked_prefill``).
    * ``decode`` — decode-phase span: KV arrival on the decode replica
      → completion on the P/D path (decode queueing + execution), first
      token → completion on step-engine unified replicas; empty only on
      legacy atomic unified runs.
    * ``inter_token`` — per-request mean inter-token gap (the decode
      span over its ``observed - 1`` gaps): the streaming-jitter stat
      TTFT alone cannot show. Same anchors as ``decode``.
    * ``kv_transfer`` — modeled prefill→decode transfer time.

    ``prefix_cache`` aggregates the shared-prefix KV-reuse counters
    across replicas (request hit rate, prefill tokens served from
    cache, LRU evictions, failure invalidations); all zero when
    ``ClusterConfig.prefix_cache`` is off.
    """

    routing: str
    n_replicas_start: int
    n_replicas_end: int
    run: RunMetrics
    shed: dict                       # GlobalAdmission.summary()
    replicas: List[ReplicaStats]
    scale_events: List[dict]
    n_rerouted: int
    ttft: LatencyStats = field(default_factory=LatencyStats)
    decode: LatencyStats = field(default_factory=LatencyStats)
    inter_token: LatencyStats = field(default_factory=LatencyStats)
    kv_transfer: LatencyStats = field(default_factory=LatencyStats)
    n_handoffs: int = 0
    n_handoffs_lost: int = 0
    n_stolen: int = 0
    prefix_cache: dict = field(default_factory=dict)
    # execution-core backend the replicas ran on ("object" | "vector")
    # — recorded so --json benchmark captures are self-describing
    backend: str = "object"

    @property
    def shed_rate(self) -> float:
        """Fraction of offered requests the front door shed."""
        return self.shed.get("shed_rate", 0.0)

    def as_dict(self) -> dict:
        """JSON-ready nested dict (benchmark --json capture)."""
        return {
            "routing": self.routing,
            "n_replicas_start": self.n_replicas_start,
            "n_replicas_end": self.n_replicas_end,
            "run": self.run.as_dict(),
            "shed": self.shed,
            "replicas": [r.as_dict() for r in self.replicas],
            "scale_events": self.scale_events,
            "n_rerouted": self.n_rerouted,
            "ttft": self.ttft.as_dict(),
            "decode": self.decode.as_dict(),
            "inter_token": self.inter_token.as_dict(),
            "kv_transfer": self.kv_transfer.as_dict(),
            "n_handoffs": self.n_handoffs,
            "n_handoffs_lost": self.n_handoffs_lost,
            "n_stolen": self.n_stolen,
            "prefix_cache": dict(self.prefix_cache),
            "backend": self.backend,
        }


def summarize_cluster(routing: str, policy: str, bias_enabled: bool,
                      completed: Sequence[Request], *,
                      replicas, admission: Optional[GlobalAdmission],
                      autoscaler: Optional[Autoscaler],
                      n_replicas_start: int,
                      replica_busy_time: Dict[int, float],
                      replica_completed: Dict[int, int],
                      n_failed_dispatches: int = 0,
                      n_rerouted: int = 0,
                      n_handoffs: int = 0,
                      n_handoffs_lost: int = 0,
                      n_stolen: int = 0,
                      backend: str = "object") -> ClusterMetrics:
    """Aggregate one cluster run into :class:`ClusterMetrics`.

    ``completed`` are the finished requests across every replica (their
    timestamps, in seconds, drive all latency stats); ``replica_busy_time``
    maps rid -> busy seconds; handoff/steal counts come from the cluster
    simulator's flow counters.
    """
    run = summarize_run(policy, bias_enabled, completed,
                        busy_time=(sum(replica_busy_time.values())
                                   / max(len(replica_busy_time), 1)),
                        n_failed_dispatches=n_failed_dispatches)
    makespan = max(run.makespan, 1e-9)
    stats = []
    prefix_totals = {"hits": 0, "misses": 0, "tokens_saved": 0,
                     "evicted_pages": 0, "invalidations": 0}
    for r in replicas:
        pc = r.prefix_cache_stats()
        for k in prefix_totals:
            prefix_totals[k] += pc.get(k, 0)
        stats.append(ReplicaStats(
            rid=r.rid, state=r.state.value, role=r.role.value,
            n_routed=r.n_routed,
            n_completed=replica_completed.get(r.rid, 0),
            busy_time=replica_busy_time.get(r.rid, 0.0),
            utilization=replica_busy_time.get(r.rid, 0.0) / makespan,
            n_handoffs_in=r.n_handoffs_in, n_handoffs_out=r.n_handoffs_out,
            n_stolen_in=r.n_stolen_in, n_stolen_away=r.n_stolen_away,
            n_prefix_hits=pc.get("hits", 0),
            n_prefix_misses=pc.get("misses", 0),
            prefix_tokens_saved=pc.get("tokens_saved", 0),
            prefix_evicted_pages=pc.get("evicted_pages", 0),
            prefix_invalidations=pc.get("invalidations", 0)))
    probed = prefix_totals["hits"] + prefix_totals["misses"]
    prefix_totals["hit_rate"] = (prefix_totals["hits"] / probed
                                 if probed else 0.0)
    from .replica import ReplicaState
    n_end = sum(1 for r in replicas
                if r.state in (ReplicaState.ACTIVE, ReplicaState.STARTING))
    return ClusterMetrics(
        routing=routing,
        n_replicas_start=n_replicas_start,
        n_replicas_end=n_end,
        run=run,
        shed=admission.summary() if admission is not None else {
            "accepted": {}, "shed": {}, "shed_rate": 0.0,
            "shed_rate_per_tier": {}},
        replicas=stats,
        scale_events=[vars(e).copy() for e in
                      (autoscaler.events if autoscaler else [])],
        n_rerouted=n_rerouted,
        ttft=LatencyStats.of([r.ttft for r in completed]),
        decode=LatencyStats.of([r.decode_latency for r in completed]),
        inter_token=LatencyStats.of(
            [r.inter_token_latency for r in completed]),
        kv_transfer=LatencyStats.of(
            [r.kv_transfer_latency for r in completed]),
        n_handoffs=n_handoffs,
        n_handoffs_lost=n_handoffs_lost,
        n_stolen=n_stolen,
        prefix_cache=prefix_totals,
        backend=backend,
    )
