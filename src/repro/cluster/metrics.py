"""Cluster-level metrics: per-run aggregation across N replicas.

Wraps the single-run :func:`repro.serving.metrics.summarize_run` (same
latency/fairness definitions, so cluster numbers are directly
comparable with the paper tables) and adds the cluster-only dimensions:
shed accounting per tier, per-replica utilization and routing share,
and the autoscaler's action trail.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from ..core.request import Request
from ..serving.metrics import RunMetrics, summarize_run
from .admission import GlobalAdmission
from .autoscaler import Autoscaler


@dataclass
class ReplicaStats:
    rid: int
    state: str
    n_routed: int
    n_completed: int
    busy_time: float
    utilization: float               # busy_time / makespan

    def as_dict(self) -> dict:
        return {"rid": self.rid, "state": self.state,
                "n_routed": self.n_routed, "n_completed": self.n_completed,
                "busy_time": self.busy_time, "utilization": self.utilization}


@dataclass
class ClusterMetrics:
    """One cluster run: the familiar RunMetrics plus cluster extras."""

    routing: str
    n_replicas_start: int
    n_replicas_end: int
    run: RunMetrics
    shed: dict                       # GlobalAdmission.summary()
    replicas: List[ReplicaStats]
    scale_events: List[dict]
    n_rerouted: int

    @property
    def shed_rate(self) -> float:
        return self.shed.get("shed_rate", 0.0)

    def as_dict(self) -> dict:
        return {
            "routing": self.routing,
            "n_replicas_start": self.n_replicas_start,
            "n_replicas_end": self.n_replicas_end,
            "run": self.run.as_dict(),
            "shed": self.shed,
            "replicas": [r.as_dict() for r in self.replicas],
            "scale_events": self.scale_events,
            "n_rerouted": self.n_rerouted,
        }


def summarize_cluster(routing: str, policy: str, bias_enabled: bool,
                      completed: Sequence[Request], *,
                      replicas, admission: Optional[GlobalAdmission],
                      autoscaler: Optional[Autoscaler],
                      n_replicas_start: int,
                      replica_busy_time: Dict[int, float],
                      replica_completed: Dict[int, int],
                      n_failed_dispatches: int = 0,
                      n_rerouted: int = 0) -> ClusterMetrics:
    run = summarize_run(policy, bias_enabled, completed,
                        busy_time=(sum(replica_busy_time.values())
                                   / max(len(replica_busy_time), 1)),
                        n_failed_dispatches=n_failed_dispatches)
    makespan = max(run.makespan, 1e-9)
    stats = [
        ReplicaStats(
            rid=r.rid, state=r.state.value, n_routed=r.n_routed,
            n_completed=replica_completed.get(r.rid, 0),
            busy_time=replica_busy_time.get(r.rid, 0.0),
            utilization=replica_busy_time.get(r.rid, 0.0) / makespan)
        for r in replicas
    ]
    from .replica import ReplicaState
    n_end = sum(1 for r in replicas
                if r.state in (ReplicaState.ACTIVE, ReplicaState.STARTING))
    return ClusterMetrics(
        routing=routing,
        n_replicas_start=n_replicas_start,
        n_replicas_end=n_end,
        run=run,
        shed=admission.summary() if admission is not None else {
            "accepted": {}, "shed": {}, "shed_rate": 0.0,
            "shed_rate_per_tier": {}},
        replicas=stats,
        scale_events=[vars(e).copy() for e in
                      (autoscaler.events if autoscaler else [])],
        n_rerouted=n_rerouted,
    )
