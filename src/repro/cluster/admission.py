"""Global admission: the cluster front door (rate limits + backpressure).

Sits *before* the router. Two gates, applied in order:

1. **Cluster-depth backpressure** — when the total outstanding
   estimated-token mass across routable replicas exceeds
   ``max_cluster_token_mass``, new work is shed rather than queued into
   an already-saturated cluster (bounded queues; the single-replica
   paper protocol deliberately unbounds them to study drift under
   saturation, the cluster layer must not).
2. **Per-tenant token buckets** — each tenant tier owns a bucket that
   refills in *estimated budget tokens* per second (Eq. 1 pricing from
   the shared estimator, so rate limiting is drift-calibrated too: a
   tenant whose jobs run long is charged more per request as the bias
   learns that). A request is shed when its tier's bucket cannot cover
   its estimated budget.

Shed requests are marked ``CANCELLED`` and accounted per tier and per
reason — the shed-rate numbers the cluster benchmark reports.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Tuple

from ..core.request import Request, RequestState, TenantTier
from ..obs import events as _tr
from ..obs import resolve_recorder

SHED_RATE_LIMIT = "rate_limited"
SHED_BACKPRESSURE = "backpressure"
SHED_NO_REPLICA = "no_replica"


@dataclass(frozen=True)
class AdmissionConfig:
    """Front-door limits. Defaults are generous enough that the paper's
    single-replica protocol would pass untouched; stress configurations
    tighten them."""

    # token-bucket capacity (burst) per tier, in estimated budget tokens
    bucket_capacity: Mapping[TenantTier, float] = field(
        default_factory=lambda: {
            TenantTier.PREMIUM: 120_000.0,
            TenantTier.STANDARD: 90_000.0,
            TenantTier.BATCH: 60_000.0,
        })
    # sustained refill, estimated budget tokens per second
    refill_rate: Mapping[TenantTier, float] = field(
        default_factory=lambda: {
            TenantTier.PREMIUM: 4_000.0,
            TenantTier.STANDARD: 3_000.0,
            TenantTier.BATCH: 2_000.0,
        })
    # cluster-wide outstanding estimated-token mass ceiling
    max_cluster_token_mass: float = float("inf")


class TokenBucket:
    """Deterministic continuous-refill token bucket."""

    def __init__(self, capacity: float, rate: float) -> None:
        self.capacity = float(capacity)
        self.rate = float(rate)
        self.level = float(capacity)
        self._t_last = 0.0

    def _refill(self, now: float) -> None:
        if now > self._t_last:
            self.level = min(self.capacity,
                             self.level + self.rate * (now - self._t_last))
            self._t_last = now

    def try_consume(self, cost: float, now: float) -> bool:
        """Debit ``cost`` (estimated budget tokens, Eq. 1 pricing from
        the shared estimator) after refilling to ``now`` (seconds);
        False (no debit) when the bucket cannot cover it."""
        self._refill(now)
        if cost <= self.level:
            self.level -= cost
            return True
        return False

    def peek(self, now: float) -> float:
        """Current level in estimated budget tokens, refilled to
        ``now`` (seconds) without consuming."""
        self._refill(now)
        return self.level


@dataclass
class ShedRecord:
    """One rejected request (per-tier accounting, Sec. II-I style log)."""

    time: float
    req_id: int
    tenant: str
    reason: str
    est_budget: float


class GlobalAdmission:
    """Tenant-rate-limited, backpressure-aware front door."""

    def __init__(self, config: Optional[AdmissionConfig] = None,
                 trace=None) -> None:
        self.cfg = config or AdmissionConfig()
        self.trace = resolve_recorder(trace)
        self.buckets: Dict[TenantTier, TokenBucket] = {
            t: TokenBucket(self.cfg.bucket_capacity[t],
                           self.cfg.refill_rate[t])
            for t in TenantTier
        }
        self.accepted: Dict[TenantTier, int] = {t: 0 for t in TenantTier}
        self.shed: Dict[TenantTier, Dict[str, int]] = {
            t: {} for t in TenantTier}
        self.shed_log: List[ShedRecord] = []

    # ------------------------------------------------------------------
    def offer(self, req: Request, est_budget: float, now: float,
              cluster_token_mass: float) -> Tuple[bool, Optional[str]]:
        """Admit or shed. Returns (admitted, shed_reason)."""
        if cluster_token_mass + est_budget > self.cfg.max_cluster_token_mass:
            return False, self._shed(req, SHED_BACKPRESSURE, est_budget, now)
        if not self.buckets[req.tenant].try_consume(est_budget, now):
            return False, self._shed(req, SHED_RATE_LIMIT, est_budget, now)
        self.accepted[req.tenant] += 1
        if self.trace.enabled:
            self.trace.emit(now, _tr.ADMIT, req_id=req.req_id,
                            tenant=req.tenant.label,
                            est_budget=est_budget)
        return True, None

    def shed_no_replica(self, req: Request, est_budget: float,
                        now: float) -> str:
        """Router found no routable replica (total outage) for an
        already-admitted request: roll back the bucket debit and the
        accept count so the outage is not also charged against the
        tenant's rate limit, then account the shed."""
        bucket = self.buckets[req.tenant]
        bucket._refill(now)
        bucket.level = min(bucket.capacity, bucket.level + est_budget)
        self.accepted[req.tenant] -= 1
        return self._shed(req, SHED_NO_REPLICA, est_budget, now)

    def _shed(self, req: Request, reason: str, est_budget: float,
              now: float) -> str:
        req.state = RequestState.CANCELLED
        per_tier = self.shed[req.tenant]
        per_tier[reason] = per_tier.get(reason, 0) + 1
        self.shed_log.append(ShedRecord(
            time=now, req_id=req.req_id, tenant=req.tenant.label,
            reason=reason, est_budget=est_budget))
        if self.trace.enabled:
            self.trace.emit(now, _tr.SHED, req_id=req.req_id,
                            tenant=req.tenant.label, reason=reason,
                            est_budget=est_budget)
        return reason

    # --- accounting ----------------------------------------------------
    def n_shed(self, tenant: Optional[TenantTier] = None) -> int:
        """Requests shed (count), for one tier or all tiers."""
        tiers = [tenant] if tenant is not None else list(TenantTier)
        return sum(sum(self.shed[t].values()) for t in tiers)

    def n_accepted(self, tenant: Optional[TenantTier] = None) -> int:
        """Requests admitted (count), for one tier or all tiers."""
        tiers = [tenant] if tenant is not None else list(TenantTier)
        return sum(self.accepted[t] for t in tiers)

    def shed_rate(self, tenant: Optional[TenantTier] = None) -> float:
        """shed / (shed + accepted) in [0, 1]; 0.0 with no traffic."""
        shed = self.n_shed(tenant)
        total = shed + self.n_accepted(tenant)
        return shed / total if total else 0.0

    def summary(self) -> dict:
        """JSON-ready accept/shed accounting: counts per tier, shed
        reasons per tier, and overall + per-tier shed rates."""
        return {
            "accepted": {t.label: self.accepted[t] for t in TenantTier},
            "shed": {t.label: dict(self.shed[t]) for t in TenantTier},
            "shed_rate": self.shed_rate(),
            "shed_rate_per_tier": {t.label: self.shed_rate(t)
                                   for t in TenantTier},
        }
