"""Cluster driver over real :class:`ServingEngine` instances.

The same :class:`ClusterRouter` / :class:`GlobalAdmission` front end
that drives the discrete-event cluster simulator, run over N live JAX
continuous-batching engines — the execution-agnostic contract
:class:`DriftScheduler` already honors, lifted one level up. Each
engine owns its own scheduler; all schedulers share one
:class:`AdaptiveTokenEstimator`, so drift feedback from any replica
calibrates routing and admission for the whole cluster.

P/D disaggregation runs for real here: under ``pd_disaggregated``
routing the pool splits into prefill and decode engines, and a
finished prefill *moves its KV pages* — the slot's page contents are
gathered off the source engine's :class:`PagedPool`, carried by a
:class:`KVTransfer` for the modeled link delay, and scattered into
freshly allocated pages on the decode engine (see
``ServingEngine.extract_sequence`` / ``accept_handoff``). The driver
keeps the transfer ledger (``_in_transit``) and mirrors the
simulator's failure contract: transfers sourced at a dead engine are
lost and their requests re-run prefill elsewhere; stranded prefilled
queue entries reset to the pre-prefill state because their pages died
with the pool. Work stealing moves queued work between engines, with
decode-ready steals paying a fresh KV transfer from the victim.

Oracle-EOS caveat (see ``serving/engine.py``): with randomly
initialised smoke models the engines stop each request at its
ground-truth output length rather than a semantic EOS token. Cluster
runs inherit this — observed lengths (and therefore the drift feedback
that routing quality depends on) are the planted ground truth, not
model behaviour. A real deployment swaps in token-id EOS detection per
engine; nothing at the cluster layer changes.

Stepping model: engines advance in lockstep rounds (every engine steps
once per simulated ``dt``); due KV transfers deliver at the start of
each round. There is no cross-engine batching — a request lives on
exactly one replica at a time, as in the simulator.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from ..core.estimator import AdaptiveTokenEstimator, DriftConfig
from ..core.request import Request
from ..core.scheduler import DriftScheduler
from ..obs import events as tr
from ..obs import resolve_recorder
from ..serving.engine import EngineConfig, ServingEngine
from ..serving.metrics import RunMetrics, summarize_run
from .admission import GlobalAdmission
from .replica import Replica, ReplicaRole, ReplicaState
from .router import ClusterRouter, RoutingPolicy


@dataclass
class KVTransfer:
    """One prefill→decode KV movement in flight between engines.

    ``payload`` is the actual page contents (host copies of the source
    pool's K/V pages plus decode-resume scalars) — not a token count.
    ``forced_dst_rid`` pins a stolen transfer to its thief;
    ``cancelled`` marks a transfer whose source engine died before
    delivery (the KV is lost — the failure path already rerouted the
    request for re-prefill)."""

    req: Request
    src_rid: int
    payload: Dict
    arrive_time: float
    forced_dst_rid: Optional[int] = None
    stolen: bool = False
    cancelled: bool = False


class EngineReplica(Replica):
    """Replica backed by a live ServingEngine."""

    def __init__(self, rid: int, engine: ServingEngine,
                 role: ReplicaRole = ReplicaRole.UNIFIED) -> None:
        super().__init__(rid, engine.sched, role=role)
        self.engine = engine

    def inflight_requests(self) -> List[Request]:
        """Requests currently occupying live decode slots."""
        return [s.req for s in self.engine.slots if s.req is not None]

    def busy_workers(self) -> int:
        """1 when any decode slot is active (the engine is one
        worker), else 0 — the cluster utilization numerator."""
        return 1 if self.engine.active_slots() else 0

    def is_idle(self) -> bool:
        """True when nothing is queued or decoding on this engine."""
        return self.queue_depth() == 0 and not self.engine.active_slots()

    def prefix_cached_tokens(self, req: Request) -> int:
        """Resident shared-prefix overlap in this engine's radix KV
        cache (``EngineConfig.prefix_cache``) — the *measured* warmth
        signal ``prefix_aware`` routing scores over real engines. Pure
        probe, like the simulator replica's."""
        return self.engine.prefix_cached_tokens(req)

    def prefix_cache_stats(self) -> dict:
        return self.engine.prefix_cache_stats()


class EngineClusterDriver:
    """Route + admit over N live engines, step them in lockstep.

    Under ``pd_disaggregated`` routing the driver also owns the P/D
    control plane: role assignment (prefill engines get the low rids,
    same as the simulator), the handoff hook on every prefill engine,
    the in-flight KV-transfer ledger, role-aware failure recovery, and
    optional work stealing."""

    def __init__(self, engines: Sequence[ServingEngine],
                 routing: str | RoutingPolicy = "drift_aware",
                 admission: Optional[GlobalAdmission] = None,
                 trace=None, *,
                 n_prefill_replicas: Optional[int] = None,
                 pd_prefill_fraction: float = 0.25,
                 kv_transfer_base: float = 0.002,
                 kv_transfer_per_token: float = 2e-5,
                 work_stealing: bool = False,
                 steal_min_depth: int = 4) -> None:
        if not engines:
            raise ValueError("need at least one engine")
        stores = {id(e.sched.estimator.bias_store) for e in engines}
        if len(stores) != 1:
            raise ValueError(
                "cluster engines must share one AdaptiveTokenEstimator "
                "(build schedulers with DriftScheduler(estimator=shared)); "
                f"got {len(stores)} distinct bias stores")
        self.estimator = engines[0].sched.estimator
        self.trace = resolve_recorder(trace)
        self.router = ClusterRouter(routing, self.estimator,
                                    trace=self.trace)
        self.pd_mode = self.router.policy.name == "pd_disaggregated"
        roles = (self._initial_roles(len(engines), n_prefill_replicas,
                                     pd_prefill_fraction)
                 if self.pd_mode
                 else [ReplicaRole.UNIFIED] * len(engines))
        if self.pd_mode:
            not_paged = [i for i, e in enumerate(engines)
                         if not e.ecfg.paged]
            if not_paged:
                raise ValueError(
                    "engine-side pd_disaggregated moves real KV pages, so "
                    "every engine needs the paged pool "
                    f"(EngineConfig.paged=True); engines {not_paged} are "
                    "not paged")
        self.replicas = [EngineReplica(i, e, role=r)
                         for i, (e, r) in enumerate(zip(engines, roles))]
        if self.trace.enabled:
            # stamp replica ids onto the engines' emissions (only when
            # live — never stomp an explicitly un-traced engine)
            if admission is not None:
                admission.trace = self.trace
            for rep in self.replicas:
                rep.engine.trace = self.trace
                rep.engine.trace_rid = rep.rid
                rep.engine.sched.drift.trace = self.trace
                rep.engine.sched.drift.trace_rid = rep.rid
        for rep in self.replicas:
            if rep.role is ReplicaRole.PREFILL:
                rep.engine.handoff_hook = (
                    lambda slot, req, now, rid=rep.rid:
                    self._on_prefill_done(rid, slot, req, now))
            elif rep.role is ReplicaRole.DECODE:
                # decode replicas attribute drift feedback to the
                # decode phase (phase-scoped bias, same as the sim)
                rep.engine.sched.feedback_phase = "decode"
        self.admission = admission
        self.kv_transfer_base = kv_transfer_base
        self.kv_transfer_per_token = kv_transfer_per_token
        self.work_stealing = work_stealing
        self.steal_min_depth = steal_min_depth
        self._in_transit: Dict[int, KVTransfer] = {}
        self._transfer_heap: List = []
        self._tseq = itertools.count()
        self.n_shed = 0
        self.n_handoffs = 0
        self.n_handoffs_lost = 0
        self.n_stolen = 0
        self.n_rerouted = 0
        self._last_submit = 0.0

    @staticmethod
    def _initial_roles(n: int, n_prefill: Optional[int],
                       fraction: float) -> List[ReplicaRole]:
        """P/D pool shape at t=0: at least one prefill and one decode
        engine; prefill engines get the low rids. Mirrors
        ``ClusterSimulator._initial_roles``."""
        if n < 2:
            raise ValueError("pd_disaggregated needs >= 2 replicas "
                             "(one prefill + one decode)")
        if n_prefill is None:
            n_prefill = round(n * fraction)
        n_prefill = min(max(n_prefill, 1), n - 1)
        return ([ReplicaRole.PREFILL] * n_prefill
                + [ReplicaRole.DECODE] * (n - n_prefill))

    # ------------------------------------------------------------------
    def submit(self, req: Request, now: float) -> bool:
        """Front door: returns False when the request was shed."""
        self._last_submit = max(self._last_submit, now)
        est = self.router.price(req)
        if self.trace.enabled:
            self.trace.emit(now, tr.ARRIVE, req_id=req.req_id,
                            tenant=req.tenant.label, est_budget=est)
        if self.admission is not None:
            mass = sum(r.token_mass() for r in self.replicas)
            ok, _ = self.admission.offer(req, est, now, mass)
            if not ok:
                self.n_shed += 1
                return False
        target = self.router.route(self.replicas, req, now, est_budget=est)
        if target is None:
            if self.admission is not None:
                self.admission.shed_no_replica(req, est, now)
            elif self.trace.enabled:
                # no front door to account (and trace) the shed
                self.trace.emit(now, tr.SHED, req_id=req.req_id,
                                tenant=req.tenant.label,
                                reason="no_replica", est_budget=est)
            self.n_shed += 1
            return False
        if self.trace.enabled and self.admission is None:
            # no front door: placement is the admission decision
            self.trace.emit(now, tr.ADMIT, req_id=req.req_id,
                            tenant=req.tenant.label, est_budget=est)
        # the chosen engine's resident-prefix overlap prices the
        # admission estimate (estimate(cached_tokens=...) discounts
        # T_input only; 0 without a prefix cache) — fed from an actual
        # tree lookup, same contract as the cluster simulator
        req.expected_cached_tokens = target.prefix_cached_tokens(req)
        target.sched.submit(req, now)
        return True

    # --- P/D handoff: real KV page movement ---------------------------
    def _kv_delay(self, req: Request) -> float:
        """Modeled KV-transfer time (s): base link cost + per-prompt-
        token page movement. The *contents* move for real; only the
        wire time is modeled."""
        return (self.kv_transfer_base
                + self.kv_transfer_per_token * req.prompt_tokens)

    def _on_prefill_done(self, rid: int, slot: int, req: Request,
                         now: float) -> bool:
        """Handoff hook on prefill engines, called by the engine the
        moment a slot's last prompt chunk lands (its first token
        exists; TTFT was just stamped): snapshot the slot's KV pages
        off the pool, start the transfer, and return True so the
        engine releases the slot without completing the request (no
        ``sched.complete`` → no drift feedback here — the at-most-once
        contract; the decode engine observes the full output)."""
        rep = self.replicas[rid]
        payload = rep.engine.extract_sequence(slot)
        req.prefill_rid = rid
        rep.n_handoffs_out += 1
        self.n_handoffs += 1
        if self.trace.enabled:
            self.trace.emit(now, tr.HANDOFF, req_id=req.req_id,
                            rid=rid, tenant=req.tenant.label,
                            edge="out")
        t = KVTransfer(req=req, src_rid=rid, payload=payload,
                       arrive_time=now + self._kv_delay(req))
        self._queue_transfer(t)
        return True

    def _queue_transfer(self, t: KVTransfer) -> None:
        self._in_transit[t.req.req_id] = t
        heapq.heappush(self._transfer_heap,
                       (t.arrive_time, next(self._tseq), t))

    def _deliver_transfers(self, now: float) -> None:
        """Land every due KV transfer on a decode engine. A stolen
        transfer is pinned to its thief while the thief is routable;
        with no decode-capable engine up, the KV waits and retries
        (source failure meanwhile cancels it and forces re-prefill)."""
        while self._transfer_heap and self._transfer_heap[0][0] <= now:
            _, _, t = heapq.heappop(self._transfer_heap)
            if t.cancelled:
                continue
            self._in_transit.pop(t.req.req_id, None)
            dst: Optional[EngineReplica] = None
            if t.forced_dst_rid is not None:
                cand = self.replicas[t.forced_dst_rid]
                if cand.routable():
                    dst = cand
            if dst is None:
                dst = self.router.route_decode(self.replicas, t.req, now)
            if dst is None:
                t.arrive_time = now + 1.0
                self._queue_transfer(t)
                continue
            t.req.handoff_time = now
            t.req.decode_rid = dst.rid
            if t.stolen:
                dst.n_stolen_in += 1   # credited where the work landed
            else:
                dst.n_handoffs_in += 1
            if self.trace.enabled:
                self.trace.emit(now, tr.HANDOFF, req_id=t.req.req_id,
                                rid=dst.rid, tenant=t.req.tenant.label,
                                edge="in", src_rid=t.src_rid,
                                stolen=t.stolen)
            dst.engine.accept_handoff(t.req, t.payload)

    # --- work stealing -------------------------------------------------
    def _run_steals(self, now: float) -> None:
        """Execute the router's steal plans over live engines.
        Not-yet-prefilled work moves instantly (it carries no state);
        decode-ready work detaches its pending KV payload from the
        victim engine and re-transfers it to the thief (the pages live
        on the victim — a steal is a second page movement)."""
        for plan in self.router.plan_steals(
                self.replicas, now, min_victim_depth=self.steal_min_depth):
            victim = self.replicas[plan.victim_rid]
            thief = self.replicas[plan.thief_rid]
            queued = victim.sched.queues.drain()
            if plan.req_ids:
                chosen = set(plan.req_ids)
                keep = [r for r in queued if r.req_id not in chosen]
                stolen = [r for r in queued if r.req_id in chosen]
            else:
                keep, stolen = (queued[:len(queued) - plan.n],
                                queued[len(queued) - plan.n:])
            for req in keep:
                victim.sched.queues.enqueue(req, req.enqueue_time)
            for req in stolen:
                req.n_steals += 1
                victim.n_stolen_away += 1
                self.n_stolen += 1
                if self.trace.enabled:
                    self.trace.emit(now, tr.STEAL, req_id=req.req_id,
                                    rid=thief.rid,
                                    tenant=req.tenant.label,
                                    victim=victim.rid,
                                    decode_ready=req.prefill_end
                                    is not None)
                payload = victim.engine.pop_pending_injection(req.req_id)
                if payload is not None:
                    # decode-ready: the KV re-transfers from the victim;
                    # n_stolen_in is credited at delivery (the planned
                    # thief may become unroutable mid-transfer)
                    self._queue_transfer(KVTransfer(
                        req=req, src_rid=victim.rid, payload=payload,
                        arrive_time=now + self._kv_delay(req),
                        forced_dst_rid=thief.rid, stolen=True))
                else:
                    thief.n_stolen_in += 1
                    thief.sched.queues.enqueue(req, req.enqueue_time)

    # --- failure handling ----------------------------------------------
    def fail_replica(self, rid: int, now: float) -> None:
        """Role-aware engine failure; the simulator's contract over
        real pools.

        1. KV transfers *sourced* at the dead engine are lost — the
           pages existed only in the payload and the dead pool: those
           requests reset to the pre-prefill state and re-run prefill
           elsewhere (estimate kept; feedback never fired, so nothing
           double-counts).
        2. In-flight slots abort (``ServingEngine.abort_all`` frees the
           pages and drops pending injections).
        3. The stranded queue reroutes to surviving engines. Work that
           had already prefilled lost its KV with the pool, so it
           resets and rejoins via stage-1 routing (prefill-capable
           pool under P/D).
        """
        rep = self.replicas[rid]
        if rep.state in (ReplicaState.STOPPED, ReplicaState.FAILED):
            return
        rep.state = ReplicaState.FAILED
        if self.trace.enabled:
            self.trace.emit(now, tr.REPLICA_FAIL, rid=rid,
                            role=rep.role.value)
        # (1) cancel in-transit transfers whose KV source died
        for t in [t for t in self._in_transit.values()
                  if t.src_rid == rid]:
            t.cancelled = True
            del self._in_transit[t.req.req_id]
            self.n_handoffs_lost += 1
            if t.stolen:
                # an undelivered steal never happened: unwind the
                # take-side accounting so the flow counters balance
                t.req.n_steals -= 1
                rep.n_stolen_away -= 1
                self.n_stolen -= 1
            t.req.reset_for_reprefill()
            self._reroute_stranded(rep, t.req, now)
        # (2) abort in-flight slots
        inflight = rep.engine.abort_all(now)
        for req in inflight:
            if req.prefill_end is not None:
                req.reset_for_reprefill()   # KV died with the pool
            else:
                req.reset_for_retry()
        # (3) reroute the whole stranded queue to surviving engines
        stranded = rep.sched.queues.drain()
        for req in stranded:
            if req.prefill_end is not None:
                req.reset_for_reprefill()
        for req in reversed(inflight + stranded):   # front-pushes: keep order
            self._reroute_stranded(rep, req, now)

    def _reroute_stranded(self, rep: EngineReplica, req: Request,
                          now: float) -> None:
        """Route one stranded request off ``rep``; with the whole pool
        down it parks on the failed engine and is served after
        recovery. The admission estimate travels with the request, but
        its *cache discount* belonged to the dead engine's residency:
        restore the full-prompt budget, then re-discount by the
        surviving engine's own resident overlap."""
        est = req.estimate
        if est is not None and est.cached_tokens:
            est.t_budget += est.cached_tokens
            est.cached_tokens = 0
            req.expected_cached_tokens = 0
        target = self.router.route(self.replicas, req, now, exclude=(rep,))
        if target is None:
            rep.sched.queues.enqueue(req, req.enqueue_time, front=True)
            return
        if est is not None:
            overlap = target.prefix_cached_tokens(req)
            if overlap:
                est.t_budget -= overlap
                est.cached_tokens = overlap
                req.expected_cached_tokens = overlap
        rep.n_rerouted_away += 1
        self.n_rerouted += 1
        target.sched.queues.enqueue(req, req.enqueue_time, front=True)

    def recover_replica(self, rid: int, now: float) -> None:
        """Bring a failed engine back (empty pool, cold caches — the
        engine's state died with the failure and ``abort_all`` already
        reset it)."""
        rep = self.replicas[rid]
        if rep.state is not ReplicaState.FAILED:
            return
        rep.state = ReplicaState.ACTIVE
        if self.trace.enabled:
            self.trace.emit(now, tr.REPLICA_RECOVER, rid=rid,
                            role=rep.role.value)

    # ------------------------------------------------------------------
    def step(self, now: float) -> int:
        """One lockstep round across all replicas; returns completions.
        Due KV transfers land first so a decode engine can dispatch
        them this very round, then engines step, then idle engines
        steal from backlogged peers."""
        self._deliver_transfers(now)
        done = sum(rep.engine.step(now) for rep in self.replicas
                   if rep.routable())
        if self.work_stealing:
            self._run_steals(now)
        return done

    def _drained(self) -> bool:
        return (all(rep.is_idle() for rep in self.replicas)
                and not self._in_transit)

    def run_until_drained(self, *, max_steps: int = 100_000,
                          dt: float = 1.0) -> RunMetrics:
        """Step every engine in lockstep (``dt`` simulated seconds per
        round) until the whole pool is idle — queues, slots, *and* the
        KV-transfer ledger — or ``max_steps`` rounds pass, then
        aggregate the familiar RunMetrics."""
        # start the clock at the latest submit time so completion
        # timestamps never precede arrivals (negative e2e latencies)
        now = self._last_submit
        if self.trace.enabled:
            self.trace.begin_segment(
                f"engine_cluster:{self.router.policy.name}"
                f"/{self.replicas[0].sched.policy.name}")
        for _ in range(max_steps):
            if self._drained():
                break
            self.step(now)
            now += dt
        completed: List[Request] = []
        busy = 0.0
        for rep in self.replicas:
            completed.extend(rep.sched.completed)
            busy += float(rep.engine.busy_steps) * dt
        completed.sort(key=lambda r: (r.completion_time, r.req_id))
        return summarize_run(
            self.replicas[0].sched.policy.name,
            self.estimator.config.bias_enabled,
            completed, busy_time=busy / len(self.replicas))


def make_engine_cluster(model_cfg, params, n_replicas: int, *,
                        policy: str = "fifo",
                        routing: str | RoutingPolicy = "drift_aware",
                        engine_config: Optional[EngineConfig] = None,
                        drift_config: Optional[DriftConfig] = None,
                        admission: Optional[GlobalAdmission] = None,
                        trace=None,
                        n_prefill_replicas: Optional[int] = None,
                        pd_prefill_fraction: float = 0.25,
                        kv_transfer_base: float = 0.002,
                        kv_transfer_per_token: float = 2e-5,
                        work_stealing: bool = False,
                        steal_min_depth: int = 4) -> EngineClusterDriver:
    """Convenience constructor: N engines over one model's params (the
    common deployment — replicas are copies of the same model), all
    schedulers sharing one estimator."""
    estimator = AdaptiveTokenEstimator(drift_config or DriftConfig())
    engines = [
        ServingEngine(model_cfg, params,
                      DriftScheduler(policy=policy, estimator=estimator),
                      engine_config)
        for _ in range(n_replicas)
    ]
    return EngineClusterDriver(
        engines, routing=routing, admission=admission, trace=trace,
        n_prefill_replicas=n_prefill_replicas,
        pd_prefill_fraction=pd_prefill_fraction,
        kv_transfer_base=kv_transfer_base,
        kv_transfer_per_token=kv_transfer_per_token,
        work_stealing=work_stealing, steal_min_depth=steal_min_depth)
