"""Thin cluster driver over real :class:`ServingEngine` instances.

The same :class:`ClusterRouter` / :class:`GlobalAdmission` front end
that drives the discrete-event cluster simulator, run over N live JAX
continuous-batching engines — the execution-agnostic contract
:class:`DriftScheduler` already honors, lifted one level up. Each
engine owns its own scheduler; all schedulers share one
:class:`AdaptiveTokenEstimator`, so drift feedback from any replica
calibrates routing and admission for the whole cluster.

Oracle-EOS caveat (see ``serving/engine.py``): with randomly
initialised smoke models the engines stop each request at its
ground-truth output length rather than a semantic EOS token. Cluster
runs inherit this — observed lengths (and therefore the drift feedback
that routing quality depends on) are the planted ground truth, not
model behaviour. A real deployment swaps in token-id EOS detection per
engine; nothing at the cluster layer changes.

Stepping model: engines advance in lockstep rounds (every engine steps
once per simulated ``dt``). There is no cross-engine batching — a
request lives on exactly one replica, as in the simulator.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

from ..core.estimator import AdaptiveTokenEstimator, DriftConfig
from ..core.request import Request
from ..core.scheduler import DriftScheduler
from ..obs import events as tr
from ..obs import resolve_recorder
from ..serving.engine import EngineConfig, ServingEngine
from ..serving.metrics import RunMetrics, summarize_run
from .admission import GlobalAdmission
from .replica import Replica
from .router import ClusterRouter, RoutingPolicy


class EngineReplica(Replica):
    """Replica backed by a live ServingEngine."""

    def __init__(self, rid: int, engine: ServingEngine) -> None:
        super().__init__(rid, engine.sched)
        self.engine = engine

    def inflight_requests(self) -> List[Request]:
        """Requests currently occupying live decode slots."""
        return [s.req for s in self.engine.slots if s.req is not None]

    def busy_workers(self) -> int:
        """1 when any decode slot is active (the engine is one
        worker), else 0 — the cluster utilization numerator."""
        return 1 if self.engine.active_slots() else 0

    def is_idle(self) -> bool:
        """True when nothing is queued or decoding on this engine."""
        return self.queue_depth() == 0 and not self.engine.active_slots()

    def prefix_cached_tokens(self, req: Request) -> int:
        """Resident shared-prefix overlap in this engine's radix KV
        cache (``EngineConfig.prefix_cache``) — the *measured* warmth
        signal ``prefix_aware`` routing scores over real engines. Pure
        probe, like the simulator replica's."""
        return self.engine.prefix_cached_tokens(req)

    def prefix_cache_stats(self) -> dict:
        return self.engine.prefix_cache_stats()


class EngineClusterDriver:
    """Route + admit over N live engines, step them in lockstep."""

    def __init__(self, engines: Sequence[ServingEngine],
                 routing: str | RoutingPolicy = "drift_aware",
                 admission: Optional[GlobalAdmission] = None,
                 trace=None) -> None:
        if not engines:
            raise ValueError("need at least one engine")
        stores = {id(e.sched.estimator.bias_store) for e in engines}
        if len(stores) != 1:
            raise ValueError(
                "cluster engines must share one AdaptiveTokenEstimator "
                "(build schedulers with DriftScheduler(estimator=shared)); "
                f"got {len(stores)} distinct bias stores")
        self.replicas = [EngineReplica(i, e) for i, e in enumerate(engines)]
        self.estimator = engines[0].sched.estimator
        self.trace = resolve_recorder(trace)
        if self.trace.enabled:
            # stamp replica ids onto the engines' emissions (only when
            # live — never stomp an explicitly un-traced engine)
            if admission is not None:
                admission.trace = self.trace
            for rep in self.replicas:
                rep.engine.trace = self.trace
                rep.engine.trace_rid = rep.rid
                rep.engine.sched.drift.trace = self.trace
                rep.engine.sched.drift.trace_rid = rep.rid
        self.router = ClusterRouter(routing, self.estimator,
                                    trace=self.trace)
        self.admission = admission
        self.n_shed = 0
        self._last_submit = 0.0

    # ------------------------------------------------------------------
    def submit(self, req: Request, now: float) -> bool:
        """Front door: returns False when the request was shed."""
        self._last_submit = max(self._last_submit, now)
        est = self.router.price(req)
        if self.trace.enabled:
            self.trace.emit(now, tr.ARRIVE, req_id=req.req_id,
                            tenant=req.tenant.label, est_budget=est)
        if self.admission is not None:
            mass = sum(r.token_mass() for r in self.replicas)
            ok, _ = self.admission.offer(req, est, now, mass)
            if not ok:
                self.n_shed += 1
                return False
        target = self.router.route(self.replicas, req, now, est_budget=est)
        if target is None:
            if self.admission is not None:
                self.admission.shed_no_replica(req, est, now)
            elif self.trace.enabled:
                # no front door to account (and trace) the shed
                self.trace.emit(now, tr.SHED, req_id=req.req_id,
                                tenant=req.tenant.label,
                                reason="no_replica", est_budget=est)
            self.n_shed += 1
            return False
        if self.trace.enabled and self.admission is None:
            # no front door: placement is the admission decision
            self.trace.emit(now, tr.ADMIT, req_id=req.req_id,
                            tenant=req.tenant.label, est_budget=est)
        # the chosen engine's resident-prefix overlap prices the
        # admission estimate (estimate(cached_tokens=...) discounts
        # T_input only; 0 without a prefix cache) — fed from an actual
        # tree lookup, same contract as the cluster simulator
        req.expected_cached_tokens = target.prefix_cached_tokens(req)
        target.sched.submit(req, now)
        return True

    def step(self, now: float) -> int:
        """One lockstep round across all replicas; returns completions."""
        return sum(rep.engine.step(now) for rep in self.replicas
                   if rep.routable())

    def run_until_drained(self, *, max_steps: int = 100_000,
                          dt: float = 1.0) -> RunMetrics:
        """Step every engine in lockstep (``dt`` simulated seconds per
        round) until the whole pool is idle or ``max_steps`` rounds
        pass, then aggregate the familiar RunMetrics."""
        # start the clock at the latest submit time so completion
        # timestamps never precede arrivals (negative e2e latencies)
        now = self._last_submit
        if self.trace.enabled:
            self.trace.begin_segment(
                f"engine_cluster:{self.router.policy.name}"
                f"/{self.replicas[0].sched.policy.name}")
        for _ in range(max_steps):
            if all(rep.is_idle() for rep in self.replicas):
                break
            self.step(now)
            now += dt
        completed: List[Request] = []
        busy = 0.0
        for rep in self.replicas:
            completed.extend(rep.sched.completed)
            busy += float(rep.engine.busy_steps) * dt
        completed.sort(key=lambda r: (r.completion_time, r.req_id))
        return summarize_run(
            self.replicas[0].sched.policy.name,
            self.estimator.config.bias_enabled,
            completed, busy_time=busy / len(self.replicas))


def make_engine_cluster(model_cfg, params, n_replicas: int, *,
                        policy: str = "fifo",
                        routing: str | RoutingPolicy = "drift_aware",
                        engine_config: Optional[EngineConfig] = None,
                        drift_config: Optional[DriftConfig] = None,
                        admission: Optional[GlobalAdmission] = None,
                        trace=None) -> EngineClusterDriver:
    """Convenience constructor: N engines over one model's params (the
    common deployment — replicas are copies of the same model), all
    schedulers sharing one estimator."""
    estimator = AdaptiveTokenEstimator(drift_config or DriftConfig())
    engines = [
        ServingEngine(model_cfg, params,
                      DriftScheduler(policy=policy, estimator=estimator),
                      engine_config)
        for _ in range(n_replicas)
    ]
    return EngineClusterDriver(engines, routing=routing,
                               admission=admission, trace=trace)
