"""Cluster-level discrete-event simulator: N replicas, one event loop.

Composes N per-replica :class:`~repro.serving.simulator.WorkerSimulator`
instances (each driving its own :class:`DriftScheduler`, all sharing
one :class:`AdaptiveTokenEstimator`) under a single event heap and a
single seed:

    arrival -> GlobalAdmission (rate limits, backpressure; shed or pass)
            -> ClusterRouter   (round_robin / least_loaded /
                                drift_aware / tenant_affinity /
                                prefix_aware / pd_disaggregated)
            -> replica's DriftScheduler -> replica workers

With ``ClusterConfig.prefix_cache=True`` (step engine required) every
replica models a radix shared-prefix KV cache: placement stamps the
chosen replica's resident-prefix overlap into
``Request.expected_cached_tokens`` (the admission estimate prices only
the uncached suffix), prefill starts at the cached boundary, and
``prefix_aware`` routing scores replicas by measured residency. A
replica failure wipes that replica's cache along with its KV pool —
stranded work re-prefills in full wherever it lands.

Under ``pd_disaggregated`` routing the lifecycle is two-stage: the
request prefills on a PREFILL-role replica, its KV moves to a
DECODE-role replica via a modeled transfer delay, and decode completes
there (drift feedback fires once, attributed to the decode phase).
Optional work stealing lets idle replicas take queued work from
overloaded role-compatible peers at every control tick.

Replica events (batch_start/batch_done/step_done/fail/repair) emitted
by a replica's simulator are routed back through the shared heap via
the sink mechanism, so cross-replica ordering is exact and
deterministic. With ``ClusterConfig.step_engine=True`` every replica
runs the iteration-level continuous-batching engine
(``serving.simulator`` module docstring): unified replicas report
honest per-request TTFT (first decoded token, not batch end), P/D
prefill handoffs fire the moment a prompt's last chunk lands rather
than at batch drain, and preemption/work-stealing observe replica state
at iteration boundaries.

Fault injection composes with the per-worker story: a replica failure
aborts its in-flight batches (re-queued with estimates preserved, no
bias feedback — the at-most-once contract), then the cluster drains the
failed replica's queue and *reroutes* the stranded requests to the
surviving replicas. The replica rejoins the routable pool when its
workers repair.

The optional :class:`Autoscaler` runs at every control tick: scale-up
provisions a fresh replica (cold start delay before it serves; its
scheduler shares the cluster estimator so it is calibration-warm from
its first request), scale-down marks the least-loaded replica DRAINING
(finishes its backlog, takes no new work, then leaves the pool).
"""

from __future__ import annotations

import heapq
import itertools
import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from ..core.estimator import AdaptiveTokenEstimator, DriftConfig
from ..core.request import Request
from ..core.scheduler import DriftScheduler
from ..obs import events as tr
from ..obs import resolve_recorder
from ..serving.cost_model import (CostModel, L4_QWEN_1_8B, decode_view,
                                  prefill_view)
from ..serving.simulator import (SimConfig, WorkerSimulator,
                                 make_worker_simulator)
from ..workload.generator import ArrivalPlan
from .admission import AdmissionConfig, GlobalAdmission
from .autoscaler import (SCALE_DOWN, SCALE_UP, Autoscaler, RoleAutoscaler)
from .metrics import ClusterMetrics, summarize_cluster
from .replica import Replica, ReplicaRole, ReplicaState
from .router import ClusterRouter, RoutingPolicy


@dataclass(frozen=True)
class ClusterConfig:
    """Cluster topology + protocol knobs. Times in seconds, masses in
    estimated budget tokens (Eq. 1), counts in requests/replicas."""

    n_replicas: int = 4
    workers_per_replica: int = 1
    routing: str = "drift_aware"
    scheduler_policy: str = "fifo"
    batch_capacity: int = 32          # per replica (paper Sec. III-B)
    batch_wait: float = 0.01
    # --- iteration-level execution core (serving.simulator docstring):
    # step_engine=False keeps the calibrated atomic-batch pricing; True
    # runs every replica on the continuous-batching step engine —
    # unified replicas then report honest per-request TTFT, and P/D
    # handoffs / preemption / work stealing land at iteration
    # boundaries. chunk_prefill_tokens budgets prefill tokens per
    # iteration (None = unbounded); continuous_joins=False degenerates
    # to the atomic/parity contract; max_new_per_step caps slot
    # admissions per iteration (DriftScheduler.dispatch_step).
    step_engine: bool = False
    chunk_prefill_tokens: Optional[int] = None
    continuous_joins: bool = True
    max_new_per_step: Optional[int] = None
    # --- execution-core backend (serving.vector_sim): "object" keeps
    # the per-Request step engine; "vector" provisions every replica as
    # a StepVectorizedWorkerSimulator, which epoch-batches full
    # pure-decode batches between cluster-visible events (requires
    # step_engine; incompatible with pd_disaggregated, whose prefill
    # replicas need per-request completion hooks).
    backend: str = "object"
    # --- shared-prefix KV cache (radix tree per replica; requires
    # step_engine). Replicas skip prefilling resident full pages of a
    # request's shared prompt prefix; `prefix_aware` routing scores
    # replicas by that residency; the router stamps the chosen
    # replica's overlap into Request.expected_cached_tokens so the
    # admission estimate prices only the uncached suffix. Replica
    # failure invalidates the replica's whole cache (KV dies with it).
    prefix_cache: bool = False
    prefix_cache_pages: int = 4096
    prefix_page_tokens: int = 128     # shareable-page granularity (tokens)
    control_interval: float = 1.0     # autoscaler / telemetry cadence
    max_time: float = 1e6             # hard stop against pathological stalls
    # replica-level fault injection: (absolute time, replica id)
    fail_events: Tuple[Tuple[float, int], ...] = ()
    repair_time: float = 30.0
    seed: int = 0
    # --- P/D disaggregation (active when routing == "pd_disaggregated")
    # explicit prefill-pool size; None derives it from the fraction
    n_prefill_replicas: Optional[int] = None
    pd_prefill_fraction: float = 0.25     # prefill share of the pool
    # modeled KV-transfer time for one handoff:
    #   kv_transfer_base + kv_transfer_per_token * prompt_tokens  (s)
    # ~PCIe/NVLink-era page migration: ms-scale, prompt-length driven
    kv_transfer_base: float = 0.002
    kv_transfer_per_token: float = 2e-5
    # --- cross-replica work stealing (any routing mode)
    work_stealing: bool = False
    steal_min_depth: int = 4          # victim queue depth before stealing


@dataclass
class Handoff:
    """One prefill→decode KV transfer in flight.

    Departs the source (prefill) replica when its prefill batch
    finishes; arrives ``kv_transfer`` seconds later, at which point the
    decode replica is chosen and the request enqueued there. If the
    source replica fails before arrival the KV is lost and the request
    re-runs prefill (``cancelled`` marks the dead transfer).
    ``forced_dst_rid`` pins the destination (work stealing re-transfers
    KV to a specific thief)."""

    req: Request
    src_rid: int
    forced_dst_rid: Optional[int] = None
    stolen: bool = False           # this transfer carries stolen work
    cancelled: bool = False


class SimReplica(Replica):
    """Replica backed by an externally-driven WorkerSimulator."""

    def __init__(self, rid: int, scheduler: DriftScheduler,
                 sim: WorkerSimulator,
                 role: ReplicaRole = ReplicaRole.UNIFIED) -> None:
        super().__init__(rid, scheduler, role=role)
        self.sim = sim

    def inflight_requests(self) -> List[Request]:
        """Requests executing on this replica's workers right now."""
        return self.sim.inflight_requests()

    def busy_workers(self) -> int:
        """Workers mid-batch (numerator of the utilization signal)."""
        return self.sim.n_busy_workers()

    def alive_workers(self) -> int:
        """Non-failed workers (denominator of the utilization signal)."""
        return self.sim.n_alive_workers()

    def is_idle(self) -> bool:
        """True when nothing is queued or in flight here."""
        return self.sim.is_idle()

    def prefix_cached_tokens(self, req: Request) -> int:
        """Resident shared-prefix overlap in this replica's KV cache
        (pure probe — see the base class contract)."""
        return self.sim.prefix_cached_tokens(req)

    def prefix_cache_stats(self) -> dict:
        return self.sim.prefix_cache_stats()

    def accept(self, req: Request, now: float) -> None:
        """Admit a routed request (full admission path: estimate, log,
        enqueue) and kick dispatch."""
        self.sim.handle_event(now, "arrival", req)

    def accept_reroute(self, req: Request, now: float) -> None:
        """Take over a request stranded on a failed replica. The
        original estimate and enqueue timestamp travel with it (no
        re-estimation, no new admission record, no bias feedback) —
        the cluster analogue of the head-of-queue readmit contract."""
        self.sched.queues.enqueue(req, req.enqueue_time, front=True)
        self.sim.handle_event(now, "kick", None)

    def accept_handoff(self, req: Request, now: float, *,
                       record: bool = True) -> None:
        """Receive a prefilled request whose KV transfer just landed
        (P/D path, stage 2). Joins the back of its tenant queue with the
        original enqueue timestamp (FIFO ordering stays admission-
        ordered); the admission estimate travels untouched — decode
        placement already consumed it, and bias feedback fires only at
        decode completion. ``record=False`` skips the ``n_handoffs_in``
        credit (stolen re-transfers count under the steal counters
        instead, keeping handoff in/out conservation exact)."""
        if record:
            self.n_handoffs_in += 1
        self.sched.queues.enqueue(req, req.enqueue_time)
        self.sim.handle_event(now, "kick", None)

    def accept_steal(self, req: Request, now: float) -> None:
        """Receive a queued request stolen from an overloaded peer.
        Estimate and enqueue timestamp preserved (stealing must not
        re-price or re-order work it moves)."""
        self.n_stolen_in += 1
        self.sched.queues.enqueue(req, req.enqueue_time)
        self.sim.handle_event(now, "kick", None)


@dataclass
class ClusterTelemetry:
    """One control-tick sample: active/starting replica counts, total
    queued estimated-token mass (Eq. 1), busy/alive utilization."""

    time: float
    n_active: int
    n_starting: int
    queue_mass: float
    utilization: float


class ClusterSimulator:
    """One event loop over N replicas, a router, and a front door.

    With ``routing="pd_disaggregated"`` the pool is role-split and the
    request lifecycle becomes the two-stage pipeline::

        admit -> prefill replica -> (KV transfer) -> decode replica
              -> complete (drift feedback, attributed to "decode")

    With ``work_stealing=True`` idle replicas additionally steal half
    the queue of their most-backlogged role-compatible peer at every
    control tick (decode-ready work pays a fresh KV transfer).
    """

    def __init__(self, plan: ArrivalPlan,
                 config: Optional[ClusterConfig] = None,
                 cost_model: Optional[CostModel] = None,
                 drift_config: Optional[DriftConfig] = None,
                 admission: Optional[GlobalAdmission] = None,
                 autoscaler: Optional[Autoscaler] = None,
                 routing: Optional[RoutingPolicy] = None,
                 trace=None) -> None:
        self.plan = plan
        self.cfg = config or ClusterConfig()
        self.cost = cost_model or L4_QWEN_1_8B
        self.rng = random.Random(self.cfg.seed)   # one seed, shared
        self.estimator = AdaptiveTokenEstimator(drift_config or DriftConfig())
        self.admission = admission
        self.autoscaler = autoscaler
        self.trace = resolve_recorder(trace)
        if self.trace.enabled:
            # the front door and the control plane emit into the same
            # recorder the replicas use (cluster-scope events: rid=None)
            if admission is not None:
                admission.trace = self.trace
            if autoscaler is not None:
                autoscaler.trace = self.trace
        self._arrived: set = set()     # req_ids already ARRIVE-traced
        self.router = ClusterRouter(routing or self.cfg.routing,
                                    self.estimator, trace=self.trace)
        self.pd_mode = self.router.policy.name == "pd_disaggregated"
        if self.cfg.backend == "vector" and self.pd_mode:
            raise ValueError(
                "ClusterConfig.backend='vector' is incompatible with "
                "pd_disaggregated routing: prefill replicas complete "
                "through per-request hooks the vectorized core does "
                "not expose. Use backend='object' for P/D runs.")
        self.replicas: List[SimReplica] = []
        self.telemetry: List[ClusterTelemetry] = []
        self.n_rerouted = 0
        self.n_handoffs = 0            # prefill→decode transfers initiated
        self.n_handoffs_lost = 0       # transfers cancelled by src failure
        self.n_stolen = 0              # requests moved by work stealing
        self.completed_total = 0
        self.phase_boundary = 0.0
        self._in_transit: Dict[int, Handoff] = {}   # req_id -> live handoff
        self._events: List[tuple] = []
        self._eseq = itertools.count()
        self._rid_seq = itertools.count()
        roles = self._initial_roles()
        # the pool shape actually built — handed to a RoleAutoscaler
        # whose config leaves target_prefill_fraction unset, so scaling
        # never fights a non-default initial split
        self._pd_target_fraction: Optional[float] = (
            roles.count(ReplicaRole.PREFILL) / len(roles)
            if self.pd_mode else None)
        for role in roles:
            self._provision_replica(ReplicaState.ACTIVE, role)

    def _initial_roles(self) -> List[ReplicaRole]:
        """Pool shape at t=0: all UNIFIED, or the P/D split (at least
        one prefill and one decode replica; prefill replicas get the
        low rids)."""
        n = self.cfg.n_replicas
        if not self.pd_mode:
            return [ReplicaRole.UNIFIED] * n
        if n < 2:
            raise ValueError("pd_disaggregated needs >= 2 replicas "
                             "(one prefill + one decode)")
        n_prefill = self.cfg.n_prefill_replicas
        if n_prefill is None:
            n_prefill = round(n * self.cfg.pd_prefill_fraction)
        n_prefill = min(max(n_prefill, 1), n - 1)
        return ([ReplicaRole.PREFILL] * n_prefill
                + [ReplicaRole.DECODE] * (n - n_prefill))

    # ------------------------------------------------------------------
    def _push(self, t: float, kind: str, payload=None) -> None:
        heapq.heappush(self._events, (t, next(self._eseq), kind, payload))

    def _provision_replica(self, state: ReplicaState,
                           role: ReplicaRole = ReplicaRole.UNIFIED
                           ) -> SimReplica:
        """Create one replica (shared estimator, shared heap, shared
        seed) with a phase-scoped cost model and completion behaviour
        matching its role: prefill replicas hand finished prefills off
        instead of completing them; decode replicas attribute drift
        feedback to the "decode" phase."""
        rid = next(self._rid_seq)
        sched = DriftScheduler(policy=self.cfg.scheduler_policy,
                               estimator=self.estimator,
                               max_new_per_step=self.cfg.max_new_per_step)
        cost = self.cost
        hook = None
        phase = "unified"
        if role is ReplicaRole.PREFILL:
            cost = prefill_view(self.cost)
            phase = "prefill"
            hook = (lambda req, now, rid=rid:
                    self._on_prefill_done(rid, req, now))
        elif role is ReplicaRole.DECODE:
            cost = decode_view(self.cost)
            phase = "decode"
            sched.feedback_phase = "decode"
        sim = make_worker_simulator(
            sched,
            config=SimConfig(
                batch_capacity=self.cfg.batch_capacity,
                batch_wait=self.cfg.batch_wait,
                n_workers=self.cfg.workers_per_replica,
                step_engine=self.cfg.step_engine,
                chunk_prefill_tokens=self.cfg.chunk_prefill_tokens,
                continuous_joins=self.cfg.continuous_joins,
                prefix_cache=self.cfg.prefix_cache,
                prefix_cache_pages=self.cfg.prefix_cache_pages,
                prefix_page_tokens=self.cfg.prefix_page_tokens,
                phase=phase,
                repair_time=self.cfg.repair_time,
                backend=self.cfg.backend,
                seed=self.cfg.seed),
            cost_model=cost,
            sink=lambda t, kind, payload, rid=rid:
                self._push(t, "replica", (rid, kind, payload)),
            rng=self.rng,
            complete_hook=hook,
            trace=self.trace)
        sim.trace_rid = rid
        sched.drift.trace_rid = rid
        rep = SimReplica(rid, sched, sim, role=role)
        rep.state = state
        self.replicas.append(rep)
        return rep

    # ------------------------------------------------------------------
    def _n_shed(self) -> int:
        return self.admission.n_shed() if self.admission else 0

    def _processed(self) -> int:
        return self.completed_total + self._n_shed()

    def cluster_token_mass(self) -> float:
        """Outstanding estimated work (Eq. 1 budgets) across the whole
        cluster: queued + executing on live replicas, plus requests
        whose KV is mid-transfer between prefill and decode replicas
        (they are nowhere else, but their work is still owed)."""
        from .replica import _budget
        return (sum(r.token_mass() for r in self.replicas
                    if r.state is not ReplicaState.STOPPED)
                + sum(_budget(h.req) for h in self._in_transit.values()))

    # ------------------------------------------------------------------
    def run(self) -> ClusterMetrics:
        """Drive the whole cluster to completion (every request
        completed or shed, or ``max_time`` reached) and summarize."""
        cfg = self.cfg
        n_start = cfg.n_replicas
        n_cal = len(self.plan.calibration)
        total = len(self.plan)
        if self.trace.enabled:
            self.trace.begin_segment(
                f"cluster:{self.router.policy.name}"
                f"/{cfg.scheduler_policy}"
                f"{':step' if cfg.step_engine else ''}")
        for t, req in self.plan.calibration:
            self._push(t, "arrival", req)
        for ft, rid in cfg.fail_events:
            self._push(ft, "replica_fail", rid)
        self._push(0.0, "control", None)

        stress_released = n_cal >= total
        now = 0.0
        while self._events and self._processed() < total:
            now, _, kind, payload = heapq.heappop(self._events)
            if now > cfg.max_time:
                break
            # Sec. II-G protocol at cluster scope: release the stress
            # burst once the calibration phase has fully drained
            # (completed or shed — shed requests never complete).
            if not stress_released and self._processed() >= n_cal:
                stress_released = True
                self.phase_boundary = now
                for dt, req in self.plan.stress:
                    self._push(now + dt, "arrival", req)
            if kind == "arrival":
                self._on_arrival(payload, now)
            elif kind == "replica":
                rid, rkind, rpayload = payload
                self._on_replica_event(rid, rkind, rpayload, now)
            elif kind == "handoff":
                self._on_handoff(payload, now)
            elif kind == "replica_fail":
                self._fail_replica(payload, now)
            elif kind == "replica_ready":
                rep = self.replicas[payload]
                if rep.state is ReplicaState.STARTING:
                    rep.state = ReplicaState.ACTIVE
            elif kind == "control":
                self._control(now)
                if self._processed() < total:
                    self._push(now + cfg.control_interval, "control", None)
        return self._summarize(n_start)

    # ------------------------------------------------------------------
    def _on_arrival(self, req: Request, now: float) -> None:
        est = self.router.price(req)
        if self.trace.enabled and req.req_id not in self._arrived:
            # park-retries re-enter this handler: trace ARRIVE once
            self._arrived.add(req.req_id)
            self.trace.emit(now, tr.ARRIVE, req_id=req.req_id,
                            tenant=req.tenant.label, est_budget=est)
        if self.admission is not None:
            ok, _ = self.admission.offer(req, est, now,
                                         self.cluster_token_mass())
            if not ok:
                return
        target = self.router.route(self.replicas, req, now, est_budget=est)
        if target is None:
            if self.admission is None:
                # no front door to account the shed: park until the
                # pool recovers by retrying shortly
                self._push(now + 1.0, "arrival", req)
            else:
                self.admission.shed_no_replica(req, est, now)
            return
        if self.trace.enabled and self.admission is None:
            # no front door: placement is the admission decision
            self.trace.emit(now, tr.ADMIT, req_id=req.req_id,
                            tenant=req.tenant.label, est_budget=est)
        # the chosen replica's resident-prefix overlap prices the
        # admission estimate: only the uncached suffix is budgeted
        # (0 without a prefix cache — the estimate is then unchanged)
        req.expected_cached_tokens = target.prefix_cached_tokens(req)
        target.accept(req, now)

    def _on_replica_event(self, rid: int, rkind: str, rpayload,
                          now: float) -> None:
        """Forward one replica-emitted event (batch_start / batch_done /
        step_done / fail / repair / kick) back into its WorkerSimulator
        and count any completions it produced. Prefill-phase finishes
        are intercepted by the completion hook and never count here."""
        rep = self.replicas[rid]
        if rkind == "repair" and rep.state is ReplicaState.FAILED:
            rep.state = ReplicaState.ACTIVE
            if self.trace.enabled:
                self.trace.emit(now, tr.REPLICA_RECOVER, rid=rid)
        self.completed_total += rep.sim.handle_event(now, rkind, rpayload)

    # --- P/D two-stage lifecycle ---------------------------------------
    def _on_prefill_done(self, rid: int, req: Request, now: float) -> bool:
        """Completion hook on prefill replicas: the request's *prefill
        phase* finished (batch end on the atomic path; the iteration its
        last prompt chunk landed on the step engine) — stamp TTFT, start
        the modeled KV transfer, and tell the WorkerSimulator the
        request was taken over (no ``sched.complete``, so no drift
        feedback: the prefill phase observes no output length)."""
        req.prefill_end = now
        req.prefill_rid = rid
        rep = self.replicas[rid]
        rep.n_handoffs_out += 1
        self.n_handoffs += 1
        if self.trace.enabled:
            # P/D TTFT anchor: the prompt's last token landed here
            self.trace.emit(now, tr.FIRST_TOKEN, req_id=req.req_id,
                            rid=rid, tenant=req.tenant.label,
                            ttft=now - req.arrival_time)
            self.trace.emit(now, tr.HANDOFF, req_id=req.req_id,
                            rid=rid, tenant=req.tenant.label,
                            edge="out")
        h = Handoff(req=req, src_rid=rid)
        self._in_transit[req.req_id] = h
        self._push(now + self._kv_delay(req), "handoff", h)
        return True

    def _kv_delay(self, req: Request) -> float:
        """Modeled KV-transfer time (s): base link cost + per-prompt-
        token page movement."""
        return (self.cfg.kv_transfer_base
                + self.cfg.kv_transfer_per_token * req.prompt_tokens)

    def _on_handoff(self, h: Handoff, now: float) -> None:
        """A KV transfer arrived: place the prefilled request on a
        decode replica. Cancelled transfers (source replica died in
        flight — KV lost) were already rerouted by the failure path.
        A stolen transfer is pinned to its thief when still routable;
        with no decode-capable replica up, the KV waits at the source
        and retries."""
        if h.cancelled:
            return
        self._in_transit.pop(h.req.req_id, None)
        dst: Optional[Replica] = None
        if h.forced_dst_rid is not None:
            cand = self.replicas[h.forced_dst_rid]
            if cand.routable():
                dst = cand
        if dst is None:
            dst = self.router.route_decode(self.replicas, h.req, now)
        if dst is None:
            # no decode-capable replica routable: KV stays at the
            # source; retry while the pool recovers (source failure
            # meanwhile cancels the handoff and forces re-prefill)
            self._in_transit[h.req.req_id] = h
            self._push(now + 1.0, "handoff", h)
            return
        h.req.handoff_time = now
        h.req.decode_rid = dst.rid
        if h.stolen:
            dst.n_stolen_in += 1   # credited where the work landed
        if self.trace.enabled:
            self.trace.emit(now, tr.HANDOFF, req_id=h.req.req_id,
                            rid=dst.rid, tenant=h.req.tenant.label,
                            edge="in", src_rid=h.src_rid,
                            stolen=h.stolen)
        dst.accept_handoff(h.req, now, record=not h.stolen)

    # --- work stealing -------------------------------------------------
    def _run_steals(self, now: float) -> None:
        """Execute the router's steal plans: move the tail (coldest,
        lowest-tier end — ``TenantQueueManager.drain`` yields premium
        first) of each victim's queue to its idle thief. Not-yet-
        prefilled work moves instantly; decode-ready work pays a fresh
        KV transfer from the victim (the pages live there)."""
        for plan in self.router.plan_steals(
                self.replicas, now, min_victim_depth=self.cfg.steal_min_depth):
            victim = self.replicas[plan.victim_rid]
            thief = self.replicas[plan.thief_rid]
            queued = victim.sched.queues.drain()
            if plan.req_ids:
                # residency-vetoed plan: move exactly the pinned set
                # (tail members whose cache discount did not outweigh
                # the imbalance gain)
                chosen = set(plan.req_ids)
                keep = [r for r in queued if r.req_id not in chosen]
                stolen = [r for r in queued if r.req_id in chosen]
            else:
                keep, stolen = queued[:len(queued) - plan.n], \
                    queued[len(queued) - plan.n:]
            for req in keep:
                victim.sched.queues.enqueue(req, req.enqueue_time)
            for req in stolen:
                req.n_steals += 1
                victim.n_stolen_away += 1
                self.n_stolen += 1
                if self.trace.enabled:
                    self.trace.emit(now, tr.STEAL, req_id=req.req_id,
                                    rid=thief.rid,
                                    tenant=req.tenant.label,
                                    victim=victim.rid,
                                    decode_ready=req.prefill_end
                                    is not None)
                if req.prefill_end is not None:
                    # decode-ready: the KV re-transfers from the victim;
                    # n_stolen_in is credited at delivery (the planned
                    # thief may become unroutable mid-transfer)
                    h = Handoff(req=req, src_rid=victim.rid,
                                forced_dst_rid=thief.rid, stolen=True)
                    self._in_transit[req.req_id] = h
                    self._push(now + self._kv_delay(req), "handoff", h)
                else:
                    thief.accept_steal(req, now)

    # --- failure handling ----------------------------------------------
    def _fail_replica(self, rid: int, now: float) -> None:
        """Role-aware replica failure.

        1. In-flight batches abort (estimates preserved, no bias
           feedback — the at-most-once contract) and land back at the
           head of the replica's own queue.
        2. KV transfers *sourced* at the dead replica are lost: those
           requests re-run prefill elsewhere (estimate kept, feedback
           never fired, so nothing double-counts).
        3. The stranded queue reroutes to surviving replicas. Work that
           had already prefilled lost its KV with the replica, so it
           resets to the pre-prefill state and rejoins via stage-1
           routing (prefill-capable pool under P/D).
        """
        rep = self.replicas[rid]
        if rep.state in (ReplicaState.STOPPED, ReplicaState.FAILED):
            return
        rep.state = ReplicaState.FAILED
        if self.trace.enabled:
            self.trace.emit(now, tr.REPLICA_FAIL, rid=rid,
                            role=rep.role.value)
        # (2) cancel in-transit handoffs whose KV source died
        for h in [h for h in self._in_transit.values()
                  if h.src_rid == rid]:
            h.cancelled = True
            del self._in_transit[h.req.req_id]
            self.n_handoffs_lost += 1
            if h.stolen:
                # an undelivered steal never happened: unwind the
                # take-side accounting so the flow counters balance
                h.req.n_steals -= 1
                rep.n_stolen_away -= 1
                self.n_stolen -= 1
            h.req.reset_for_reprefill()
            self._reroute_stranded(rep, h.req, now)
        # (1) abort in-flight batches
        for wid in range(len(rep.sim.workers)):
            rep.sim.handle_event(now, "fail", wid)
        # (3) reroute the whole stranded queue to surviving replicas
        stranded = rep.sched.queues.drain()
        for req in reversed(stranded):      # front-pushes: keep order
            if req.prefill_end is not None:
                req.reset_for_reprefill()   # KV died with the replica
            self._reroute_stranded(rep, req, now)

    def _reroute_stranded(self, rep: SimReplica, req: Request,
                          now: float) -> None:
        """Route one stranded request off ``rep``; with the whole pool
        down it parks on the failed replica and is served after
        repair.

        The admission estimate travels with the request (no re-pricing
        of its bias-derived parts — the at-most-once contract), but the
        *cache discount* inside it belonged to the dead replica's
        residency, which no longer exists: restore the full-prompt
        budget, then re-discount by the surviving replica's own
        resident overlap. A re-prefill is priced where it will actually
        run."""
        est = req.estimate
        if est is not None and est.cached_tokens:
            est.t_budget += est.cached_tokens
            est.cached_tokens = 0
            req.expected_cached_tokens = 0
        target = self.router.route(self.replicas, req, now, exclude=(rep,))
        if target is None:
            rep.sched.queues.enqueue(req, req.enqueue_time, front=True)
            return
        if est is not None:
            overlap = target.prefix_cached_tokens(req)
            if overlap:
                est.t_budget -= overlap
                est.cached_tokens = overlap
                req.expected_cached_tokens = overlap
        rep.n_rerouted_away += 1
        self.n_rerouted += 1
        target.accept_reroute(req, now)

    def _control(self, now: float) -> None:
        """Control-plane tick (every ``control_interval`` s): finish
        draining replicas, run work stealing, then let the autoscaler
        act (role-aware when a :class:`RoleAutoscaler` drives a P/D
        pool)."""
        for rep in self.replicas:
            if rep.state is ReplicaState.DRAINING and rep.is_idle():
                rep.state = ReplicaState.STOPPED
        if self.cfg.work_stealing:
            self._run_steals(now)
        if self.autoscaler is not None:
            self._autoscale(now)
        mass, util, n_active = Autoscaler.signals(self.replicas)
        self.telemetry.append(ClusterTelemetry(
            time=now, n_active=n_active,
            n_starting=sum(1 for r in self.replicas
                           if r.state is ReplicaState.STARTING),
            queue_mass=mass, utilization=util))
        if self.trace.enabled:
            self.trace.emit(now, tr.GAUGE, name="cluster_queue_mass",
                            value=mass)
            self.trace.emit(now, tr.GAUGE, name="cluster_utilization",
                            value=util)
            self.trace.emit(now, tr.GAUGE, name="active_replicas",
                            value=n_active)

    def _autoscale(self, now: float) -> None:
        """One autoscaler decision. A RoleAutoscaler on a P/D pool
        scales each role pool separately; otherwise whole-pool scaling
        (new replicas join as DECODE in P/D mode — the larger,
        output-length-bound pool — and UNIFIED elsewhere)."""
        starting = [r for r in self.replicas
                    if r.state is ReplicaState.STARTING]
        if self.pd_mode and isinstance(self.autoscaler, RoleAutoscaler):
            by_role: Dict[ReplicaRole, int] = {}
            for r in starting:
                by_role[r.role] = by_role.get(r.role, 0) + 1
            decision = self.autoscaler.decide_role(
                now, self.replicas, by_role,
                default_target=self._pd_target_fraction)
            if decision is None:
                return
            action, role = decision
            if action == SCALE_UP:
                rep = self._provision_replica(ReplicaState.STARTING, role)
                self._push(now + self.autoscaler.cfg.startup_delay,
                           "replica_ready", rep.rid)
            else:
                target = self.autoscaler.pick_drain_target(self.replicas,
                                                           role=role)
                if target is not None:
                    target.state = ReplicaState.DRAINING
            return
        action = self.autoscaler.decide(now, self.replicas, len(starting))
        if action == SCALE_UP:
            role = (ReplicaRole.DECODE if self.pd_mode
                    else ReplicaRole.UNIFIED)
            rep = self._provision_replica(ReplicaState.STARTING, role)
            self._push(now + self.autoscaler.cfg.startup_delay,
                       "replica_ready", rep.rid)
        elif action == SCALE_DOWN:
            target = self.autoscaler.pick_drain_target(self.replicas)
            if target is not None and not self._last_of_role(target):
                target.state = ReplicaState.DRAINING

    def _last_of_role(self, target: SimReplica) -> bool:
        """In P/D mode a role pool must never drain to zero: losing the
        last prefill replica would silently degrade stage-1 routing to
        the decode-pool fallback (prompt cost unmodeled, no handoffs)
        for the rest of the run. RoleAutoscaler guards this itself;
        this check protects the plain-Autoscaler path."""
        if not self.pd_mode:
            return False
        return sum(1 for r in self.replicas
                   if r.state is ReplicaState.ACTIVE
                   and r.role is target.role) <= 1

    # ------------------------------------------------------------------
    def _summarize(self, n_start: int) -> ClusterMetrics:
        """Collect completions across replicas (stable completion-time
        order) and aggregate into :class:`ClusterMetrics`."""
        completed: List[Request] = []
        busy: Dict[int, float] = {}
        done: Dict[int, int] = {}
        n_failed = 0
        for rep in self.replicas:
            completed.extend(rep.sched.completed)
            busy[rep.rid] = (sum(w.busy_time for w in rep.sim.workers)
                             / max(len(rep.sim.workers), 1))
            done[rep.rid] = len(rep.sched.completed)
            n_failed += rep.sim.n_failed_dispatches
        completed.sort(key=lambda r: (r.completion_time, r.req_id))
        return summarize_cluster(
            self.router.policy.name, self.cfg.scheduler_policy,
            self.estimator.config.bias_enabled, completed,
            replicas=self.replicas, admission=self.admission,
            autoscaler=self.autoscaler, n_replicas_start=n_start,
            replica_busy_time=busy, replica_completed=done,
            n_failed_dispatches=n_failed, n_rerouted=self.n_rerouted,
            n_handoffs=self.n_handoffs, n_handoffs_lost=self.n_handoffs_lost,
            n_stolen=self.n_stolen, backend=self.cfg.backend)
