"""Cluster-level discrete-event simulator: N replicas, one event loop.

Composes N per-replica :class:`~repro.serving.simulator.WorkerSimulator`
instances (each driving its own :class:`DriftScheduler`, all sharing
one :class:`AdaptiveTokenEstimator`) under a single event heap and a
single seed:

    arrival -> GlobalAdmission (rate limits, backpressure; shed or pass)
            -> ClusterRouter   (round_robin / least_loaded /
                                drift_aware / tenant_affinity)
            -> replica's DriftScheduler -> replica workers

Replica events (batch_start/batch_done/fail/repair) emitted by a
replica's simulator are routed back through the shared heap via the
sink mechanism, so cross-replica ordering is exact and deterministic.

Fault injection composes with the per-worker story: a replica failure
aborts its in-flight batches (re-queued with estimates preserved, no
bias feedback — the at-most-once contract), then the cluster drains the
failed replica's queue and *reroutes* the stranded requests to the
surviving replicas. The replica rejoins the routable pool when its
workers repair.

The optional :class:`Autoscaler` runs at every control tick: scale-up
provisions a fresh replica (cold start delay before it serves; its
scheduler shares the cluster estimator so it is calibration-warm from
its first request), scale-down marks the least-loaded replica DRAINING
(finishes its backlog, takes no new work, then leaves the pool).
"""

from __future__ import annotations

import heapq
import itertools
import random
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from ..core.estimator import AdaptiveTokenEstimator, DriftConfig
from ..core.request import Request
from ..core.scheduler import DriftScheduler
from ..serving.cost_model import CostModel, L4_QWEN_1_8B
from ..serving.simulator import SimConfig, WorkerSimulator
from ..workload.generator import ArrivalPlan
from .admission import AdmissionConfig, GlobalAdmission
from .autoscaler import SCALE_DOWN, SCALE_UP, Autoscaler
from .metrics import ClusterMetrics, summarize_cluster
from .replica import Replica, ReplicaState
from .router import ClusterRouter, RoutingPolicy


@dataclass(frozen=True)
class ClusterConfig:
    n_replicas: int = 4
    workers_per_replica: int = 1
    routing: str = "drift_aware"
    scheduler_policy: str = "fifo"
    batch_capacity: int = 32          # per replica (paper Sec. III-B)
    batch_wait: float = 0.01
    control_interval: float = 1.0     # autoscaler / telemetry cadence
    max_time: float = 1e6             # hard stop against pathological stalls
    # replica-level fault injection: (absolute time, replica id)
    fail_events: Tuple[Tuple[float, int], ...] = ()
    repair_time: float = 30.0
    seed: int = 0


class SimReplica(Replica):
    """Replica backed by an externally-driven WorkerSimulator."""

    def __init__(self, rid: int, scheduler: DriftScheduler,
                 sim: WorkerSimulator) -> None:
        super().__init__(rid, scheduler)
        self.sim = sim

    def inflight_requests(self) -> List[Request]:
        return self.sim.inflight_requests()

    def busy_workers(self) -> int:
        return self.sim.n_busy_workers()

    def alive_workers(self) -> int:
        return self.sim.n_alive_workers()

    def is_idle(self) -> bool:
        return self.sim.is_idle()

    def accept(self, req: Request, now: float) -> None:
        """Admit a routed request (full admission path: estimate, log,
        enqueue) and kick dispatch."""
        self.sim.handle_event(now, "arrival", req)

    def accept_reroute(self, req: Request, now: float) -> None:
        """Take over a request stranded on a failed replica. The
        original estimate and enqueue timestamp travel with it (no
        re-estimation, no new admission record, no bias feedback) —
        the cluster analogue of the head-of-queue readmit contract."""
        self.sched.queues.enqueue(req, req.enqueue_time, front=True)
        self.sim.handle_event(now, "kick", None)


@dataclass
class ClusterTelemetry:
    time: float
    n_active: int
    n_starting: int
    queue_mass: float
    utilization: float


class ClusterSimulator:
    """One event loop over N replicas, a router, and a front door."""

    def __init__(self, plan: ArrivalPlan,
                 config: Optional[ClusterConfig] = None,
                 cost_model: Optional[CostModel] = None,
                 drift_config: Optional[DriftConfig] = None,
                 admission: Optional[GlobalAdmission] = None,
                 autoscaler: Optional[Autoscaler] = None,
                 routing: Optional[RoutingPolicy] = None) -> None:
        self.plan = plan
        self.cfg = config or ClusterConfig()
        self.cost = cost_model or L4_QWEN_1_8B
        self.rng = random.Random(self.cfg.seed)   # one seed, shared
        self.estimator = AdaptiveTokenEstimator(drift_config or DriftConfig())
        self.admission = admission
        self.autoscaler = autoscaler
        self.router = ClusterRouter(routing or self.cfg.routing,
                                    self.estimator)
        self.replicas: List[SimReplica] = []
        self.telemetry: List[ClusterTelemetry] = []
        self.n_rerouted = 0
        self.completed_total = 0
        self.phase_boundary = 0.0
        self._events: List[tuple] = []
        self._eseq = itertools.count()
        self._rid_seq = itertools.count()
        for _ in range(self.cfg.n_replicas):
            self._provision_replica(ReplicaState.ACTIVE)

    # ------------------------------------------------------------------
    def _push(self, t: float, kind: str, payload=None) -> None:
        heapq.heappush(self._events, (t, next(self._eseq), kind, payload))

    def _provision_replica(self, state: ReplicaState) -> SimReplica:
        rid = next(self._rid_seq)
        sched = DriftScheduler(policy=self.cfg.scheduler_policy,
                               estimator=self.estimator)
        sim = WorkerSimulator(
            sched,
            config=SimConfig(
                batch_capacity=self.cfg.batch_capacity,
                batch_wait=self.cfg.batch_wait,
                n_workers=self.cfg.workers_per_replica,
                repair_time=self.cfg.repair_time,
                seed=self.cfg.seed),
            cost_model=self.cost,
            sink=lambda t, kind, payload, rid=rid:
                self._push(t, "replica", (rid, kind, payload)),
            rng=self.rng)
        rep = SimReplica(rid, sched, sim)
        rep.state = state
        self.replicas.append(rep)
        return rep

    # ------------------------------------------------------------------
    def _n_shed(self) -> int:
        return self.admission.n_shed() if self.admission else 0

    def _processed(self) -> int:
        return self.completed_total + self._n_shed()

    def cluster_token_mass(self) -> float:
        return sum(r.token_mass() for r in self.replicas
                   if r.state is not ReplicaState.STOPPED)

    # ------------------------------------------------------------------
    def run(self) -> ClusterMetrics:
        cfg = self.cfg
        n_start = cfg.n_replicas
        n_cal = len(self.plan.calibration)
        total = len(self.plan)
        for t, req in self.plan.calibration:
            self._push(t, "arrival", req)
        for ft, rid in cfg.fail_events:
            self._push(ft, "replica_fail", rid)
        self._push(0.0, "control", None)

        stress_released = n_cal >= total
        now = 0.0
        while self._events and self._processed() < total:
            now, _, kind, payload = heapq.heappop(self._events)
            if now > cfg.max_time:
                break
            # Sec. II-G protocol at cluster scope: release the stress
            # burst once the calibration phase has fully drained
            # (completed or shed — shed requests never complete).
            if not stress_released and self._processed() >= n_cal:
                stress_released = True
                self.phase_boundary = now
                for dt, req in self.plan.stress:
                    self._push(now + dt, "arrival", req)
            if kind == "arrival":
                self._on_arrival(payload, now)
            elif kind == "replica":
                rid, rkind, rpayload = payload
                self._on_replica_event(rid, rkind, rpayload, now)
            elif kind == "replica_fail":
                self._fail_replica(payload, now)
            elif kind == "replica_ready":
                rep = self.replicas[payload]
                if rep.state is ReplicaState.STARTING:
                    rep.state = ReplicaState.ACTIVE
            elif kind == "control":
                self._control(now)
                if self._processed() < total:
                    self._push(now + cfg.control_interval, "control", None)
        return self._summarize(n_start)

    # ------------------------------------------------------------------
    def _on_arrival(self, req: Request, now: float) -> None:
        est = self.router.price(req)
        if self.admission is not None:
            ok, _ = self.admission.offer(req, est, now,
                                         self.cluster_token_mass())
            if not ok:
                return
        target = self.router.route(self.replicas, req, now, est_budget=est)
        if target is None:
            if self.admission is None:
                # no front door to account the shed: park until the
                # pool recovers by retrying shortly
                self._push(now + 1.0, "arrival", req)
            else:
                self.admission.shed_no_replica(req, est, now)
            return
        target.accept(req, now)

    def _on_replica_event(self, rid: int, rkind: str, rpayload,
                          now: float) -> None:
        rep = self.replicas[rid]
        if rkind == "repair" and rep.state is ReplicaState.FAILED:
            rep.state = ReplicaState.ACTIVE
        self.completed_total += rep.sim.handle_event(now, rkind, rpayload)

    def _fail_replica(self, rid: int, now: float) -> None:
        rep = self.replicas[rid]
        if rep.state in (ReplicaState.STOPPED, ReplicaState.FAILED):
            return
        rep.state = ReplicaState.FAILED
        # abort in-flight batches: estimates preserved, no bias feedback,
        # requests land back at the head of the replica's own queue
        for wid in range(len(rep.sim.workers)):
            rep.sim.handle_event(now, "fail", wid)
        # then reroute the whole stranded queue to surviving replicas
        stranded = rep.sched.queues.drain()
        for req in reversed(stranded):      # front-pushes: keep order
            target = self.router.route(self.replicas, req, now,
                                       exclude=(rep,))
            if target is None:
                # total outage: park on the failed replica, served
                # after its repair
                rep.sched.queues.enqueue(req, req.enqueue_time, front=True)
                continue
            rep.n_rerouted_away += 1
            self.n_rerouted += 1
            target.accept_reroute(req, now)

    def _control(self, now: float) -> None:
        for rep in self.replicas:
            if rep.state is ReplicaState.DRAINING and rep.is_idle():
                rep.state = ReplicaState.STOPPED
        if self.autoscaler is not None:
            n_starting = sum(1 for r in self.replicas
                             if r.state is ReplicaState.STARTING)
            action = self.autoscaler.decide(now, self.replicas, n_starting)
            if action == SCALE_UP:
                rep = self._provision_replica(ReplicaState.STARTING)
                self._push(now + self.autoscaler.cfg.startup_delay,
                           "replica_ready", rep.rid)
            elif action == SCALE_DOWN:
                target = self.autoscaler.pick_drain_target(self.replicas)
                if target is not None:
                    target.state = ReplicaState.DRAINING
        mass, util, n_active = Autoscaler.signals(self.replicas)
        self.telemetry.append(ClusterTelemetry(
            time=now, n_active=n_active,
            n_starting=sum(1 for r in self.replicas
                           if r.state is ReplicaState.STARTING),
            queue_mass=mass, utilization=util))

    # ------------------------------------------------------------------
    def _summarize(self, n_start: int) -> ClusterMetrics:
        completed: List[Request] = []
        busy: Dict[int, float] = {}
        done: Dict[int, int] = {}
        n_failed = 0
        for rep in self.replicas:
            completed.extend(rep.sched.completed)
            busy[rep.rid] = (sum(w.busy_time for w in rep.sim.workers)
                             / max(len(rep.sim.workers), 1))
            done[rep.rid] = len(rep.sched.completed)
            n_failed += rep.sim.n_failed_dispatches
        completed.sort(key=lambda r: (r.completion_time, r.req_id))
        return summarize_cluster(
            self.router.policy.name, self.cfg.scheduler_policy,
            self.estimator.config.bias_enabled, completed,
            replicas=self.replicas, admission=self.admission,
            autoscaler=self.autoscaler, n_replicas_start=n_start,
            replica_busy_time=busy, replica_completed=done,
            n_failed_dispatches=n_failed, n_rerouted=self.n_rerouted)
