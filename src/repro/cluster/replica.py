"""Replica abstraction for the cluster serving layer.

A *replica* is one serving unit behind the cluster router: a
:class:`~repro.core.scheduler.DriftScheduler` plus an execution backend
(the discrete-event :class:`~repro.serving.simulator.WorkerSimulator`,
or a real :class:`~repro.serving.engine.ServingEngine` via the driver).
The router and autoscaler only see the :class:`Replica` introspection
surface — queued/in-flight estimated-token mass, depth, lifecycle state
— so routing policies are execution-agnostic, exactly like the
scheduler itself. Under the iteration-level step engine
(``ClusterConfig.step_engine``) that surface is iteration-fresh:
in-flight mass drops the moment a slot retires mid-batch, rather than
only at batch drain, so load signals (and the work stealing / autoscale
decisions built on them) track continuous batching honestly.

Token mass is measured in *estimated budget tokens* (Eq. 1): the
cluster layer deliberately reasons in the same calibrated unit the
admission-time estimator produces, so better drift compensation
directly sharpens routing and scaling decisions.

Mass queries walk the live queues (O(depth) per routing decision) the
same way ``ScoredQueue.pop_min_rescored`` re-scores the whole heap:
exact semantics over cached counters, cheap at the experiment scales
here (<= a few thousand queued). Swap in incremental counters at the
enqueue/dispatch/complete hooks if replica counts grow by orders of
magnitude.
"""

from __future__ import annotations

import enum
from typing import List, Optional

from ..core.request import Request
from ..core.scheduler import DriftScheduler


class ReplicaState(enum.Enum):
    """Replica lifecycle (autoscaler + fault-injection driven)."""

    STARTING = "starting"    # provisioned by the autoscaler, not ready yet
    ACTIVE = "active"        # routable
    DRAINING = "draining"    # scale-down: finishes its queue, takes no new work
    FAILED = "failed"        # fault injection: in-flight + queue rerouted
    STOPPED = "stopped"      # drained and removed from the pool


class ReplicaRole(enum.Enum):
    """Which serving phase(s) a replica executes.

    ``UNIFIED`` replicas run prefill + decode in one batch (the paper's
    single-worker protocol, and PR-1 cluster behaviour). Under
    prefill/decode disaggregation, ``PREFILL`` replicas run only prompt
    processing and hand the request off (modeled KV transfer) to a
    ``DECODE`` replica, which runs only token generation — so long
    prefills stop stalling decode batches (arXiv 2602.02987).
    """

    UNIFIED = "unified"
    PREFILL = "prefill"
    DECODE = "decode"

    def can_prefill(self) -> bool:
        """True when new (not-yet-prefilled) requests may land here."""
        return self is not ReplicaRole.DECODE

    def can_decode(self) -> bool:
        """True when prefilled requests may decode here."""
        return self is not ReplicaRole.PREFILL


def _budget(req: Request) -> float:
    """Estimated token budget of a queued request (Eq. 1). Requests are
    always estimated at admission, but be defensive for bare ones."""
    return req.estimate.t_budget if req.estimate is not None else float(
        req.prompt_tokens + req.max_tokens)


class Replica:
    """Base replica: scheduler-backed introspection, no execution.

    All mass quantities are in *estimated budget tokens* (Eq. 1,
    ``Estimate.t_budget`` from the shared estimator); depths are request
    counts; times are seconds.
    """

    def __init__(self, rid: int, scheduler: DriftScheduler,
                 role: ReplicaRole = ReplicaRole.UNIFIED) -> None:
        self.rid = rid
        self.sched = scheduler
        self.state = ReplicaState.ACTIVE
        self.role = role
        self.n_routed = 0            # requests the router sent here
        self.n_rerouted_away = 0     # requests moved off after a failure
        self.n_handoffs_out = 0      # prefills handed off for decode
        self.n_handoffs_in = 0       # decode work received via handoff
        self.n_stolen_away = 0       # queued requests stolen by peers
        self.n_stolen_in = 0         # queued requests stolen from peers

    # --- lifecycle ----------------------------------------------------
    def routable(self) -> bool:
        """True when the router may place new work here (ACTIVE only)."""
        return self.state is ReplicaState.ACTIVE

    # --- load introspection (router / autoscaler signals) -------------
    def queued_requests(self) -> List[Request]:
        """Snapshot of queued (not yet dispatched) requests, in tenant
        queue order."""
        return list(self.sched.queues.all_requests())

    def inflight_requests(self) -> List[Request]:
        """Requests currently executing on this replica's workers
        (empty on the base class: no execution backend)."""
        return []

    def queue_depth(self) -> int:
        """Number of queued requests (count, not token mass)."""
        return self.sched.queue_depth()

    def queued_token_mass(self) -> float:
        """Estimated budget tokens (Eq. 1) waiting in the queues."""
        return sum(_budget(r) for r in self.sched.queues.all_requests())

    def inflight_token_mass(self) -> float:
        """Estimated budget tokens (Eq. 1) currently executing."""
        return sum(_budget(r) for r in self.inflight_requests())

    def prefix_cached_tokens(self, req: Request) -> int:
        """Resident shared-prefix overlap this replica's KV cache holds
        for ``req``, in tokens — THE warmth signal ``prefix_aware``
        routing scores (0 on the base class: no execution backend, no
        cache). Must be a pure probe: called once per routable replica
        per placement, it must not perturb LRU or refcount state."""
        return 0

    def prefix_cache_stats(self) -> dict:
        """Cumulative prefix-cache counters (hits / misses /
        tokens_saved / evicted_pages / resident_pages / invalidations);
        all zero without a cache-backed executor."""
        return {"hits": 0, "misses": 0, "tokens_saved": 0,
                "evicted_pages": 0, "resident_pages": 0,
                "invalidations": 0}

    def token_mass(self) -> float:
        """Total outstanding estimated work (queued + executing)."""
        return self.queued_token_mass() + self.inflight_token_mass()

    def mean_queued_budget(self) -> Optional[float]:
        """Mean estimated budget of queued requests — the homogeneity
        signal drift-aware routing packs against. None when empty."""
        budgets = [_budget(r) for r in self.sched.queues.all_requests()]
        if not budgets:
            return None
        return sum(budgets) / len(budgets)

    def busy_workers(self) -> int:
        """Workers currently executing a batch (utilization signal)."""
        return 1 if self.inflight_requests() else 0

    def alive_workers(self) -> int:
        """Workers not currently failed (utilization denominator)."""
        return 1

    def is_idle(self) -> bool:
        """True when nothing is queued or executing — the precondition
        for this replica to *steal* work from an overloaded peer."""
        return self.queue_depth() == 0 and not self.inflight_requests()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Replica(rid={self.rid}, role={self.role.value}, "
                f"state={self.state.value}, "
                f"depth={self.queue_depth()}, mass={self.token_mass():.0f})")
