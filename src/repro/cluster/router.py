"""Cluster router: which replica serves which request.

Four pluggable routing policies over the :class:`Replica` introspection
surface:

* ``round_robin``     — cycle over routable replicas, load-blind.
* ``least_loaded``    — smallest outstanding estimated-token mass
  (queued + in-flight, Eq. 1 budgets).
* ``drift_aware``     — size-band packing from the calibrated budget
  distribution: each replica owns a contiguous band of the service-
  weighted size distribution, so heavy and light jobs land on different
  replicas and batches stay homogeneous. Batch execution walks to its
  longest member (cost model ``c_decode_max``), so homogeneous batches
  shorten every batch — the cluster-level analogue of SJF's win, and it
  sharpens as the shared estimator's drift compensation converges. A
  load-aware spill keeps the policy work-conserving.
* ``tenant_affinity`` — keeps a tenant's stream on its warm replica
  (stable tenant -> replica mapping), spilling to the least-loaded
  replica when the warm one is overloaded.
* ``prefix_aware``    — scores replicas by *measured* resident-prefix
  overlap (tokens of the request's shared prompt prefix already in the
  replica's radix KV cache — the thing that actually makes a replica
  warm), seeding cold prefix groups onto a stable group ring and
  spilling to least-loaded under imbalance. See
  :class:`PrefixAwareRouting`.
* ``pd_disaggregated`` — two-stage prefill/decode placement over a
  role-split pool: new requests go to prefill replicas (by prompt-token
  load), prefilled requests hand off to decode replicas (by estimated
  budget-token mass) via a modeled KV transfer. See
  :class:`PDDisaggregatedRouting`.

The router also owns the cross-replica *work-stealing* protocol
(:meth:`ClusterRouter.plan_steals`): idle replicas take half the queue
of their most-backlogged role-compatible peer, estimates preserved.

Selection is deterministic: replicas are scanned in ``rid`` order and
ties break toward the lowest ``rid``.
"""

from __future__ import annotations

import math
import zlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.admission import count_tokens
from ..core.estimator import AdaptiveTokenEstimator
from ..core.request import Request
from ..obs import events as tr
from ..obs import resolve_recorder
from .replica import Replica, ReplicaRole, ReplicaState, _budget


class RoutingPolicy:
    """Base class. Subclasses override :meth:`select`."""

    name: str = "base"

    def select(self, replicas: Sequence[Replica], req: Request,
               est_budget: float, now: float) -> Replica:
        """Pick one replica from a non-empty routable pool.

        ``est_budget`` is the request's estimated token budget (Eq. 1,
        prompt + calibrated output estimate from the shared estimator);
        ``now`` is the simulated/wall-clock time in seconds.
        """
        raise NotImplementedError


class RoundRobinRouting(RoutingPolicy):
    """Cycle over routable replicas (membership-change tolerant: the
    cursor indexes the current routable list, not absolute rids)."""

    name = "round_robin"

    def __init__(self) -> None:
        self._cursor = 0

    def select(self, replicas, req, est_budget, now):
        """Next replica in rotation; ignores the estimate entirely."""
        chosen = replicas[self._cursor % len(replicas)]
        self._cursor = (self._cursor + 1) % max(len(replicas), 1)
        return chosen


class LeastLoadedRouting(RoutingPolicy):
    """Smallest outstanding estimated-token mass wins."""

    name = "least_loaded"

    def select(self, replicas, req, est_budget, now):
        """Replica with the least outstanding estimated budget-token
        mass (Eq. 1, queued + in flight); ties to the lowest rid."""
        return min(replicas, key=lambda r: (r.token_mass(), r.rid))


class DriftAwareRouting(RoutingPolicy):
    """Service-weighted size-band packing with load-aware spill.

    Two calibrated signals, both in estimated-token units (Eq. 1):

    1. **Band placement.** The router maintains an online histogram of
       *service weight* — ``overhead_tokens + est_budget``, the token-
       equivalent cost of one request including its per-request batch
       overhead share — over log-spaced size buckets. A request's
       position in the service-weighted CDF maps it onto the replica
       ring: replica 0 serves the lightest band, replica n-1 the
       heaviest, and each band carries an (approximately) equal share
       of predicted service time. Homogeneous bands mean homogeneous
       batches, which cuts the walk-to-longest-member cost every batch
       pays under continuous batching.
    2. **Spill.** Band placement alone is open-loop; arrival noise can
       pile one band up while another drains. When the preferred
       replica's outstanding service load exceeds ``spill_factor`` x
       the minimum load plus ``spill_slack``, the request spills to
       the least-loaded replica instead — work-conserving by
       construction.

    Both signals improve as the shared estimator's bias converges: the
    CDF sharpens and the load measure tracks true occupancy. Defaults
    are calibrated for the L4 cost models (``overhead_tokens`` ~
    ``t_base / c_decode_max``).
    """

    name = "drift_aware"

    #: histogram domain: log2-spaced buckets over [16, 4096] est tokens
    _LOG_LO, _LOG_HI = 4.0, 12.0

    def __init__(self, overhead_tokens: float = 70.0,
                 spill_factor: float = 1.5,
                 spill_slack: float = 4000.0,
                 n_buckets: int = 64) -> None:
        self.overhead_tokens = float(overhead_tokens)
        self.spill_factor = float(spill_factor)
        self.spill_slack = float(spill_slack)
        self.n_buckets = int(n_buckets)
        self._weight = [0.0] * self.n_buckets

    def _bucket(self, est: float) -> int:
        x = max(est, 2.0 ** self._LOG_LO)
        frac = (math.log2(x) - self._LOG_LO) / (self._LOG_HI - self._LOG_LO)
        return min(max(int(frac * self.n_buckets), 0), self.n_buckets - 1)

    def _service_load(self, r: Replica) -> float:
        """Outstanding predicted service time, in service-weight units."""
        k = self.overhead_tokens
        return sum(k + _budget(q) for q in r.queued_requests()) \
            + sum(k + _budget(q) for q in r.inflight_requests())

    def select(self, replicas, req, est_budget, now):
        """Band placement from the service-weighted CDF position of
        ``est_budget`` (Eq. 1 tokens), with least-loaded spill when the
        preferred band replica is overloaded."""
        b = self._bucket(est_budget)
        below = sum(self._weight[:b + 1])
        total = sum(self._weight)
        if req.estimate is None:
            # first routing of this request: record it in the size CDF.
            # Rerouted requests carry their admission estimate and are
            # already counted — re-adding would skew the bands toward
            # whatever a failed replica happened to hold.
            self._weight[b] += self.overhead_tokens + est_budget
        q = below / total if total > 0 else 0.5
        n = len(replicas)
        pref = replicas[min(int(q * n), n - 1)]
        loads = {r.rid: self._service_load(r) for r in replicas}
        if loads[pref.rid] > (self.spill_factor * min(loads.values())
                              + self.spill_slack):
            return min(replicas, key=lambda r: (loads[r.rid], r.rid))
        return pref


class TenantAffinityRouting(RoutingPolicy):
    """Stable tenant -> replica mapping with load spill.

    A tenant's requests land on its *warm* replica (continuous-batching
    engines reuse compiled shapes / KV pages for a tenant's recurring
    traffic) unless that replica's mass exceeds ``spill_factor`` times
    the routable mean, in which case the request spills to the
    least-loaded replica.
    """

    name = "tenant_affinity"

    def __init__(self, spill_factor: float = 1.5) -> None:
        self.spill_factor = float(spill_factor)

    def select(self, replicas, req, est_budget, now):
        """Warm replica for the request's tenant unless its mass
        (Eq. 1 tokens) exceeds ``spill_factor`` x the routable mean."""
        # ring mapping on stable rids (not pool indices): the warm
        # replica of every other tenant survives membership changes —
        # a failed replica only remaps the tenants it was warming
        target = int(req.tenant)
        warm = next((r for r in replicas if r.rid >= target), replicas[0])
        mean_mass = sum(r.token_mass() for r in replicas) / len(replicas)
        if warm.token_mass() <= self.spill_factor * max(mean_mass, 1.0):
            return warm
        return min(replicas, key=lambda r: (r.token_mass(), r.rid))


class PrefixAwareRouting(RoutingPolicy):
    """Shared-prefix KV-reuse routing: follow the resident pages.

    ``tenant_affinity`` models warmth as stickiness; this policy
    measures it. Each replica exposes
    :meth:`~repro.cluster.replica.Replica.prefix_cached_tokens` — the
    tokens of the request's shared prompt prefix already resident in
    its radix KV cache (``ClusterConfig.prefix_cache``) — and placement
    follows three rules, in order:

    1. **Follow residency.** The replica with the largest resident
       overlap wins (ties to the lowest rid): every overlapping token
       is prefill work the cluster never re-pays, and the admission
       estimate prices the request's uncached suffix accordingly
       (``Request.expected_cached_tokens``, stamped by the cluster
       simulator at placement).
    2. **Seed cold groups deterministically.** A prefix group nobody
       holds yet maps onto the rid ring by a stable content hash
       (crc32 — NOT Python's salted ``hash``; placement must be
       reproducible across runs), so a group's stream concentrates and
       builds residency instead of spraying one cold miss onto every
       replica. SageServe's observation, applied at the router:
       cache state must be *built* by placement, not just consulted.
    3. **Spill on overload.** Either preference yields to the
       least-loaded replica when its outstanding mass (Eq. 1 tokens)
       exceeds ``spill_factor`` x the routable mean — work conservation
       beats warmth, exactly like ``tenant_affinity``'s spill.

    Requests with no shareable prefix route least-loaded. Residency
    probes are pure reads (no LRU/refcount perturbation), so scoring N
    replicas per placement cannot distort eviction order.
    """

    name = "prefix_aware"

    def __init__(self, spill_factor: float = 1.5) -> None:
        self.spill_factor = float(spill_factor)

    def select(self, replicas, req, est_budget, now):
        """Max resident-prefix overlap -> stable group-ring seed ->
        least-loaded spill (see class docstring)."""
        mean_mass = sum(r.token_mass() for r in replicas) / len(replicas)

        def overloaded(r: Replica) -> bool:
            return r.token_mass() > self.spill_factor * max(mean_mass, 1.0)

        if req.prefix_group is not None and req.shared_prefix_tokens > 0:
            overlaps = {r.rid: r.prefix_cached_tokens(req)
                        for r in replicas}
            best = max(replicas, key=lambda r: (overlaps[r.rid], -r.rid))
            if overlaps[best.rid] > 0 and not overloaded(best):
                return best
            target = zlib.crc32(repr(req.prefix_group).encode()) \
                % (replicas[-1].rid + 1)
            warm = next((r for r in replicas if r.rid >= target),
                        replicas[0])
            if not overloaded(warm):
                return warm
        return min(replicas, key=lambda r: (r.token_mass(), r.rid))


class PDDisaggregatedRouting(RoutingPolicy):
    """Prefill/decode-disaggregated two-stage placement.

    Admitted requests are placed on *prefill-capable* replicas by
    outstanding prompt-token load (prefill replicas only pay prompt
    processing, so their load is prompt mass — raw prompt tokens, not
    Eq. 1 budgets). Once prefill finishes, :meth:`select_decode` places
    the request on a *decode-capable* replica by outstanding estimated-
    token mass (Eq. 1 budgets — decode cost is output-length driven,
    which is exactly what the calibrated estimator predicts). The
    cluster simulator moves the KV between the two via a modeled
    transfer delay.

    Separating the pools removes prefill/decode contention: a long
    prompt no longer stalls the decode batch behind it
    (arXiv 2602.02987's head-of-line effect).
    """

    name = "pd_disaggregated"

    @staticmethod
    def _prompt_load(r: Replica) -> float:
        """Outstanding prompt tokens (queued + in flight) — the work a
        prefill replica actually pays for."""
        return (sum(q.prompt_tokens for q in r.queued_requests())
                + sum(q.prompt_tokens for q in r.inflight_requests()))

    def select(self, replicas, req, est_budget, now):
        """Stage 1: least prompt-loaded prefill-capable replica."""
        pool = [r for r in replicas if r.role.can_prefill()]
        if not pool:           # degenerate pool (e.g. every prefill
            pool = replicas    # replica failed): decode pool serves both
        return min(pool, key=lambda r: (self._prompt_load(r), r.rid))

    def select_decode(self, replicas: Sequence[Replica], req: Request,
                      est_budget: float, now: float) -> Optional[Replica]:
        """Stage 2: least-loaded decode-capable replica (estimated
        budget-token mass, Eq. 1), or None when no decode-capable
        replica is routable (caller parks the KV and retries)."""
        pool = [r for r in replicas if r.role.can_decode()]
        if not pool:
            return None
        return min(pool, key=lambda r: (r.token_mass(), r.rid))


ROUTING_POLICIES: Dict[str, type] = {
    p.name: p for p in (RoundRobinRouting, LeastLoadedRouting,
                        DriftAwareRouting, TenantAffinityRouting,
                        PrefixAwareRouting, PDDisaggregatedRouting)
}


def make_routing_policy(name: str, **kwargs) -> RoutingPolicy:
    """Instantiate a routing policy by registry name (case-insensitive);
    raises ValueError listing the registry on an unknown name."""
    try:
        cls = ROUTING_POLICIES[name.lower()]
    except KeyError:
        raise ValueError(f"unknown routing policy {name!r}; "
                         f"available: {sorted(ROUTING_POLICIES)}") from None
    return cls(**kwargs)


@dataclass
class RoutingRecord:
    """One routing decision (cluster metrics / debugging).

    ``stage`` is "admit" for first placement (prefill placement under
    P/D disaggregation) and "decode" for the post-prefill handoff
    placement; ``est_budget`` is in estimated budget tokens (Eq. 1)."""

    time: float
    req_id: int
    tenant: str
    est_budget: float
    rid: int
    stage: str = "admit"


@dataclass(frozen=True)
class StealPlan:
    """One planned work-stealing move: ``n`` queued requests leave
    replica ``victim_rid`` for the idle replica ``thief_rid``. The owner
    (cluster simulator) executes the move; for decode-ready work it also
    pays a fresh KV-transfer delay, since the pages live on the
    victim.

    ``req_ids`` pins exactly which queued requests move: the planner
    filters the victim's queue tail for prefix-cache residency (work
    that is cheap *because* it is queued where its prefix is resident
    must not be dragged to a cold thief), so a bare count no longer
    identifies the moved set."""

    victim_rid: int
    thief_rid: int
    n: int
    req_ids: Tuple[int, ...] = ()


class ClusterRouter:
    """Routes admitted requests onto replicas.

    Uses the *shared* :class:`AdaptiveTokenEstimator` to price a request
    before it reaches any replica, so every routing decision sees the
    same calibrated bias state the replicas' admission controllers use.
    """

    def __init__(self, policy: str | RoutingPolicy,
                 estimator: AdaptiveTokenEstimator,
                 record_log: bool = True, trace=None) -> None:
        self.policy: RoutingPolicy = (
            policy if isinstance(policy, RoutingPolicy)
            else make_routing_policy(policy))
        self.estimator = estimator
        self.log: List[RoutingRecord] = []
        self._record = record_log
        self.trace = resolve_recorder(trace)

    def price(self, req: Request) -> float:
        """Estimated token budget (Eq. 1) under the current bias state.
        Uses the preserved admission estimate when one exists (reroutes
        must not be re-priced — the original estimate travels with the
        request, mirroring the single-replica readmit contract)."""
        if req.estimate is not None:
            return req.estimate.t_budget
        prompt_tokens = req.prompt_tokens or count_tokens(req.prompt)
        return self.estimator.estimate(
            req.category, req.tenant, prompt_tokens).t_budget

    def route(self, replicas: Sequence[Replica], req: Request, now: float,
              est_budget: Optional[float] = None,
              exclude: Sequence[Replica] = ()) -> Optional[Replica]:
        """Pick a routable replica, or None when the pool is empty
        (caller sheds or parks the request). ``est_budget`` lets a
        caller that already priced the request (the admission gate)
        skip re-estimating."""
        pool = [r for r in replicas if r.routable() and r not in exclude]
        if not pool:
            return None
        pool.sort(key=lambda r: r.rid)
        est = est_budget if est_budget is not None else self.price(req)
        chosen = self.policy.select(pool, req, est, now)
        chosen.n_routed += 1
        if self._record:
            self.log.append(RoutingRecord(
                time=now, req_id=req.req_id, tenant=req.tenant.label,
                est_budget=est, rid=chosen.rid))
        if self.trace.enabled:
            self.trace.emit(now, tr.ROUTE, req_id=req.req_id,
                            rid=chosen.rid, tenant=req.tenant.label,
                            stage="admit", policy=self.policy.name,
                            est_budget=est)
        return chosen

    def route_decode(self, replicas: Sequence[Replica], req: Request,
                     now: float,
                     exclude: Sequence[Replica] = ()) -> Optional[Replica]:
        """Stage-2 placement: pick the decode replica a prefilled
        request hands off to, or None when no decode-capable replica is
        routable (the caller parks the KV at its source and retries).
        Policies without a two-stage story (everything but
        ``pd_disaggregated``) fall back to :meth:`route`."""
        pool = [r for r in replicas if r.routable() and r not in exclude]
        if not pool:
            return None
        pool.sort(key=lambda r: r.rid)
        select_decode = getattr(self.policy, "select_decode", None)
        if select_decode is None:
            return self.route(replicas, req, now, exclude=exclude)
        est = self.price(req)
        chosen = select_decode(pool, req, est, now)
        if chosen is None:
            return None
        if self._record:
            self.log.append(RoutingRecord(
                time=now, req_id=req.req_id, tenant=req.tenant.label,
                est_budget=est, rid=chosen.rid, stage="decode"))
        if self.trace.enabled:
            self.trace.emit(now, tr.ROUTE, req_id=req.req_id,
                            rid=chosen.rid, tenant=req.tenant.label,
                            stage="decode", policy=self.policy.name,
                            est_budget=est)
        return chosen

    # --- work stealing -------------------------------------------------
    def plan_steals(self, replicas: Sequence[Replica], now: float, *,
                    min_victim_depth: int = 4) -> List[StealPlan]:
        """Cross-replica work stealing: pair every idle routable replica
        (thief) with its most-backlogged role-compatible peer (victim)
        and plan to move half the victim's queue (requests, counted —
        mass-greedy victims are picked by queued estimated-token mass).

        Role compatibility keys off the *phase the victim's queued work
        needs next*: a decode replica's queue holds prefilled,
        decode-ready requests, so only decode-capable thieves may take
        them; prefill and unified queues hold not-yet-prefilled work,
        so the thief must be prefill-capable. Replicas still DRAINING
        count as victims (stealing is precisely how their backlog drains
        faster) but never as thieves. Estimates travel with the stolen
        requests — stealing must not re-price work.

        **Prefix-cache residency veto.** Not-yet-prefilled work in the
        steal set consults measured residency
        (:meth:`Replica.prefix_cached_tokens`): moving a request whose
        shared prefix is resident on the victim but not on the thief
        forfeits that many cached prefill tokens, so the move is
        refused when the forfeited discount meets or exceeds the
        request's own estimated budget — the queue-imbalance gain one
        stolen request can relieve. Decode-ready work is exempt (its
        KV re-transfers either way), as is everything when no replica
        runs a prefix cache (zero residency everywhere: the plans are
        exactly the pre-veto ones).
        """
        thieves = sorted((r for r in replicas
                          if r.routable() and r.is_idle()),
                         key=lambda r: r.rid)
        taken: set = set()
        plans: List[StealPlan] = []
        for thief in thieves:
            candidates = [
                v for v in replicas
                if v is not thief and v.rid not in taken
                and v.state in (ReplicaState.ACTIVE, ReplicaState.DRAINING)
                and v.queue_depth() >= min_victim_depth
                and self._steal_compatible(v, thief)
            ]
            if not candidates:
                continue
            victim = max(candidates,
                         key=lambda v: (v.queued_token_mass(), -v.rid))
            n = victim.queue_depth() // 2
            if n <= 0:
                continue
            # the executor moves the queue *tail* (coldest end); veto
            # tail members whose residency discount outweighs the gain
            queued = victim.queued_requests()
            movable = [
                r for r in queued[len(queued) - n:]
                if r.prefill_end is not None
                or (victim.prefix_cached_tokens(r)
                    - thief.prefix_cached_tokens(r)) < _budget(r)
            ]
            if not movable:
                continue
            taken.add(victim.rid)
            plans.append(StealPlan(
                victim_rid=victim.rid, thief_rid=thief.rid,
                n=len(movable),
                req_ids=tuple(r.req_id for r in movable)))
        return plans

    @staticmethod
    def _steal_compatible(victim: Replica, thief: Replica) -> bool:
        if victim.role is ReplicaRole.DECODE:
            return thief.role.can_decode()
        # prefill / unified queues hold not-yet-prefilled work
        return thief.role.can_prefill()
