"""Elastic autoscaling from utilization + queue-mass signals.

The autoscaler watches two cluster signals at every control tick:

* **queue mass per active replica** — outstanding estimated-token mass
  (Eq. 1 budgets) divided by the active replica count; the demand
  signal. Token mass, not request count: ten queued reports are a very
  different backlog than ten short QAs, and the calibrated estimator is
  what makes the distinction trustworthy.
* **worker utilization** — busy workers / alive workers; the supply
  signal for scale-down.

Decisions use hysteresis (disjoint up/down thresholds) plus a cooldown
after *any* action, so a burst cannot flap the pool. The autoscaler
only *decides*; the owner (cluster simulator or driver) provisions the
replica (with a cold-start delay) or marks one draining.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

from .replica import Replica, ReplicaState

SCALE_UP = "up"
SCALE_DOWN = "down"


@dataclass(frozen=True)
class AutoscalerConfig:
    min_replicas: int = 1
    max_replicas: int = 8
    # scale up when queue mass per active replica exceeds this
    up_queue_mass_per_replica: float = 20_000.0
    # scale down only when BOTH hold (hysteresis band)
    down_queue_mass_per_replica: float = 2_000.0
    down_utilization: float = 0.5
    cooldown: float = 20.0           # s between scaling actions
    startup_delay: float = 5.0       # cold start before a replica serves


@dataclass
class ScaleEvent:
    time: float
    action: str                      # "up" | "down"
    n_active: int                    # active count when decided
    queue_mass_per_replica: float
    utilization: float


class Autoscaler:
    """Hysteresis + cooldown scaling decisions over the replica pool."""

    def __init__(self, config: Optional[AutoscalerConfig] = None) -> None:
        self.cfg = config or AutoscalerConfig()
        self.events: List[ScaleEvent] = []
        self._last_action_time = -float("inf")

    # ------------------------------------------------------------------
    @staticmethod
    def signals(replicas: Sequence[Replica]) -> tuple:
        """(queue_mass_per_active_replica, utilization, n_active)."""
        active = [r for r in replicas if r.state is ReplicaState.ACTIVE]
        if not active:
            return 0.0, 0.0, 0
        mass = sum(r.token_mass() for r in active) / len(active)
        busy = sum(r.busy_workers() for r in active)
        alive = sum(r.alive_workers() for r in active)
        util = busy / alive if alive else 0.0
        return mass, util, len(active)

    def decide(self, now: float, replicas: Sequence[Replica],
               n_starting: int = 0) -> Optional[str]:
        """Return SCALE_UP, SCALE_DOWN, or None. ``n_starting`` counts
        replicas already provisioning (they count toward max and damp
        repeated scale-ups during their cold start)."""
        cfg = self.cfg
        if now - self._last_action_time < cfg.cooldown:
            return None
        mass, util, n_active = self.signals(replicas)
        if n_active == 0:
            return None
        pool = n_active + n_starting
        action: Optional[str] = None
        if mass > cfg.up_queue_mass_per_replica and pool < cfg.max_replicas:
            action = SCALE_UP
        elif (mass < cfg.down_queue_mass_per_replica
              and util < cfg.down_utilization
              and n_active > cfg.min_replicas and n_starting == 0):
            action = SCALE_DOWN
        if action is not None:
            self._last_action_time = now
            self.events.append(ScaleEvent(
                time=now, action=action, n_active=n_active,
                queue_mass_per_replica=mass, utilization=util))
        return action

    def pick_drain_target(self, replicas: Sequence[Replica]) -> Optional[Replica]:
        """Least-loaded active replica drains first (cheapest to empty)."""
        active = [r for r in replicas if r.state is ReplicaState.ACTIVE]
        if len(active) <= self.cfg.min_replicas:
            return None
        return min(active, key=lambda r: (r.token_mass(), -r.rid))
