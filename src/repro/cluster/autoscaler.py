"""Elastic autoscaling from utilization + queue-mass signals.

The autoscaler watches two cluster signals at every control tick:

* **queue mass per active replica** — outstanding estimated-token mass
  (Eq. 1 budgets) divided by the active replica count; the demand
  signal. Token mass, not request count: ten queued reports are a very
  different backlog than ten short QAs, and the calibrated estimator is
  what makes the distinction trustworthy.
* **worker utilization** — busy workers / alive workers; the supply
  signal for scale-down.

Decisions use hysteresis (disjoint up/down thresholds) plus a cooldown
after *any* action, so a burst cannot flap the pool. The autoscaler
only *decides*; the owner (cluster simulator or driver) provisions the
replica (with a cold-start delay) or marks one draining.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from ..obs import events as _tr
from ..obs import resolve_recorder
from .replica import Replica, ReplicaRole, ReplicaState

SCALE_UP = "up"
SCALE_DOWN = "down"


@dataclass(frozen=True)
class AutoscalerConfig:
    """Scaling limits and hysteresis thresholds. Queue-mass thresholds
    are in estimated budget tokens (Eq. 1) per active replica;
    utilization in [0, 1]; times in seconds."""

    min_replicas: int = 1
    max_replicas: int = 8
    # scale up when queue mass per active replica exceeds this
    up_queue_mass_per_replica: float = 20_000.0
    # scale down only when BOTH hold (hysteresis band)
    down_queue_mass_per_replica: float = 2_000.0
    down_utilization: float = 0.5
    cooldown: float = 20.0           # s between scaling actions
    startup_delay: float = 5.0       # cold start before a replica serves


@dataclass
class ScaleEvent:
    """One autoscaling decision: when, which way, and the signal values
    (queue mass in estimated budget tokens per active replica,
    utilization in [0, 1]) that justified it. ``role`` is set by the
    role-aware autoscaler to the pool ("prefill" / "decode") the action
    targets; None for whole-pool (unified) decisions."""

    time: float
    action: str                      # "up" | "down"
    n_active: int                    # active count when decided
    queue_mass_per_replica: float
    utilization: float
    role: Optional[str] = None


class Autoscaler:
    """Hysteresis + cooldown scaling decisions over the replica pool."""

    def __init__(self, config: Optional[AutoscalerConfig] = None,
                 trace=None) -> None:
        self.cfg = config or AutoscalerConfig()
        self.events: List[ScaleEvent] = []
        self._last_action_time = -float("inf")
        self.trace = resolve_recorder(trace)

    # ------------------------------------------------------------------
    @staticmethod
    def signals(replicas: Sequence[Replica]) -> tuple:
        """(queue_mass_per_active_replica, utilization, n_active)."""
        active = [r for r in replicas if r.state is ReplicaState.ACTIVE]
        if not active:
            return 0.0, 0.0, 0
        mass = sum(r.token_mass() for r in active) / len(active)
        busy = sum(r.busy_workers() for r in active)
        alive = sum(r.alive_workers() for r in active)
        util = busy / alive if alive else 0.0
        return mass, util, len(active)

    def decide(self, now: float, replicas: Sequence[Replica],
               n_starting: int = 0) -> Optional[str]:
        """Return SCALE_UP, SCALE_DOWN, or None. ``n_starting`` counts
        replicas already provisioning (they count toward max and damp
        repeated scale-ups during their cold start)."""
        cfg = self.cfg
        if now - self._last_action_time < cfg.cooldown:
            return None
        mass, util, n_active = self.signals(replicas)
        if n_active == 0:
            return None
        pool = n_active + n_starting
        action: Optional[str] = None
        if mass > cfg.up_queue_mass_per_replica and pool < cfg.max_replicas:
            action = SCALE_UP
        elif (mass < cfg.down_queue_mass_per_replica
              and util < cfg.down_utilization
              and n_active > cfg.min_replicas and n_starting == 0):
            action = SCALE_DOWN
        if action is not None:
            self._last_action_time = now
            self.events.append(ScaleEvent(
                time=now, action=action, n_active=n_active,
                queue_mass_per_replica=mass, utilization=util))
            if self.trace.enabled:
                self.trace.emit(
                    now, _tr.SCALE_UP if action == SCALE_UP
                    else _tr.SCALE_DOWN,
                    n_active=n_active, queue_mass_per_replica=mass,
                    utilization=util)
        return action

    def pick_drain_target(self, replicas: Sequence[Replica]) -> Optional[Replica]:
        """Least-loaded active replica drains first (cheapest to empty)."""
        active = [r for r in replicas if r.state is ReplicaState.ACTIVE]
        if len(active) <= self.cfg.min_replicas:
            return None
        return min(active, key=lambda r: (r.token_mass(), -r.rid))


@dataclass(frozen=True)
class RoleAutoscalerConfig(AutoscalerConfig):
    """Role-aware scaling limits. Inherits the hysteresis thresholds
    (applied *per role pool*: queue mass in estimated budget tokens per
    active replica of that role) and adds the pool-shape target."""

    # target share of the pool that should be prefill replicas. None
    # (the default) inherits the owner's topology target — the cluster
    # simulator passes the fraction its pool was actually built with
    # (ClusterConfig.pd_prefill_fraction / n_prefill_replicas) — so the
    # autoscaler never fights a non-default initial split. An explicit
    # value here overrides that; standalone use falls back to 0.25
    # (decode work dominates token time under both L4 cost regimes).
    target_prefill_fraction: Optional[float] = None


class RoleAutoscaler(Autoscaler):
    """Per-role scaling for a P/D-disaggregated pool (SageServe-style
    role-aware scaling of a heterogeneous replica fleet).

    Each role pool (prefill / decode) is watched with the same
    hysteresis signals the unified autoscaler uses — queue mass per
    active replica of that role (estimated budget tokens, Eq. 1) and
    busy/alive worker utilization — and actions name the role they
    apply to. Scale-up goes to the most overloaded role; scale-down
    drains from the role most over-provisioned relative to
    ``target_prefill_fraction``, never below one replica per role.
    """

    ROLES = (ReplicaRole.PREFILL, ReplicaRole.DECODE)

    def __init__(self, config: Optional[RoleAutoscalerConfig] = None,
                 trace=None) -> None:
        super().__init__(config or RoleAutoscalerConfig(), trace=trace)

    @staticmethod
    def role_signals(replicas: Sequence[Replica],
                     role: ReplicaRole) -> tuple:
        """(queue_mass_per_active_replica, utilization, n_active) for
        one role pool; mass in estimated budget tokens (Eq. 1)."""
        return Autoscaler.signals(
            [r for r in replicas if r.role is role])

    def decide_role(self, now: float, replicas: Sequence[Replica],
                    n_starting_by_role: Optional[
                        Dict[ReplicaRole, int]] = None,
                    default_target: Optional[float] = None
                    ) -> Optional[Tuple[str, ReplicaRole]]:
        """Return (SCALE_UP | SCALE_DOWN, role) or None.

        ``n_starting_by_role`` counts replicas already provisioning per
        role; they count toward ``max_replicas`` (whole-pool cap) and
        toward the pool shape, damping repeated scale-ups during cold
        starts. ``default_target`` is the owner's prefill-share target,
        used when the config leaves ``target_prefill_fraction`` unset."""
        cfg: RoleAutoscalerConfig = self.cfg  # type: ignore[assignment]
        if now - self._last_action_time < cfg.cooldown:
            return None
        starting = n_starting_by_role or {}
        sig = {role: self.role_signals(replicas, role)
               for role in self.ROLES}
        n_active_total = sum(s[2] for s in sig.values())
        if n_active_total == 0:
            return None
        pool_total = n_active_total + sum(starting.values())

        # scale up: the role with the larger per-replica backlog wins
        overloaded = [(sig[role][0], role.value, role) for role in self.ROLES
                      if sig[role][0] > cfg.up_queue_mass_per_replica]
        if overloaded and pool_total < cfg.max_replicas:
            _, _, role = max(overloaded)
            return self._emit(now, SCALE_UP, role, sig[role])

        # scale down: every pool must be inside the hysteresis band
        calm = all(s[0] < cfg.down_queue_mass_per_replica
                   and s[1] < cfg.down_utilization
                   for s in sig.values() if s[2] > 0)
        if (calm and n_active_total > max(cfg.min_replicas, 2)
                and not any(starting.values())):
            role = self._overprovisioned_role(sig, starting, cfg,
                                              default_target)
            if role is not None:
                return self._emit(now, SCALE_DOWN, role, sig[role])
        return None

    def _overprovisioned_role(self, sig, starting, cfg, default_target):
        """The role whose pool share most exceeds its target share;
        None when neither pool can give up a replica (≥1 each kept)."""
        target = cfg.target_prefill_fraction
        if target is None:
            target = default_target if default_target is not None else 0.25
        n_prefill = sig[ReplicaRole.PREFILL][2] \
            + starting.get(ReplicaRole.PREFILL, 0)
        n_decode = sig[ReplicaRole.DECODE][2] \
            + starting.get(ReplicaRole.DECODE, 0)
        total = n_prefill + n_decode
        if total == 0:
            return None
        excess_prefill = n_prefill / total - target
        candidates = []
        if n_prefill > 1:
            candidates.append((excess_prefill, ReplicaRole.PREFILL))
        if n_decode > 1:
            candidates.append((-excess_prefill, ReplicaRole.DECODE))
        if not candidates:
            return None
        return max(candidates, key=lambda c: c[0])[1]

    def _emit(self, now: float, action: str, role: ReplicaRole,
              sig: tuple) -> Tuple[str, ReplicaRole]:
        self._last_action_time = now
        self.events.append(ScaleEvent(
            time=now, action=action, n_active=sig[2],
            queue_mass_per_replica=sig[0], utilization=sig[1],
            role=role.value))
        if self.trace.enabled:
            self.trace.emit(
                now, _tr.SCALE_UP if action == SCALE_UP
                else _tr.SCALE_DOWN,
                role=role.value, n_active=sig[2],
                queue_mass_per_replica=sig[0], utilization=sig[1])
        return action, role

    def pick_drain_target(self, replicas: Sequence[Replica],
                          role: Optional[ReplicaRole] = None
                          ) -> Optional[Replica]:
        """Least-loaded active replica of ``role`` (whole pool when
        None), keeping at least one active replica per role."""
        if role is None:
            return super().pick_drain_target(replicas)
        active = [r for r in replicas
                  if r.state is ReplicaState.ACTIVE and r.role is role]
        if len(active) <= 1:
            return None
        return min(active, key=lambda r: (r.token_mass(), -r.rid))
