"""Cluster serving layer: many replicas behind one calibrated front end.

The paper validates DriftSched on a single worker; this package scales
the same state machine out to N replicas without changing it:

* :mod:`replica`    — the execution-agnostic replica surface (state,
  estimated-token mass, worker signals) routing and scaling reason over;
* :mod:`router`     — ``ClusterRouter`` with six pluggable policies
  (``round_robin`` / ``least_loaded`` / ``drift_aware`` /
  ``tenant_affinity`` / ``prefix_aware`` / ``pd_disaggregated``), all
  priced by the *shared* ``AdaptiveTokenEstimator``, plus the
  cross-replica work-stealing protocol;
* :mod:`admission`  — ``GlobalAdmission``: per-tenant token-bucket rate
  limits in estimated budget tokens, cluster-depth backpressure, and
  per-tier shed accounting;
* :mod:`autoscaler` — utilization + queue-mass elastic scaling with
  hysteresis, cooldowns, and cold-start delays; ``RoleAutoscaler``
  scales prefill and decode pools separately against a ratio target;
* :mod:`simulator`  — ``ClusterSimulator``: N per-replica
  ``WorkerSimulator`` instances composed under one event heap and one
  seed, with replica-failure rerouting; under ``pd_disaggregated``
  routing the request lifecycle becomes a two-stage pipeline (prefill
  replica → modeled KV transfer → decode replica);
* :mod:`driver`     — the same router/admission front end over real
  ``ServingEngine`` instances (oracle-EOS caveat applies, see the
  module docstring);
* :mod:`metrics`    — cluster-level aggregation (RunMetrics + shed
  rates, per-replica utilization, scale events).
"""

from .admission import (AdmissionConfig, GlobalAdmission, TokenBucket,
                        SHED_BACKPRESSURE, SHED_NO_REPLICA, SHED_RATE_LIMIT)
from .autoscaler import (Autoscaler, AutoscalerConfig, RoleAutoscaler,
                         RoleAutoscalerConfig, ScaleEvent)
from .metrics import ClusterMetrics, ReplicaStats, summarize_cluster
from .replica import Replica, ReplicaRole, ReplicaState
from .router import (ClusterRouter, DriftAwareRouting, LeastLoadedRouting,
                     PDDisaggregatedRouting, PrefixAwareRouting,
                     ROUTING_POLICIES, RoundRobinRouting, RoutingPolicy,
                     StealPlan, TenantAffinityRouting, make_routing_policy)
from .simulator import ClusterConfig, ClusterSimulator, Handoff, SimReplica

__all__ = [
    "AdmissionConfig", "Autoscaler", "AutoscalerConfig", "ClusterConfig",
    "ClusterMetrics", "ClusterRouter", "ClusterSimulator",
    "DriftAwareRouting", "GlobalAdmission", "Handoff",
    "LeastLoadedRouting", "PDDisaggregatedRouting", "PrefixAwareRouting",
    "ROUTING_POLICIES",
    "Replica", "ReplicaRole", "ReplicaState", "ReplicaStats",
    "RoleAutoscaler", "RoleAutoscalerConfig", "RoundRobinRouting",
    "RoutingPolicy", "SHED_BACKPRESSURE", "SHED_NO_REPLICA",
    "SHED_RATE_LIMIT", "ScaleEvent", "SimReplica", "StealPlan",
    "TenantAffinityRouting", "TokenBucket", "make_routing_policy",
    "summarize_cluster",
]
