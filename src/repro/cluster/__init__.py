"""Cluster serving layer: many replicas behind one calibrated front end.

The paper validates DriftSched on a single worker; this package scales
the same state machine out to N replicas without changing it:

* :mod:`replica`    — the execution-agnostic replica surface (state,
  estimated-token mass, worker signals) routing and scaling reason over;
* :mod:`router`     — ``ClusterRouter`` with four pluggable policies
  (``round_robin`` / ``least_loaded`` / ``drift_aware`` /
  ``tenant_affinity``), all priced by the *shared*
  ``AdaptiveTokenEstimator``;
* :mod:`admission`  — ``GlobalAdmission``: per-tenant token-bucket rate
  limits in estimated budget tokens, cluster-depth backpressure, and
  per-tier shed accounting;
* :mod:`autoscaler` — utilization + queue-mass elastic scaling with
  hysteresis, cooldowns, and cold-start delays;
* :mod:`simulator`  — ``ClusterSimulator``: N per-replica
  ``WorkerSimulator`` instances composed under one event heap and one
  seed, with replica-failure rerouting;
* :mod:`driver`     — the same router/admission front end over real
  ``ServingEngine`` instances (oracle-EOS caveat applies, see the
  module docstring);
* :mod:`metrics`    — cluster-level aggregation (RunMetrics + shed
  rates, per-replica utilization, scale events).
"""

from .admission import (AdmissionConfig, GlobalAdmission, TokenBucket,
                        SHED_BACKPRESSURE, SHED_NO_REPLICA, SHED_RATE_LIMIT)
from .autoscaler import Autoscaler, AutoscalerConfig, ScaleEvent
from .metrics import ClusterMetrics, ReplicaStats, summarize_cluster
from .replica import Replica, ReplicaState
from .router import (ClusterRouter, DriftAwareRouting, LeastLoadedRouting,
                     ROUTING_POLICIES, RoundRobinRouting, RoutingPolicy,
                     TenantAffinityRouting, make_routing_policy)
from .simulator import ClusterConfig, ClusterSimulator, SimReplica

__all__ = [
    "AdmissionConfig", "Autoscaler", "AutoscalerConfig", "ClusterConfig",
    "ClusterMetrics", "ClusterRouter", "ClusterSimulator",
    "DriftAwareRouting", "GlobalAdmission", "LeastLoadedRouting",
    "ROUTING_POLICIES", "Replica", "ReplicaState", "ReplicaStats",
    "RoundRobinRouting", "RoutingPolicy", "SHED_BACKPRESSURE",
    "SHED_NO_REPLICA", "SHED_RATE_LIMIT", "ScaleEvent", "SimReplica",
    "TenantAffinityRouting", "TokenBucket", "make_routing_policy",
    "summarize_cluster",
]
