"""Run-level metrics aggregation (Sec. II-I / IV).

Computes everything the paper's tables report from a list of completed
requests: latency percentiles (P50/P95/P99), queue waits, per-tenant
and per-job-class breakdowns, GPU execution latency, throughput, and
Jain's fairness index over tenant latencies — plus the step-engine
streaming stats the paper could not observe at batch granularity:
decode-phase latency and the per-request mean inter-token gap
(``Request.inter_token_latency``), both empty on legacy atomic unified
runs where no first-token anchor exists.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional

from ..core.request import JobClass, Request, TenantTier
# the exact-statistics helpers moved to the shared observability layer
# (repro.obs.stats); re-exported here so existing imports keep working
from ..obs.stats import LatencyStats, jain_index, percentile

__all__ = ["LatencyStats", "RunMetrics", "jain_index", "percentile",
           "summarize_run", "summarize_run_arrays"]


@dataclass
class RunMetrics:
    """Everything a benchmark needs from one experiment run."""

    policy: str
    bias_enabled: bool
    e2e: LatencyStats
    queue_wait: LatencyStats
    gpu_exec: LatencyStats
    per_tenant: Dict[str, dict]
    per_class_wait: Dict[str, float]
    throughput_rps: float
    gpu_utilization: float
    fairness: float
    n_completed: int
    n_failed_dispatches: int
    makespan: float
    # step-engine streaming stats (empty when no first-token anchor
    # exists, i.e. legacy atomic unified runs): decode span per request
    # and the mean inter-token gap over its `observed - 1` gaps
    decode: LatencyStats = field(default_factory=LatencyStats)
    inter_token: LatencyStats = field(default_factory=LatencyStats)

    def as_dict(self) -> dict:
        return {
            "policy": self.policy,
            "bias_enabled": self.bias_enabled,
            "e2e": self.e2e.as_dict(),
            "queue_wait": self.queue_wait.as_dict(),
            "gpu_exec": self.gpu_exec.as_dict(),
            "per_tenant": self.per_tenant,
            "per_class_wait": self.per_class_wait,
            "throughput_rps": self.throughput_rps,
            "gpu_utilization": self.gpu_utilization,
            "fairness": self.fairness,
            "n_completed": self.n_completed,
            "n_failed_dispatches": self.n_failed_dispatches,
            "makespan": self.makespan,
            "decode": self.decode.as_dict(),
            "inter_token": self.inter_token.as_dict(),
        }


def summarize_run(policy: str, bias_enabled: bool,
                  completed: Iterable[Request], *,
                  busy_time: float = 0.0,
                  n_failed_dispatches: int = 0) -> RunMetrics:
    reqs = list(completed)
    e2e = [r.e2e_latency for r in reqs]
    waits = [r.queue_wait for r in reqs]
    execs = [r.gpu_latency for r in reqs]

    per_tenant = {}
    for tier in TenantTier:
        sel = [r for r in reqs if r.tenant == tier]
        per_tenant[tier.label] = {
            "latency": LatencyStats.of([r.e2e_latency for r in sel]).as_dict(),
            "queue_wait": LatencyStats.of([r.queue_wait for r in sel]).as_dict(),
        }

    per_class = {}
    for jc in JobClass:
        sel = [r.queue_wait for r in reqs
               if r.estimate and r.estimate.job_class == jc]
        sel = [w for w in sel if w is not None]
        per_class[jc.value] = sum(sel) / len(sel) if sel else float("nan")

    makespan = max((r.completion_time for r in reqs
                    if r.completion_time is not None), default=0.0)
    tenant_means = [per_tenant[t.label]["latency"]["mean"]
                    for t in TenantTier
                    if per_tenant[t.label]["latency"]["n"] > 0]

    return RunMetrics(
        policy=policy,
        bias_enabled=bias_enabled,
        e2e=LatencyStats.of(e2e),
        queue_wait=LatencyStats.of(waits),
        gpu_exec=LatencyStats.of(execs),
        per_tenant=per_tenant,
        per_class_wait=per_class,
        throughput_rps=len(reqs) / makespan if makespan > 0 else 0.0,
        gpu_utilization=busy_time / makespan if makespan > 0 else 0.0,
        fairness=jain_index(tenant_means),
        n_completed=len(reqs),
        n_failed_dispatches=n_failed_dispatches,
        makespan=makespan,
        decode=LatencyStats.of([r.decode_latency for r in reqs]),
        inter_token=LatencyStats.of([r.inter_token_latency for r in reqs]),
    )


def _nan_to_none(a) -> List[Optional[float]]:
    """NaN -> None for array-to-stats handoff. CRITICAL for parity:
    :meth:`LatencyStats.of` filters None (the object world's missing
    value) but would happily average a NaN through."""
    import math
    return [None if math.isnan(x) else x for x in a.tolist()]


def summarize_run_arrays(policy: str, bias_enabled: bool, state,
                         order, *, busy_time: float = 0.0,
                         n_failed_dispatches: int = 0) -> RunMetrics:
    """Array-core twin of :func:`summarize_run`: computes the same
    :class:`RunMetrics` from ``repro.serving.vector_sim.VectorState``
    columns and a completion-order index array, bit-identically.

    Per-request quantities are single IEEE subtractions/divisions on
    float64 columns — the same operations the ``Request`` latency
    properties perform on the same values — and the reductions reuse
    the exact :class:`LatencyStats`/:func:`jain_index` helpers (Python
    sequential sums), so a vector run and an object run with identical
    event trajectories summarize to identical metrics."""
    import math

    import numpy as np

    order = np.asarray(order, dtype=np.int64)
    comp = state.completion[order]
    arrival = state.arrival[order]
    e2e_a = comp - arrival
    waits_a = state.dispatch[order] - arrival
    execs_a = state.exec_end[order] - state.exec_start[order]
    decode_a = comp - state.prefill_end[order]
    obs = state.observed[order].astype(np.float64)
    with np.errstate(invalid="ignore"):
        inter_a = np.where(obs > 1.0,
                           decode_a / np.maximum(obs - 1.0, 1.0), np.nan)

    tenants = state.tenant[order]
    per_tenant = {}
    for tier in TenantTier:
        m = tenants == int(tier)
        per_tenant[tier.label] = {
            "latency": LatencyStats.of(_nan_to_none(e2e_a[m])).as_dict(),
            "queue_wait": LatencyStats.of(
                _nan_to_none(waits_a[m])).as_dict(),
        }

    classes = state.job_class[order]
    per_class = {}
    for code, jc in enumerate(JobClass):
        sel = [w for w in waits_a[classes == code].tolist()
               if not math.isnan(w)]
        per_class[jc.value] = sum(sel) / len(sel) if sel else float("nan")

    n = int(order.shape[0])
    makespan = float(np.max(comp)) if n else 0.0
    tenant_means = [per_tenant[t.label]["latency"]["mean"]
                    for t in TenantTier
                    if per_tenant[t.label]["latency"]["n"] > 0]

    return RunMetrics(
        policy=policy,
        bias_enabled=bias_enabled,
        e2e=LatencyStats.of(_nan_to_none(e2e_a)),
        queue_wait=LatencyStats.of(_nan_to_none(waits_a)),
        gpu_exec=LatencyStats.of(_nan_to_none(execs_a)),
        per_tenant=per_tenant,
        per_class_wait=per_class,
        throughput_rps=n / makespan if makespan > 0 else 0.0,
        gpu_utilization=busy_time / makespan if makespan > 0 else 0.0,
        fairness=jain_index(tenant_means),
        n_completed=n,
        n_failed_dispatches=n_failed_dispatches,
        makespan=makespan,
        decode=LatencyStats.of(_nan_to_none(decode_a)),
        inter_token=LatencyStats.of(_nan_to_none(inter_a)),
    )
