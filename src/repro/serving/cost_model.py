"""Service-time model for the cluster simulator.

Two calibrations:

* ``L4_QWEN_1_8B`` — mirrors the paper's measurement platform (NVIDIA
  L4, Qwen1.5-1.8B FP16, vLLM, GPU batch 32). Constants are fitted so
  the FIFO baseline lands on the paper's own observations: per-batch
  GPU execution P50 ~= 10.5 s with a tight tail (P99 ~= 11.3 s, Fig 9),
  queue-dominated e2e latencies (Tables III-IV).
* ``from_roofline`` — TPU projection: reads a roofline JSON produced by
  the dry-run analysis and converts the per-step lower bound into
  per-token service rates, so the same simulator projects DriftSched
  behaviour onto the v5e serving deployment.

The primitive is one continuous-batching *iteration* (Orca/vLLM /
Sarathi chunked prefill):

    T(step) = c_decode_max                      # per-iteration walk/launch
            + c_decode_sum * n_decoding         # one token per active slot
            + c_prefill * prefill_tokens        # chunked-prefill share

:meth:`CostModel.batch_time` — the paper's atomic-batch price (worker
timestamps recorded around each GPU batch, Sec. II-I) — is the *derived
legacy view*: the closed form of ``t_base`` plus the sum of step times
over a batch run to completion with unbounded chunk budget and no
mid-flight joins,

    T(batch) = t_base + c_prefill * sum(prompt_tokens)
             + c_decode_max * max(output_tokens)       # batch walks to
             + c_decode_sum * sum(output_tokens)       # its longest member

(slot i emits in iterations 1..out_i, so the sum telescopes). The
identity is locked by ``tests/test_step_engine.py``; the L4
calibrations below were fitted against the atomic view and stay
meaningful for the step engine because of it.
"""

from __future__ import annotations

import json
import math
from dataclasses import dataclass, replace
from typing import Iterable, Optional

from ..core.request import Request


@dataclass(frozen=True)
class CostModel:
    name: str
    t_base: float            # fixed per-batch launch/teardown
    c_prefill: float         # s per prompt token (summed over batch)
    c_decode_max: float      # s per token of the batch's longest output
    c_decode_sum: float      # s per output token summed over batch
    jitter_sigma: float = 0.02   # lognormal execution noise

    def step_time(self, n_decoding: int, prefill_tokens: int = 0, *,
                  cached_tokens: int = 0, include_base: bool = False,
                  jitter: float = 1.0) -> float:
        """Price ONE continuous-batching iteration: ``n_decoding`` slots
        each emit one token, plus a chunked-prefill share of
        ``prefill_tokens`` prompt tokens processed alongside them
        (Sarathi-style piggybacking). ``cached_tokens`` of those prompt
        tokens are resident in the prefix KV cache and cost nothing —
        only the uncached suffix is priced (the step engine already
        passes net chunk sizes; the argument serves callers pricing a
        request's remaining prefill against known cache state). Decode
        cost is unaffected: attention still reads the cached pages.
        ``include_base`` adds the per-dispatch launch overhead
        ``t_base`` — charged once per batch formation, not per
        iteration (continuous batching amortises the launch across the
        busy period). Returns 0 for an empty step."""
        prefill_tokens = max(prefill_tokens - cached_tokens, 0)
        if n_decoding <= 0 and prefill_tokens <= 0:
            return 0.0
        t = (self.c_decode_max
             + self.c_decode_sum * n_decoding
             + self.c_prefill * prefill_tokens)
        if include_base:
            t += self.t_base
        return t * jitter

    def decode_step_time(self, n_decoding: int) -> float:
        """Pre-jitter price of one pure-decode iteration with
        ``n_decoding`` emitting slots. Delegates to :meth:`step_time`
        so the value is bit-identical to what the per-step engine pays
        (``x * 1.0 == x`` exactly in IEEE arithmetic) — the epoch-
        batched fast paths in ``repro.serving.vector_sim`` multiply
        this base by per-iteration jitter draws and MUST price each
        collapsed iteration to the same float the object engine
        would."""
        return self.step_time(n_decoding, 0, include_base=False,
                              jitter=1.0)

    def batch_time(self, requests: Iterable[Request], *,
                   cached_tokens: int = 0, jitter: float = 1.0) -> float:
        """Atomic-batch price — the derived/legacy view of
        :meth:`step_time` (see module docstring for the telescoped
        identity): the batch prefills every prompt up front and decodes
        until its longest member finishes. ``cached_tokens`` discounts
        prompt tokens resident in the prefix KV cache (summed over the
        batch) — the atomic executor itself never populates a prefix
        cache, so this serves estimation callers only."""
        reqs = list(requests)
        if not reqs:
            return 0.0
        sum_prompt = max(
            sum(r.prompt_tokens for r in reqs) - cached_tokens, 0)
        outs = [min(r.true_output_tokens, r.max_tokens) for r in reqs]
        t = (self.t_base
             + self.c_prefill * sum_prompt
             + self.c_decode_max * max(outs)
             + self.c_decode_sum * sum(outs))
        return t * jitter

    def jitter(self, rng) -> float:
        if self.jitter_sigma <= 0:
            return 1.0
        return math.exp(rng.gauss(0.0, self.jitter_sigma)
                        - 0.5 * self.jitter_sigma ** 2)


# Paper platform: Qwen1.5-1.8B FP16 on one NVIDIA L4 via vLLM.
# Calibrated by grid search against the paper's own FIFO/SJF
# observations (Tables III-IV): full FIFO batches execute in ~10-12 s
# with a tight tail (Fig 9), total GPU time is mostly token-volume
# driven (continuous batching) with a batch-walk component on the
# longest member, giving SJF its throughput edge. See EXPERIMENTS.md
# §Paper-validation for the residuals.
L4_QWEN_1_8B = CostModel(
    name="l4-qwen1.5-1.8b",
    t_base=0.25,
    c_prefill=5e-5,
    c_decode_max=3.7e-3,
    c_decode_sum=1.22e-3,
)


# Alternative calibration: batch time dominated by the longest member
# (each dispatched batch runs to completion before the next, so the
# near-cap report in every saturated FIFO batch walks it). Under this
# regime SJF's homogeneous batches genuinely shorten total GPU time —
# reproducing the paper's SJF P99 win (Table III) — but shorts then
# drain so fast that SJF's P50/wait land far below the paper's.
# bench_tail_latency reports both regimes; the truth of the paper's
# vLLM backend sits between them (EXPERIMENTS.md §Paper-validation).
L4_MAX_DRIVEN = CostModel(
    name="l4-max-driven",
    t_base=0.6,
    c_prefill=5e-5,
    c_decode_max=9.0e-3,
    c_decode_sum=1.5e-4,
)


def prefill_view(cost: CostModel) -> CostModel:
    """Phase-scoped view for a P/D *prefill* replica: a batch there only
    pays launch overhead + prompt processing. Decode coefficients are
    zeroed, so batch time is independent of output lengths — which the
    prefill stage never produces. The ``cached_tokens`` discount of
    ``step_time``/``batch_time`` applies unchanged: a prefill replica
    with a resident shared prefix prices only the uncached suffix."""
    return replace(cost, name=cost.name + "+prefill",
                   c_decode_max=0.0, c_decode_sum=0.0)


def decode_view(cost: CostModel) -> CostModel:
    """Phase-scoped view for a P/D *decode* replica: prompt tokens were
    already prefilled elsewhere (the KV arrives via the modeled
    transfer), so only launch overhead + decode terms remain. Both
    phases keep ``t_base``: disaggregation pays two batch launches per
    request — that overhead is part of its price."""
    return replace(cost, name=cost.name + "+decode", c_prefill=0.0)


def from_roofline(path: str, *, batch_capacity: int = 32,
                  name: Optional[str] = None) -> CostModel:
    """TPU projection from a decode-cell roofline JSON: the step-time
    lower bound of one decode iteration (batch B) gives c_decode.
    Prefill cost from the matching prefill cell if present."""
    with open(path) as f:
        rec = json.load(f)
    r = rec["roofline"]
    step = float(r["step_time_lower_bound_s"])
    # one decode step advances every active slot one token
    c_decode_sum = step / max(batch_capacity, 1)
    return CostModel(
        name=name or f"roofline:{rec['arch']}",
        t_base=0.005,
        c_prefill=step / (batch_capacity * 64),   # chunked-prefill share
        c_decode_max=0.0,                          # continuous batching:
        c_decode_sum=c_decode_sum,                 # cost ~ total tokens
        jitter_sigma=0.01,
    )
