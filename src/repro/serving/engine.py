"""The real JAX continuous-batching engine (slot-ring design).

XLA needs static shapes, so the iteration-level batching of Orca/vLLM
becomes a fixed-size ring of decode slots:

* ``n_slots`` sequences decode in lockstep, one token per engine step
  (a single jitted ``serve_step`` on the whole slot batch);
* join = prefill the prompt (jitted per prompt-length bucket) and
  scatter the resulting cache into the slot's batch index;
* leave = mark the slot free (its lane keeps computing garbage that is
  masked out — the standard TPU serving trade);
* per-slot positions: each lane decodes at its own depth (the
  ``pos``-vector decode path in models/layers.py).

The engine drives the *identical* DriftScheduler state machine the
simulator uses — admission, dispatch, completion feedback (Eq. 5-6) —
so scheduling behaviour validated on the simulator transfers 1:1.

EOS: with randomly-initialised smoke models there is no semantic EOS,
so requests stop at their ground-truth output length (oracle EOS,
clipped by max_tokens) — exactly the signal the drift compensator must
learn. A real deployment swaps in token-id EOS detection.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.request import Request, RequestState
from ..core.scheduler import DriftScheduler
from ..models.config import ModelConfig
from ..models.registry import get_api
from ..models.steps import sample_logits
from .metrics import RunMetrics, summarize_run


@dataclass(frozen=True)
class EngineConfig:
    n_slots: int = 8
    max_len: int = 256               # per-slot cache capacity
    prompt_buckets: Tuple[int, ...] = (16, 32, 64)
    temperature: float = 0.0
    batch_wait_steps: int = 0
    # vLLM-style paged KV pool instead of the slot-ring cache
    # (transformer-family archs; kernels/paged_attention on TPU)
    paged: bool = False
    page_size: int = 16


def _bucket(n: int, buckets: Tuple[int, ...]) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


@dataclass
class SlotState:
    req: Optional[Request] = None
    generated: int = 0
    target: int = 0
    last_token: int = 0


class ServingEngine:
    """Continuous-batching engine for one model on the local backend."""

    def __init__(self, cfg: ModelConfig, params, scheduler: DriftScheduler,
                 config: Optional[EngineConfig] = None,
                 extras: Optional[Dict] = None) -> None:
        self.cfg = cfg
        self.params = params
        self.sched = scheduler
        self.ecfg = config or EngineConfig()
        self.extras = extras or {}
        self.api = get_api(cfg)
        n, S = self.ecfg.n_slots, self.ecfg.max_len
        self.slots: List[SlotState] = [SlotState() for _ in range(n)]
        self.step_count = 0
        self.busy_steps = 0
        self._rng = jax.random.PRNGKey(0)
        self._prefill_cache = {}

        if self.ecfg.paged:
            if cfg.family not in ("dense", "moe", "vlm"):
                raise ValueError(
                    f"paged engine supports transformer-family archs, "
                    f"not {cfg.family!r} (SSM state is O(1) already)")
            from .kv_cache import PagedAllocator, PagedPool
            pages_per_seq = -(-S // self.ecfg.page_size)
            # pool has one extra page the allocator never hands out:
            # inactive slots scatter their (masked) writes into it
            self.alloc = PagedAllocator(
                n_pages=n * pages_per_seq,
                page_size=self.ecfg.page_size,
                pages_per_seq=pages_per_seq)
            self.pool = PagedPool.create(cfg, self.alloc.n_pages + 1,
                                         self.ecfg.page_size)
            self._decode_paged = jax.jit(self._decode_paged_fn)
        else:
            self.cache = self.api.init_cache(cfg, n, S)
            self._decode = jax.jit(self._decode_fn)

    # --- jitted units ---------------------------------------------------
    def _decode_fn(self, params, cache, tokens, pos, rng):
        logits, cache = self.api.decode_step(self.cfg, params, cache,
                                             tokens, pos)
        toks = sample_logits(logits, rng, self.ecfg.temperature)
        return toks, cache

    def _decode_paged_fn(self, params, pool, tokens, page_table,
                         seq_lens, rng):
        from ..models import transformer
        logits, pool = transformer.decode_step_paged(
            self.cfg, params, pool, tokens, page_table, seq_lens)
        toks = sample_logits(logits, rng, self.ecfg.temperature)
        return toks, pool

    def _prefill_fn_for(self, bucket: int):
        if bucket not in self._prefill_cache:
            def fn(params, batch, rng):
                logits, cache = self.api.prefill(
                    self.cfg, params, batch, max_len=self.ecfg.max_len)
                return sample_logits(logits, rng,
                                     self.ecfg.temperature), cache
            self._prefill_cache[bucket] = jax.jit(fn)
        return self._prefill_cache[bucket]

    # --- slot management --------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.req is None]

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.req is not None]

    def _insert_cache(self, slot: int, cache_1: Dict) -> None:
        """Scatter a batch-1 prefill cache into slot ``slot``."""
        def ins(full, one):
            axis = 1 if full.ndim > 1 else 0
            idx = [slice(None)] * full.ndim
            idx[axis] = slot
            return full.at[tuple(idx)].set(
                jnp.take(one, 0, axis=axis).astype(full.dtype))
        self.cache = jax.tree_util.tree_map(ins, self.cache, cache_1)

    def _admit(self, req: Request, slot: int, now: float) -> None:
        prompt_len = max(req.prompt_tokens, 1)
        bucket = _bucket(prompt_len, self.ecfg.prompt_buckets)
        prompt_len = min(prompt_len, bucket)      # truncate to the bucket
        tokens = np.zeros((1, bucket), np.int32)
        ids = np.frombuffer(req.prompt.encode()[:prompt_len * 4],
                            dtype=np.uint8)[:prompt_len]
        if len(ids):
            tokens[0, -len(ids):] = ids % max(self.cfg.vocab - 1, 1) + 1
        batch = {"tokens": jnp.asarray(tokens)}
        if self.cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (1, self.cfg.prefix_len, self.cfg.d_model), jnp.bfloat16)
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (1, self.cfg.enc_seq, self.cfg.d_model), jnp.bfloat16)
        self._rng, sub = jax.random.split(self._rng)
        if self.ecfg.paged:
            from ..models import transformer
            from .kv_cache import write_prefill_pages
            logits, k_lv, v_lv = transformer.prefill_kv(
                self.cfg, self.params, batch["tokens"],
                patches=batch.get("patches"))
            pages = self.alloc.alloc(slot, bucket)
            self.pool = write_prefill_pages(
                self.pool, (k_lv[:, 0], v_lv[:, 0]), pages, bucket)
            tok = sample_logits(logits, sub, self.ecfg.temperature)
        else:
            tok, cache_1 = self._prefill_fn_for(bucket)(self.params,
                                                        batch, sub)
            self._insert_cache(slot, cache_1)
        st = self.slots[slot]
        st.req = req
        st.generated = 1                       # prefill emitted one token
        st.target = max(1, min(req.true_output_tokens, req.max_tokens,
                               self.ecfg.max_len - bucket - 2))
        st.last_token = int(tok[0])
        req.state = RequestState.EXECUTING
        req.exec_start = now

    def _retire(self, slot: int, now: float) -> None:
        st = self.slots[slot]
        req = st.req
        req.exec_end = now
        self.sched.complete(req, st.generated, now)
        if self.ecfg.paged:
            self.alloc.free(slot)
        st.req = None
        st.generated = 0
        st.target = 0

    # --- main loop ----------------------------------------------------------
    def step(self, now: float) -> int:
        """One engine iteration: admit into free slots, advance every
        active slot one token, retire finished ones. Returns number of
        completions this step. Per-iteration admission honours the
        scheduler's ``max_new_per_step`` knob — the same slot-granular
        contract the discrete-event step engine uses
        (``DriftScheduler.dispatch_step``)."""
        # admission
        joined = 0
        cap = self.sched.max_new_per_step
        for slot in self.free_slots():
            if self.sched.queue_depth() == 0:
                break
            if cap is not None and joined >= cap:
                break
            req = self.sched.dispatch(now)
            if req is None:
                break
            self._admit(req, slot, now)
            joined += 1

        active = self.active_slots()
        if not active:
            return 0

        tokens = np.zeros((self.ecfg.n_slots,), np.int32)
        for i in active:
            tokens[i] = self.slots[i].last_token
        self._rng, sub = jax.random.split(self._rng)
        if self.ecfg.paged:
            sids = [i if self.slots[i].req is not None else None
                    for i in range(self.ecfg.n_slots)]
            pt = self.alloc.table_array(sids)
            scratch = self.pool.n_pages - 1      # never allocated: inactive
            for i, sid in enumerate(sids):       # slots write there
                if sid is None:
                    pt[i, :] = scratch
            lens = self.alloc.lens_array(sids)
            toks, new_pool = self._decode_paged(
                self.params, {"k": self.pool.k, "v": self.pool.v},
                jnp.asarray(tokens), jnp.asarray(pt),
                jnp.asarray(lens), sub)
            from .kv_cache import PagedPool
            self.pool = PagedPool(k=new_pool["k"], v=new_pool["v"],
                                  page_size=self.ecfg.page_size)
            for i in active:
                self.alloc.extend(i, 1)
        else:
            pos = np.asarray(self.cache["lens"])     # per-slot depth
            toks, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(pos, np.int32), sub)
        toks = np.asarray(toks)

        done = 0
        for i in active:
            st = self.slots[i]
            st.generated += 1
            st.last_token = int(toks[i])
            if st.generated >= st.target:       # oracle EOS
                self._retire(i, now)
                done += 1
        self.step_count += 1
        self.busy_steps += 1
        return done

    def run_until_drained(self, *, max_steps: int = 100_000,
                          dt: float = 1.0) -> RunMetrics:
        """Process everything queued in the scheduler; ``dt`` is the
        simulated wall-clock per engine step (CPU steps are not
        representative of TPU step time)."""
        now = 0.0
        for _ in range(max_steps):
            if (self.sched.queue_depth() == 0
                    and not self.active_slots()):
                break
            self.step(now)
            now += dt
        return summarize_run(self.sched.policy.name,
                             self.sched.config.bias_enabled,
                             self.sched.completed,
                             busy_time=float(self.busy_steps) * dt)
