"""The real JAX continuous-batching engine (slot-ring design).

XLA needs static shapes, so the iteration-level batching of Orca/vLLM
becomes a fixed-size ring of decode slots:

* ``n_slots`` sequences decode in lockstep, one token per engine step
  (a single jitted ``serve_step`` on the whole slot batch);
* join = prefill the prompt (jitted per prompt-length bucket) and
  scatter the resulting cache into the slot's batch index;
* leave = mark the slot free (its lane keeps computing garbage that is
  masked out — the standard TPU serving trade);
* per-slot positions: each lane decodes at its own depth (the
  ``pos``-vector decode path in models/layers.py).

The engine drives the *identical* DriftScheduler state machine the
simulator uses — admission, dispatch, completion feedback (Eq. 5-6) —
so scheduling behaviour validated on the simulator transfers 1:1.

Iteration-level execution (mirrors the simulator's step engine,
``serving/simulator.py``; pinned by ``tests/test_engine_parity.py``):

* **Chunked prefill** (``EngineConfig.chunk_prefill_tokens``): a
  joining slot's prompt is consumed across iterations against a
  per-step prefill token budget shared by prefilling slots in join
  order (Sarathi-style). The slot's first token — and its honest TTFT
  anchor ``Request.prefill_end`` — lands at the iteration its last
  chunk completes; admissions keep interleaving with decode under
  ``DriftScheduler.max_new_per_step``, and slot state only changes at
  iteration boundaries. ``None`` (unbounded) is the legacy contract:
  the whole bucket prefills in the admission step, which the parity
  suite locks bit-for-bit against the pre-chunking engine.
  Chunk accounting runs in *request* prompt tokens (clipped to the
  bucket): the XLA padding a bucket adds is a static-shape artifact,
  not billable workload. On the paged transformer path every chunk
  *executes on device* the iteration its budget is consumed, through
  the fused chunked-prefill kernel
  (``kernels/chunked_prefill.py``): scatter the slab's K/V into the
  sequence's pages, then attend it against everything resident —
  prefix-tree pages and earlier chunks — under query-offset causal
  masking. The final chunk covers the bucket's padding tail and its
  last-position logits produce the first token. (The vlm family and
  the slot-ring cache keep the legacy single-shot bucket prefill.)
* **Shared-prefix reuse** (``EngineConfig.prefix_cache``, paged mode
  only): ``kv_cache.PrefixTree`` runs over the engine's own page pool.
  A joining request whose prompt starts with a resident shared prefix
  (``Request.prefix_group`` / ``shared_prefix_tokens``) skips
  prefilling the cached full pages — its page table references the
  tree's refcount-pinned pages directly and chunked prefill starts at
  the cached boundary. At prefill completion the freshly-written full
  prefix pages are *donated* to the tree (``insert(pages=...)``: page
  identity survives because the KV is already on device), and the pin
  is released at retirement. ``prefix_cache_pages`` extra pool pages
  back residency; unreferenced LRU leaves evict under pressure.
  Shared-prefix prompts are tokenized with a deterministic per-group
  prefix (content-hashed, positions 0..shared-1) so donated pages hold
  exactly the KV any group member would compute.

EOS: with randomly-initialised smoke models there is no semantic EOS,
so requests stop at their ground-truth output length (oracle EOS,
clipped by max_tokens) — exactly the signal the drift compensator must
learn. A real deployment swaps in token-id EOS detection. Note the
cache interaction: a prefix served from cache re-observes no drift —
feedback comes only from the decode the request actually ran.
"""

from __future__ import annotations

import math
import zlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.request import Request, RequestState
from ..core.scheduler import DriftScheduler
from ..models.config import ModelConfig
from ..models.registry import get_api
from ..models.steps import sample_logits
from ..obs import events as tr
from ..obs import resolve_recorder
from .kv_cache import PagedSeqLedger, prefix_page_key
from .metrics import RunMetrics, summarize_run


@dataclass(frozen=True)
class EngineConfig:
    n_slots: int = 8
    max_len: int = 256               # per-slot cache capacity
    prompt_buckets: Tuple[int, ...] = (16, 32, 64)
    temperature: float = 0.0
    batch_wait_steps: int = 0
    # vLLM-style paged KV pool instead of the slot-ring cache
    # (transformer-family archs; kernels/paged_attention on TPU)
    paged: bool = False
    page_size: int = 16
    # --- iteration-level prefill (Sarathi chunking) ---
    # per-STEP prefill token budget shared by prefilling slots in join
    # order; None = unbounded (whole-bucket prefill in the admission
    # step — the legacy contract, locked by tests/test_engine_parity.py)
    chunk_prefill_tokens: Optional[int] = None
    # --- shared-prefix radix cache over the paged pool ---
    # requires paged=True: sharing is physical (page-table aliasing)
    prefix_cache: bool = False
    # extra pool pages reserved for cache residency; also the LRU
    # budget the tree is evicted back to after each donation
    prefix_cache_pages: int = 64


def _bucket(n: int, buckets: Tuple[int, ...]) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


@dataclass
class SlotState:
    req: Optional[Request] = None
    generated: int = 0
    target: int = 0
    last_token: int = 0
    # --- chunked-prefill progress (request prompt tokens, bucket-clipped)
    prompt_len: int = 0
    prefill_remaining: int = 0     # uncached prompt tokens not yet consumed
    cached_tokens: int = 0         # prompt tokens served from the cache
    pending_prefill: bool = False  # device prefill not yet executed
    batch: Optional[Dict] = None   # tokenized prompt awaiting prefill
    bucket: int = 0
    prefill_pos: int = 0           # bucket position device prefill reached


class ServingEngine:
    """Continuous-batching engine for one model on the local backend."""

    def __init__(self, cfg: ModelConfig, params, scheduler: DriftScheduler,
                 config: Optional[EngineConfig] = None,
                 extras: Optional[Dict] = None,
                 trace=None) -> None:
        self.cfg = cfg
        self.params = params
        self.sched = scheduler
        self.ecfg = config or EngineConfig()
        self.extras = extras or {}
        self.trace = resolve_recorder(trace)
        # replica id stamped on emitted events; the cluster driver sets
        # it after construction (None = standalone / unset)
        self.trace_rid: Optional[int] = None
        if self.trace.enabled:
            self.sched.drift.trace = self.trace
        self.api = get_api(cfg)
        c = self.ecfg.chunk_prefill_tokens
        if c is not None and c < 1:
            raise ValueError(
                f"chunk_prefill_tokens must be >= 1 or None, got {c}")
        if self.ecfg.prefix_cache and not self.ecfg.paged:
            raise ValueError(
                "prefix_cache requires paged=True: prefix sharing is "
                "physical page-table aliasing over the paged pool")
        n, S = self.ecfg.n_slots, self.ecfg.max_len
        self.slots: List[SlotState] = [SlotState() for _ in range(n)]
        self.step_count = 0
        self.busy_steps = 0
        self._rng = jax.random.PRNGKey(0)
        self._prefill_cache = {}
        self._chunk_cache = {}             # jitted chunk fns, keyed by length
        self._join_order: List[int] = []   # slot ids, chunk-budget order
        # --- per-chunk device execution counters (paged prefill path) ---
        self.n_prefill_launches = 0
        self.prefill_chunk_log: List[Tuple[int, int]] = []  # (slot, length)
        # --- P/D disaggregation plumbing (cluster driver) ---
        # called as hook(slot, req, now) when a slot's prefill completes;
        # returning True means the driver took the request over (KV
        # extracted for transfer) and the engine must not decode it
        self.handoff_hook = None
        self._pending_injections: Dict = {}   # req_id -> KV payload
        # --- prefix-cache counters (mirror WorkerSimulator's) ---
        self.prefix_tree = None
        self.n_prefix_hits = 0
        self.n_prefix_misses = 0
        self.prefix_tokens_saved = 0

        if self.ecfg.paged:
            if cfg.family not in ("dense", "moe", "vlm"):
                raise ValueError(
                    f"paged engine supports transformer-family archs, "
                    f"not {cfg.family!r} (SSM state is O(1) already)")
            from .kv_cache import PagedAllocator, PagedPool, PrefixTree
            pages_per_seq = -(-S // self.ecfg.page_size)
            extra = (self.ecfg.prefix_cache_pages
                     if self.ecfg.prefix_cache else 0)
            # pool has one extra page the allocator never hands out:
            # inactive slots scatter their (masked) writes into it
            self.alloc = PagedAllocator(
                n_pages=n * pages_per_seq + extra,
                page_size=self.ecfg.page_size,
                pages_per_seq=pages_per_seq)
            if self.ecfg.prefix_cache:
                self.prefix_tree = PrefixTree(self.alloc)
            self.ledger = PagedSeqLedger(
                self.alloc, self.prefix_tree,
                cache_pages_budget=(self.ecfg.prefix_cache_pages
                                    if self.ecfg.prefix_cache else None))
            self.pool = PagedPool.create(cfg, self.alloc.n_pages + 1,
                                         self.ecfg.page_size)
            self._decode_paged = jax.jit(self._decode_paged_fn,
                                         static_argnames=("max_pages",))
        else:
            self.cache = self.api.init_cache(cfg, n, S)
            self._decode = jax.jit(self._decode_fn)

    # --- jitted units ---------------------------------------------------
    def _decode_fn(self, params, cache, tokens, pos, rng):
        logits, cache = self.api.decode_step(self.cfg, params, cache,
                                             tokens, pos)
        toks = sample_logits(logits, rng, self.ecfg.temperature)
        return toks, cache

    def _decode_paged_fn(self, params, pool, tokens, page_table,
                         seq_lens, rng, *, max_pages=None):
        from ..models import transformer
        logits, pool = transformer.decode_step_paged(
            self.cfg, params, pool, tokens, page_table, seq_lens,
            max_pages=max_pages)
        toks = sample_logits(logits, rng, self.ecfg.temperature)
        return toks, pool

    def _chunk_fn_for(self, chunk_len: int):
        """Jitted fused-chunked-prefill step, cached per chunk length
        (the engine's analogue of per-bucket prefill jitting)."""
        if chunk_len not in self._chunk_cache:
            def fn(params, pool, tokens, page_table, q_offset):
                from ..models import transformer
                return transformer.prefill_chunk_paged(
                    self.cfg, params, pool, tokens, page_table, q_offset)
            self._chunk_cache[chunk_len] = jax.jit(fn)
        return self._chunk_cache[chunk_len]

    def _prefill_fn_for(self, bucket: int):
        if bucket not in self._prefill_cache:
            def fn(params, batch, rng):
                logits, cache = self.api.prefill(
                    self.cfg, params, batch, max_len=self.ecfg.max_len)
                return sample_logits(logits, rng,
                                     self.ecfg.temperature), cache
            self._prefill_cache[bucket] = jax.jit(fn)
        return self._prefill_cache[bucket]

    # --- slot management --------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.req is None]

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.req is not None]

    def _insert_cache(self, slot: int, cache_1: Dict) -> None:
        """Scatter a batch-1 prefill cache into slot ``slot``."""
        def ins(full, one):
            axis = 1 if full.ndim > 1 else 0
            idx = [slice(None)] * full.ndim
            idx[axis] = slot
            return full.at[tuple(idx)].set(
                jnp.take(one, 0, axis=axis).astype(full.dtype))
        self.cache = jax.tree_util.tree_map(ins, self.cache, cache_1)

    # --- prefix-cache plumbing -------------------------------------------
    def _shared_eff(self, req: Request, prompt_len: int) -> int:
        """Shareable prefix tokens after bucket clipping."""
        if req.prefix_group is None:
            return 0
        return min(req.shared_prefix_tokens, prompt_len)

    def _prefix_key(self, req: Request, prompt_len: int) -> tuple:
        return prefix_page_key(req.prefix_group,
                               self._shared_eff(req, prompt_len),
                               self.ecfg.page_size)

    def prefix_cached_tokens(self, req: Request) -> int:
        """Resident shared-prefix overlap this engine holds for
        ``req``, in tokens. Pure probe (no LRU/refcount perturbation) —
        the cluster router calls this per routable replica per
        placement."""
        if self.prefix_tree is None:
            return 0
        prompt_len = min(max(req.prompt_tokens, 1),
                         _bucket(max(req.prompt_tokens, 1),
                                 self.ecfg.prompt_buckets))
        key = self._prefix_key(req, prompt_len)
        if not key:
            return 0
        return min(self.prefix_tree.cached_tokens(key), prompt_len)

    def prefix_cache_stats(self) -> Dict[str, int]:
        """Cumulative cache counters (all zero when disabled)."""
        return {
            "hits": self.n_prefix_hits,
            "misses": self.n_prefix_misses,
            "tokens_saved": self.prefix_tokens_saved,
            "evicted_pages": (self.prefix_tree.n_evicted_pages
                              if self.prefix_tree else 0),
            "resident_pages": (self.prefix_tree.total_pages()
                               if self.prefix_tree else 0),
            "invalidations": 0,
        }

    # --- tokenization -----------------------------------------------------
    def _tokenize(self, req: Request, bucket: int, prompt_len: int,
                  shared_eff: int) -> np.ndarray:
        """[1, bucket] int32 prompt ids.

        Legacy layout (no shareable prefix): prompt bytes right-aligned,
        zero padding in front — bit-identical to the pre-chunking
        engine. Prefix layout (``prefix_cache`` + a shareable prefix):
        a deterministic content-hashed group prefix occupies positions
        ``[0, shared_eff)`` — every member of a prefix group computes
        identical KV there, which is what makes donated pages reusable
        — and the request's own bytes fill the rest cyclically (no
        trailing padding, so the last position stays a real token for
        the prefill logits)."""
        vocab = max(self.cfg.vocab - 1, 1)
        tokens = np.zeros((1, bucket), np.int32)
        if self.prefix_tree is not None and shared_eff > 0:
            seed = zlib.crc32(repr(req.prefix_group).encode())
            pos = np.arange(bucket, dtype=np.int64)
            tokens[0] = (seed + pos * 2654435761) % vocab + 1
            body = np.frombuffer(req.prompt.encode() or b"\x01",
                                 dtype=np.uint8).astype(np.int64)
            tail = bucket - shared_eff
            if tail > 0:
                reps = np.resize(body, tail)
                tokens[0, shared_eff:] = reps % vocab + 1
        else:
            ids = np.frombuffer(req.prompt.encode()[:prompt_len * 4],
                                dtype=np.uint8)[:prompt_len]
            if len(ids):
                tokens[0, -len(ids):] = ids % vocab + 1
        return tokens

    # --- admission --------------------------------------------------------
    def _admit(self, req: Request, slot: int, now: float) -> None:
        """Open a slot for ``req``: tokenize, probe/pin the prefix
        cache, allocate pages (paged mode) and queue the prompt for
        chunked prefill. The device prefill itself runs at the
        iteration the last chunk is consumed (:meth:`_run_prefill`)."""
        prompt_len = max(req.prompt_tokens, 1)
        bucket = _bucket(prompt_len, self.ecfg.prompt_buckets)
        prompt_len = min(prompt_len, bucket)      # truncate to the bucket
        shared_eff = self._shared_eff(req, prompt_len)
        tokens = self._tokenize(req, bucket, prompt_len, shared_eff)
        batch = {"tokens": jnp.asarray(tokens)}
        if self.cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (1, self.cfg.prefix_len, self.cfg.d_model), jnp.bfloat16)
        if self.cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (1, self.cfg.enc_seq, self.cfg.d_model), jnp.bfloat16)
        cached = 0
        if self.ecfg.paged:
            key = (self._prefix_key(req, prompt_len)
                   if self.prefix_tree is not None else ())
            evicted_before = (self.prefix_tree.n_evicted_pages
                              if self.prefix_tree is not None else 0)
            cached = self.ledger.admit(slot, bucket, key, now)
            cached = min(cached, shared_eff)
            if key:
                if cached > 0:
                    self.n_prefix_hits += 1
                    self.prefix_tokens_saved += cached
                else:
                    self.n_prefix_misses += 1
                if self.trace.enabled:
                    self.trace.emit(
                        now, tr.PREFIX_HIT if cached > 0
                        else tr.PREFIX_MISS,
                        req_id=req.req_id, rid=self.trace_rid,
                        tenant=req.tenant.label,
                        **({"tokens": cached} if cached > 0 else {}))
            if self.trace.enabled and self.prefix_tree is not None:
                delta = self.prefix_tree.n_evicted_pages - evicted_before
                if delta > 0:
                    self.trace.emit(now, tr.PREFIX_EVICT,
                                    rid=self.trace_rid, pages=delta)
        req.cached_prompt_tokens = cached
        st = self.slots[slot]
        st.req = req
        st.generated = 0
        st.target = max(1, min(req.true_output_tokens, req.max_tokens,
                               self.ecfg.max_len - bucket - 2))
        st.prompt_len = prompt_len
        st.cached_tokens = cached
        st.prefill_remaining = prompt_len - cached
        st.pending_prefill = True
        st.batch = batch
        st.bucket = bucket
        st.prefill_pos = cached
        self._join_order.append(slot)
        req.state = RequestState.EXECUTING
        req.exec_start = now

    @property
    def _chunked_device_prefill(self) -> bool:
        """Paged transformer prefill goes through the fused
        chunked-prefill kernel per chunk. The vlm family keeps the
        legacy single-shot path: its patch-embedding prefix-LM prefill
        has no chunked counterpart."""
        return self.ecfg.paged and self.cfg.family != "vlm"

    def _run_prefill(self, slot: int, now: float) -> None:
        """Legacy single-shot device prefill (slot-ring cache, and the
        vlm-paged path): the slot's last prompt chunk landed, execute
        the whole bucket at once and emit the first token."""
        st = self.slots[slot]
        self._rng, sub = jax.random.split(self._rng)
        if self.ecfg.paged:
            from ..models import transformer
            from .kv_cache import write_prefill_pages
            logits, k_lv, v_lv = transformer.prefill_kv(
                self.cfg, self.params, st.batch["tokens"],
                patches=st.batch.get("patches"))
            cached = self.ledger.cached_tokens(slot)
            pages = self.ledger.table(slot)[cached // self.ecfg.page_size:]
            self.pool = write_prefill_pages(
                self.pool, (k_lv[:, 0], v_lv[:, 0]), pages, st.bucket,
                start_token=cached)
            if self.prefix_tree is not None:
                self.ledger.donate(slot, now)
            tok = sample_logits(logits, sub, self.ecfg.temperature)
        else:
            tok, cache_1 = self._prefill_fn_for(st.bucket)(
                self.params, st.batch, sub)
            self._insert_cache(slot, cache_1)
        self._emit_first_token(slot, int(tok[0]), now)

    def _advance_prefill(self, slot: int, take: int, now: float) -> None:
        """Execute this iteration's device chunk for a paged slot
        through the fused kernel. The ``take`` prompt tokens the budget
        loop just consumed map 1:1 onto bucket positions
        ``[prefill_pos, prefill_pos + take)``; the final chunk extends
        through the bucket's padding tail (a static-shape artifact, not
        billable workload) so its last position yields the first-token
        logits. The prefill-completing iteration donates shareable
        pages and emits the first token."""
        st = self.slots[slot]
        final = st.prefill_remaining <= 0
        start, end = st.prefill_pos, st.prefill_pos + take
        if final:
            end = st.bucket
            if end <= start:
                # fully-cached prompt: re-run the last bucket position
                # (recomputing KV already resident in the cached pages)
                # purely for the first-token logits
                start = end - 1
        if end <= start:
            return                       # budget exhausted, mid-prompt
        chunk_len = end - start
        pt = jnp.asarray(self.ledger.table_array(
            [slot], self.alloc.pages_per_seq))
        logits, new_pool = self._chunk_fn_for(chunk_len)(
            self.params, {"k": self.pool.k, "v": self.pool.v},
            st.batch["tokens"][:, start:end], pt,
            jnp.asarray([start], jnp.int32))
        from .kv_cache import PagedPool
        self.pool = PagedPool(k=new_pool["k"], v=new_pool["v"],
                              page_size=self.ecfg.page_size)
        self.n_prefill_launches += 1
        self.prefill_chunk_log.append((slot, chunk_len))
        st.prefill_pos = end
        if final:
            self._rng, sub = jax.random.split(self._rng)
            if self.prefix_tree is not None:
                self.ledger.donate(slot, now)
            tok = sample_logits(logits, sub, self.ecfg.temperature)
            self._emit_first_token(slot, int(tok[0]), now)

    def _emit_first_token(self, slot: int, tok: int, now: float) -> None:
        """Prefill-completion bookkeeping shared by the single-shot and
        per-chunk paths: first token, honest TTFT anchor, and the P/D
        handoff hook (a hooked request leaves for a decode replica
        instead of joining this engine's decode set)."""
        st = self.slots[slot]
        st.generated = 1                       # prefill emitted one token
        st.last_token = tok
        st.pending_prefill = False
        st.batch = None
        st.req.prefill_end = now               # first token exists now
        if self.trace.enabled:
            self.trace.emit(now, tr.FIRST_TOKEN, req_id=st.req.req_id,
                            rid=self.trace_rid,
                            tenant=st.req.tenant.label,
                            ttft=now - st.req.arrival_time)
        if self.handoff_hook is not None \
                and self.handoff_hook(slot, st.req, now):
            self._release_slot(slot)

    # --- P/D disaggregation: KV extraction / injection --------------------
    def _release_slot(self, slot: int) -> None:
        """Free a slot without completing its request (the request
        lives on elsewhere — P/D handoff or failure reroute): no
        ``sched.complete``, so no drift feedback fires here."""
        st = self.slots[slot]
        if self.ecfg.paged:
            self.ledger.free(slot)
        self._join_order.remove(slot)
        st.req = None
        st.generated = 0
        st.target = 0
        st.prefill_remaining = 0
        st.cached_tokens = 0
        st.pending_prefill = False
        st.batch = None

    def extract_sequence(self, slot: int) -> Dict:
        """Snapshot a prefilled slot's state for a P/D handoff: the
        sequence's page *contents* gathered off the pool (the real KV
        page movement — prefix-tree pages are copied too, the receiver
        gets private pages) plus the decode-resume scalars."""
        assert self.ecfg.paged, "KV extraction requires the paged pool"
        st = self.slots[slot]
        pages = jnp.asarray(self.ledger.table(slot), jnp.int32)
        return {
            "k": np.asarray(self.pool.k[:, pages]),
            "v": np.asarray(self.pool.v[:, pages]),
            "seq_len": st.bucket,          # resident tokens (whole bucket)
            "bucket": st.bucket,
            "prompt_len": st.prompt_len,
            "last_token": st.last_token,
            "generated": st.generated,
            "target": st.target,
        }

    def accept_handoff(self, req: Request, payload: Dict) -> None:
        """Queue a prefilled request whose KV transfer just landed.
        Mirrors ``SimReplica.accept_handoff``: back of its tenant queue
        at the original enqueue timestamp, estimate untouched; the KV
        payload is injected into the paged pool when a slot dispatches
        it."""
        self._pending_injections[req.req_id] = payload
        self.sched.queues.enqueue(req, req.enqueue_time)

    def pop_pending_injection(self, req_id: int) -> Optional[Dict]:
        """Detach a queued request's undispatched KV payload (the
        cluster driver re-transfers it when the request is stolen off
        this engine's queue)."""
        return self._pending_injections.pop(req_id, None)

    def _admit_prefilled(self, req: Request, slot: int, payload: Dict,
                         now: float) -> None:
        """Open a slot for a request that already prefilled elsewhere:
        allocate pages, write the transferred KV into them, and enter
        decode directly (no prefill chunks, no first token — both
        happened on the source replica)."""
        assert self.ecfg.paged, "KV injection requires the paged pool"
        self.ledger.admit(slot, payload["seq_len"], (), now)
        pages = jnp.asarray(self.ledger.table(slot), jnp.int32)
        from .kv_cache import PagedPool
        self.pool = PagedPool(
            k=self.pool.k.at[:, pages].set(
                jnp.asarray(payload["k"]).astype(self.pool.k.dtype)),
            v=self.pool.v.at[:, pages].set(
                jnp.asarray(payload["v"]).astype(self.pool.v.dtype)),
            page_size=self.ecfg.page_size)
        st = self.slots[slot]
        st.req = req
        st.generated = payload["generated"]
        st.target = payload["target"]
        st.last_token = payload["last_token"]
        st.prompt_len = payload["prompt_len"]
        st.cached_tokens = 0
        st.prefill_remaining = 0
        st.pending_prefill = False
        st.batch = None
        st.bucket = payload["bucket"]
        st.prefill_pos = payload["seq_len"]
        self._join_order.append(slot)
        req.state = RequestState.EXECUTING
        if req.exec_start is None:
            req.exec_start = now

    def abort_all(self, now: float) -> List[Request]:
        """Failure path: drop every in-flight slot and pending KV
        injection; stranded requests go back to the caller (the cluster
        driver resets and reroutes them). Pool contents die with the
        replica, so a prefix tree is emptied too."""
        stranded = []
        for slot in list(self._join_order):
            stranded.append(self.slots[slot].req)
            self._release_slot(slot)
        self._pending_injections.clear()
        if self.prefix_tree is not None:
            self.prefix_tree.clear()
        return stranded

    def _retire(self, slot: int, now: float) -> None:
        st = self.slots[slot]
        req = st.req
        req.exec_end = now
        self.sched.complete(req, st.generated, now)
        if self.trace.enabled:
            self.trace.emit(now, tr.COMPLETE, req_id=req.req_id,
                            rid=self.trace_rid, tenant=req.tenant.label,
                            observed=st.generated, e2e=req.e2e_latency,
                            ttft=req.ttft,
                            inter_token=req.inter_token_latency)
        if self.ecfg.paged:
            self.ledger.free(slot)
        self._join_order.remove(slot)
        st.req = None
        st.generated = 0
        st.target = 0
        st.prefill_remaining = 0
        st.cached_tokens = 0
        st.pending_prefill = False

    # --- main loop ----------------------------------------------------------
    def step(self, now: float) -> int:
        """One engine iteration: admit into free slots, consume the
        per-step prefill chunk budget in join order (running the device
        prefill for slots whose last chunk landed), advance every
        decoding slot one token, retire finished ones. Returns number
        of completions this step. Per-iteration admission honours the
        scheduler's ``max_new_per_step`` knob — the same slot-granular
        contract the discrete-event step engine uses
        (``DriftScheduler.dispatch_step``)."""
        # admission (iteration boundary, interleaving with decode)
        joined = 0
        cap = self.sched.max_new_per_step
        pages_per_seq = (self.alloc.pages_per_seq if self.ecfg.paged
                         else 0)
        for slot in self.free_slots():
            if self.sched.queue_depth() == 0:
                break
            if cap is not None and joined >= cap:
                break
            if self.ecfg.paged and self.prefix_tree is not None \
                    and not self.ledger.can_admit(
                        pages_per_seq * self.ecfg.page_size):
                # conservative page guard: admission waits for
                # retirements/evictions to free room (only reachable
                # with a prefix cache — the plain pool is sized exactly)
                break
            req = self.sched.dispatch(now)
            if req is None:
                break
            payload = self._pending_injections.pop(req.req_id, None)
            if payload is not None:
                self._admit_prefilled(req, slot, payload, now)
            else:
                self._admit(req, slot, now)
            joined += 1

        # chunked prefill: apportion the per-step budget in join order;
        # a slot's prefill-completing iteration also emits its first
        # token (and, slot-ring legacy, joins this step's decode batch)
        budget = (math.inf if self.ecfg.chunk_prefill_tokens is None
                  else self.ecfg.chunk_prefill_tokens)
        for slot in list(self._join_order):
            st = self.slots[slot]
            if not st.pending_prefill:
                continue
            take = int(min(st.prefill_remaining, budget))
            st.prefill_remaining -= take
            budget -= take
            if take and self.trace.enabled:
                self.trace.emit(now, tr.PREFILL_CHUNK,
                                req_id=st.req.req_id, rid=self.trace_rid,
                                tenant=st.req.tenant.label, tokens=take)
            if self._chunked_device_prefill:
                # every chunk executes on device the iteration its
                # budget is consumed (fused chunked-prefill kernel)
                self._advance_prefill(slot, take, now)
            elif st.prefill_remaining <= 0:
                self._run_prefill(slot, now)
            if budget <= 0:
                break

        decoding = [i for i in self.active_slots()
                    if not self.slots[i].pending_prefill]
        if not decoding:
            if self.active_slots():
                # prefill-only iteration (budget exhausted mid-prompt)
                self.step_count += 1
                self.busy_steps += 1
            return 0

        tokens = np.zeros((self.ecfg.n_slots,), np.int32)
        for i in decoding:
            tokens[i] = self.slots[i].last_token
        self._rng, sub = jax.random.split(self._rng)
        if self.ecfg.paged:
            sids = [i if (self.slots[i].req is not None
                          and not self.slots[i].pending_prefill) else None
                    for i in range(self.ecfg.n_slots)]
            pt = self.ledger.table_array(sids, self.alloc.pages_per_seq)
            scratch = self.pool.n_pages - 1      # never allocated: inactive
            for i, sid in enumerate(sids):       # slots write there
                if sid is None:
                    pt[i, :] = scratch
            lens = self.ledger.lens_array(sids)
            # static page-grid trim for the batched kernel: next power
            # of two above the deepest live sequence (bounded set of
            # jit variants), clamped to the table width
            needed = max(1, -(-int(lens.max()) // self.ecfg.page_size))
            max_pages = 1
            while max_pages < needed:
                max_pages *= 2
            max_pages = min(max_pages, self.alloc.pages_per_seq)
            toks, new_pool = self._decode_paged(
                self.params, {"k": self.pool.k, "v": self.pool.v},
                jnp.asarray(tokens), jnp.asarray(pt),
                jnp.asarray(lens), sub, max_pages=max_pages)
            from .kv_cache import PagedPool
            self.pool = PagedPool(k=new_pool["k"], v=new_pool["v"],
                                  page_size=self.ecfg.page_size)
            for i in decoding:
                _, cows = self.ledger.extend(i, 1)
                for old, new in cows:
                    # copy-on-write boundary: the ledger handed this
                    # slot a private copy of a shared page — mirror it
                    # device-side before the next write lands there.
                    # Unreachable with full-page prefix keys (suffix
                    # pages are always private) but wired for the
                    # partial-page layouts cow_extend exists for.
                    self.pool = PagedPool(
                        k=self.pool.k.at[:, new].set(self.pool.k[:, old]),
                        v=self.pool.v.at[:, new].set(self.pool.v[:, old]),
                        page_size=self.ecfg.page_size)
        else:
            pos = np.asarray(self.cache["lens"])     # per-slot depth
            toks, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(pos, np.int32), sub)
        toks = np.asarray(toks)

        done = 0
        tron = self.trace.enabled
        for i in decoding:
            st = self.slots[i]
            st.generated += 1
            st.last_token = int(toks[i])
            if tron:
                self.trace.emit(now, tr.DECODE_STEP,
                                req_id=st.req.req_id, rid=self.trace_rid,
                                n=st.generated)
            if st.generated >= st.target:       # oracle EOS
                self._retire(i, now)
                done += 1
        self.step_count += 1
        self.busy_steps += 1
        if tron:
            self.trace.emit(now, tr.GAUGE, rid=self.trace_rid,
                            name="queue_depth",
                            value=self.sched.queue_depth())
            self.trace.emit(now, tr.GAUGE, rid=self.trace_rid,
                            name="active_slots",
                            value=len(self.active_slots()))
            if self.ecfg.paged:
                self.trace.emit(now, tr.GAUGE, rid=self.trace_rid,
                                name="kv_free_pages",
                                value=self.alloc.free_pages)
        return done

    def run_until_drained(self, *, max_steps: int = 100_000,
                          dt: float = 1.0) -> RunMetrics:
        """Process everything queued in the scheduler; ``dt`` is the
        simulated wall-clock per engine step (CPU steps are not
        representative of TPU step time)."""
        now = 0.0
        if self.trace.enabled:
            self.trace.begin_segment(
                f"engine:{self.sched.policy.name}")
        for _ in range(max_steps):
            if (self.sched.queue_depth() == 0
                    and not self.active_slots()):
                break
            self.step(now)
            now += dt
        return summarize_run(self.sched.policy.name,
                             self.sched.config.bias_enabled,
                             self.sched.completed,
                             busy_time=float(self.busy_steps) * dt)
