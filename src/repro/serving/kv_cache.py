"""Paged KV-cache pool + host-side allocator (vLLM's PagedAttention,
adapted to TPU) and the shared-prefix radix cache built on top of it
(SGLang's RadixAttention, at page granularity).

The GPU version's warp-level gather becomes page-granular DMA issued by
the Pallas paged-attention kernel (kernels/paged_attention.py) via a
scalar-prefetched page table. This module owns the other half of the
design: the global page pool (one JAX array per K/V, page-major), the
host-side allocator (free list, per-sequence page tables, alloc on
prefill / extend on decode / free on completion), and the
:class:`PrefixTree` — a radix tree of *full* KV pages keyed by prefix
content, so sequences sharing a prompt prefix (tenant system prompts,
RAG templates) reference the same physical pages instead of
re-prefilling them.

Fragmentation-free by construction: every allocation is page-granular,
exactly the property the vLLM paper exploits to push batch sizes up.

Everything except :class:`PagedPool` / :func:`write_prefill_pages` is
pure host-side bookkeeping and importable without JAX — the
discrete-event simulator reuses the identical allocator + prefix-tree
state machine the engine runs, without pulling in the device stack.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

try:  # device half only; the allocator + prefix tree are JAX-free
    import jax
    import jax.numpy as jnp
except Exception:  # pragma: no cover - exercised on JAX-less installs
    jax = None
    jnp = None

if jax is not None:
    from ..models.config import ModelConfig


@dataclass
class PagedPool:
    """Device-side page pool for one model: [L, n_pages, page, Hk, hd]."""

    k: "jax.Array"
    v: "jax.Array"
    page_size: int

    @classmethod
    def create(cls, cfg: "ModelConfig", n_pages: int, page_size: int = 128,
               dtype=None) -> "PagedPool":
        if jnp is None:  # pragma: no cover
            raise ImportError("PagedPool.create requires JAX")
        dtype = dtype or jnp.dtype(cfg.dtype)
        shape = (cfg.n_layers, n_pages, page_size, cfg.n_kv_heads, cfg.d_head)
        return cls(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   page_size=page_size)

    @property
    def n_pages(self) -> int:
        return self.k.shape[1]


class OutOfPagesError(RuntimeError):
    pass


class PagedAllocator:
    """Host-side page accounting. Deterministic (free list is a stack)."""

    def __init__(self, n_pages: int, page_size: int,
                 pages_per_seq: int) -> None:
        self.page_size = page_size
        self.pages_per_seq = pages_per_seq
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        # seq_id -> (page ids, current token length)
        self._tables: Dict[int, List[int]] = {}
        self._lens: Dict[int, int] = {}
        self.n_pages = n_pages

    # ------------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pages_needed(self, n_tokens: int) -> int:
        return max(1, -(-n_tokens // self.page_size))

    def can_admit(self, prompt_tokens: int, max_new: int) -> bool:
        return self.pages_needed(prompt_tokens + max_new) <= self.free_pages

    def alloc(self, seq_id: int, n_tokens: int) -> List[int]:
        """Allocate pages for a prefill of ``n_tokens``."""
        if seq_id in self._tables:
            raise ValueError(f"seq {seq_id} already allocated")
        need = self.pages_needed(n_tokens)
        if need > len(self._free):
            raise OutOfPagesError(
                f"need {need} pages, only {len(self._free)} free")
        pages = [self._free.pop() for _ in range(need)]
        self._tables[seq_id] = pages
        self._lens[seq_id] = n_tokens
        return pages

    def extend(self, seq_id: int, n_new: int = 1) -> List[int]:
        """Grow a sequence by ``n_new`` tokens; allocate pages on
        boundary crossings. Returns any newly-allocated pages."""
        pages = self._tables[seq_id]
        old_len = self._lens[seq_id]
        new_len = old_len + n_new
        need = self.pages_needed(new_len) - len(pages)
        fresh: List[int] = []
        if need > 0:
            if need > len(self._free):
                raise OutOfPagesError(
                    f"seq {seq_id}: need {need} pages, "
                    f"{len(self._free)} free")
            fresh = [self._free.pop() for _ in range(need)]
            pages.extend(fresh)
        self._lens[seq_id] = new_len
        return fresh

    def free(self, seq_id: int) -> None:
        for p in self._tables.pop(seq_id):
            self._free.append(p)
        del self._lens[seq_id]

    # --- raw page ops (prefix-tree ownership) --------------------------
    # The prefix tree owns pages directly rather than through a seq
    # table: its pages belong to *content* (a shared prefix), not to any
    # one sequence's lifetime.
    def alloc_raw(self, n: int) -> List[int]:
        """Take ``n`` pages off the free list with no seq accounting."""
        if n > len(self._free):
            raise OutOfPagesError(
                f"need {n} raw pages, only {len(self._free)} free")
        return [self._free.pop() for _ in range(n)]

    def free_raw(self, pages: Sequence[int]) -> None:
        """Return raw pages (from :meth:`alloc_raw`) to the free list."""
        self._free.extend(pages)

    def seq_len(self, seq_id: int) -> int:
        return self._lens[seq_id]

    def table(self, seq_id: int) -> List[int]:
        return self._tables[seq_id]

    # ------------------------------------------------------------------
    def table_array(self, seq_ids: List[Optional[int]]) -> np.ndarray:
        """[B, pages_per_seq] int32 physical page ids (0-padded) for the
        current batch — the scalar-prefetch operand of the kernel."""
        out = np.zeros((len(seq_ids), self.pages_per_seq), np.int32)
        for i, sid in enumerate(seq_ids):
            if sid is None:
                continue
            pages = self._tables[sid]
            out[i, :len(pages)] = pages
        return out

    def lens_array(self, seq_ids: List[Optional[int]]) -> np.ndarray:
        return np.array([0 if sid is None else self._lens[sid]
                         for sid in seq_ids], np.int32)

    # --- checkpoint/restore -------------------------------------------
    def state_dict(self) -> dict:
        return {"free": list(self._free),
                "tables": {str(k): v for k, v in self._tables.items()},
                "lens": {str(k): v for k, v in self._lens.items()}}

    def load_state_dict(self, state: dict) -> None:
        self._free = list(state["free"])
        self._tables = {int(k): list(v) for k, v in state["tables"].items()}
        self._lens = {int(k): int(v) for k, v in state["lens"].items()}


# ----------------------------------------------------------------------
# Shared-prefix radix cache (SGLang RadixAttention, page-granular)
# ----------------------------------------------------------------------

def prefix_page_key(prefix_group: Optional[Hashable],
                    shared_prefix_tokens: int,
                    page_size: int) -> Tuple[Hashable, ...]:
    """Page-granular cache key for a request's shared prompt prefix:
    one hashable element per *full* page of the prefix. Only full pages
    are shareable — a partially-filled page cannot be referenced by two
    sequences that diverge inside it (that is the copy-on-write
    boundary), so the partial remainder is always prefilled privately.
    Returns () when the request carries no shareable prefix."""
    if prefix_group is None or shared_prefix_tokens < page_size:
        return ()
    return tuple((prefix_group, i)
                 for i in range(shared_prefix_tokens // page_size))


def pages_needed_array(n_tokens: np.ndarray, page_size: int) -> np.ndarray:
    """Vectorized :meth:`PagedAllocator.pages_needed`: per-sequence
    page counts (ceil division, min 1 page per live sequence) over an
    int array of token counts. Used by the flat-array simulator core's
    telemetry to reproduce the object engine's per-slot page rounding
    without a per-slot Python loop."""
    tokens = np.asarray(n_tokens)
    return np.maximum(1, -(-tokens // page_size))


class PrefixNode:
    """One radix-tree node: a run of consecutive prefix pages.

    ``key`` is the compressed key segment (one element per page) and
    ``pages`` the physical page ids backing it (``len(pages) ==
    len(key)``). ``refcount`` counts live sequences currently reading
    these pages (locked via :meth:`PrefixTree.lock`); only unreferenced
    *leaves* are evictable. ``last_access`` drives LRU eviction."""

    __slots__ = ("key", "pages", "children", "parent", "refcount",
                 "last_access")

    def __init__(self, key: Tuple[Hashable, ...], pages: List[int],
                 parent: Optional["PrefixNode"],
                 last_access: float = 0.0) -> None:
        self.key = key
        self.pages = pages
        self.children: Dict[Hashable, "PrefixNode"] = {}
        self.parent = parent
        self.refcount = 0
        self.last_access = last_access

    def is_leaf(self) -> bool:
        return not self.children


class PrefixTree:
    """Radix tree of shared-prefix KV pages over a :class:`PagedAllocator`.

    The RadixAttention design at page granularity: tree paths spell out
    prefix *content* (one key element per full page), nodes own the
    physical pages backing their segment, and a sequence whose prompt
    starts with a cached prefix skips prefilling the matched pages
    entirely. Contracts:

    * **Refcounts pin pages.** :meth:`lock` increments every node from
      the matched node to the root; :meth:`release` undoes it. A locked
      node (or any ancestor of one — ancestors always carry >= their
      descendants' locks) is never evicted, so a running sequence's
      cached prefix cannot vanish under it.
    * **LRU eviction under page pressure.** :meth:`insert` allocates
      new pages via the shared allocator's raw free list; when the list
      runs dry it evicts unreferenced leaves oldest-``last_access``
      first (iteratively, so a fully-unreferenced chain unwinds). If
      pressure persists the insert is truncated — caching is
      best-effort, correctness never depends on a hit.
    * **Copy-on-write past a shared page.** A sequence extending
      *through* a cached page (decode continuing past the prefix, or a
      prompt diverging inside a page) must not mutate pages other
      sequences reference: :meth:`cow_extend` hands it a private copy
      of the boundary page instead. Pure ownership transfer here — the
      engine does the actual device-side page copy.
    * **Checkpointable.** ``state_dict`` / ``load_state_dict`` round-
      trip the tree structure and page ownership; refcounts are
      deliberately *not* serialized (locks belong to live sequences,
      which do not survive a restore).

    Determinism: no randomness; LRU ties break on insertion order.
    """

    def __init__(self, allocator: PagedAllocator) -> None:
        self.allocator = allocator
        self.page_size = allocator.page_size
        self.root = PrefixNode((), [], None)
        self.n_evicted_pages = 0      # cumulative pages LRU-evicted
        self.n_cow_pages = 0          # cumulative copy-on-write copies

    # --- introspection -------------------------------------------------
    def total_pages(self) -> int:
        """Pages currently owned by the tree (resident cached prefix)."""
        return sum(len(n.pages) for n in self._nodes())

    def path_pages(self, node: PrefixNode) -> List[int]:
        """Position-ordered physical pages spelling the path from the
        root to (and including) ``node`` — the page-table prefix a
        sequence reading ``node``'s cached run references."""
        segs: List[List[int]] = []
        while node is not None and node is not self.root:
            segs.append(node.pages)
            node = node.parent
        out: List[int] = []
        for seg in reversed(segs):
            out.extend(seg)
        return out

    def cached_tokens(self, key: Sequence[Hashable]) -> int:
        """Resident-prefix overlap for ``key`` in tokens, without
        touching LRU state (pure probe — what the cluster router calls
        per routing decision)."""
        _, n_pages = self._walk(key)
        return n_pages * self.page_size

    def _nodes(self) -> List[PrefixNode]:
        out: List[PrefixNode] = []
        stack = [self.root]
        while stack:
            node = stack.pop()
            if node is not self.root:
                out.append(node)
            stack.extend(node.children.values())
        return out

    # --- match / lock lifecycle ---------------------------------------
    def _walk(self, key: Sequence[Hashable]
              ) -> Tuple[PrefixNode, int]:
        """Longest-prefix walk. Returns (deepest node whose pages are
        used, total pages matched). A partial match inside a node's
        segment counts its matched pages and stops there."""
        node = self.root
        i = 0
        n = len(key)
        while i < n:
            child = node.children.get(key[i])
            if child is None:
                break
            seg = child.key
            j = 1
            while j < len(seg) and i + j < n and seg[j] == key[i + j]:
                j += 1
            i += j
            node = child
            if j < len(seg):      # diverged / exhausted mid-segment
                return node, i
        return node, i

    def match(self, key: Sequence[Hashable],
              now: Optional[float] = None) -> Tuple[PrefixNode, int]:
        """Longest cached prefix of ``key``: (node, n_pages_matched).
        ``now`` (when given) refreshes LRU stamps along the path —
        probes that must not perturb eviction order pass None (or use
        :meth:`cached_tokens`)."""
        node, n_pages = self._walk(key)
        if now is not None:
            self._touch(node, now)
        return node, n_pages

    def _touch(self, node: PrefixNode, now: float) -> None:
        while node is not None and node is not self.root:
            node.last_access = now
            node = node.parent

    def lock(self, node: PrefixNode) -> None:
        """Pin ``node``'s pages (and its ancestors') against eviction
        for the lifetime of one reading sequence."""
        while node is not None and node.parent is not None:
            node.refcount += 1
            node = node.parent

    def release(self, node: PrefixNode) -> None:
        """Undo one :meth:`lock` (sequence finished or aborted).

        Termination is parent-based, not identity-based, so releasing
        a lock into a tree that was since :meth:`clear`-ed (the holder
        survived a failure wipe) walks the orphaned chain and stops at
        its old root instead of raising — a harmless no-op on dead
        state."""
        while node is not None and node.parent is not None:
            if node.refcount <= 0:
                raise ValueError("release without matching lock")
            node.refcount -= 1
            node = node.parent

    # --- insert / evict ------------------------------------------------
    def insert(self, key: Sequence[Hashable], now: float,
               pages: Optional[List[int]] = None
               ) -> Tuple[PrefixNode, int]:
        """Make ``key`` resident: after a sequence prefills a shareable
        prefix, its full pages enter the tree so future sequences hit.

        ``pages`` (when given) donates the caller's freshly-written
        physical pages for the *uncached tail* of the key — the engine
        path, where page ids must match what was written on device.
        Without it, pages are drawn from the allocator's free list (the
        simulator path, where page identity is pure accounting),
        evicting LRU leaves on pressure and truncating the insert if
        pressure persists.

        Returns (deepest resident node for this key, pages added).
        """
        node, n_matched = self._walk(key)
        self._touch(node, now)
        remaining = list(key[n_matched:])
        if not remaining:
            return node, 0
        if node is not self.root and n_matched < self._depth_pages(node):
            # partial match inside `node`'s segment: the new key
            # diverges mid-node — split so the shared run is its own
            # node and both continuations hang off it
            node = self._split(node, n_matched - self._depth_pages(node.parent))
        if pages is None:
            # pin the attach point while claiming: under pressure the
            # LRU sweep must not evict the (possibly unreferenced)
            # matched path we are about to hang the new child off —
            # that would orphan the child and leak its pages
            self.lock(node)
            try:
                take = self._claim_pages(len(remaining))
            finally:
                self.release(node)
        else:
            if len(pages) != len(remaining):
                raise ValueError(
                    f"donated {len(pages)} pages for {len(remaining)} "
                    "uncached key pages")
            take = list(pages)
        if not take:
            return node, 0
        child = PrefixNode(tuple(remaining[:len(take)]), take, node,
                           last_access=now)
        node.children[child.key[0]] = child
        return child, len(take)

    def _depth_pages(self, node: Optional[PrefixNode]) -> int:
        d = 0
        while node is not None and node is not self.root:
            d += len(node.key)
            node = node.parent
        return d

    def _split(self, node: PrefixNode, at: int) -> PrefixNode:
        """Split ``node``'s segment after ``at`` pages; returns the new
        upper node (which keeps the shared run)."""
        upper = PrefixNode(node.key[:at], node.pages[:at], node.parent,
                           last_access=node.last_access)
        upper.refcount = node.refcount
        node.parent.children[upper.key[0]] = upper
        node.key = node.key[at:]
        node.pages = node.pages[at:]
        node.parent = upper
        upper.children[node.key[0]] = node
        return upper

    def _claim_pages(self, n: int) -> List[int]:
        """Up to ``n`` pages from the free list, evicting LRU
        unreferenced leaves under pressure; may return fewer."""
        short = n - self.allocator.free_pages
        if short > 0:
            self.evict(short)
        take = min(n, self.allocator.free_pages)
        return self.allocator.alloc_raw(take) if take > 0 else []

    def evict(self, n_pages: int) -> int:
        """Free up to ``n_pages`` cached pages, unreferenced leaves
        first, oldest ``last_access`` first. Returns pages freed."""
        freed = 0
        while freed < n_pages:
            leaves = [nd for nd in self._nodes()
                      if nd.is_leaf() and nd.refcount == 0]
            if not leaves:
                break
            victim = min(leaves, key=lambda nd: nd.last_access)
            self.allocator.free_raw(victim.pages)
            freed += len(victim.pages)
            self.n_evicted_pages += len(victim.pages)
            del victim.parent.children[victim.key[0]]
        return freed

    def clear(self) -> int:
        """Drop the whole cache (replica failure: the KV pool died with
        the device). All pages return to the allocator regardless of
        refcounts — the sequences holding locks died too. Returns pages
        freed."""
        freed = 0
        for node in self._nodes():
            self.allocator.free_raw(node.pages)
            freed += len(node.pages)
        self.root = PrefixNode((), [], None)
        return freed

    # --- copy-on-write boundary ---------------------------------------
    def cow_extend(self, node: PrefixNode) -> int:
        """A sequence must write into (extend past) ``node``'s last
        page while others reference it: allocate a private copy and
        hand ownership to the caller (who frees it with
        ``allocator.free_raw`` when the sequence retires). Raises
        :class:`OutOfPagesError` only when eviction cannot make room."""
        pages = self._claim_pages(1)
        if not pages:
            raise OutOfPagesError("no page available for copy-on-write")
        self.n_cow_pages += 1
        return pages[0]

    # --- checkpoint/restore -------------------------------------------
    def state_dict(self) -> dict:
        """Structure + page ownership + LRU stamps. Refcounts are not
        saved: locks belong to live sequences, which don't survive a
        restore (the engine re-locks on resume)."""
        def pack(node: PrefixNode) -> dict:
            return {"key": list(node.key), "pages": list(node.pages),
                    "last_access": node.last_access,
                    "children": [pack(c) for c in
                                 sorted(node.children.values(),
                                        key=lambda c: repr(c.key[0]))]}
        return {"n_evicted_pages": self.n_evicted_pages,
                "n_cow_pages": self.n_cow_pages,
                "root": pack(self.root)}

    def load_state_dict(self, state: dict) -> None:
        def unpack(rec: dict, parent: Optional[PrefixNode]) -> PrefixNode:
            node = PrefixNode(tuple(rec["key"]), list(rec["pages"]),
                              parent, last_access=rec["last_access"])
            for crec in rec["children"]:
                child = unpack(crec, node)
                node.children[child.key[0]] = child
            return node
        self.n_evicted_pages = int(state.get("n_evicted_pages", 0))
        self.n_cow_pages = int(state.get("n_cow_pages", 0))
        self.root = unpack(state["root"], None)


# ----------------------------------------------------------------------
# Per-sequence page tables with shared-prefix reuse (engine host side)
# ----------------------------------------------------------------------

@dataclass
class _SeqPages:
    """One live sequence's page state inside a :class:`PagedSeqLedger`.

    ``pages`` is the position-ordered physical page table (what the
    kernel's scalar-prefetch operand is built from); ``owned`` the
    subset this sequence must return to the free list when it retires —
    the rest belong to the :class:`PrefixTree` and are pinned via
    ``node``'s refcount instead."""

    pages: List[int]
    owned: List[int]
    seq_len: int
    key: Tuple[Hashable, ...] = ()
    node: Optional[PrefixNode] = None
    cached_pages: int = 0
    donated: bool = False


class PagedSeqLedger:
    """Host-side per-sequence page bookkeeping for the engine's paged
    path with shared-prefix reuse. Pure accounting, importable without
    JAX — the engine does the device-side writes; the differential
    parity suite and the hypothesis page-conservation property drive
    this class directly.

    Composition contract (mirrors the simulator's prefix integration):

    * :meth:`admit` walks the tree for the sequence's prefix key, locks
      the matched path (refcount pin), and allocates private pages only
      for the *uncached* remainder — the page table interleaves
      tree-owned prefix pages with privately-owned suffix pages in
      position order.
    * :meth:`donate` (at prefill completion) hands the freshly-written
      full prefix pages to the tree via ``PrefixTree.insert(pages=...)``
      — page *identity* must survive donation because the KV was
      written on device — then re-pins the deepened path and enforces
      the residency budget by LRU-evicting unreferenced leaves.
    * :meth:`extend` grows the sequence one decode token at a time,
      allocating on page-boundary crossings (evicting cache leaves
      under pressure). If a write would land inside a page the
      sequence does not own, the boundary page is copy-on-write
      replaced via ``PrefixTree.cow_extend`` — unreachable with
      full-page prefix keys (the suffix always starts page-aligned,
      so decode never extends *into* a shared page) but kept as the
      guard the tree API is designed around.
    * :meth:`free` returns owned pages and releases the tree pin.

    Conservation invariant (hypothesis-tested):
    ``allocator.free_pages + owned_pages() + tree.total_pages()``
    equals the pool size at every point.
    """

    def __init__(self, allocator: PagedAllocator,
                 tree: Optional[PrefixTree] = None,
                 cache_pages_budget: Optional[int] = None) -> None:
        self.allocator = allocator
        self.tree = tree
        self.cache_pages_budget = cache_pages_budget
        self.page_size = allocator.page_size
        self._seqs: Dict[int, _SeqPages] = {}
        self.n_cow_copies = 0        # device-copy events the engine owes

    # --- introspection -------------------------------------------------
    def seq_len(self, seq_id: int) -> int:
        return self._seqs[seq_id].seq_len

    def table(self, seq_id: int) -> List[int]:
        return self._seqs[seq_id].pages

    def cached_tokens(self, seq_id: int) -> int:
        return self._seqs[seq_id].cached_pages * self.page_size

    def owned_pages(self) -> int:
        """Pages privately owned by live sequences (conservation leg)."""
        return sum(len(rec.owned) for rec in self._seqs.values())

    def probe(self, key: Sequence[Hashable]) -> int:
        """Resident-prefix overlap for ``key`` in tokens; pure read."""
        if self.tree is None or not key:
            return 0
        return self.tree.cached_tokens(key)

    # --- allocation helpers --------------------------------------------
    def _claim(self, n: int) -> List[int]:
        """``n`` pages off the free list, evicting unreferenced cache
        leaves under pressure; raises :class:`OutOfPagesError` when
        eviction cannot make room (never returns fewer)."""
        short = n - self.allocator.free_pages
        if short > 0 and self.tree is not None:
            self.tree.evict(short)
        return self.allocator.alloc_raw(n)

    def can_admit(self, n_tokens: int,
                  key: Sequence[Hashable] = ()) -> bool:
        """Whether a prefill of ``n_tokens`` can be admitted right now:
        uncached pages needed vs free + evictable cache pages."""
        cached = 0
        if self.tree is not None and key:
            cached = min(self.tree.cached_tokens(key), n_tokens)
            cached -= cached % self.page_size
        need = -(-(n_tokens - cached) // self.page_size)
        avail = self.allocator.free_pages
        if self.tree is not None:
            avail += sum(len(nd.pages) for nd in self.tree._nodes()
                         if nd.refcount == 0)
        return need <= avail

    # --- lifecycle -----------------------------------------------------
    def admit(self, seq_id: int, n_tokens: int,
              key: Sequence[Hashable] = (), now: float = 0.0) -> int:
        """Open a sequence of ``n_tokens`` prompt tokens. Returns the
        tokens served from the prefix cache (page-granular; the caller
        starts its chunked prefill at that boundary)."""
        if seq_id in self._seqs:
            raise ValueError(f"seq {seq_id} already admitted")
        # only full pages the prompt actually covers are shareable
        key = tuple(key)[:n_tokens // self.page_size]
        node: Optional[PrefixNode] = None
        path: List[int] = []
        cached_pages = 0
        if self.tree is not None and key:
            cand, matched = self.tree.match(key, now)
            cached_pages = min(matched, n_tokens // self.page_size)
            if cached_pages > 0:
                node = cand
                self.tree.lock(node)
                path = self.tree.path_pages(node)[:cached_pages]
        cached = cached_pages * self.page_size
        need = -(-(n_tokens - cached) // self.page_size)
        try:
            fresh = self._claim(need) if need > 0 else []
        except OutOfPagesError:
            if node is not None:
                self.tree.release(node)
            raise
        self._seqs[seq_id] = _SeqPages(
            pages=path + fresh, owned=fresh, seq_len=n_tokens,
            key=tuple(key), node=node, cached_pages=cached_pages)
        return cached

    def donate(self, seq_id: int, now: float) -> int:
        """Prefill finished: make the sequence's shareable full pages
        resident. Pages the tree does not already cover transfer
        ownership (they stay in this sequence's table — the KV is
        already written in them); the pin moves to the deepest resident
        node so the whole referenced path survives until :meth:`free`.
        Returns pages donated."""
        rec = self._seqs[seq_id]
        if self.tree is None or not rec.key or rec.donated:
            return 0
        rec.donated = True
        _, matched_now = self.tree.match(rec.key, now)
        # a concurrent donor may have made more of the key resident
        # since admit; our lock guarantees it cannot have become less
        donated = list(rec.pages[matched_now:len(rec.key)])
        new_node, added = self.tree.insert(rec.key, now, pages=donated)
        if added:
            owned = set(donated)
            rec.owned = [p for p in rec.owned if p not in owned]
        if rec.node is not None:
            self.tree.release(rec.node)
        self.tree.lock(new_node)
        rec.node = new_node
        if self.cache_pages_budget is not None:
            over = self.tree.total_pages() - self.cache_pages_budget
            if over > 0:
                self.tree.evict(over)
        return added

    def extend(self, seq_id: int, n_new: int = 1
               ) -> Tuple[List[int], List[Tuple[int, int]]]:
        """Grow a sequence by ``n_new`` decode tokens. Returns
        (freshly-allocated pages, copy-on-write (old, new) page pairs
        the caller must copy device-side)."""
        rec = self._seqs[seq_id]
        fresh: List[int] = []
        cows: List[Tuple[int, int]] = []
        P = self.page_size
        for _ in range(n_new):
            idx = rec.seq_len // P
            if idx < len(rec.pages):
                page = rec.pages[idx]
                if page not in rec.owned and self.tree is not None:
                    # writing into a shared page: private copy first
                    new_page = self.tree.cow_extend(rec.node)
                    rec.pages[idx] = new_page
                    rec.owned.append(new_page)
                    cows.append((page, new_page))
                    self.n_cow_copies += 1
            else:
                page = self._claim(1)[0]
                rec.pages.append(page)
                rec.owned.append(page)
                fresh.append(page)
            rec.seq_len += 1
        return fresh, cows

    def free(self, seq_id: int) -> None:
        """Retire a sequence: owned pages return to the free list, the
        cached-path pin is released (a release into a tree that was
        since cleared is a harmless no-op on dead state)."""
        rec = self._seqs.pop(seq_id)
        self.allocator.free_raw(rec.owned)
        if rec.node is not None and self.tree is not None:
            self.tree.release(rec.node)

    # --- kernel operands ------------------------------------------------
    def table_array(self, seq_ids: List[Optional[int]],
                    width: int) -> np.ndarray:
        """[B, width] int32 physical page ids (0-padded) — the paged
        kernel's scalar-prefetch operand, shared pages included."""
        out = np.zeros((len(seq_ids), width), np.int32)
        for i, sid in enumerate(seq_ids):
            if sid is None:
                continue
            pages = self._seqs[sid].pages
            out[i, :len(pages)] = pages
        return out

    def lens_array(self, seq_ids: List[Optional[int]]) -> np.ndarray:
        return np.array([0 if sid is None else self._seqs[sid].seq_len
                         for sid in seq_ids], np.int32)


def write_prefill_pages(pool: PagedPool, layer_kv: Tuple["jax.Array", "jax.Array"],
                        pages: List[int], n_tokens: int, *,
                        start_token: int = 0) -> PagedPool:
    """Scatter a prefilled [L, S, Hk, hd] K/V into the pool's pages.

    ``start_token`` skips the leading cache-resident positions: with a
    shared-prefix hit the donor already wrote pages for tokens
    ``[0, start_token)``, so ``pages`` covers positions from
    ``start_token`` (page-aligned) onward only."""
    if start_token % pool.page_size:
        raise ValueError(
            f"start_token {start_token} must be page-aligned "
            f"({pool.page_size})")
    k_new, v_new = layer_kv
    P = pool.page_size
    k = pool.k
    v = pool.v
    for i, page in enumerate(pages):
        lo = start_token + i * P
        hi = min(lo + P, n_tokens)
        if lo >= n_tokens:
            break
        chunk_k = k_new[:, lo:hi]
        chunk_v = v_new[:, lo:hi]
        if hi - lo < P:
            pad = P - (hi - lo)
            chunk_k = jnp.pad(chunk_k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            chunk_v = jnp.pad(chunk_v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = k.at[:, page].set(chunk_k.astype(k.dtype))
        v = v.at[:, page].set(chunk_v.astype(v.dtype))
    return PagedPool(k=k, v=v, page_size=P)
