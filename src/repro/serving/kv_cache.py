"""Paged KV-cache pool + host-side allocator (vLLM's PagedAttention,
adapted to TPU).

The GPU version's warp-level gather becomes page-granular DMA issued by
the Pallas paged-attention kernel (kernels/paged_attention.py) via a
scalar-prefetched page table. This module owns the other half of the
design: the global page pool (one JAX array per K/V, page-major) and
the host-side allocator (free list, per-sequence page tables, alloc on
prefill / extend on decode / free on completion).

Fragmentation-free by construction: every allocation is page-granular,
exactly the property the vLLM paper exploits to push batch sizes up.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig


@dataclass
class PagedPool:
    """Device-side page pool for one model: [L, n_pages, page, Hk, hd]."""

    k: jax.Array
    v: jax.Array
    page_size: int

    @classmethod
    def create(cls, cfg: ModelConfig, n_pages: int, page_size: int = 128,
               dtype=None) -> "PagedPool":
        dtype = dtype or jnp.dtype(cfg.dtype)
        shape = (cfg.n_layers, n_pages, page_size, cfg.n_kv_heads, cfg.d_head)
        return cls(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   page_size=page_size)

    @property
    def n_pages(self) -> int:
        return self.k.shape[1]


class OutOfPagesError(RuntimeError):
    pass


class PagedAllocator:
    """Host-side page accounting. Deterministic (free list is a stack)."""

    def __init__(self, n_pages: int, page_size: int,
                 pages_per_seq: int) -> None:
        self.page_size = page_size
        self.pages_per_seq = pages_per_seq
        self._free: List[int] = list(range(n_pages - 1, -1, -1))
        # seq_id -> (page ids, current token length)
        self._tables: Dict[int, List[int]] = {}
        self._lens: Dict[int, int] = {}
        self.n_pages = n_pages

    # ------------------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    def pages_needed(self, n_tokens: int) -> int:
        return max(1, -(-n_tokens // self.page_size))

    def can_admit(self, prompt_tokens: int, max_new: int) -> bool:
        return self.pages_needed(prompt_tokens + max_new) <= self.free_pages

    def alloc(self, seq_id: int, n_tokens: int) -> List[int]:
        """Allocate pages for a prefill of ``n_tokens``."""
        if seq_id in self._tables:
            raise ValueError(f"seq {seq_id} already allocated")
        need = self.pages_needed(n_tokens)
        if need > len(self._free):
            raise OutOfPagesError(
                f"need {need} pages, only {len(self._free)} free")
        pages = [self._free.pop() for _ in range(need)]
        self._tables[seq_id] = pages
        self._lens[seq_id] = n_tokens
        return pages

    def extend(self, seq_id: int, n_new: int = 1) -> List[int]:
        """Grow a sequence by ``n_new`` tokens; allocate pages on
        boundary crossings. Returns any newly-allocated pages."""
        pages = self._tables[seq_id]
        old_len = self._lens[seq_id]
        new_len = old_len + n_new
        need = self.pages_needed(new_len) - len(pages)
        fresh: List[int] = []
        if need > 0:
            if need > len(self._free):
                raise OutOfPagesError(
                    f"seq {seq_id}: need {need} pages, "
                    f"{len(self._free)} free")
            fresh = [self._free.pop() for _ in range(need)]
            pages.extend(fresh)
        self._lens[seq_id] = new_len
        return fresh

    def free(self, seq_id: int) -> None:
        for p in self._tables.pop(seq_id):
            self._free.append(p)
        del self._lens[seq_id]

    def seq_len(self, seq_id: int) -> int:
        return self._lens[seq_id]

    def table(self, seq_id: int) -> List[int]:
        return self._tables[seq_id]

    # ------------------------------------------------------------------
    def table_array(self, seq_ids: List[Optional[int]]) -> np.ndarray:
        """[B, pages_per_seq] int32 physical page ids (0-padded) for the
        current batch — the scalar-prefetch operand of the kernel."""
        out = np.zeros((len(seq_ids), self.pages_per_seq), np.int32)
        for i, sid in enumerate(seq_ids):
            if sid is None:
                continue
            pages = self._tables[sid]
            out[i, :len(pages)] = pages
        return out

    def lens_array(self, seq_ids: List[Optional[int]]) -> np.ndarray:
        return np.array([0 if sid is None else self._lens[sid]
                         for sid in seq_ids], np.int32)

    # --- checkpoint/restore -------------------------------------------
    def state_dict(self) -> dict:
        return {"free": list(self._free),
                "tables": {str(k): v for k, v in self._tables.items()},
                "lens": {str(k): v for k, v in self._lens.items()}}

    def load_state_dict(self, state: dict) -> None:
        self._free = list(state["free"])
        self._tables = {int(k): list(v) for k, v in state["tables"].items()}
        self._lens = {int(k): int(v) for k, v in state["lens"].items()}


def write_prefill_pages(pool: PagedPool, layer_kv: Tuple[jax.Array, jax.Array],
                        pages: List[int], n_tokens: int) -> PagedPool:
    """Scatter a prefilled [L, S, Hk, hd] K/V into the pool's pages."""
    k_new, v_new = layer_kv
    P = pool.page_size
    n_full = n_tokens // P
    k = pool.k
    v = pool.v
    for i, page in enumerate(pages):
        lo = i * P
        hi = min(lo + P, n_tokens)
        if lo >= n_tokens:
            break
        chunk_k = k_new[:, lo:hi]
        chunk_v = v_new[:, lo:hi]
        if hi - lo < P:
            pad = P - (hi - lo)
            chunk_k = jnp.pad(chunk_k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            chunk_v = jnp.pad(chunk_v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = k.at[:, page].set(chunk_k.astype(k.dtype))
        v = v.at[:, page].set(chunk_v.astype(v.dtype))
    return PagedPool(k=k, v=v, page_size=P)
