"""Vectorized array-based simulator core (million-request sweeps).

Two fast executors, both locked to the object engine
(:class:`repro.serving.simulator.WorkerSimulator`) by the differential
parity suite (``tests/test_vector_parity.py``):

* :class:`VectorWorkerSimulator` — the **standalone** flat-array engine
  behind ``SimConfig.backend="vector"``. Every per-request field (slot
  tables, token ledgers, arrival/prefill/decode state, tenant/class
  ids, lifecycle stamps) lives in a flat numpy column of
  :class:`VectorState`; admission, queueing, dispatch, chunked-prefill
  budget sharing, continuous joins, retirement, paged-KV page
  accounting and prefix-cache discounts are array/index operations
  instead of per-request Python objects. Consecutive pure-decode
  iterations of a batch are additionally *epoch-batched*: one heap
  event advances ``k`` iterations at once whenever no other event can
  observe or perturb the batch in between (see ``_schedule_step``).
  For ``N <= a few hundred`` with a matched seed it reproduces the
  object engine's completion order, TTFT/e2e stamps, token ledgers,
  prefix hit/miss counters, depth history and ``RunMetrics``
  **bit-for-bit** (the ``aging`` policy is order-equivalent in real
  arithmetic but not bit-locked — its selection key is algebraically
  shifted; see ``_VectorQueues``).

* :class:`StepVectorizedWorkerSimulator` — the **composed** (cluster)
  fast path: a drop-in :class:`WorkerSimulator` subclass that keeps
  the real :class:`DriftScheduler` and Request objects (so routing,
  stealing, reroute and cluster metrics work unchanged) but
  epoch-batches full pure-decode batches when the cost model is
  jitter-free, with exact mid-epoch truncation on worker failure.
  Requires an external event sink (the cluster heap).

Exactness contract (what is and is not bit-identical) is documented in
``docs/architecture.md`` §"Vectorized core & differential oracle".
Known, deliberate divergences of the standalone engine: no lifecycle
trace emission, and ``HeartbeatMonitor``/``StragglerDetector`` internal
state is not advanced on epoch-interior iterations (both are
unobservable in any reported metric; straggler *mitigation* disables
epochs entirely, so mitigation decisions never see stale state).

Determinism: one ``random.Random(seed)`` consumed in the identical
order as the object engine (epoch loops draw per-iteration jitter from
the same stream; a draw made while probing an epoch boundary is carried
into the next scheduled step, never discarded).
"""

from __future__ import annotations

import bisect
import heapq
import itertools
import math
import random
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.estimator import DriftConfig
from ..core.request import Category, JobClass, TenantTier
from ..distributed.fault_tolerance import HeartbeatMonitor, StragglerDetector
from ..workload.generator import (ArrivalPlan, CATEGORY_ORDER, TIER_ORDER,
                                  VectorPlan)
from .cost_model import CostModel, L4_QWEN_1_8B
from .kv_cache import (PagedAllocator, PrefixTree, pages_needed_array,
                       prefix_page_key)
from .metrics import RunMetrics, summarize_run_arrays
from .simulator import (GPU_MEM_DYNAMIC_GB, GPU_MEM_PLATEAU_GB,
                        KV_MAX_CONTEXT_TOKENS, KV_PAGE_TOKENS, SimConfig,
                        TelemetrySample, WorkerSimulator, WorkerState,
                        _pages_needed)

__all__ = ["VectorState", "VectorWorkerSimulator",
           "StepVectorizedWorkerSimulator"]

# Request lifecycle codes (mirror RequestState declaration order).
S_CREATED, S_QUEUED, S_DISPATCHED, S_EXECUTING, S_COMPLETED, S_FAILED = \
    range(6)

_JOB_CLASS_ORDER: Tuple[JobClass, ...] = tuple(JobClass)


class _Col:
    """Append-only numpy column with amortised doubling (compact
    history storage: depth samples, bias trajectory, telemetry)."""

    __slots__ = ("_a", "n")

    def __init__(self, dtype, cap: int = 1024) -> None:
        self._a = np.empty(cap, dtype=dtype)
        self.n = 0

    def append(self, v) -> None:
        if self.n == self._a.shape[0]:
            self._a = np.concatenate([self._a, np.empty_like(self._a)])
        self._a[self.n] = v
        self.n += 1

    def extend(self, vs) -> None:
        m = len(vs)
        while self.n + m > self._a.shape[0]:
            self._a = np.concatenate([self._a, np.empty_like(self._a)])
        self._a[self.n:self.n + m] = vs
        self.n += m

    def view(self) -> np.ndarray:
        return self._a[:self.n]


class VectorState:
    """Flat per-request state columns for one simulation run.

    Row ``i`` is request ``i`` of the :class:`VectorPlan` (arrival
    order within each burst). Lifecycle stamps are float64 with NaN as
    the object world's ``None``."""

    def __init__(self, plan: VectorPlan) -> None:
        n = len(plan)
        self.n = n
        self.plan = plan
        # --- identity (borrowed from the plan, never mutated) ---
        self.req_id = plan.req_id
        self.tenant = plan.tenant.astype(np.int64)
        self.category = plan.category.astype(np.int64)
        self.prompt_tokens = plan.prompt_tokens.astype(np.int64)
        self.max_tokens = plan.max_tokens.astype(np.int64)
        self.true_output_tokens = plan.true_output_tokens.astype(np.int64)
        self.shared_prefix_tokens = plan.shared_prefix_tokens.astype(np.int64)
        self.prefix_gid = plan.prefix_gid.astype(np.int64)
        # --- lifecycle stamps (NaN = unset) ---
        self.arrival = np.full(n, np.nan)
        self.enqueue = np.full(n, np.nan)
        self.dispatch = np.full(n, np.nan)
        self.exec_start = np.full(n, np.nan)
        self.exec_end = np.full(n, np.nan)
        self.completion = np.full(n, np.nan)
        self.prefill_end = np.full(n, np.nan)
        self.observed = np.full(n, -1, dtype=np.int64)
        self.state = np.full(n, S_CREATED, dtype=np.int8)
        self.seq = np.full(n, -1, dtype=np.int64)
        self.retries = np.zeros(n, dtype=np.int32)
        self.worker = np.full(n, -1, dtype=np.int32)
        # --- admission estimate (Eq. 1-4) ---
        self.t_budget = np.full(n, np.nan)
        self.est_out = np.full(n, np.nan)
        self.bias_used = np.full(n, np.nan)
        self.f_input = np.full(n, np.nan)
        self.job_class = np.full(n, -1, dtype=np.int8)
        # --- execution-side accounting ---
        # token ledger legs ([prefill processed, decode emitted]) and
        # the prefix-cache credit; `has_ledger` mirrors dict membership
        # in the object engine (entries pop on worker failure).
        self.led_prefill = np.zeros(n, dtype=np.int64)
        self.led_decode = np.zeros(n, dtype=np.int64)
        self.prefix_credit = np.zeros(n, dtype=np.int64)
        self.has_ledger = np.zeros(n, dtype=bool)
        self.cached_prompt_tokens = np.zeros(n, dtype=np.int64)
        # enqueue generation for lazy heap invalidation (sjf/aging)
        self.ticket = np.zeros(n, dtype=np.int64)

    # -- dict views (parity/introspection; do not call at 10^6 scale) --
    def token_ledger(self) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {}
        for i in np.nonzero(self.has_ledger)[0]:
            out[int(self.req_id[i])] = [int(self.led_prefill[i]),
                                        int(self.led_decode[i])]
        return out

    def prefix_ledger(self) -> Dict[int, int]:
        return {int(self.req_id[i]): int(self.prefix_credit[i])
                for i in np.nonzero(self.has_ledger)[0]}


class _VectorBias:
    """Per-category EMA bias store on scalars (exact mirror of
    :class:`repro.core.estimator.BiasStore` arithmetic, no locks —
    the vector engine is single-threaded by construction)."""

    def __init__(self, cfg: DriftConfig) -> None:
        self.cfg = cfg
        self.t_base = [float(cfg.base_estimates[c]) for c in CATEGORY_ORDER]
        self.bias = [float(cfg.bias_init)] * len(CATEGORY_ORDER)
        self.updates = [0] * len(CATEGORY_ORDER)
        self.step = 0
        # compact Fig.-5 trajectory: (step implicit), time, cat, bias
        self.hist_time = _Col(np.float64)
        self.hist_cat = _Col(np.int8)
        self.hist_bias = _Col(np.float64)

    def get(self, cat: int) -> float:
        if not self.cfg.bias_enabled:
            return self.cfg.bias_init
        return self.bias[cat]

    def update(self, cat: int, t_actual: float, now: float) -> float:
        cfg = self.cfg
        lo, hi = cfg.bias_clip
        b_measured = min(max(t_actual / self.t_base[cat], lo), hi)
        if cfg.bias_enabled:
            b_old = self.bias[cat]
            b_new = (1.0 - cfg.ema_alpha) * b_old + cfg.ema_alpha * b_measured
            self.bias[cat] = b_new
        else:
            b_new = self.bias[cat]
        self.updates[cat] += 1
        self.step += 1
        self.hist_time.append(now)
        self.hist_cat.append(cat)
        self.hist_bias.append(b_new)
        return b_new

    def update_many(self, cats: List[int], t_actuals, now: float) -> None:
        """Batch form of :meth:`update`: one call per retired slot in
        join order, identical float sequence (the EMA recurrence is
        inherently sequential; only the history appends are bulked)."""
        cfg = self.cfg
        lo, hi = cfg.bias_clip
        enabled = cfg.bias_enabled
        alpha = cfg.ema_alpha
        one_m = 1.0 - alpha
        bias = self.bias
        t_base = self.t_base
        updates = self.updates
        out = []
        for cat, t_actual in zip(cats, t_actuals):
            b_measured = min(max(t_actual / t_base[cat], lo), hi)
            if enabled:
                b_new = one_m * bias[cat] + alpha * b_measured
                bias[cat] = b_new
            else:
                b_new = bias[cat]
            updates[cat] += 1
            out.append(b_new)
        n = len(out)
        self.step += n
        self.hist_time.extend([now] * n)
        self.hist_cat.extend(cats)
        self.hist_bias.extend(out)

    def snapshot(self) -> Dict[str, float]:
        return {c.value: self.bias[i] for i, c in enumerate(CATEGORY_ORDER)}

    def update_counts(self) -> Dict[str, int]:
        return {c.value: self.updates[i]
                for i, c in enumerate(CATEGORY_ORDER)}


_EXACT_POLICIES = ("fifo", "priority", "sjf", "weighted")
_VECTOR_POLICIES = _EXACT_POLICIES + ("aging",)


class VectorSched:
    """Admission + tenant queues + policy selection over
    :class:`VectorState` rows.

    Arithmetic mirrors :class:`AdaptiveTokenEstimator` /
    :class:`DriftScheduler` operation-for-operation (the f_input ratio
    is computed before the log2, the EMA in the object's association
    order) so estimates, job classes and therefore SJF order are
    bit-identical.

    Queue structures per policy:

    * ``fifo`` / ``priority`` / ``weighted`` — one deque per tenant
      tier (entries are row indices; failure re-queues appendleft).
      Head-min / pattern-cursor selection mirrors the object policies.
    * ``sjf`` — a lazy min-heap keyed ``(t_budget, seq)``; entries are
      invalidated by a per-row enqueue ticket instead of the object's
      O(depth) scan-remove. ``seq`` is unique, so heap order equals the
      object's scan-min order exactly.
    * ``aging`` — a lazy heap on the time-shifted key ``tier*threshold
      + rate*enqueue_time`` (the object evaluates ``tier*threshold -
      rate*(now - enqueue_time)``; the two orders agree in real
      arithmetic but may diverge in the last float ulp, so aging is
      vector-supported but excluded from the bit-exact parity arms).
    """

    def __init__(self, state: VectorState, policy: str = "fifo",
                 drift_config: Optional[DriftConfig] = None,
                 max_new_per_step: Optional[int] = None, *,
                 depth_stride: int = 1,
                 aging_threshold: float = 240.0,
                 aging_rate: float = 1.0) -> None:
        if policy not in _VECTOR_POLICIES:
            raise ValueError(
                f"backend='vector' supports policies {_VECTOR_POLICIES}, "
                f"got {policy!r}")
        if max_new_per_step is not None and max_new_per_step < 1:
            raise ValueError(
                f"max_new_per_step must be >= 1 or None, got {max_new_per_step}")
        self.state = state
        self.policy = policy
        self.config = drift_config or DriftConfig()
        self.max_new_per_step = max_new_per_step
        self.bias = _VectorBias(self.config)
        self._safety = [float(self.config.tenant_safety[t])
                        for t in TIER_ORDER]
        self._aging_thr = float(aging_threshold)
        self._aging_rate = float(aging_rate)
        self._seq = 0
        self.dispatched = 0
        self.n_completed = 0
        self.completed_order = _Col(np.int64)
        # per-tier queued counts + containers
        self._depth = [0, 0, 0]
        self._tier_q: List = [None, None, None]
        if policy in ("fifo", "priority", "weighted"):
            from collections import deque
            self._tier_q = [deque(), deque(), deque()]
        self._heap: List[tuple] = []
        self._fin_cache: Dict[int, float] = {}
        self._wpattern = [0] * 5 + [1] * 3 + [2] * 2
        self._wcursor = 0
        # depth history (queues.record_depth mirror), optionally strided
        self.depth_stride = max(int(depth_stride), 1)
        self._depth_calls = 0
        self.d_time = _Col(np.float64)
        self.d_p = _Col(np.int32)
        self.d_s = _Col(np.int32)
        self.d_b = _Col(np.int32)
        self.phase_feedback = 0

    # --- admission (Eq. 1-4, op-order faithful) -----------------------
    def submit(self, i: int, now: float) -> None:
        st, cfg = self.state, self.config
        st.arrival[i] = now
        st.seq[i] = self._seq
        self._seq += 1
        cat = int(st.category[i])
        p = int(st.prompt_tokens[i])
        bias = self.bias.get(cat)
        safety = self._safety[int(st.tenant[i])]
        # f_input depends only on the (heavily repeated) prompt length:
        # memoise the exact float the inline computation produces
        f_in = self._fin_cache.get(p)
        if f_in is None:
            ratio = max(float(p), 1.0) / cfg.f_input_ref_tokens
            raw = 1.0 + cfg.f_input_log_slope * math.log2(ratio)
            lo, hi = cfg.f_input_clip
            f_in = min(max(raw, lo), hi)
            self._fin_cache[p] = f_in
        est_out = self.bias.t_base[cat] * bias * safety * f_in
        # standalone arrivals carry no expected cached tokens (the
        # router-side hint is a cluster concept): cached == 0 here.
        t_budget = float(p - 0) + est_out
        st.bias_used[i] = bias
        st.f_input[i] = f_in
        st.est_out[i] = est_out
        st.t_budget[i] = t_budget
        if t_budget <= cfg.short_threshold:
            st.job_class[i] = 0
        elif t_budget <= cfg.long_threshold:
            st.job_class[i] = 1
        else:
            st.job_class[i] = 2
        self._enqueue(i, now)

    def _enqueue(self, i: int, now: float, front: bool = False) -> None:
        st = self.state
        tier = int(st.tenant[i])
        st.enqueue[i] = now
        st.state[i] = S_QUEUED
        st.ticket[i] += 1
        self._depth[tier] += 1
        if self.policy == "sjf":
            heapq.heappush(self._heap, (float(st.t_budget[i]),
                                        int(st.seq[i]), i,
                                        int(st.ticket[i])))
        elif self.policy == "aging":
            key = tier * self._aging_thr + self._aging_rate * now
            heapq.heappush(self._heap, (key, int(st.seq[i]), i,
                                        int(st.ticket[i])))
        else:
            dq = self._tier_q[tier]
            if front:
                dq.appendleft(i)
            else:
                dq.append(i)

    # --- selection ----------------------------------------------------
    def _pop_heads(self, keyfn) -> Optional[int]:
        best = None
        best_key = None
        best_tier = -1
        for tier in range(3):
            dq = self._tier_q[tier]
            if not dq:
                continue
            k = keyfn(dq[0], tier)
            if best is None or k < best_key:
                best, best_key, best_tier = dq[0], k, tier
        if best is None:
            return None
        return self._tier_q[best_tier].popleft()

    def _pop_lazy(self) -> Optional[int]:
        st = self.state
        while self._heap:
            _, _, i, ticket = self._heap[0]
            heapq.heappop(self._heap)
            if st.state[i] == S_QUEUED and st.ticket[i] == ticket:
                return i
        return None

    def _pop_weighted(self) -> Optional[int]:
        if sum(self._depth) == 0:
            return None
        n = len(self._wpattern)
        for step in range(n):
            tier = self._wpattern[(self._wcursor + step) % n]
            dq = self._tier_q[tier]
            if dq:
                self._wcursor = (self._wcursor + step + 1) % n
                return dq.popleft()
        return None

    def _pop_fifo(self) -> Optional[int]:
        # _pop_heads specialised to the fifo key (smallest admission
        # seq across tier heads) — no lambda/tuple per probe; this is
        # the hottest selection path in big sweeps
        seq = self.state.seq
        best_tier = -1
        best_key = None
        for tier in range(3):
            dq = self._tier_q[tier]
            if dq:
                k = seq[dq[0]]
                if best_tier < 0 or k < best_key:
                    best_key, best_tier = k, tier
        if best_tier < 0:
            return None
        return self._tier_q[best_tier].popleft()

    def _select(self, now: float) -> Optional[int]:
        st = self.state
        if self.policy == "fifo":
            return self._pop_fifo()
        if self.policy == "priority":
            return self._pop_heads(
                lambda i, tier: (tier * 1e12 + float(st.arrival[i]),
                                 int(st.seq[i])))
        if self.policy == "weighted":
            return self._pop_weighted()
        return self._pop_lazy()          # sjf / aging

    def dispatch(self, now: float) -> Optional[int]:
        i = self._select(now)
        if i is None:
            return None
        st = self.state
        self._depth[int(st.tenant[i])] -= 1
        st.dispatch[i] = now
        st.state[i] = S_DISPATCHED
        self.dispatched += 1
        return i

    def dispatch_step(self, now: float, free_slots: int) -> List[int]:
        cap = free_slots
        if self.max_new_per_step is not None:
            cap = min(cap, self.max_new_per_step)
        out: List[int] = []
        for _ in range(max(cap, 0)):
            i = self.dispatch(now)
            if i is None:
                break
            out.append(i)
        return out

    # --- feedback / failure -------------------------------------------
    def complete(self, i: int, observed: int, now: float) -> None:
        st = self.state
        st.observed[i] = observed
        st.completion[i] = now
        st.state[i] = S_COMPLETED
        self.bias.update(int(st.category[i]), float(observed), now)
        self.phase_feedback += 1
        self.completed_order.append(i)
        self.n_completed += 1

    def complete_many(self, rows: List[int], observed: List[int],
                      now: float) -> int:
        """Batch form of :meth:`complete` for a drained batch's held
        retirements: same end state, same EMA/feedback order (join
        order), stamps applied as one masked write."""
        st = self.state
        ridx = np.asarray(rows, dtype=np.int64)
        st.observed[ridx] = observed
        st.completion[ridx] = now
        st.state[ridx] = S_COMPLETED
        self.bias.update_many(st.category[ridx].tolist(), observed, now)
        n = len(rows)
        self.phase_feedback += n
        self.completed_order.extend(rows)
        self.n_completed += n
        return n

    def fail(self, i: int, now: float) -> None:
        """Worker failure: re-queue at the head, estimate preserved, no
        bias feedback (mirrors ``reset_for_retry`` + readmit)."""
        st = self.state
        st.retries[i] += 1
        st.dispatch[i] = np.nan
        st.exec_start[i] = np.nan
        st.exec_end[i] = np.nan
        st.worker[i] = -1
        st.cached_prompt_tokens[i] = 0
        self._enqueue(i, now, front=True)

    # --- introspection ------------------------------------------------
    def queue_depth(self) -> int:
        return sum(self._depth)

    def depths(self) -> Dict[TenantTier, int]:
        return {t: self._depth[int(t)] for t in TIER_ORDER}

    def record_depth(self, now: float) -> None:
        self._depth_calls += 1
        if self.depth_stride > 1 and (self._depth_calls % self.depth_stride):
            return
        self.d_time.append(now)
        self.d_p.append(self._depth[0])
        self.d_s.append(self._depth[1])
        self.d_b.append(self._depth[2])

    def depth_history(self) -> List[Tuple[float, int, int, int]]:
        return list(zip(self.d_time.view().tolist(),
                        self.d_p.view().tolist(),
                        self.d_s.view().tolist(),
                        self.d_b.view().tolist()))


class _VectorBatch:
    """Array-form :class:`RunningBatch`: one row per occupied slot, in
    join order. ``held`` are retired-but-held slots (non-continuous
    joins drain everyone at batch end)."""

    __slots__ = ("idx", "pr", "tgt", "done", "cached", "nodes", "keys",
                 "held", "gen", "pending", "epoch", "ek")

    def __init__(self, gen: int) -> None:
        self.idx = np.empty(0, dtype=np.int64)   # VectorState row ids
        self.pr = np.empty(0, dtype=np.int64)    # prefill remaining
        self.tgt = np.empty(0, dtype=np.int64)   # decode target
        self.done = np.empty(0, dtype=np.int64)  # decode emitted
        self.cached = np.empty(0, dtype=np.int64)
        self.nodes: List = []                    # locked PrefixNodes
        self.keys: List[tuple] = []              # prefix page keys
        self.held: List[tuple] = []              # (row, done, node, cached)
        self.gen = gen
        self.pending = None                      # (take, emits) arrays
        self.epoch = None                        # sorted boundary times
        self.ek = 0                              # epoch steps per slot
        #                                          (int, or int64 array
        #                                          when a drain epoch
        #                                          crosses retirements)


class VectorWorkerSimulator:
    """Standalone flat-array replica simulator (``backend="vector"``).

    Drop-in for a standalone step-engine :class:`WorkerSimulator` run:
    same :class:`SimConfig`, same cost model, same seed discipline, and
    (for the bit-exact policies) the same event trajectory — but
    per-request state lives in :class:`VectorState` columns, iteration
    boundaries are array updates, and runs of pure-decode iterations
    are collapsed into epochs. Raises rather than approximating on the
    features the array core does not model (atomic batches, hedging,
    P/D phases, external sinks): those stay on the object engine.
    """

    def __init__(self, plan, config: Optional[SimConfig] = None,
                 cost_model: Optional[CostModel] = None, *,
                 policy: str = "fifo",
                 drift_config: Optional[DriftConfig] = None,
                 max_new_per_step: Optional[int] = None,
                 rng: Optional[random.Random] = None,
                 aging_threshold: float = 240.0,
                 aging_rate: float = 1.0) -> None:
        self.cfg = config or SimConfig()
        cfg = self.cfg
        if not cfg.step_engine:
            raise ValueError(
                "backend='vector' implements only the iteration-level "
                "step engine; set SimConfig(step_engine=True) or use "
                "the object backend for atomic batches")
        if cfg.hedge:
            raise ValueError("hedging is an object-engine feature "
                             "(and is incompatible with step_engine)")
        if cfg.phase != "unified":
            raise ValueError(
                "backend='vector' serves the unified phase only; P/D "
                "disaggregation needs the object engine")
        if cfg.chunk_prefill_tokens is not None \
                and cfg.chunk_prefill_tokens < 1:
            raise ValueError(
                f"chunk_prefill_tokens must be >= 1 or None, "
                f"got {cfg.chunk_prefill_tokens}")
        if plan is None:
            raise ValueError("VectorWorkerSimulator needs a plan")
        if isinstance(plan, ArrivalPlan):
            plan = VectorPlan.from_plan(plan)
        self.plan: VectorPlan = plan
        self.state = VectorState(plan)
        self.sched = VectorSched(self.state, policy, drift_config,
                                 max_new_per_step,
                                 depth_stride=cfg.depth_stride,
                                 aging_threshold=aging_threshold,
                                 aging_rate=aging_rate)
        self.cost = cost_model or L4_QWEN_1_8B
        self.rng = rng or random.Random(cfg.seed)
        self.workers = [WorkerState() for _ in range(cfg.n_workers)]
        self.heartbeats = HeartbeatMonitor(timeout=10.0)
        self.stragglers = StragglerDetector()
        self.telemetry: List[TelemetrySample] = []
        self.n_failed_dispatches = 0
        self.n_steps = 0
        self.n_joins = 0
        self.n_epochs = 0            # epoch events (each covers >=1 steps)
        self.phase_boundary: float = 0.0
        self.prefix_tree: Optional[PrefixTree] = None
        if cfg.prefix_cache:
            self.prefix_tree = PrefixTree(PagedAllocator(
                n_pages=cfg.prefix_cache_pages,
                page_size=cfg.prefix_page_tokens, pages_per_seq=1))
        self.n_prefix_hits = 0
        self.n_prefix_misses = 0
        self.prefix_tokens_saved = 0
        self.n_cache_invalidations = 0
        self._events: List[tuple] = []
        self._eseq = itertools.count()
        self._gen = itertools.count(1)
        self._pending_batch_start: Dict[int, bool] = {}
        self._batches: Dict[int, _VectorBatch] = {}
        self._carry_jitter: Dict[int, float] = {}
        self._key_cache: Dict[Tuple[int, int], tuple] = {}
        # times at which worker/queue state can change out-of-band
        # (failures, straggler onset, repairs): epochs never cross them
        self._disrupts: List[float] = sorted(cfg.fail_times)
        if cfg.straggler_worker is not None:
            bisect.insort(self._disrupts, cfg.straggler_after)
        # arrival-array cursor state (installed by run())
        self._arr_t: Optional[np.ndarray] = None
        self._arr_es: Optional[np.ndarray] = None
        self._ap = 0
        self._arr_ready = 0
        self._stress_released = False

    # --- object-engine-compatible introspection -----------------------
    @property
    def token_ledger(self) -> Dict[int, List[int]]:
        return self.state.token_ledger()

    @property
    def prefix_ledger(self) -> Dict[int, int]:
        return self.state.prefix_ledger()

    def prefix_cache_stats(self) -> Dict[str, int]:
        return {
            "hits": self.n_prefix_hits,
            "misses": self.n_prefix_misses,
            "tokens_saved": self.prefix_tokens_saved,
            "evicted_pages": (self.prefix_tree.n_evicted_pages
                              if self.prefix_tree else 0),
            "resident_pages": (self.prefix_tree.total_pages()
                               if self.prefix_tree else 0),
            "invalidations": self.n_cache_invalidations,
        }

    def n_busy_workers(self) -> int:
        return sum(1 for w in self.workers if w.alive and not w.idle)

    def n_alive_workers(self) -> int:
        return sum(1 for w in self.workers if w.alive)

    # --- event plumbing ------------------------------------------------
    def _push(self, t: float, kind: str, payload=None) -> None:
        heapq.heappush(self._events, (t, next(self._eseq), kind, payload))

    def _eligible_workers(self, now: float) -> List[int]:
        out = []
        for i, w in enumerate(self.workers):
            if not (w.alive and w.idle):
                continue
            if (self.cfg.mitigate_stragglers
                    and i in self.stragglers.stragglers()):
                continue
            out.append(i)
        return out

    def _try_dispatch(self, now: float) -> None:
        if self.sched.queue_depth() == 0:
            return
        for wid in self._eligible_workers(now):
            if self._pending_batch_start.get(wid):
                continue
            self._pending_batch_start[wid] = True
            self._push(now + self.cfg.batch_wait, "batch_start", wid)

    # --- slot creation --------------------------------------------------
    def _prefix_key(self, i: int) -> tuple:
        gid = int(self.state.prefix_gid[i])
        if gid < 0 or self.prefix_tree is None:
            return ()
        shared = int(self.state.shared_prefix_tokens[i])
        ck = (gid, shared)
        key = self._key_cache.get(ck)
        if key is None:
            key = prefix_page_key(self.plan.group_table[gid], shared,
                                  self.cfg.prefix_page_tokens)
            self._key_cache[ck] = key
        return key

    def _make_slot(self, i: int, now: float) -> Tuple[int, int, int,
                                                      object, tuple]:
        """Returns ``(prefill_remaining, target, cached, node, key)``
        for row ``i`` joining a batch (mirrors ``WorkerSimulator.
        _make_slot`` minus P/D handoff, which the vector core refuses
        at construction)."""
        st = self.state
        prefill = int(st.prompt_tokens[i])
        target = int(min(st.true_output_tokens[i], st.max_tokens[i]))
        cached = 0
        node = None
        key = ()
        if self.prefix_tree is not None and prefill > 0:
            key = self._prefix_key(i)
            if key:
                n0, n_pages = self.prefix_tree.match(key, now)
                c = min(n_pages * self.cfg.prefix_page_tokens, prefill)
                if c > 0:
                    self.prefix_tree.lock(n0)
                    node = n0
                    cached = c
                    self.n_prefix_hits += 1
                    self.prefix_tokens_saved += c
                else:
                    self.n_prefix_misses += 1
        st.cached_prompt_tokens[i] = cached
        st.led_prefill[i] = 0
        st.led_decode[i] = 0
        st.prefix_credit[i] = cached
        st.has_ledger[i] = True
        return prefill - cached, target, cached, node, key

    def _start_batch(self, wid: int, now: float) -> None:
        w = self.workers[wid]
        if not (w.alive and w.idle):
            return
        rows = self.sched.dispatch_step(now, self.cfg.batch_capacity)
        if not rows:
            return
        st = self.state
        idx = np.asarray(rows, dtype=np.int64)
        st.state[idx] = S_EXECUTING
        st.exec_start[idx] = now
        st.worker[idx] = wid
        w.idle = False
        w.exec_started = now
        w.batches += 1
        batch = _VectorBatch(gen=next(self._gen))
        self._append_slots(batch, rows, now)
        self._batches[wid] = batch
        self._schedule_step(wid, now, include_base=True)
        self.sched.record_depth(now)

    def _append_slots(self, batch: _VectorBatch, rows: List[int],
                      now: float) -> None:
        n = len(rows)
        ridx = np.asarray(rows, dtype=np.int64)
        if self.prefix_tree is None:
            # no cache: every slot is a miss-free full prefill, so the
            # whole join is one masked update (same values _make_slot
            # would produce row by row with cached == 0)
            st = self.state
            prs_a = st.prompt_tokens[ridx].copy()
            tgts_a = np.minimum(st.true_output_tokens[ridx],
                                st.max_tokens[ridx])
            cacheds_a = np.zeros(n, dtype=np.int64)
            st.cached_prompt_tokens[ridx] = 0
            st.led_prefill[ridx] = 0
            st.led_decode[ridx] = 0
            st.prefix_credit[ridx] = 0
            st.has_ledger[ridx] = True
            batch.nodes.extend([None] * n)
            batch.keys.extend([()] * n)
        else:
            prs, tgts, cacheds = [], [], []
            for i in rows:
                pr, tgt, cached, node, key = self._make_slot(i, now)
                prs.append(pr)
                tgts.append(tgt)
                cacheds.append(cached)
                batch.nodes.append(node)
                batch.keys.append(key)
            prs_a = np.asarray(prs, dtype=np.int64)
            tgts_a = np.asarray(tgts, dtype=np.int64)
            cacheds_a = np.asarray(cacheds, dtype=np.int64)
        if len(batch.idx) == 0:
            batch.idx = ridx
            batch.pr = prs_a
            batch.tgt = tgts_a
            batch.done = np.zeros(n, dtype=np.int64)
            batch.cached = cacheds_a
            return
        batch.idx = np.concatenate([batch.idx, ridx])
        batch.pr = np.concatenate([batch.pr, prs_a])
        batch.tgt = np.concatenate([batch.tgt, tgts_a])
        batch.done = np.concatenate(
            [batch.done, np.zeros(n, dtype=np.int64)])
        batch.cached = np.concatenate([batch.cached, cacheds_a])

    # --- iteration scheduling -------------------------------------------
    def _schedule_step(self, wid: int, now: float, *,
                       include_base: bool = False) -> None:
        w = self.workers[wid]
        batch = self._batches[wid]
        cfg = self.cfg
        if (not include_base and not cfg.mitigate_stragglers
                and not batch.pr.any()
                and (self.cost.jitter_sigma <= 0
                     or len(self.workers) == 1)
                and len(batch.idx) > 0 and int(batch.done.min()) >= 1
                and self._schedule_epoch(wid, now)):
            return
        pr, tgt, done = batch.pr, batch.tgt, batch.done
        budget = cfg.chunk_prefill_tokens
        if budget is None:
            take = pr.copy()
        else:
            # exact chunk apportioning in join order: slot i gets
            # min(pr_i, budget - sum(takes before i)), clipped at 0
            before = np.cumsum(pr) - pr
            take = np.clip(budget - before, 0, pr)
        emits = np.where(pr > 0, (take == pr) & (tgt > 0), done < tgt)
        n_emit = int(emits.sum())
        prefill_tokens = int(take.sum())
        jit = self._carry_jitter.pop(wid, None)
        if jit is None:
            jit = self.cost.jitter(self.rng)
        dt = self.cost.step_time(n_emit, prefill_tokens,
                                 include_base=include_base, jitter=jit)
        if w.slow:
            dt *= cfg.straggler_factor
        w.busy_until = now + dt
        w.busy_time += dt
        self.n_steps += 1
        self.heartbeats.beat(wid, now)
        self.stragglers.observe(wid, dt)
        batch.pending = (take, emits)
        batch.epoch = None
        self._push(now + dt, "step_done", (wid, batch.gen))

    def _schedule_epoch(self, wid: int, now: float) -> bool:
        """Try to collapse the next run of pure-decode iterations into
        one event. Legal only while nothing can observe the batch
        between boundaries: the epoch stops before the next disruption
        (failure/straggler onset/repair) and — when mid-flight joins
        are possible — the next arrival. Returns False to fall back to
        single-step.

        With continuous joins the epoch additionally stops at the min
        slot's retirement (a retirement frees a slot someone could
        join). Without joins the membership is frozen, and the object
        engine's interior retirements are *unobservable*: a finished
        slot moves to ``held`` with no completion stamp, no depth
        record, and no tree release until the whole batch drains. The
        only interior effect is the shrinking batch repricing
        ``decode_step_time`` — so the epoch runs through every
        retirement boundary to full drain (one event per batch instead
        of one per distinct retirement), repricing as slots retire.
        ``batch.ek`` records per-slot applied steps for the boundary
        application."""
        cfg = self.cfg
        w = self.workers[wid]
        batch = self._batches[wid]
        rem = batch.tgt - batch.done
        k_min = int(rem.min())
        drain = not cfg.continuous_joins
        k_cap = int(rem.max()) if drain else k_min
        if k_cap < 2:
            return False
        d = self._disrupts
        while d and d[0] < now:
            d.pop(0)
        cap_t = d[0] if d else math.inf
        if cfg.continuous_joins and len(batch.idx) < cfg.batch_capacity:
            # joins could fire at any boundary once work is queued
            if self.sched.queue_depth() > 0 or not self._stress_released:
                return False
            if self._ap < self._arr_ready:
                cap_t = min(cap_t, float(self._arr_t[self._ap]))
        n_emit = len(batch.idx)
        # retirement profile: after step s, ret_counts[s] slots leave
        ret_counts = None
        if k_cap > k_min:
            ret_counts = np.bincount(np.minimum(rem, k_cap)).tolist()
        cost, rng = self.cost, self.rng
        dt_base = cost.decode_step_time(n_emit)
        factor = cfg.straggler_factor if w.slow else 1.0
        carry = self._carry_jitter.pop(wid, None)
        t = now
        boundaries: List[float] = []
        k = 0
        n_act = n_emit
        busy = w.busy_time
        if cost.jitter_sigma <= 0 and cap_t == math.inf:
            # deterministic regime, nothing to cap at: jitter() returns
            # 1.0 without consuming rng state (x * 1.0 == x exactly),
            # so the draw and carry bookkeeping vanish and dt is
            # constant between retirements. Busy time still accumulates
            # one add per step to keep float rounding order identical.
            bapp = boundaries.append
            if ret_counts is None:
                segs = [(k_cap, n_emit)]
            else:
                uniq, cnts = np.unique(rem, return_counts=True)
                segs = []
                prev = 0
                alive = n_emit
                for u, c in zip(uniq.tolist(), cnts.tolist()):
                    segs.append((u - prev, alive))
                    alive -= c
                    prev = u
            for m, na in segs:
                dt = cost.decode_step_time(na)
                if factor != 1.0:
                    dt *= factor
                for _ in range(m):
                    t += dt
                    bapp(t)
                    busy += dt
            k = k_cap
        elif cost.jitter_sigma <= 0:
            # deterministic but a disruption is pending: per-step cap
            # check (the crossing step belongs to the next schedule
            # call; no jitter draw exists to carry)
            dt = dt_base if factor == 1.0 else dt_base * factor
            while k < k_cap:
                nt = t + dt
                if k >= 1 and nt >= cap_t:
                    break
                t = nt
                boundaries.append(t)
                busy += dt
                k += 1
                if ret_counts is not None and k < k_cap:
                    rn = ret_counts[k] if k < len(ret_counts) else 0
                    if rn:
                        n_act -= rn
                        dt_base = cost.decode_step_time(n_act)
                        dt = (dt_base if factor == 1.0
                              else dt_base * factor)
        else:
            while k < k_cap:
                jit = carry if carry is not None else cost.jitter(rng)
                carry = None
                dt = dt_base * jit
                if factor != 1.0:
                    dt *= factor
                nt = t + dt
                if k >= 1 and nt >= cap_t:
                    # the crossing step belongs to the next schedule
                    # call; its jitter draw is carried, keeping the rng
                    # stream identical to the object engine's
                    # one-draw-per-step
                    self._carry_jitter[wid] = jit
                    break
                t = nt
                boundaries.append(t)
                busy += dt
                k += 1
                if ret_counts is not None and k < k_cap:
                    rn = ret_counts[k] if k < len(ret_counts) else 0
                    if rn:
                        n_act -= rn
                        dt_base = cost.decode_step_time(n_act)
        w.busy_time = busy
        w.busy_until = boundaries[-1]
        self.n_steps += k
        self.n_epochs += 1
        self.heartbeats.beat(wid, now)
        batch.pending = None
        batch.epoch = boundaries
        batch.ek = k if k <= k_min else np.minimum(rem, k)
        self._push(boundaries[-1], "step_done", (wid, batch.gen))
        return True

    # --- iteration boundary ---------------------------------------------
    def _on_slot_prefilled(self, batch: _VectorBatch, s: int,
                           now: float) -> None:
        if self.prefix_tree is None:
            return
        key = batch.keys[s]
        if not key:
            return
        node, _ = self.prefix_tree.insert(key, now)
        old = batch.nodes[s]
        if old is not None:
            self.prefix_tree.release(old)
        self.prefix_tree.lock(node)
        batch.nodes[s] = node

    def _complete_row(self, i: int, dcount: int, node, now: float) -> int:
        if node is not None and self.prefix_tree is not None:
            self.prefix_tree.release(node)
        st = self.state
        st.exec_end[i] = now
        self.sched.complete(i, dcount, now)
        return 1

    def _apply_sequential(self, batch: _VectorBatch, now: float) -> int:
        """Per-slot boundary application in exact object order — used
        whenever a prefix tree is live, because retiring slot ``a`` may
        release pins that slot ``b``'s prefill-completion insert then
        evicts (order-dependent tree state). Mirrors the object
        engine's single loop verbatim."""
        st = self.state
        cfg = self.cfg
        take, emits = batch.pending
        done_n = 0
        keep: List[int] = []
        for s in range(len(batch.idx)):
            i = int(batch.idx[s])
            tk = int(take[s])
            if tk:
                batch.pr[s] -= tk
                st.led_prefill[i] += tk
                if batch.pr[s] <= 0:
                    self._on_slot_prefilled(batch, s, now)
            if emits[s]:
                batch.done[s] += 1
                st.led_decode[i] += 1
                if batch.done[s] == 1 and math.isnan(st.prefill_end[i]):
                    st.prefill_end[i] = now
            finished = batch.pr[s] <= 0 and batch.done[s] >= batch.tgt[s]
            if not finished:
                keep.append(s)
            elif cfg.continuous_joins:
                done_n += self._complete_row(i, int(batch.done[s]),
                                             batch.nodes[s], now)
                batch.nodes[s] = None
            else:
                batch.held.append((i, int(batch.done[s]), batch.nodes[s],
                                   int(batch.cached[s])))
                batch.nodes[s] = None
        self._compress(batch, keep)
        return done_n

    def _apply_vectorized(self, batch: _VectorBatch, now: float) -> int:
        """Masked-array boundary application (no prefix tree: slot
        bookkeeping is order-independent, so progress and retirement
        can be two-phase without changing any observable)."""
        st = self.state
        cfg = self.cfg
        idx = batch.idx
        take, emits = batch.pending
        if take.any():
            batch.pr -= take
            st.led_prefill[idx] += take
        if emits.any():
            batch.done += emits
            st.led_decode[idx] += emits
            first = emits & (batch.done == 1)
            if first.any():
                fidx = idx[first]
                unset = np.isnan(st.prefill_end[fidx])
                if unset.any():
                    st.prefill_end[fidx[unset]] = now
        done_n = 0
        finished = (batch.pr <= 0) & (batch.done >= batch.tgt)
        if finished.any():
            keep = [int(s) for s in np.nonzero(~finished)[0]]
            for s in np.nonzero(finished)[0]:
                s = int(s)
                i = int(idx[s])
                if cfg.continuous_joins:
                    done_n += self._complete_row(i, int(batch.done[s]),
                                                 batch.nodes[s], now)
                else:
                    batch.held.append((i, int(batch.done[s]),
                                       batch.nodes[s],
                                       int(batch.cached[s])))
                batch.nodes[s] = None
            self._compress(batch, keep)
        return done_n

    @staticmethod
    def _compress(batch: _VectorBatch, keep: List[int]) -> None:
        if len(keep) == len(batch.idx):
            return
        sel = np.asarray(keep, dtype=np.int64)
        batch.idx = batch.idx[sel]
        batch.pr = batch.pr[sel]
        batch.tgt = batch.tgt[sel]
        batch.done = batch.done[sel]
        batch.cached = batch.cached[sel]
        batch.nodes = [batch.nodes[s] for s in keep]
        batch.keys = [batch.keys[s] for s in keep]

    def _finish_step(self, wid: int, gen: int, now: float) -> int:
        w = self.workers[wid]
        batch = self._batches.get(wid)
        if batch is None or batch.gen != gen or not w.alive:
            return 0                       # stale event (aborted batch)
        st = self.state
        cfg = self.cfg
        done_n = 0
        if batch.epoch is not None:
            # epoch boundary: the collapsed iterations land at once.
            # Epoch legality guarantees no first tokens and no joins in
            # between; ``ek`` is a scalar when no slot crossed its
            # retirement, a per-slot array when a drain epoch ran
            # through retirements (whose held-until-drain stamps all
            # happen here, exactly as the object engine's do).
            batch.done += batch.ek
            st.led_decode[batch.idx] += batch.ek
            batch.epoch = None
            batch.ek = 0
            finished = batch.done >= batch.tgt
            if finished.any():
                keep = [int(s) for s in np.nonzero(~finished)[0]]
                for s in np.nonzero(finished)[0]:
                    s = int(s)
                    i = int(batch.idx[s])
                    if cfg.continuous_joins:
                        done_n += self._complete_row(
                            i, int(batch.done[s]), batch.nodes[s], now)
                    else:
                        batch.held.append((i, int(batch.done[s]),
                                           batch.nodes[s],
                                           int(batch.cached[s])))
                    batch.nodes[s] = None
                self._compress(batch, keep)
        elif self.prefix_tree is not None:
            done_n = self._apply_sequential(batch, now)
        else:
            done_n = self._apply_vectorized(batch, now)
        batch.pending = None

        if cfg.continuous_joins and len(batch.idx) > 0:
            free = cfg.batch_capacity - len(batch.idx)
            if free > 0 and self.sched.queue_depth() > 0:
                joined = self.sched.dispatch_step(now, free)
                if joined:
                    jidx = np.asarray(joined, dtype=np.int64)
                    st.state[jidx] = S_EXECUTING
                    st.exec_start[jidx] = now
                    st.worker[jidx] = wid
                    self._append_slots(batch, joined, now)
                    self.n_joins += len(joined)
                    self.sched.record_depth(now)

        if len(batch.idx) > 0:
            self._schedule_step(wid, now)
        else:
            # batch drained: flush held retirements in join order
            # (bulk: node releases first, in join order, then stamps +
            # EMA feedback — no observer sits between them)
            held = batch.held
            if held:
                if self.prefix_tree is not None:
                    for (_i, _d, node, _c) in held:
                        if node is not None:
                            self.prefix_tree.release(node)
                rows = [h[0] for h in held]
                st.exec_end[np.asarray(rows, dtype=np.int64)] = now
                done_n += self.sched.complete_many(
                    rows, [h[1] for h in held], now)
            del self._batches[wid]
            w.idle = True
            w.busy_until = now
        if done_n:
            self.sched.record_depth(now)
        return done_n

    # --- failure / repair -----------------------------------------------
    def _fail_worker(self, wid: int, now: float) -> None:
        w = self.workers[wid]
        if not w.alive:
            return
        w.alive = False
        w.idle = False
        self._carry_jitter.pop(wid, None)
        batch = self._batches.pop(wid, None)
        rows: List[int] = []
        if batch is not None:
            rows = [int(i) for i in batch.idx] \
                + [h[0] for h in batch.held]
        if self.prefix_tree is not None:
            self.prefix_tree.clear()
            self.n_cache_invalidations += 1
        if rows:
            w.busy_time -= max(w.busy_until - now, 0.0)
            st = self.state
            for i in rows:
                st.prefill_end[i] = np.nan
                st.has_ledger[i] = False
                self.sched.fail(i, now)
                self.n_failed_dispatches += 1
        repair_at = now + self.cfg.repair_time
        self._push(repair_at, "repair", wid)
        bisect.insort(self._disrupts, repair_at)
        self.sched.record_depth(now)

    # --- telemetry (array snapshot, optionally strided) -----------------
    def _slot_kv_pages(self, now: float) -> int:
        pages = 0
        for batch in self._batches.values():
            applied = 0
            if batch.epoch is not None:
                applied = bisect.bisect_left(batch.epoch, now)
            if len(batch.idx):
                # min with tgt: inside a drain epoch a slot past its
                # retirement boundary is frozen at its target (the
                # object engine's held rows stop growing)
                tokens = (self.state.prompt_tokens[batch.idx]
                          - batch.cached - batch.pr
                          + np.minimum(batch.done + applied, batch.tgt))
                live = tokens[tokens > 0]
                if live.size:
                    pages += int(pages_needed_array(
                        live, KV_PAGE_TOKENS).sum())
            for (i, dcount, _node, cached) in batch.held:
                tokens = (int(self.state.prompt_tokens[i]) - cached
                          + dcount)
                if tokens > 0:
                    pages += _pages_needed(tokens)
        return pages

    def _sample_telemetry(self, now: float) -> None:
        active = sum(len(b.idx) + len(b.held)
                     for b in self._batches.values())
        busy_now = sum(1 for w in self.workers if not w.idle and w.alive)
        alive = max(sum(1 for w in self.workers if w.alive), 1)
        pool_pages = (len(self.workers) * self.cfg.batch_capacity
                      * _pages_needed(KV_MAX_CONTEXT_TOKENS))
        used_pages = self._slot_kv_pages(now) if busy_now else 0
        if self.prefix_tree is not None and self.prefix_tree.total_pages():
            used_pages += _pages_needed(self.prefix_tree.total_pages()
                                        * self.cfg.prefix_page_tokens)
        occupancy = min(used_pages / max(pool_pages, 1), 1.0)
        mem = GPU_MEM_PLATEAU_GB + GPU_MEM_DYNAMIC_GB * occupancy
        self.telemetry.append(TelemetrySample(
            time=now,
            gpu_util=0.85 + 0.07 * (busy_now / alive)
            if busy_now else 0.05,
            gpu_mem_gb=mem,
            active_requests=active,
            queue_depth=self.sched.queue_depth(),
        ))

    # --- run loop ---------------------------------------------------------
    def run(self) -> RunMetrics:
        cfg = self.cfg
        plan = self.plan
        total = self.state.n
        n_cal = plan.n_calibration
        # arrivals live in sorted arrays, merged with the event heap by
        # (time, eseq); their eseqs reproduce the object engine's push
        # order (calibration block, then fail/slow/telemetry pushes,
        # then the stress block at release)
        arr_t = plan.arrival.astype(np.float64).copy()
        arr_es = np.zeros(total, dtype=np.int64)
        arr_es[:n_cal] = np.arange(n_cal)
        self._eseq = itertools.count(n_cal)
        for ft in cfg.fail_times:
            self._push(ft, "fail", cfg.fail_worker)
        if cfg.straggler_worker is not None:
            self._push(cfg.straggler_after, "slow", cfg.straggler_worker)
        # the periodic telemetry tick lives outside the heap (a scalar
        # cursor): at big N it is the single most frequent event, and
        # the merge below orders it by the same (time, eseq) key the
        # object engine's heap entry would carry — the eseq is
        # allocated at the exact program points the object pushes at
        tick_t = 0.0
        tick_e = next(self._eseq)
        self._arr_t = arr_t
        self._arr_es = arr_es
        self._ap = 0
        self._arr_ready = n_cal
        self._stress_released = n_cal >= total
        stride = max(cfg.telemetry_stride, 1)
        tick = 0
        completed = 0
        ev = self._events
        workers = self.workers
        # python-list mirrors of the arrival arrays: the merge below
        # runs once per event and np-scalar unboxing dominates it
        arrl_t = arr_t.tolist()
        arrl_e = arr_es.tolist()
        pop = heapq.heappop
        while completed < total and (ev or tick_t is not None
                                     or self._ap < self._arr_ready):
            # three-way merge by (time, eseq): heap top, telemetry
            # cursor, arrival cursor — identical order to the object
            # engine's single heap
            kind = None
            from_tick = False
            if ev:
                h = ev[0]
                ht = h[0]
                he = h[1]
                if tick_t is not None and (tick_t < ht or
                                           (tick_t == ht
                                            and tick_e < he)):
                    ht, he, from_tick = tick_t, tick_e, True
            elif tick_t is not None:
                ht, he, from_tick = tick_t, tick_e, True
            else:
                ht = None
            ap = self._ap
            if ap < self._arr_ready:
                at = arrl_t[ap]
                if ht is None or at < ht or (at == ht
                                             and arrl_e[ap] < he):
                    now, kind, payload = at, "arrival", ap
                    self._ap = ap + 1
            if kind is None:
                if from_tick:
                    now, kind, payload = tick_t, "telemetry", None
                    tick_t = None
                else:
                    now, _, kind, payload = pop(ev)
            # Sec. II-G: the stress burst is submitted once the
            # calibration phase has fully drained.
            if not self._stress_released and completed >= n_cal:
                self._stress_released = True
                self.phase_boundary = now
                k = total - n_cal
                arr_t[n_cal:] = now + plan.arrival[n_cal:]
                base = next(self._eseq)
                arr_es[n_cal:] = np.arange(base, base + k)
                self._eseq = itertools.count(base + k)
                self._arr_ready = total
                arrl_t[n_cal:] = arr_t[n_cal:].tolist()
                arrl_e[n_cal:] = arr_es[n_cal:].tolist()
            if kind == "telemetry":
                # the tick cadence must survive striding: telemetry
                # pops participate in stress-release timing. Striding
                # only skips the (costly) snapshot.
                if tick % stride == 0:
                    self._sample_telemetry(now)
                tick += 1
                if completed < total:
                    tick_t = now + cfg.telemetry_interval
                    tick_e = next(self._eseq)
            elif kind == "arrival":
                self.sched.submit(payload, now)
                self.sched.record_depth(now)
                self._try_dispatch(now)
            elif kind == "batch_start":
                self._pending_batch_start[payload] = False
                self._start_batch(payload, now)
            elif kind == "step_done":
                completed += self._finish_step(payload[0], payload[1],
                                               now)
                self._try_dispatch(now)
            elif kind == "fail":
                self._fail_worker(payload, now)
            elif kind == "repair":
                workers[payload].alive = True
                workers[payload].idle = True
                self._try_dispatch(now)
            elif kind == "slow":
                workers[payload].slow = True
            else:
                raise ValueError(f"unknown simulator event {kind!r}")
        busy = sum(w.busy_time for w in workers) / max(len(workers), 1)
        return summarize_run_arrays(
            self.sched.policy,
            self.sched.config.bias_enabled,
            self.state,
            self.sched.completed_order.view(),
            busy_time=busy,
            n_failed_dispatches=self.n_failed_dispatches,
        )

    @classmethod
    def from_scheduler(cls, scheduler, plan,
                       config: Optional[SimConfig] = None,
                       cost_model: Optional[CostModel] = None,
                       rng: Optional[random.Random] = None
                       ) -> "VectorWorkerSimulator":
        """Build from a freshly-constructed :class:`DriftScheduler`
        (the factory path: the vector core re-implements the scheduler
        internally, so only its configuration is carried over)."""
        pol = scheduler.policy
        return cls(plan, config, cost_model, policy=pol.name,
                   drift_config=scheduler.config,
                   max_new_per_step=scheduler.max_new_per_step, rng=rng,
                   aging_threshold=getattr(pol, "aging_threshold", 240.0),
                   aging_rate=getattr(pol, "aging_rate", 1.0))


class StepVectorizedWorkerSimulator(WorkerSimulator):
    """Composed (cluster) fast path behind ``backend="vector"``.

    A :class:`WorkerSimulator` subclass that keeps the real
    :class:`DriftScheduler` and :class:`Request` objects — so routing,
    reroute, stealing, autoscaling probes and cluster metrics read the
    exact surfaces they always did — but collapses runs of pure-decode
    iterations of a *full* batch into one epoch event when the cost
    model is jitter-free (``jitter_sigma <= 0``; the cluster shares one
    rng across replicas, so per-iteration draws cannot be batched
    without reordering the stream).

    Epochs are invisible to the cluster: batch membership (what
    ``token_mass``/``inflight_requests`` read) only changes at epoch
    ends, joins are impossible while the batch is full, and a worker
    failure mid-epoch truncates exactly — the iterations that the
    object engine would have completed before the failure are applied,
    the in-flight one is discarded, and ``busy_time`` is corrected by
    the inherited un-spend formula. The only tolerated divergence is
    float-ulp noise in ``busy_time`` after such a truncation.
    """

    def __init__(self, scheduler, plan=None,
                 config: Optional[SimConfig] = None,
                 cost_model: Optional[CostModel] = None,
                 sink=None, rng=None, complete_hook=None,
                 trace=None) -> None:
        if sink is None:
            raise ValueError(
                "StepVectorizedWorkerSimulator is the composed "
                "(sink-driven) vector path; standalone vector runs use "
                "VectorWorkerSimulator")
        super().__init__(scheduler, plan, config, cost_model, sink=sink,
                         rng=rng, complete_hook=complete_hook,
                         trace=trace)
        # wid -> (batch gen, epoch boundary times)
        self._epochs: Dict[int, Tuple[int, List[float]]] = {}
        self.n_epochs = 0            # epoch events (each covers >=2 steps)

    def _schedule_step(self, wid: int, now: float, *,
                       include_base: bool = False) -> None:
        cfg = self.cfg
        batch = self._batches[wid]
        w = self.workers[wid]
        if (not include_base
                and not cfg.mitigate_stragglers
                and cfg.straggler_worker is None
                and self.cost.jitter_sigma <= 0
                and not self.trace.enabled
                and len(batch.slots) == cfg.batch_capacity
                and all(s.prefill_remaining <= 0 for s in batch.slots)
                and all(s.decode_done >= 1 for s in batch.slots)):
            k = min(s.target - s.decode_done for s in batch.slots)
            if k >= 2:
                dt = self.cost.decode_step_time(len(batch.slots))
                if w.slow:
                    dt *= cfg.straggler_factor
                # accumulate per step: k separate adds round exactly
                # like k object-engine iterations would
                t = now
                boundaries: List[float] = []
                for _ in range(k):
                    t = t + dt
                    boundaries.append(t)
                    w.busy_time += dt
                w.busy_until = boundaries[-1]
                self.n_steps += k
                self.n_epochs += 1
                self.heartbeats.beat(wid, now)
                self._epochs[wid] = (batch.gen, boundaries)
                batch.pending = []
                self._push(boundaries[-1], "step_done", (wid, batch.gen))
                return
        super()._schedule_step(wid, now, include_base=include_base)

    def _finish_step(self, wid: int, gen: int, now: float) -> int:
        ep = self._epochs.get(wid)
        if ep is not None and ep[0] == gen:
            del self._epochs[wid]
            batch = self._batches.get(wid)
            w = self.workers[wid]
            if batch is None or batch.gen != gen or not w.alive:
                return 0
            k = len(ep[1])
            # fold the first k-1 iterations in silently (no retirement,
            # no first token, no joins are possible before the epoch
            # end by construction), then let the inherited boundary
            # logic run the k-th: retirement, joins, rescheduling and
            # depth recording all behave exactly as in the object run.
            for slot in batch.slots:
                slot.decode_done += k - 1
                self.token_ledger[slot.req.req_id][1] += k - 1
            batch.pending = [(slot, 0, True) for slot in batch.slots]
        return super()._finish_step(wid, gen, now)

    def _fail_worker(self, wid: int, now: float) -> None:
        ep = self._epochs.pop(wid, None)
        if ep is not None:
            batch = self._batches.get(wid)
            w = self.workers[wid]
            if batch is not None and batch.gen == ep[0] and w.alive:
                boundaries = ep[1]
                k = len(boundaries)
                # iterations with a boundary strictly before `now`
                # completed in the object trajectory; one more was in
                # flight. The epoch pre-charged all k to n_steps; give
                # back the never-scheduled remainder. busy_time needs
                # no correction here: the inherited un-spend
                # (busy_until - now) removes the uncompleted tail in
                # one subtraction. Decode progress and ledger entries
                # die with the requeue either way.
                j = bisect.bisect_left(boundaries, now)
                self.n_steps -= k - min(j + 1, k)
        super()._fail_worker(wid, now)
