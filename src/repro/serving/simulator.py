"""Discrete-event simulation of one serving worker group (a *replica*).

Drives the *real* DriftScheduler (the identical state machine the JAX
engine uses) against a calibrated service-time model, reproducing the
paper's protocol: two-phase arrivals (calibration + stress), batch
capacity 32, batch wait 0.01 s, GPU saturation, telemetry sampling.

:class:`WorkerSimulator` can run standalone (its own event loop, the
paper's single-replica protocol) or be composed: when constructed with
an external event ``sink`` it emits its events there instead of its own
heap, and the owner drives it through :meth:`handle_event`. The
cluster-level simulator (``repro.cluster.simulator``) composes N of
these under one heap and one seed.

Beyond-paper cluster features (DESIGN.md §7) are simulated faithfully:

* multiple workers (the paper uses 1; scale-out experiments use more);
* worker failure injection — in-flight batches abort, requests re-queue
  at the head of their tenant queue with their estimate preserved and
  NO bias feedback (at-most-once feedback), the worker rejoins after
  ``repair_time``;
* straggler hedging — a slowed worker's batches take ``slow_factor``x
  longer; the StragglerDetector flags it and (if enabled) the engine
  stops dispatching to it until it recovers.

Determinism: one ``random.Random(seed)`` drives everything.
"""

from __future__ import annotations

import heapq
import itertools
import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from ..core.request import Request, RequestState
from ..core.scheduler import DriftScheduler
from ..distributed.fault_tolerance import HeartbeatMonitor, StragglerDetector
from ..workload.generator import ArrivalPlan
from .cost_model import CostModel, L4_QWEN_1_8B
from .metrics import RunMetrics, summarize_run


@dataclass(frozen=True)
class SimConfig:
    batch_capacity: int = 32          # paper Sec. III-B
    batch_wait: float = 0.01          # paper Sec. III-B
    n_workers: int = 1
    telemetry_interval: float = 0.2   # paper: 200 ms nvidia-smi sampling
    # fault injection
    fail_times: Tuple[float, ...] = ()    # absolute failure times
    fail_worker: int = 0                  # which worker fails
    repair_time: float = 30.0
    # straggler injection
    straggler_worker: Optional[int] = None
    straggler_after: float = 0.0
    straggler_factor: float = 3.0
    mitigate_stragglers: bool = False
    # hedged dispatch (Dean & Barroso): when a batch has been executing
    # longer than hedge_factor x its cost-model estimate and another
    # worker is idle, speculatively re-execute it there; first completion
    # wins, the loser's results are discarded (GPU batches are not
    # cancellable mid-flight, so the loser runs to completion).
    hedge: bool = False
    hedge_factor: float = 2.5
    seed: int = 0


@dataclass
class WorkerState:
    busy_until: float = 0.0
    idle: bool = True
    alive: bool = True
    slow: bool = False
    busy_time: float = 0.0
    batches: int = 0
    exec_started: float = 0.0
    expected_exec: float = 0.0
    hedged: bool = False           # this batch already has a hedge copy


@dataclass
class TelemetrySample:
    time: float
    gpu_util: float
    gpu_mem_gb: float
    active_requests: int
    queue_depth: int


class WorkerSimulator:
    """Event-driven worker group: arrivals -> DriftScheduler -> workers."""

    def __init__(self, scheduler: DriftScheduler,
                 plan: Optional[ArrivalPlan] = None,
                 config: Optional[SimConfig] = None,
                 cost_model: Optional[CostModel] = None,
                 sink: Optional[Callable[[float, str, object], None]] = None,
                 rng: Optional[random.Random] = None,
                 complete_hook: Optional[
                     Callable[[Request, float], bool]] = None) -> None:
        """``complete_hook(req, now) -> bool``, when given, is consulted
        as each request's batch finishes: returning True means the owner
        took the request over (e.g. a P/D prefill replica handing the
        prefilled request off for decode elsewhere) and the normal
        completion path — ``sched.complete`` and its drift feedback —
        must not run for it. Disables hedged dispatch: intercepted
        requests never reach COMPLETED inside this simulator, so the
        hedge-loser no-op guard cannot work."""
        self.sched = scheduler
        self._complete_hook = complete_hook
        self.plan = plan
        self.cfg = config or SimConfig()
        self.cost = cost_model or L4_QWEN_1_8B
        self.rng = rng or random.Random(self.cfg.seed)
        self._sink = sink
        self.workers = [WorkerState() for _ in range(self.cfg.n_workers)]
        self.heartbeats = HeartbeatMonitor(timeout=10.0)
        self.stragglers = StragglerDetector()
        self.telemetry: List[TelemetrySample] = []
        self.n_failed_dispatches = 0
        self.n_hedges = 0
        self.n_hedge_wins = 0
        self.phase_boundary: float = 0.0   # set when the stress burst fires
        self._events: List[tuple] = []
        self._eseq = itertools.count()
        self._pending_batch_start: Dict[int, bool] = {}
        self._inflight: Dict[int, List[Request]] = {}

    # ------------------------------------------------------------------
    def _push(self, t: float, kind: str, payload=None) -> None:
        if self._sink is not None:
            self._sink(t, kind, payload)
        else:
            heapq.heappush(self._events, (t, next(self._eseq), kind, payload))

    def handle_event(self, now: float, kind: str, payload=None) -> int:
        """Process one event; returns the number of completions it
        produced. Used by :meth:`run` and by external composers (the
        cluster simulator) alike. ``telemetry`` is loop-owned and not
        handled here."""
        if kind == "arrival":
            self.sched.submit(payload, now)
            self.sched.queues.record_depth(now)
            self._try_dispatch(now)
        elif kind == "batch_start":
            wid = payload
            self._pending_batch_start[wid] = False
            self._start_batch(wid, now)
        elif kind == "batch_done":
            wid, reqs, aborted = payload
            done = self._finish_batch(wid, reqs, aborted, now)
            self._try_dispatch(now)
            return done
        elif kind == "fail":
            self._fail_worker(payload, now)
        elif kind == "repair":
            self.workers[payload].alive = True
            self.workers[payload].idle = True
            self._try_dispatch(now)
        elif kind == "slow":
            self.workers[payload].slow = True
        elif kind == "kick":
            # external composer enqueued work directly (e.g. a cluster
            # reroute); just re-evaluate dispatch
            self._try_dispatch(now)
        else:
            raise ValueError(f"unknown simulator event {kind!r}")
        return 0

    def run(self) -> RunMetrics:
        if self.plan is None:
            raise ValueError("standalone run() needs an ArrivalPlan")
        if self._sink is not None:
            raise ValueError("externally-driven simulator has no run loop")
        cfg = self.cfg
        n_cal = len(self.plan.calibration)
        for t, req in self.plan.calibration:
            self._push(t, "arrival", req)
        for ft in cfg.fail_times:
            self._push(ft, "fail", cfg.fail_worker)
        if cfg.straggler_worker is not None:
            self._push(cfg.straggler_after, "slow", cfg.straggler_worker)
        self._push(0.0, "telemetry", None)

        total = len(self.plan)
        completed = 0
        stress_released = n_cal >= total
        now = 0.0
        while self._events and completed < total:
            now, _, kind, payload = heapq.heappop(self._events)
            # Sec. II-G: the stress burst is submitted once the
            # calibration phase has fully drained.
            if not stress_released and completed >= n_cal:
                stress_released = True
                self.phase_boundary = now
                for dt, req in self.plan.stress:
                    self._push(now + dt, "arrival", req)
            if kind == "telemetry":
                self._sample_telemetry(now)
                self._maybe_hedge(now)
                if completed < total:
                    self._push(now + cfg.telemetry_interval, "telemetry", None)
            else:
                completed += self.handle_event(now, kind, payload)

        busy = sum(w.busy_time for w in self.workers) / max(len(self.workers), 1)
        return summarize_run(
            self.sched.policy.name,
            self.sched.config.bias_enabled,
            self.sched.completed,
            busy_time=busy,
            n_failed_dispatches=self.n_failed_dispatches,
        )

    # --- composition introspection (used by repro.cluster) -------------
    def inflight_requests(self) -> List[Request]:
        return [r for reqs in self._inflight.values() for r in reqs]

    def n_busy_workers(self) -> int:
        return sum(1 for w in self.workers if w.alive and not w.idle)

    def n_alive_workers(self) -> int:
        return sum(1 for w in self.workers if w.alive)

    def is_idle(self) -> bool:
        return not self._inflight and self.sched.queue_depth() == 0

    # ------------------------------------------------------------------
    def _eligible_workers(self, now: float) -> List[int]:
        out = []
        for i, w in enumerate(self.workers):
            if not (w.alive and w.idle):
                continue
            if (self.cfg.mitigate_stragglers
                    and i in self.stragglers.stragglers()):
                continue
            out.append(i)
        return out

    def _try_dispatch(self, now: float) -> None:
        if self.sched.queue_depth() == 0:
            return
        for wid in self._eligible_workers(now):
            if self._pending_batch_start.get(wid):
                continue
            # paper: wait batch_wait before dispatching a formed batch
            self._pending_batch_start[wid] = True
            self._push(now + self.cfg.batch_wait, "batch_start", wid)

    def _start_batch(self, wid: int, now: float) -> None:
        w = self.workers[wid]
        if not (w.alive and w.idle):
            return
        reqs = self.sched.dispatch_batch(now, self.cfg.batch_capacity)
        if not reqs:
            return
        for r in reqs:
            r.state = RequestState.EXECUTING
            r.exec_start = now
            r.worker_id = wid
        self._run_batch(wid, reqs, now)
        self.sched.queues.record_depth(now)

    def _run_batch(self, wid: int, reqs: List[Request], now: float) -> None:
        w = self.workers[wid]
        w.idle = False
        jitter = self.cost.jitter(self.rng)
        t_exec = self.cost.batch_time(reqs, jitter=jitter)
        w.expected_exec = self.cost.batch_time(reqs, jitter=1.0)
        if w.slow:
            t_exec *= self.cfg.straggler_factor
        self._inflight[wid] = reqs
        w.exec_started = now
        w.hedged = False
        w.busy_until = now + t_exec
        w.busy_time += t_exec
        w.batches += 1
        self.heartbeats.beat(wid, now)
        self.stragglers.observe(wid, t_exec)
        self._push(now + t_exec, "batch_done", (wid, reqs, False))

    def _maybe_hedge(self, now: float) -> None:
        """Speculatively re-execute overdue batches on idle workers."""
        if not self.cfg.hedge:
            return
        if self._complete_hook is not None:
            # hedging relies on the COMPLETED-state guard to make the
            # losing copy a no-op; hook-intercepted requests never reach
            # COMPLETED here, so a hedge would fire the hook twice
            # (double handoff -> double feedback). Mutually exclusive.
            return
        idle = [i for i, w in enumerate(self.workers)
                if w.alive and w.idle]
        if not idle:
            return
        for wid, w in enumerate(self.workers):
            if w.idle or w.hedged or not w.alive:
                continue
            if wid not in self._inflight:
                continue
            overdue = (now - w.exec_started
                       > self.cfg.hedge_factor * max(w.expected_exec, 1e-6))
            if not overdue:
                continue
            spare = idle.pop(0)
            w.hedged = True
            self.n_hedges += 1
            # copy of the request list: each worker's inflight entry is
            # its own; first completion wins, the other is a no-op
            self._run_batch(spare, list(self._inflight[wid]), now)
            if not idle:
                break

    def _finish_batch(self, wid: int, reqs: List[Request],
                      aborted: bool, now: float) -> int:
        w = self.workers[wid]
        if self._inflight.get(wid) is not reqs:
            return 0  # stale event (batch was aborted by a failure)
        del self._inflight[wid]
        w.idle = True
        done = 0
        hedge_win = False
        for r in reqs:
            if r.state is RequestState.COMPLETED:
                continue               # the other copy won the hedge race
            if self._complete_hook is not None \
                    and self._complete_hook(r, now):
                continue               # owner intercepted (phase handoff)
            if r.worker_id != wid:
                hedge_win = True       # we are the speculative copy
            r.exec_end = now
            observed = min(r.true_output_tokens, r.max_tokens)
            self.sched.complete(r, observed, now)
            done += 1
        if hedge_win and done:
            self.n_hedge_wins += 1
        self.sched.queues.record_depth(now)
        return done

    def _fail_worker(self, wid: int, now: float) -> None:
        w = self.workers[wid]
        if not w.alive:
            return
        w.alive = False
        w.idle = False
        reqs = self._inflight.pop(wid, [])
        # abort: un-spend the remaining busy time, re-queue the requests
        if reqs:
            w.busy_time -= max(w.busy_until - now, 0.0)
            for r in reqs:
                self.sched.fail(r, now)
                self.n_failed_dispatches += 1
        self._push(now + self.cfg.repair_time, "repair", wid)
        self.sched.queues.record_depth(now)

    # ------------------------------------------------------------------
    def _sample_telemetry(self, now: float) -> None:
        active = sum(len(v) for v in self._inflight.values())
        busy_now = sum(1 for w in self.workers if not w.idle and w.alive)
        alive = max(sum(1 for w in self.workers if w.alive), 1)
        # memory model: weights (~3.7 GB FP16 1.8B) + activations + the
        # vLLM preallocated KV pool -> observed ~14.5 GB plateau
        mem = 14.0 + 0.5 * (active / max(self.cfg.batch_capacity, 1))
        self.telemetry.append(TelemetrySample(
            time=now,
            gpu_util=0.85 + 0.07 * (busy_now / alive)
            if busy_now else 0.05,
            gpu_mem_gb=mem if busy_now else 14.0,
            active_requests=active,
            queue_depth=self.sched.queue_depth(),
        ))


# Backwards-compatible alias: before the cluster layer existed this class
# was the only "cluster" in the codebase. The cluster-level simulator now
# lives in repro.cluster.simulator.ClusterSimulator.
ClusterSimulator = WorkerSimulator
