"""Discrete-event simulation of one serving worker group (a *replica*).

Drives the *real* DriftScheduler (the identical state machine the JAX
engine uses) against a calibrated service-time model, reproducing the
paper's protocol: two-phase arrivals (calibration + stress), batch
capacity 32, batch wait 0.01 s, GPU saturation, telemetry sampling.

Two execution modes, selected by :attr:`SimConfig.step_engine`:

* **atomic** (default, the paper's protocol): a dispatched batch is
  priced as one unit by :meth:`CostModel.batch_time` and runs to
  completion; every member completes at batch end. This is the
  calibration target of the L4 cost models.
* **step engine** (``step_engine=True``): iteration-level continuous
  batching. The worker holds a :class:`RunningBatch` of per-slot
  progress (prefill tokens remaining, tokens decoded); every event is
  ONE iteration priced by :meth:`CostModel.step_time`. Prefill is
  chunked against a per-step token budget
  (``chunk_prefill_tokens``, Sarathi-style), free slots admit queued
  requests at every iteration boundary (``continuous_joins``, capped by
  the scheduler's ``max_new_per_step``), requests retire — and stamp
  real per-request TTFT (``Request.prefill_end``, the iteration that
  emitted their first token) and completion times — at their own
  iteration, and worker failure preempts at the iteration boundary:
  already-completed members stay completed, unfinished slots re-queue
  with estimates preserved (at-most-once feedback).

  With ``prefix_cache=True`` the worker group additionally models a
  replica-wide **shared-prefix radix cache** (``kv_cache.PrefixTree``
  over a ``PagedAllocator`` page budget): a joining request whose
  prompt starts with a resident shared prefix skips prefilling the
  cached full pages (chunked prefill starts at the cached boundary),
  a finished prefill inserts its shareable full pages for future
  requests, unreferenced LRU leaves evict under page pressure at
  iteration boundaries, and worker failure invalidates the whole cache
  (the KV pool died with the device — subsequent retries re-prefill in
  full). Per-request cache credits live in :attr:`prefix_ledger`;
  conservation becomes ``cached + chunk-prefilled == prompt`` and
  ``emissions == observed``.

  **Parity mode** — ``chunk_prefill_tokens=None`` (unbounded) and
  ``continuous_joins=False`` — degenerates the step engine to the
  atomic contract: the whole batch prefills in its first iteration, no
  one joins mid-flight, and retirements are held until the batch
  drains, so every member completes at batch end. Because
  ``batch_time`` is exactly ``t_base`` plus the telescoped sum of
  ``step_time`` (cost_model.py), parity-mode results reproduce the
  atomic path bit-for-bit modulo float summation order (locked by
  tests/test_step_engine.py) and the existing paper-validation
  calibrations stay meaningful.

:class:`WorkerSimulator` can run standalone (its own event loop, the
paper's single-replica protocol) or be composed: when constructed with
an external event ``sink`` it emits its events there instead of its own
heap, and the owner drives it through :meth:`handle_event`. The
cluster-level simulator (``repro.cluster.simulator``) composes N of
these under one heap and one seed.

Beyond-paper cluster features (DESIGN.md §7) are simulated faithfully:

* multiple workers (the paper uses 1; scale-out experiments use more);
* worker failure injection — in-flight batches abort, requests re-queue
  at the head of their tenant queue with their estimate preserved and
  NO bias feedback (at-most-once feedback), the worker rejoins after
  ``repair_time``;
* straggler hedging — a slowed worker's batches take ``slow_factor``x
  longer; the StragglerDetector flags it and (if enabled) the engine
  stops dispatching to it until it recovers.

Determinism: one ``random.Random(seed)`` drives everything.
"""

from __future__ import annotations

import heapq
import itertools
import math
import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from ..core.request import Request, RequestState
from ..core.scheduler import DriftScheduler
from ..distributed.fault_tolerance import HeartbeatMonitor, StragglerDetector
from ..obs import events as tr
from ..obs import resolve_recorder
from ..workload.generator import ArrivalPlan
from .cost_model import CostModel, L4_QWEN_1_8B
from .kv_cache import PagedAllocator, PrefixTree, prefix_page_key
from .metrics import RunMetrics, summarize_run


@dataclass(frozen=True)
class SimConfig:
    batch_capacity: int = 32          # paper Sec. III-B
    batch_wait: float = 0.01          # paper Sec. III-B
    n_workers: int = 1
    telemetry_interval: float = 0.2   # paper: 200 ms nvidia-smi sampling
    # --- iteration-level execution core (continuous batching) ---
    # step_engine=False keeps the paper's atomic-batch pricing; True
    # switches to per-iteration events (see module docstring).
    step_engine: bool = False
    # per-STEP prefill token budget shared by joining slots in join
    # order (None = unbounded: a joining prompt prefills fully in its
    # first iteration). Only meaningful with step_engine=True.
    chunk_prefill_tokens: Optional[int] = None
    # admit queued requests into freed slots at iteration boundaries;
    # False = atomic batches (the legacy/parity contract: retirements
    # held to batch drain). Only meaningful with step_engine=True.
    continuous_joins: bool = True
    # which serving phase this worker group executes ("unified",
    # "prefill", "decode") — set by the cluster layer under P/D
    # disaggregation. Prefill-phase slots retire at prefill completion
    # (no decode); decode-phase work arrives with its KV handed off.
    phase: str = "unified"
    # --- shared-prefix KV cache (radix tree; step engine only) ---
    # model a replica-wide prefix cache: requests carrying a
    # prefix_group skip prefilling resident full pages of their shared
    # prompt prefix. prefix_cache_pages bounds residency (page size
    # KV_PAGE_TOKENS); LRU leaves evict under pressure.
    prefix_cache: bool = False
    prefix_cache_pages: int = 4096
    # token granularity of one prefix-cache page (the radix tree's
    # shareable unit). Default matches the telemetry page model
    # (KV_PAGE_TOKENS = 128); the engine↔simulator parity suite sets
    # it to the engine's device page_size so cached-token accounting
    # agrees across both executors.
    prefix_page_tokens: int = 128
    # fault injection
    fail_times: Tuple[float, ...] = ()    # absolute failure times
    fail_worker: int = 0                  # which worker fails
    repair_time: float = 30.0
    # straggler injection
    straggler_worker: Optional[int] = None
    straggler_after: float = 0.0
    straggler_factor: float = 3.0
    mitigate_stragglers: bool = False
    # hedged dispatch (Dean & Barroso): when a batch has been executing
    # longer than hedge_factor x its cost-model estimate and another
    # worker is idle, speculatively re-execute it there; first completion
    # wins, the loser's results are discarded (GPU batches are not
    # cancellable mid-flight, so the loser runs to completion).
    # Batch-granular by nature — mutually exclusive with step_engine.
    hedge: bool = False
    hedge_factor: float = 2.5
    # --- execution backend selection ---
    # "object": this event-heap, Request-object engine (the oracle).
    # "vector": the flat-array core in repro.serving.vector_sim —
    # standalone runs get VectorWorkerSimulator, sink-driven (cluster)
    # replicas get StepVectorizedWorkerSimulator. Construct through
    # make_worker_simulator(); WorkerSimulator itself refuses
    # backend="vector" so the fast path can never silently fall back
    # to the object engine.
    backend: str = "object"
    # sample every Nth telemetry tick (vector backend; the 200 ms tick
    # cadence itself is kept — ticks participate in event ordering —
    # only the stored snapshots are thinned). 1 = every tick (exact).
    telemetry_stride: int = 1
    # record every Nth queue-depth sample (vector backend). 1 = exact.
    depth_stride: int = 1
    seed: int = 0


@dataclass
class WorkerState:
    busy_until: float = 0.0
    idle: bool = True
    alive: bool = True
    slow: bool = False
    busy_time: float = 0.0
    batches: int = 0
    exec_started: float = 0.0
    expected_exec: float = 0.0
    hedged: bool = False           # this batch already has a hedge copy


@dataclass
class SlotProgress:
    """Per-request execution state inside a :class:`RunningBatch`."""

    req: Request
    prefill_remaining: int      # prompt tokens not yet prefilled here
    target: int                 # decode tokens to emit (0 on prefill phase)
    decode_done: int = 0        # tokens emitted so far
    # --- prefix-cache state (SimConfig.prefix_cache) ---
    cached_tokens: int = 0      # prompt tokens served from the cache
    prefix_key: tuple = ()      # page key of the shareable prefix
    prefix_node: object = None  # locked PrefixNode pinning cached pages


@dataclass
class RunningBatch:
    """One worker's live continuous batch (step engine only).

    ``pending`` is the iteration currently executing, precomputed when
    it was scheduled: (slot, prefill_tokens_this_step, emits_token).
    ``finished`` holds retired members awaiting batch drain when
    mid-flight joins are disabled (the atomic/parity contract).
    ``gen`` invalidates in-flight step events after an abort."""

    slots: List[SlotProgress]
    gen: int
    pending: List[Tuple[SlotProgress, int, bool]] = field(
        default_factory=list)
    finished: List[SlotProgress] = field(default_factory=list)


# --- telemetry memory model (satellite of the step-engine rework) ------
# The paper platform preallocates its paged KV pool vLLM-style, so
# nvidia-smi shows a ~14 GB plateau (weights ~3.7 GB FP16 1.8B + the
# reserved pool + CUDA context). What *moves* with load is the working
# set: pages actually holding KV (prompt + decoded tokens, page-granular
# like kv_cache.PagedAllocator.pages_needed) drive allocator state and
# activation workspace. We model mem as plateau + workspace scaled by
# pool occupancy, so telemetry responds to chunked prefill (pages
# materialise as tokens do) while reproducing the paper's Fig 9 plateau.
KV_PAGE_TOKENS = 128                  # kv_cache.PagedPool default page
KV_MAX_CONTEXT_TOKENS = 2048          # per-slot pool sizing: prompt+output
GPU_MEM_PLATEAU_GB = 14.0             # weights + reserved pool + context
GPU_MEM_DYNAMIC_GB = 1.2              # workspace swing at full occupancy


def _pages_needed(n_tokens: int) -> int:
    """Mirror of ``kv_cache.PagedAllocator.pages_needed`` at the
    telemetry page size (kept as a module-level helper: telemetry page
    math must not depend on whether a prefix cache was configured)."""
    return max(1, math.ceil(n_tokens / KV_PAGE_TOKENS))


@dataclass
class TelemetrySample:
    time: float
    gpu_util: float
    gpu_mem_gb: float
    active_requests: int
    queue_depth: int


class WorkerSimulator:
    """Event-driven worker group: arrivals -> DriftScheduler -> workers."""

    def __init__(self, scheduler: DriftScheduler,
                 plan: Optional[ArrivalPlan] = None,
                 config: Optional[SimConfig] = None,
                 cost_model: Optional[CostModel] = None,
                 sink: Optional[Callable[[float, str, object], None]] = None,
                 rng: Optional[random.Random] = None,
                 complete_hook: Optional[
                     Callable[[Request, float], bool]] = None,
                 trace=None) -> None:
        """``complete_hook(req, now) -> bool``, when given, is consulted
        as each request finishes: returning True means the owner took
        the request over (e.g. a P/D prefill replica handing the
        prefilled request off for decode elsewhere) and the normal
        completion path — ``sched.complete`` and its drift feedback —
        must not run for it. Disables hedged dispatch: intercepted
        requests never reach COMPLETED inside this simulator, so the
        hedge-loser no-op guard cannot work.

        ``trace`` is an observability recorder
        (:class:`repro.obs.TraceRecorder`); None resolves the
        process-global one (the no-op sentinel unless installed via
        ``repro.obs.set_recorder``). Tracing is RNG-free and changes no
        control flow: traced runs are bit-identical to untraced ones."""
        self.sched = scheduler
        self.trace = resolve_recorder(trace)
        # replica id stamped on emitted events; the cluster layer sets
        # it after construction (None = standalone / unset)
        self.trace_rid: Optional[int] = None
        if self.trace.enabled:
            self.sched.drift.trace = self.trace
        self._complete_hook = complete_hook
        self.plan = plan
        self.cfg = config or SimConfig()
        if self.cfg.backend not in ("object", "vector"):
            raise ValueError(
                f"unknown SimConfig.backend {self.cfg.backend!r} "
                "(expected 'object' or 'vector')")
        if self.cfg.backend == "vector" and type(self) is WorkerSimulator:
            # no-silent-fallback guard: constructing the object engine
            # under backend="vector" would quietly run the slow path
            # (and look like "vectorization has no speedup"). Vector
            # subclasses pass; direct construction must go through
            # make_worker_simulator().
            raise ValueError(
                "SimConfig.backend='vector' must be constructed via "
                "make_worker_simulator() (or the vector classes in "
                "repro.serving.vector_sim); refusing to silently run "
                "the object engine")
        c = self.cfg.chunk_prefill_tokens
        if c is not None and c < 1:
            raise ValueError(
                f"chunk_prefill_tokens must be >= 1 or None, got {c}")
        if self.cfg.step_engine:
            if self.cfg.hedge:
                raise ValueError(
                    "hedged dispatch is batch-granular and incompatible "
                    "with the iteration-level step engine")
        elif c is not None:
            # a budget on the atomic path would be silently ignored and
            # misread as "chunking has no effect" — refuse instead
            raise ValueError(
                "chunk_prefill_tokens requires step_engine=True: the "
                "atomic-batch path prefills whole prompts by definition")
        elif self.cfg.prefix_cache:
            # same refusal logic: the atomic path prices whole batches
            # and never consults per-slot prefill progress, so a cache
            # there would be silently inert
            raise ValueError(
                "prefix_cache requires step_engine=True: only the "
                "iteration-level engine prefills from a cached boundary")
        self.cost = cost_model or L4_QWEN_1_8B
        self.rng = rng or random.Random(self.cfg.seed)
        self._sink = sink
        self.workers = [WorkerState() for _ in range(self.cfg.n_workers)]
        self.heartbeats = HeartbeatMonitor(timeout=10.0)
        self.stragglers = StragglerDetector()
        self.telemetry: List[TelemetrySample] = []
        self.n_failed_dispatches = 0
        self.n_hedges = 0
        self.n_hedge_wins = 0
        self.n_steps = 0                   # step-engine iterations run
        self.n_joins = 0                   # mid-flight slot joins
        self.phase_boundary: float = 0.0   # set when the stress burst fires
        # --- shared-prefix radix cache (replica-wide KV reuse) ---
        self.prefix_tree: Optional[PrefixTree] = None
        if self.cfg.prefix_cache:
            self.prefix_tree = PrefixTree(PagedAllocator(
                n_pages=self.cfg.prefix_cache_pages,
                page_size=self.cfg.prefix_page_tokens, pages_per_seq=1))
        self.n_prefix_hits = 0             # slots that found resident pages
        self.n_prefix_misses = 0           # shareable prefixes that found none
        self.prefix_tokens_saved = 0       # prefill tokens never re-computed
        self.n_cache_invalidations = 0     # failure-driven cache wipes
        # req_id -> prompt tokens served from the cache (the third leg
        # of token conservation: prefix_ledger + token_ledger[0] ==
        # prompt_tokens for every completed request)
        self.prefix_ledger: Dict[int, int] = {}
        # per-request token accounting (step engine): req_id ->
        # [prefill tokens processed, decode tokens emitted]. Reset on
        # abort (preempted iterations were never observed), so for every
        # completed request it must equal [prompt_tokens, observed]
        # (conservation, locked by tests/test_step_engine.py).
        self.token_ledger: Dict[int, List[int]] = {}
        self._events: List[tuple] = []
        self._eseq = itertools.count()
        self._gen = itertools.count(1)
        self._pending_batch_start: Dict[int, bool] = {}
        self._inflight: Dict[int, List[Request]] = {}      # atomic mode
        self._batches: Dict[int, RunningBatch] = {}        # step mode

    # ------------------------------------------------------------------
    def _push(self, t: float, kind: str, payload=None) -> None:
        if self._sink is not None:
            self._sink(t, kind, payload)
        else:
            heapq.heappush(self._events, (t, next(self._eseq), kind, payload))

    def handle_event(self, now: float, kind: str, payload=None) -> int:
        """Process one event; returns the number of completions it
        produced. Used by :meth:`run` and by external composers (the
        cluster simulator) alike. ``telemetry`` is loop-owned and not
        handled here."""
        if kind == "arrival":
            if self.trace.enabled and self._sink is None:
                # standalone: this simulator IS the front door. Composed
                # replicas skip this — the cluster already emitted
                # arrive/admit/route before handing the request over.
                self.trace.emit(now, tr.ARRIVE, req_id=payload.req_id,
                                rid=self.trace_rid,
                                tenant=payload.tenant.label)
            self.sched.submit(payload, now)
            if self.trace.enabled and self._sink is None:
                self.trace.emit(now, tr.ADMIT, req_id=payload.req_id,
                                rid=self.trace_rid,
                                tenant=payload.tenant.label,
                                est_budget=payload.estimate.t_budget)
            self.sched.queues.record_depth(now)
            self._try_dispatch(now)
        elif kind == "batch_start":
            wid = payload
            self._pending_batch_start[wid] = False
            self._start_batch(wid, now)
        elif kind == "batch_done":
            wid, reqs, aborted = payload
            done = self._finish_batch(wid, reqs, aborted, now)
            self._try_dispatch(now)
            return done
        elif kind == "step_done":
            wid, gen = payload
            done = self._finish_step(wid, gen, now)
            self._try_dispatch(now)
            return done
        elif kind == "fail":
            self._fail_worker(payload, now)
        elif kind == "repair":
            self.workers[payload].alive = True
            self.workers[payload].idle = True
            if self.trace.enabled:
                self.trace.emit(now, tr.WORKER_REPAIR, rid=self.trace_rid,
                                wid=payload)
            self._try_dispatch(now)
        elif kind == "slow":
            self.workers[payload].slow = True
        elif kind == "kick":
            # external composer enqueued work directly (e.g. a cluster
            # reroute); just re-evaluate dispatch
            self._try_dispatch(now)
        else:
            raise ValueError(f"unknown simulator event {kind!r}")
        return 0

    def run(self) -> RunMetrics:
        if self.plan is None:
            raise ValueError("standalone run() needs an ArrivalPlan")
        if self._sink is not None:
            raise ValueError("externally-driven simulator has no run loop")
        if self.trace.enabled:
            self.trace.begin_segment(
                f"worker:{self.sched.policy.name}"
                f"{':step' if self.cfg.step_engine else ''}")
        cfg = self.cfg
        n_cal = len(self.plan.calibration)
        for t, req in self.plan.calibration:
            self._push(t, "arrival", req)
        for ft in cfg.fail_times:
            self._push(ft, "fail", cfg.fail_worker)
        if cfg.straggler_worker is not None:
            self._push(cfg.straggler_after, "slow", cfg.straggler_worker)
        self._push(0.0, "telemetry", None)

        total = len(self.plan)
        completed = 0
        stress_released = n_cal >= total
        now = 0.0
        while self._events and completed < total:
            now, _, kind, payload = heapq.heappop(self._events)
            # Sec. II-G: the stress burst is submitted once the
            # calibration phase has fully drained.
            if not stress_released and completed >= n_cal:
                stress_released = True
                self.phase_boundary = now
                for dt, req in self.plan.stress:
                    self._push(now + dt, "arrival", req)
            if kind == "telemetry":
                self._sample_telemetry(now)
                self._maybe_hedge(now)
                if completed < total:
                    self._push(now + cfg.telemetry_interval, "telemetry", None)
            else:
                completed += self.handle_event(now, kind, payload)

        busy = sum(w.busy_time for w in self.workers) / max(len(self.workers), 1)
        return summarize_run(
            self.sched.policy.name,
            self.sched.config.bias_enabled,
            self.sched.completed,
            busy_time=busy,
            n_failed_dispatches=self.n_failed_dispatches,
        )

    # --- composition introspection (used by repro.cluster) -------------
    def inflight_requests(self) -> List[Request]:
        out = [r for reqs in self._inflight.values() for r in reqs]
        for batch in self._batches.values():
            out.extend(s.req for s in batch.slots)
            out.extend(s.req for s in batch.finished)
        return out

    def n_busy_workers(self) -> int:
        return sum(1 for w in self.workers if w.alive and not w.idle)

    def n_alive_workers(self) -> int:
        return sum(1 for w in self.workers if w.alive)

    def is_idle(self) -> bool:
        return (not self._inflight and not self._batches
                and self.sched.queue_depth() == 0)

    def prefix_cached_tokens(self, req: Request) -> int:
        """Resident-prefix overlap this worker group holds for ``req``,
        in tokens (0 without a cache / a shareable prefix / for work
        whose KV already arrived via handoff). Pure probe: does not
        touch LRU or refcount state — the cluster router calls this for
        every routable replica on every placement."""
        if self.prefix_tree is None or req.handoff_time is not None:
            return 0
        key = prefix_page_key(req.prefix_group, req.shared_prefix_tokens,
                              self.cfg.prefix_page_tokens)
        if not key:
            return 0
        return min(self.prefix_tree.cached_tokens(key), req.prompt_tokens)

    def prefix_cache_stats(self) -> Dict[str, int]:
        """Cumulative cache counters (all zero when disabled)."""
        return {
            "hits": self.n_prefix_hits,
            "misses": self.n_prefix_misses,
            "tokens_saved": self.prefix_tokens_saved,
            "evicted_pages": (self.prefix_tree.n_evicted_pages
                              if self.prefix_tree else 0),
            "resident_pages": (self.prefix_tree.total_pages()
                               if self.prefix_tree else 0),
            "invalidations": self.n_cache_invalidations,
        }

    # ------------------------------------------------------------------
    def _eligible_workers(self, now: float) -> List[int]:
        out = []
        for i, w in enumerate(self.workers):
            if not (w.alive and w.idle):
                continue
            if (self.cfg.mitigate_stragglers
                    and i in self.stragglers.stragglers()):
                continue
            out.append(i)
        return out

    def _try_dispatch(self, now: float) -> None:
        if self.sched.queue_depth() == 0:
            return
        for wid in self._eligible_workers(now):
            if self._pending_batch_start.get(wid):
                continue
            # paper: wait batch_wait before dispatching a formed batch
            self._pending_batch_start[wid] = True
            self._push(now + self.cfg.batch_wait, "batch_start", wid)

    def _start_batch(self, wid: int, now: float) -> None:
        w = self.workers[wid]
        if not (w.alive and w.idle):
            return
        if self.cfg.step_engine:
            reqs = self.sched.dispatch_step(now, self.cfg.batch_capacity)
        else:
            reqs = self.sched.dispatch_batch(now, self.cfg.batch_capacity)
        if not reqs:
            return
        for r in reqs:
            r.state = RequestState.EXECUTING
            r.exec_start = now
            r.worker_id = wid
        if self.cfg.step_engine:
            self._start_step_batch(wid, reqs, now)
        else:
            self._run_batch(wid, reqs, now)
        self.sched.queues.record_depth(now)

    # --- atomic-batch execution (the paper's calibrated protocol) -------
    def _run_batch(self, wid: int, reqs: List[Request], now: float) -> None:
        w = self.workers[wid]
        w.idle = False
        jitter = self.cost.jitter(self.rng)
        t_exec = self.cost.batch_time(reqs, jitter=jitter)
        w.expected_exec = self.cost.batch_time(reqs, jitter=1.0)
        if w.slow:
            t_exec *= self.cfg.straggler_factor
        self._inflight[wid] = reqs
        w.exec_started = now
        w.hedged = False
        w.busy_until = now + t_exec
        w.busy_time += t_exec
        w.batches += 1
        self.heartbeats.beat(wid, now)
        self.stragglers.observe(wid, t_exec)
        self._push(now + t_exec, "batch_done", (wid, reqs, False))

    def _maybe_hedge(self, now: float) -> None:
        """Speculatively re-execute overdue batches on idle workers."""
        if not self.cfg.hedge:
            return
        if self._complete_hook is not None:
            # hedging relies on the COMPLETED-state guard to make the
            # losing copy a no-op; hook-intercepted requests never reach
            # COMPLETED here, so a hedge would fire the hook twice
            # (double handoff -> double feedback). Mutually exclusive.
            return
        idle = [i for i, w in enumerate(self.workers)
                if w.alive and w.idle]
        if not idle:
            return
        for wid, w in enumerate(self.workers):
            if w.idle or w.hedged or not w.alive:
                continue
            if wid not in self._inflight:
                continue
            overdue = (now - w.exec_started
                       > self.cfg.hedge_factor * max(w.expected_exec, 1e-6))
            if not overdue:
                continue
            spare = idle.pop(0)
            w.hedged = True
            self.n_hedges += 1
            # copy of the request list: each worker's inflight entry is
            # its own; first completion wins, the other is a no-op
            self._run_batch(spare, list(self._inflight[wid]), now)
            if not idle:
                break

    def _finish_batch(self, wid: int, reqs: List[Request],
                      aborted: bool, now: float) -> int:
        w = self.workers[wid]
        if self._inflight.get(wid) is not reqs:
            return 0  # stale event (batch was aborted by a failure)
        del self._inflight[wid]
        w.idle = True
        done = 0
        hedge_win = False
        for r in reqs:
            if r.state is RequestState.COMPLETED:
                continue               # the other copy won the hedge race
            if self._complete_hook is not None \
                    and self._complete_hook(r, now):
                continue               # owner intercepted (phase handoff)
            if r.worker_id != wid:
                hedge_win = True       # we are the speculative copy
            r.exec_end = now
            observed = min(r.true_output_tokens, r.max_tokens)
            self.sched.complete(r, observed, now)
            if self.trace.enabled:
                self.trace.emit(now, tr.COMPLETE, req_id=r.req_id,
                                rid=self.trace_rid,
                                tenant=r.tenant.label,
                                observed=observed, e2e=r.e2e_latency,
                                ttft=r.ttft,
                                inter_token=r.inter_token_latency)
            done += 1
        if hedge_win and done:
            self.n_hedge_wins += 1
        self.sched.queues.record_depth(now)
        return done

    # --- iteration-level execution (continuous batching) ----------------
    def _make_slot(self, req: Request, now: float) -> SlotProgress:
        """Slot state for a joining request. Work already prefilled
        elsewhere (its KV arrived via a P/D handoff) skips prefill;
        prefill-phase slots decode nothing (target 0) and retire at
        prefill completion. With a prefix cache, the resident full
        pages of the request's shared prefix are served from cache:
        prefill starts at the cached boundary and the matched tree
        path is locked against eviction until the slot retires."""
        prefill = 0 if req.handoff_time is not None else req.prompt_tokens
        slot = SlotProgress(
            req=req, prefill_remaining=prefill,
            target=(0 if self.cfg.phase == "prefill"
                    else min(req.true_output_tokens, req.max_tokens)))
        if self.prefix_tree is not None and prefill > 0:
            slot.prefix_key = prefix_page_key(
                req.prefix_group, req.shared_prefix_tokens,
                self.cfg.prefix_page_tokens)
            if slot.prefix_key:
                node, n_pages = self.prefix_tree.match(slot.prefix_key,
                                                       now)
                cached = min(n_pages * self.cfg.prefix_page_tokens,
                             prefill)
                if cached > 0:
                    self.prefix_tree.lock(node)
                    slot.prefix_node = node
                    slot.cached_tokens = cached
                    slot.prefill_remaining = prefill - cached
                    self.n_prefix_hits += 1
                    self.prefix_tokens_saved += cached
                    if self.trace.enabled:
                        self.trace.emit(now, tr.PREFIX_HIT,
                                        req_id=req.req_id,
                                        rid=self.trace_rid,
                                        tenant=req.tenant.label,
                                        tokens=cached)
                else:
                    self.n_prefix_misses += 1
                    if self.trace.enabled:
                        self.trace.emit(now, tr.PREFIX_MISS,
                                        req_id=req.req_id,
                                        rid=self.trace_rid,
                                        tenant=req.tenant.label)
        if req.handoff_time is None:
            # record the realized hit only where prefill actually runs:
            # a decode-phase slot must not wipe the prefill replica's
            # attribution before completion feeds the drift sample
            req.cached_prompt_tokens = slot.cached_tokens
        self.token_ledger[req.req_id] = [0, 0]
        self.prefix_ledger[req.req_id] = slot.cached_tokens
        return slot

    def _start_step_batch(self, wid: int, reqs: List[Request],
                          now: float) -> None:
        w = self.workers[wid]
        w.idle = False
        w.exec_started = now
        w.batches += 1
        batch = RunningBatch(slots=[self._make_slot(r, now) for r in reqs],
                             gen=next(self._gen))
        self._batches[wid] = batch
        self._schedule_step(wid, now, include_base=True)

    def _schedule_step(self, wid: int, now: float, *,
                       include_base: bool = False) -> None:
        """Precompute and schedule ONE iteration: apportion the per-step
        prefill chunk budget across prefilling slots in join order, mark
        which slots emit a decode token (a slot's prefill-completing
        iteration also emits its first token, like the JAX engine's
        prefill), and price it via :meth:`CostModel.step_time`."""
        w = self.workers[wid]
        batch = self._batches[wid]
        budget = self.cfg.chunk_prefill_tokens
        remaining = math.inf if budget is None else budget
        pending: List[Tuple[SlotProgress, int, bool]] = []
        n_emit = 0
        prefill_tokens = 0
        for slot in batch.slots:
            take = 0
            if slot.prefill_remaining > 0:
                take = int(min(slot.prefill_remaining, remaining))
                remaining -= take
                emits = (take == slot.prefill_remaining
                         and slot.target > 0)
            else:
                emits = slot.decode_done < slot.target
            pending.append((slot, take, emits))
            prefill_tokens += take
            n_emit += int(emits)
        batch.pending = pending
        dt = self.cost.step_time(
            n_emit, prefill_tokens, include_base=include_base,
            jitter=self.cost.jitter(self.rng))
        if w.slow:
            dt *= self.cfg.straggler_factor
        w.busy_until = now + dt
        w.busy_time += dt
        self.n_steps += 1
        self.heartbeats.beat(wid, now)
        self.stragglers.observe(wid, dt)
        self._push(now + dt, "step_done", (wid, batch.gen))

    def _release_prefix(self, slot: SlotProgress) -> None:
        """Drop the slot's pin on its cached prefix pages (retirement
        or takeover). After a failure-driven cache wipe the old node is
        orphaned and releasing it is a harmless no-op on dead state."""
        if slot.prefix_node is not None:
            self.prefix_tree.release(slot.prefix_node)
            slot.prefix_node = None

    def _on_slot_prefilled(self, slot: SlotProgress, now: float) -> None:
        """A slot's last prompt chunk just landed: its shareable full
        pages become resident for future requests (RadixAttention
        inserts at prefill completion). The pin moves from the matched
        prefix to the deepest inserted node so the whole resident run
        survives until this slot retires. Insertion may evict LRU
        unreferenced leaves (this is the iteration-boundary eviction
        point) and truncates under unrelievable pressure — caching is
        best-effort."""
        if self.prefix_tree is None or not slot.prefix_key:
            return
        evicted_before = self.prefix_tree.n_evicted_pages
        node, _ = self.prefix_tree.insert(slot.prefix_key, now)
        self._release_prefix(slot)
        self.prefix_tree.lock(node)
        slot.prefix_node = node
        if self.trace.enabled:
            delta = self.prefix_tree.n_evicted_pages - evicted_before
            if delta > 0:
                self.trace.emit(now, tr.PREFIX_EVICT, rid=self.trace_rid,
                                pages=delta)

    def _complete_step_request(self, slot: SlotProgress, now: float) -> int:
        """Retire one finished slot: stamp timestamps and run the normal
        completion path unless the owner's hook intercepts (P/D prefill
        handoff). Returns 1 when a completion was produced."""
        req = slot.req
        self._release_prefix(slot)
        if self._complete_hook is not None and self._complete_hook(req, now):
            return 0
        req.exec_end = now
        self.sched.complete(req, slot.decode_done, now)
        if self.trace.enabled:
            self.trace.emit(now, tr.COMPLETE, req_id=req.req_id,
                            rid=self.trace_rid, tenant=req.tenant.label,
                            observed=slot.decode_done, e2e=req.e2e_latency,
                            ttft=req.ttft,
                            inter_token=req.inter_token_latency)
        return 1

    def _finish_step(self, wid: int, gen: int, now: float) -> int:
        """One iteration boundary: apply the precomputed progress, stamp
        TTFT on slots whose first token just landed, retire finished
        slots (immediately with mid-flight joins; held to batch drain in
        the atomic/parity contract), then refill free slots and schedule
        the next iteration."""
        w = self.workers[wid]
        batch = self._batches.get(wid)
        if batch is None or batch.gen != gen or not w.alive:
            return 0                       # stale event (aborted batch)
        done = 0
        still: List[SlotProgress] = []
        tron = self.trace.enabled
        for slot, take, emits in batch.pending:
            ledger = self.token_ledger[slot.req.req_id]
            if take:
                slot.prefill_remaining -= take
                ledger[0] += take
                if tron:
                    self.trace.emit(now, tr.PREFILL_CHUNK,
                                    req_id=slot.req.req_id,
                                    rid=self.trace_rid,
                                    tenant=slot.req.tenant.label,
                                    tokens=take)
                if slot.prefill_remaining <= 0:
                    self._on_slot_prefilled(slot, now)
            if emits:
                slot.decode_done += 1
                ledger[1] += 1
                if slot.decode_done == 1 and slot.req.prefill_end is None:
                    # first token observed at this iteration's end: the
                    # honest unified-replica TTFT anchor
                    slot.req.prefill_end = now
                    if tron:
                        self.trace.emit(
                            now, tr.FIRST_TOKEN,
                            req_id=slot.req.req_id, rid=self.trace_rid,
                            tenant=slot.req.tenant.label,
                            ttft=now - slot.req.arrival_time)
                elif tron:
                    self.trace.emit(now, tr.DECODE_STEP,
                                    req_id=slot.req.req_id,
                                    rid=self.trace_rid,
                                    n=slot.decode_done)
            finished = (slot.prefill_remaining <= 0
                        and slot.decode_done >= slot.target)
            if not finished:
                still.append(slot)
            elif self.cfg.continuous_joins:
                done += self._complete_step_request(slot, now)
            else:
                batch.finished.append(slot)
        batch.slots = still
        batch.pending = []

        if self.cfg.continuous_joins and batch.slots:
            free = self.cfg.batch_capacity - len(batch.slots)
            if free > 0 and self.sched.queue_depth() > 0:
                joined = self.sched.dispatch_step(now, free)
                for r in joined:
                    r.state = RequestState.EXECUTING
                    r.exec_start = now
                    r.worker_id = wid
                    batch.slots.append(self._make_slot(r, now))
                if joined:
                    self.n_joins += len(joined)
                    self.sched.queues.record_depth(now)

        if batch.slots:
            self._schedule_step(wid, now)
        else:
            # batch drained: flush held retirements (atomic contract —
            # everyone completes at batch end, matching batch_time)
            for slot in batch.finished:
                done += self._complete_step_request(slot, now)
            del self._batches[wid]
            w.idle = True
            w.busy_until = now
        if done:
            self.sched.queues.record_depth(now)
        return done

    # ------------------------------------------------------------------
    def _fail_worker(self, wid: int, now: float) -> None:
        w = self.workers[wid]
        if not w.alive:
            return
        w.alive = False
        w.idle = False
        reqs = self._inflight.pop(wid, [])
        batch = self._batches.pop(wid, None)
        if batch is not None:
            # iteration-boundary preemption: members that already
            # retired stay completed; unfinished slots (and retirements
            # held for the atomic drain) re-queue from scratch
            reqs = [s.req for s in batch.slots] \
                + [s.req for s in batch.finished]
        if self.prefix_tree is not None:
            # the KV pool died with the worker: every resident prefix —
            # and every lock held by the aborted slots — is gone. A
            # retry anywhere re-probes/re-prefills from scratch (lost
            # KV → full re-prefill; the at-most-once feedback contract
            # is untouched because aborted work never fed back).
            self.prefix_tree.clear()
            self.n_cache_invalidations += 1
        if self.trace.enabled:
            self.trace.emit(now, tr.WORKER_FAIL, rid=self.trace_rid,
                            wid=wid, n_requeued=len(reqs))
        # abort: un-spend the remaining busy time, re-queue the requests
        if reqs:
            w.busy_time -= max(w.busy_until - now, 0.0)
            for r in reqs:
                if self.trace.enabled:
                    self.trace.emit(now, tr.PREEMPT, req_id=r.req_id,
                                    rid=self.trace_rid,
                                    tenant=r.tenant.label,
                                    reason="worker_fail")
                if r.handoff_time is None:
                    # partial unified/prefill progress dies with the
                    # worker; clear the TTFT stamp so a retry re-anchors
                    # it (handed-off decode work keeps its prefill_end:
                    # that phase really did finish elsewhere)
                    r.prefill_end = None
                self.token_ledger.pop(r.req_id, None)
                self.prefix_ledger.pop(r.req_id, None)
                self.sched.fail(r, now)
                self.n_failed_dispatches += 1
        self._push(now + self.cfg.repair_time, "repair", wid)
        self.sched.queues.record_depth(now)

    # ------------------------------------------------------------------
    def _slot_kv_pages(self) -> int:
        """Pages materialised in the KV pool right now, rounded PER
        SLOT exactly as ``kv_cache.PagedAllocator`` allocates (page
        granularity is per sequence, not over the aggregate token sum).
        Step engine: exact per-slot progress (prefilled + decoded —
        this is what makes memory telemetry respond to chunked
        prefill); cache-served prefix tokens are excluded here (their
        pages are shared — the prefix tree reports them once, see
        :meth:`_sample_telemetry`). Atomic mode: the batch's full
        reservation (prompt + planned output), the vLLM-style upper
        bound an atomic batch allocates up front."""
        pages = 0
        for batch in self._batches.values():
            for slot in itertools.chain(batch.slots, batch.finished):
                tokens = (slot.req.prompt_tokens - slot.cached_tokens
                          - slot.prefill_remaining + slot.decode_done)
                if tokens > 0:
                    pages += _pages_needed(tokens)
        for reqs in self._inflight.values():
            for r in reqs:
                pages += _pages_needed(
                    r.prompt_tokens + min(r.true_output_tokens,
                                          r.max_tokens))
        return pages

    def _sample_telemetry(self, now: float) -> None:
        active = sum(len(v) for v in self._inflight.values()) \
            + sum(len(b.slots) + len(b.finished)
                  for b in self._batches.values())
        busy_now = sum(1 for w in self.workers if not w.idle and w.alive)
        alive = max(sum(1 for w in self.workers if w.alive), 1)
        # memory: preallocated plateau + workspace scaled by paged-KV
        # pool occupancy (see the telemetry memory model notes above)
        # each worker models one GPU with its own reserved pool of
        # batch_capacity x max-context pages; occupancy is fleet-wide
        # used pages over the fleet-wide pool
        pool_pages = (len(self.workers) * self.cfg.batch_capacity
                      * _pages_needed(KV_MAX_CONTEXT_TOKENS))
        used_pages = self._slot_kv_pages() if busy_now else 0
        if self.prefix_tree is not None and self.prefix_tree.total_pages():
            # resident shared prefixes occupy pool pages whether or not
            # any batch is running — that is the point of the cache.
            # Tree pages are prefix_page_tokens-sized (configurable);
            # convert to the fixed KV_PAGE_TOKENS telemetry granularity
            # so occupancy units agree.
            used_pages += _pages_needed(self.prefix_tree.total_pages()
                                        * self.cfg.prefix_page_tokens)
        occupancy = min(used_pages / max(pool_pages, 1), 1.0)
        mem = GPU_MEM_PLATEAU_GB + GPU_MEM_DYNAMIC_GB * occupancy
        self.telemetry.append(TelemetrySample(
            time=now,
            gpu_util=0.85 + 0.07 * (busy_now / alive)
            if busy_now else 0.05,
            gpu_mem_gb=mem,
            active_requests=active,
            queue_depth=self.sched.queue_depth(),
        ))
        if self.trace.enabled:
            rid = self.trace_rid
            self.trace.emit(now, tr.GAUGE, rid=rid, name="queue_depth",
                            value=self.sched.queue_depth())
            self.trace.emit(now, tr.GAUGE, rid=rid,
                            name="active_requests", value=active)
            self.trace.emit(now, tr.GAUGE, rid=rid, name="kv_free_pages",
                            value=max(pool_pages - used_pages, 0))
            for tier, depth in self.sched.queues.depths().items():
                self.trace.emit(now, tr.GAUGE, rid=rid,
                                name=f"queue_{tier.label}", value=depth)


def make_worker_simulator(scheduler: DriftScheduler,
                          plan: Optional[ArrivalPlan] = None,
                          config: Optional[SimConfig] = None,
                          cost_model: Optional[CostModel] = None,
                          sink: Optional[Callable[[float, str, object],
                                                  None]] = None,
                          rng: Optional[random.Random] = None,
                          complete_hook: Optional[
                              Callable[[Request, float], bool]] = None,
                          trace=None):
    """Backend-dispatching constructor for worker-group simulators.

    ``SimConfig.backend`` picks the executor:

    * ``"object"`` — :class:`WorkerSimulator` (the event-heap oracle).
    * ``"vector"`` — the flat-array core: sink-driven (cluster)
      replicas get :class:`StepVectorizedWorkerSimulator`, standalone
      runs get :class:`VectorWorkerSimulator` built from the
      scheduler's configuration. Never silently falls back — vector
      construction either returns a vector class or raises.
    """
    cfg = config or SimConfig()
    if cfg.backend == "object":
        return WorkerSimulator(scheduler, plan, cfg, cost_model,
                               sink=sink, rng=rng,
                               complete_hook=complete_hook, trace=trace)
    if cfg.backend != "vector":
        raise ValueError(
            f"unknown SimConfig.backend {cfg.backend!r} "
            "(expected 'object' or 'vector')")
    from .vector_sim import (StepVectorizedWorkerSimulator,
                             VectorWorkerSimulator)
    if sink is not None:
        return StepVectorizedWorkerSimulator(
            scheduler, plan, cfg, cost_model, sink=sink, rng=rng,
            complete_hook=complete_hook, trace=trace)
    if complete_hook is not None:
        raise ValueError(
            "backend='vector' standalone runs do not support "
            "complete_hook (P/D handoff is an object-engine feature)")
    return VectorWorkerSimulator.from_scheduler(
        scheduler, plan, config=cfg, cost_model=cost_model, rng=rng)


def __getattr__(name: str):
    # Deliberately ImportError, not AttributeError: the tombstone must
    # surface its migration pointer on the common breakage path
    # (`from repro.serving.simulator import ClusterSimulator`), where an
    # AttributeError would be swallowed and replaced by the generic
    # "cannot import name" message. The cost — hasattr/getattr probes
    # for the removed alias fail loudly instead of returning False — is
    # intended: nothing should feature-detect a pre-cluster-layer alias.
    if name == "ClusterSimulator":
        raise ImportError(
            "repro.serving.simulator.ClusterSimulator was a pre-cluster-"
            "layer alias of WorkerSimulator and has been removed. Use "
            "repro.cluster.ClusterSimulator for the N-replica cluster "
            "simulator, or repro.serving.WorkerSimulator for a single "
            "replica.")
    raise AttributeError(
        f"module {__name__!r} has no attribute {name!r}")
