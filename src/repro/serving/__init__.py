"""Serving substrate: the execution layer DriftSched schedules onto.

* :mod:`kv_cache`   — vLLM-style paged KV pool + host-side allocator
  (the TPU adaptation of PagedAttention feeds from it), plus the
  page-granular shared-prefix radix cache (``PrefixTree``);
* :mod:`cost_model` — service-time model: L4-calibrated for paper
  reproduction, roofline-derived for TPU projection;
* :mod:`simulator`  — discrete-event simulation of the serving cluster
  (arrivals, batching, workers, failures, telemetry);
* :mod:`engine`     — the real JAX continuous-batching engine (slot
  ring, paged decode) exercised by integration tests and examples;
* :mod:`metrics`    — latency/fairness/drift aggregation shared by the
  benchmarks.
"""

from .cost_model import CostModel, L4_QWEN_1_8B
from .engine import EngineConfig, ServingEngine
from .kv_cache import (PagedAllocator, PagedPool, PrefixTree,
                       pages_needed_array, prefix_page_key)
from .metrics import (RunMetrics, percentile, summarize_run,
                      summarize_run_arrays)
from .simulator import SimConfig, WorkerSimulator, make_worker_simulator
from .vector_sim import (StepVectorizedWorkerSimulator, VectorState,
                         VectorWorkerSimulator)

__all__ = [
    "CostModel", "EngineConfig", "L4_QWEN_1_8B",
    "PagedAllocator", "PagedPool", "PrefixTree", "RunMetrics",
    "ServingEngine", "SimConfig", "StepVectorizedWorkerSimulator",
    "VectorState", "VectorWorkerSimulator", "WorkerSimulator",
    "make_worker_simulator", "pages_needed_array", "percentile",
    "prefix_page_key", "summarize_run", "summarize_run_arrays",
]
