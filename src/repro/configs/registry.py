"""Architecture registry: ``--arch <id>`` resolution for every launcher."""

from __future__ import annotations

import importlib
from typing import Dict, List

from ..models.config import ModelConfig

# arch id -> module name (one module per assigned architecture)
_MODULES: Dict[str, str] = {
    "minitron-8b": "minitron_8b",
    "smollm-135m": "smollm_135m",
    "minitron-4b": "minitron_4b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "whisper-large-v3": "whisper_large_v3",
    "mamba2-2.7b": "mamba2_2_7b",
    "zamba2-1.2b": "zamba2_1_2b",
    "grok-1-314b": "grok_1_314b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "paligemma-3b": "paligemma_3b",
}

ARCHS: List[str] = list(_MODULES)


def _load(name: str):
    if name not in _MODULES:
        raise ValueError(f"unknown arch {name!r}; available: {ARCHS}")
    return importlib.import_module(f".{_MODULES[name]}", __package__)


def get_config(name: str) -> ModelConfig:
    return _load(name).CONFIG


def smoke_config(name: str) -> ModelConfig:
    return _load(name).SMOKE
