"""paligemma-3b — SigLIP + gemma VLM [arXiv:2407.07726; hf].

18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=257216; gemma-style
head_dim 256, tied embeddings, sqrt(d) embedding scale, GeGLU. The
SigLIP patch frontend is a STUB: 256 precomputed patch embeddings are
prepended as a prefix and attended with prefix-LM masking.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16_384,
    vocab=257_216,
    prefix_len=256,
    norm="rmsnorm",
    act="geglu",
    pos="rope",
    embed_scale=True,
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="paligemma-3b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=32,
    d_ff=128, vocab=256, prefix_len=8,
)
