"""llama4-scout-17b-a16e — 16-expert top-1 MoE, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202_048,
    n_experts=16,
    experts_per_token=1,
    norm="rmsnorm",
    act="swiglu",
    pos="rope",
)

SMOKE = CONFIG.replace(
    name="llama4-scout-17b-a16e-smoke",
    n_layers=2, d_model=40, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=80, vocab=256, n_experts=4, experts_per_token=1,
    moe_group_size=64,
    moe_capacity_factor=8.0,   # no token drops: smoke parity is deterministic
)
