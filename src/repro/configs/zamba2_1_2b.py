"""zamba2-1.2b — Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf].

38L d_model=2048 (d_inner 4096, 64 ssm heads of 64, state 64); the
shared attention block runs at width 2*d_model=4096 with 32 heads of
head_dim 128 (kv=32), d_ff=8192, applied every 6 Mamba layers. vocab
32000. For long_500k the shared attention uses a 4096 sliding window
(DESIGN.md §2 adaptation note).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,            # shared block width 2*d = 4096 = 32 x 128
    d_ff=8192,
    vocab=32_000,
    ssm_state=64,
    ssm_headdim=64,
    ssm_ngroups=1,
    ssm_chunk=256,
    ssm_expand=2,
    attn_every=6,
    sliding_window=4096,
    norm="rmsnorm",
    act="swiglu",
    pos="rope",
)

SMOKE = CONFIG.replace(
    name="zamba2-1.2b-smoke",
    n_layers=4, d_model=32, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=64, vocab=256, ssm_state=16, ssm_headdim=16, ssm_chunk=16,
    attn_every=2, sliding_window=16,
)
