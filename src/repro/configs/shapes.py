"""Assigned input shapes and per-cell input specs (ShapeDtypeStructs).

Four shapes per LM architecture:

    train_4k     seq 4,096   global_batch 256   -> train_step
    prefill_32k  seq 32,768  global_batch 32    -> prefill_step
    decode_32k   seq 32,768  global_batch 128   -> serve_step (1 token,
                                                   KV cache of seq_len)
    long_500k    seq 524,288 global_batch 1     -> serve_step

``long_500k`` requires a sub-quadratic decode state and is skipped for
pure full-attention architectures (DESIGN.md §Arch-applicability);
``shape_applicable`` encodes that rule. ``input_specs`` returns
weak-type-correct ShapeDtypeStruct stand-ins for every model input —
no device allocation, the same pattern the dry-run lowers.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig


@dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: Shape) -> Tuple[bool, str]:
    """(runnable, reason). long_500k needs sub-quadratic decode state."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: O(L) KV cache at 500k "
                       "context is infeasible; skipped per assignment")
    return True, ""


def cells_for(cfg: ModelConfig) -> List[Shape]:
    return [s for s in SHAPES.values() if shape_applicable(cfg, s)[0]]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _modality_specs(cfg: ModelConfig, batch: int) -> Dict:
    """Stub frontend inputs: precomputed frame/patch embeddings."""
    out = {}
    if cfg.family == "vlm":
        out["patches"] = _sds((batch, cfg.prefix_len, cfg.d_model),
                              jnp.dtype(cfg.dtype))
    elif cfg.family == "encdec":
        out["frames"] = _sds((batch, cfg.enc_seq, cfg.d_model),
                             jnp.dtype(cfg.dtype))
    return out


def input_specs(cfg: ModelConfig, shape: Shape, *,
                cache_fn=None) -> Dict:
    """ShapeDtypeStruct stand-ins for one (arch x shape) cell.

    train:   {tokens, labels, modality...}
    prefill: {tokens, modality...}
    decode:  {tokens [B], pos scalar, cache pytree, rng}
             (cache shapes come from the family's init_cache via
             jax.eval_shape — pass ``cache_fn`` to override)
    """
    B, L = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        specs = {
            "tokens": _sds((B, L), jnp.int32),
            "labels": _sds((B, L), jnp.int32),
        }
        specs.update(_modality_specs(cfg, B))
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": _sds((B, L), jnp.int32)}
        specs.update(_modality_specs(cfg, B))
        return specs
    # decode: one new token against a cache of seq_len
    if cache_fn is None:
        from ..models.registry import get_api
        cache_fn = get_api(cfg).init_cache
    cache = jax.eval_shape(lambda: cache_fn(cfg, B, L))
    return {
        "tokens": _sds((B,), jnp.int32),
        "pos": _sds((), jnp.int32),
        "cache": cache,
        "rng": _sds((2,), jnp.uint32),
    }
