"""h2o-danube-1.8b — llama+mistral mix with sliding-window attention
[arXiv:2401.16818; hf].

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, SWA window 4096.
The sliding window bounds the decode cache to the window, so this dense
arch runs long_500k (DESIGN.md §Arch-applicability).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab=32_000,
    sliding_window=4096,
    norm="rmsnorm",
    act="swiglu",
    pos="rope",
)

SMOKE = CONFIG.replace(
    name="h2o-danube-1.8b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=256, sliding_window=16,
)
