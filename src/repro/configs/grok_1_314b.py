"""grok-1-314b — 8-expert top-2 MoE [hf:xai-org/grok-1].

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8e top-2.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32_768,
    vocab=131_072,
    n_experts=8,
    experts_per_token=2,
    norm="rmsnorm",
    act="geglu",
    pos="rope",
    logit_softcap=30.0,
)

SMOKE = CONFIG.replace(
    name="grok-1-314b-smoke",
    n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab=256, n_experts=4, experts_per_token=2,
    moe_group_size=64,
    moe_capacity_factor=8.0,   # no token drops: smoke parity is deterministic
)
