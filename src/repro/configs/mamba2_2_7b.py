"""mamba2-2.7b — attention-free SSM with state-space duality
[arXiv:2405.21060].

64L d_model=2560, ssm_state=128, expand 2 (d_inner 5120, 80 heads of
headdim 64), vocab 50280. Constant-size decode state (the SSM answer to
a KV cache) — runs long_500k.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab=50_280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_ngroups=1,
    ssm_chunk=256,
    ssm_expand=2,
    norm="rmsnorm",
    pos="none",
)

SMOKE = CONFIG.replace(
    name="mamba2-2.7b-smoke",
    n_layers=2, d_model=64, vocab=256,
    ssm_state=16, ssm_headdim=16, ssm_chunk=32,
)
