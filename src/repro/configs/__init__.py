"""Assigned architecture configs (one module per arch) + input shapes.

``get_config(name)`` returns the full published config;
``smoke_config(name)`` returns the reduced same-family config used by
CPU smoke tests; ``ARCHS`` lists all ten assigned architecture ids.
"""

from .registry import ARCHS, get_config, smoke_config
from .shapes import SHAPES, Shape, cells_for, input_specs, shape_applicable

__all__ = [
    "ARCHS", "SHAPES", "Shape", "cells_for", "get_config",
    "input_specs", "shape_applicable", "smoke_config",
]
