"""whisper-large-v3 — encoder-decoder audio backbone [arXiv:2212.04356].

32L d_model=1280 20H (MHA: kv=20) d_ff=5120 vocab=51866; 32 encoder
layers over 1500 post-conv audio frames. The conv frontend is a STUB
(``input_specs`` provides precomputed frame embeddings). LayerNorm,
GELU, sinusoidal positions, tied embeddings — whisper flavour.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,              # decoder depth
    n_enc_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51_866,
    enc_seq=1500,
    norm="layernorm",
    act="gelu",
    pos="sinusoidal",
    tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="whisper-large-v3-smoke",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab=256, enc_seq=16,
)
