"""minitron-4b — pruned Nemotron dense LM [arXiv:2407.14679; hf].

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=9216,
    vocab=256_000,
    norm="rmsnorm",
    act="swiglu",
    pos="rope",
)

SMOKE = CONFIG.replace(
    name="minitron-4b-smoke",
    n_layers=2, d_model=48, n_heads=3, n_kv_heads=1, head_dim=16,
    d_ff=96, vocab=256,
)
