"""smollm-135m — llama-arch small dense LM [hf:HuggingFaceTB/SmolLM-135M].

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152. The 9-head
attention does not divide a 16-way model axis — the divisibility-aware
sharding rules fall back per-tensor (DESIGN.md §4).
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab=49_152,
    norm="rmsnorm",
    act="swiglu",
    pos="rope",
)

SMOKE = CONFIG.replace(
    name="smollm-135m-smoke",
    n_layers=2, d_model=72, n_heads=3, n_kv_heads=1, head_dim=24,
    d_ff=144, vocab=256,
)
