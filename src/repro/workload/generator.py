"""Multi-tenant workload generator (Sec. II-B, Algorithm 1).

Produces the paper's experimental traffic:

* 3000 requests per run — 1000 calibration + 2000 stress (Sec. II-G),
* weighted probabilistic category selection (Algorithm 1),
* tenant tier assignment (Premium / Standard / Batch),
* burst arrival processes that saturate the GPU queues (the paper uses
  a 50-client thread pool; we model the resulting arrival pattern as two
  open-loop Poisson bursts separated by a drain gap, which reproduces
  the two queue-buildup phases of Fig. 6).

The generator is deterministic given its seed. Ground-truth output
lengths are attached to each request (hidden from the scheduler) so the
simulator / engine can "generate" them.
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Mapping, Optional, Sequence, Tuple

from ..core.request import Category, Request, TenantTier
from .corpus import Corpus, build_corpus


@dataclass(frozen=True)
class GeneratorConfig:
    """Traffic composition (paper Sec. III-B defaults)."""

    total_requests: int = 3000
    calibration_requests: int = 1000          # Sec. II-G: 1:2 split
    # Algorithm 1 weighted category distribution.
    category_weights: Mapping[Category, float] = field(default_factory=lambda: {
        Category.SHORT_QA: 0.35,
        Category.SUMMARY: 0.25,
        Category.TECHNICAL: 0.25,
        Category.REPORT: 0.15,
    })
    # Tenant mix.
    tenant_weights: Mapping[TenantTier, float] = field(default_factory=lambda: {
        TenantTier.PREMIUM: 0.30,
        TenantTier.STANDARD: 0.40,
        TenantTier.BATCH: 0.30,
    })
    # Arrival process (paper Sec. II-G / IV-D): two BURSTS. The 50-client
    # thread pool floods the gateway, so each phase is a near-instant
    # queue spike; the stress burst is released only after the
    # calibration phase drains ("After calibration completes, the
    # remaining 2000 requests are submitted"). ``*_rate`` is the
    # gateway ingestion rate of each burst.
    calibration_rate: float = 18.0
    stress_rate: float = 18.0
    max_tokens: int = 1024                     # user-configured cap
    output_noise_sigma: float = 0.10          # per-request sampling noise
    # scale factor on prompt token counts (the corpus prompts are terse,
    # 3-32 tokens; chunked-prefill experiments need RAG/agent-scale
    # prompts of hundreds of tokens, modeled by scaling the counts while
    # keeping the corpus text/verbosity structure)
    prompt_tokens_scale: float = 1.0
    # --- shared-prefix population (radix KV-cache workloads) ---
    # Real multi-tenant chat/RAG traffic front-loads every prompt with
    # a tenant system prompt / retrieval template. Model that: each
    # request draws one of ``prefix_groups_per_tenant`` groups for its
    # tenant tier and gains ``shared_prefix_tokens`` extra prompt
    # tokens (NOT scaled by prompt_tokens_scale — system prompts are a
    # fixed population, not per-request verbosity) tagged as shareable
    # (Request.prefix_group / shared_prefix_tokens). 0 disables the
    # mechanism and leaves the arrival plan bit-identical to earlier
    # protocol versions (no extra rng draws).
    shared_prefix_tokens: int = 0
    prefix_groups_per_tenant: int = 4
    seed: int = 0


@dataclass
class ArrivalPlan:
    """Materialised arrival schedule.

    ``calibration``: absolute arrival times from t=0.
    ``stress``: offsets *relative to the stress-release instant* (the
    executor — simulator or engine — releases the stress burst once
    every calibration request has completed, per Sec. II-G).
    """

    calibration: List[Tuple[float, Request]]
    stress: List[Tuple[float, Request]]
    config: GeneratorConfig

    def __iter__(self) -> Iterator[Tuple[float, Request]]:
        """All arrivals with stress offsets appended after the last
        calibration arrival (open-loop view, used by tests)."""
        yield from self.calibration
        t0 = self.calibration[-1][0] if self.calibration else 0.0
        for dt, r in self.stress:
            yield (t0 + dt, r)

    def __len__(self) -> int:
        return len(self.calibration) + len(self.stress)


def cluster_stress_config(n_replicas: int, *,
                          total_requests: int = 1200,
                          per_replica_rate: float = 8.0,
                          seed: int = 0,
                          max_tokens: int = 1024,
                          prompt_tokens_scale: float = 1.0,
                          shared_prefix_tokens: int = 0,
                          prefix_groups_per_tenant: int = 4
                          ) -> GeneratorConfig:
    """Heterogeneous cluster stress traffic (multi-replica arrival plan).

    Same two-burst protocol as the paper, with (a) arrival rates scaled
    to the replica count so the cluster — not one worker — is what
    saturates, and (b) a heavier-tailed category mix (more technical /
    report traffic) so request sizes are genuinely heterogeneous: the
    regime where routing policy choice matters.
    """
    return GeneratorConfig(
        total_requests=total_requests,
        calibration_requests=total_requests // 3,
        category_weights={
            Category.SHORT_QA: 0.30,
            Category.SUMMARY: 0.20,
            Category.TECHNICAL: 0.25,
            Category.REPORT: 0.25,
        },
        calibration_rate=0.75 * per_replica_rate * n_replicas,
        stress_rate=per_replica_rate * n_replicas,
        max_tokens=max_tokens,
        prompt_tokens_scale=prompt_tokens_scale,
        shared_prefix_tokens=shared_prefix_tokens,
        prefix_groups_per_tenant=prefix_groups_per_tenant,
        seed=seed,
    )


class WorkloadGenerator:
    """Algorithm 1, deterministic."""

    def __init__(self, config: Optional[GeneratorConfig] = None,
                 corpus: Optional[Corpus] = None) -> None:
        self.config = config or GeneratorConfig()
        self.corpus = corpus or build_corpus()
        self._cats = list(self.config.category_weights.keys())
        self._cat_w = list(self.config.category_weights.values())
        self._tiers = list(self.config.tenant_weights.keys())
        self._tier_w = list(self.config.tenant_weights.values())

    # ------------------------------------------------------------------
    def make_request(self, rng: random.Random) -> Request:
        cfg = self.config
        category = rng.choices(self._cats, weights=self._cat_w)[0]
        tenant = rng.choices(self._tiers, weights=self._tier_w)[0]
        spec = self.corpus.sample(category, rng)
        true_out = spec.sample_output(
            rng, noise_sigma=cfg.output_noise_sigma, max_tokens=cfg.max_tokens
        )
        prefix_group = None
        shared = 0
        if cfg.shared_prefix_tokens > 0:
            shared = cfg.shared_prefix_tokens
            prefix_group = (tenant.label,
                            rng.randrange(max(cfg.prefix_groups_per_tenant,
                                              1)))
        return Request(
            tenant=tenant,
            category=category,
            prompt=spec.text,
            prompt_tokens=max(1, round(spec.prompt_tokens
                                       * cfg.prompt_tokens_scale)) + shared,
            max_tokens=cfg.max_tokens,
            true_output_tokens=true_out,
            prefix_group=prefix_group,
            shared_prefix_tokens=shared,
        )

    def plan(self, seed: Optional[int] = None) -> ArrivalPlan:
        """Materialise the two-burst arrival schedule."""
        cfg = self.config
        rng = random.Random(cfg.seed if seed is None else seed)

        t = 0.0
        calibration: List[Tuple[float, Request]] = []
        n_cal = min(cfg.calibration_requests, cfg.total_requests)
        for _ in range(n_cal):
            t += rng.expovariate(cfg.calibration_rate)
            calibration.append((t, self.make_request(rng)))

        t = 0.0
        stress: List[Tuple[float, Request]] = []
        for _ in range(cfg.total_requests - n_cal):
            t += rng.expovariate(cfg.stress_rate)
            stress.append((t, self.make_request(rng)))

        return ArrivalPlan(calibration=calibration, stress=stress, config=cfg)

    # ------------------------------------------------------------------
    def category_histogram(self, plan: ArrivalPlan) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for _, r in plan:
            out[r.category.value] = out.get(r.category.value, 0) + 1
        return out
