"""Multi-tenant workload generator (Sec. II-B, Algorithm 1).

Produces the paper's experimental traffic:

* 3000 requests per run — 1000 calibration + 2000 stress (Sec. II-G),
* weighted probabilistic category selection (Algorithm 1),
* tenant tier assignment (Premium / Standard / Batch),
* burst arrival processes that saturate the GPU queues (the paper uses
  a 50-client thread pool; we model the resulting arrival pattern as two
  open-loop Poisson bursts separated by a drain gap, which reproduces
  the two queue-buildup phases of Fig. 6).

The generator is deterministic given its seed. Ground-truth output
lengths are attached to each request (hidden from the scheduler) so the
simulator / engine can "generate" them.
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from ..core.request import Category, Request, TenantTier
from .corpus import Corpus, build_corpus, generation_curve

# Stable integer codes for the array representation (VectorPlan and the
# vectorized simulator core index per-category/per-tier tables by these).
CATEGORY_ORDER: Tuple[Category, ...] = tuple(Category)
TIER_ORDER: Tuple[TenantTier, ...] = tuple(TenantTier)
_CAT_CODE = {c: i for i, c in enumerate(CATEGORY_ORDER)}


@dataclass(frozen=True)
class GeneratorConfig:
    """Traffic composition (paper Sec. III-B defaults)."""

    total_requests: int = 3000
    calibration_requests: int = 1000          # Sec. II-G: 1:2 split
    # Algorithm 1 weighted category distribution.
    category_weights: Mapping[Category, float] = field(default_factory=lambda: {
        Category.SHORT_QA: 0.35,
        Category.SUMMARY: 0.25,
        Category.TECHNICAL: 0.25,
        Category.REPORT: 0.15,
    })
    # Tenant mix.
    tenant_weights: Mapping[TenantTier, float] = field(default_factory=lambda: {
        TenantTier.PREMIUM: 0.30,
        TenantTier.STANDARD: 0.40,
        TenantTier.BATCH: 0.30,
    })
    # Arrival process (paper Sec. II-G / IV-D): two BURSTS. The 50-client
    # thread pool floods the gateway, so each phase is a near-instant
    # queue spike; the stress burst is released only after the
    # calibration phase drains ("After calibration completes, the
    # remaining 2000 requests are submitted"). ``*_rate`` is the
    # gateway ingestion rate of each burst.
    calibration_rate: float = 18.0
    stress_rate: float = 18.0
    max_tokens: int = 1024                     # user-configured cap
    output_noise_sigma: float = 0.10          # per-request sampling noise
    # scale factor on prompt token counts (the corpus prompts are terse,
    # 3-32 tokens; chunked-prefill experiments need RAG/agent-scale
    # prompts of hundreds of tokens, modeled by scaling the counts while
    # keeping the corpus text/verbosity structure)
    prompt_tokens_scale: float = 1.0
    # --- shared-prefix population (radix KV-cache workloads) ---
    # Real multi-tenant chat/RAG traffic front-loads every prompt with
    # a tenant system prompt / retrieval template. Model that: each
    # request draws one of ``prefix_groups_per_tenant`` groups for its
    # tenant tier and gains ``shared_prefix_tokens`` extra prompt
    # tokens (NOT scaled by prompt_tokens_scale — system prompts are a
    # fixed population, not per-request verbosity) tagged as shareable
    # (Request.prefix_group / shared_prefix_tokens). 0 disables the
    # mechanism and leaves the arrival plan bit-identical to earlier
    # protocol versions (no extra rng draws).
    shared_prefix_tokens: int = 0
    prefix_groups_per_tenant: int = 4
    seed: int = 0


@dataclass
class ArrivalPlan:
    """Materialised arrival schedule.

    ``calibration``: absolute arrival times from t=0.
    ``stress``: offsets *relative to the stress-release instant* (the
    executor — simulator or engine — releases the stress burst once
    every calibration request has completed, per Sec. II-G).
    """

    calibration: List[Tuple[float, Request]]
    stress: List[Tuple[float, Request]]
    config: GeneratorConfig

    def __iter__(self) -> Iterator[Tuple[float, Request]]:
        """All arrivals with stress offsets appended after the last
        calibration arrival (open-loop view, used by tests)."""
        yield from self.calibration
        t0 = self.calibration[-1][0] if self.calibration else 0.0
        for dt, r in self.stress:
            yield (t0 + dt, r)

    def __len__(self) -> int:
        return len(self.calibration) + len(self.stress)


def cluster_stress_config(n_replicas: int, *,
                          total_requests: int = 1200,
                          per_replica_rate: float = 8.0,
                          seed: int = 0,
                          max_tokens: int = 1024,
                          prompt_tokens_scale: float = 1.0,
                          shared_prefix_tokens: int = 0,
                          prefix_groups_per_tenant: int = 4
                          ) -> GeneratorConfig:
    """Heterogeneous cluster stress traffic (multi-replica arrival plan).

    Same two-burst protocol as the paper, with (a) arrival rates scaled
    to the replica count so the cluster — not one worker — is what
    saturates, and (b) a heavier-tailed category mix (more technical /
    report traffic) so request sizes are genuinely heterogeneous: the
    regime where routing policy choice matters.
    """
    return GeneratorConfig(
        total_requests=total_requests,
        calibration_requests=total_requests // 3,
        category_weights={
            Category.SHORT_QA: 0.30,
            Category.SUMMARY: 0.20,
            Category.TECHNICAL: 0.25,
            Category.REPORT: 0.25,
        },
        calibration_rate=0.75 * per_replica_rate * n_replicas,
        stress_rate=per_replica_rate * n_replicas,
        max_tokens=max_tokens,
        prompt_tokens_scale=prompt_tokens_scale,
        shared_prefix_tokens=shared_prefix_tokens,
        prefix_groups_per_tenant=prefix_groups_per_tenant,
        seed=seed,
    )


class WorkloadGenerator:
    """Algorithm 1, deterministic."""

    def __init__(self, config: Optional[GeneratorConfig] = None,
                 corpus: Optional[Corpus] = None) -> None:
        self.config = config or GeneratorConfig()
        self.corpus = corpus or build_corpus()
        self._cats = list(self.config.category_weights.keys())
        self._cat_w = list(self.config.category_weights.values())
        self._tiers = list(self.config.tenant_weights.keys())
        self._tier_w = list(self.config.tenant_weights.values())

    # ------------------------------------------------------------------
    def make_request(self, rng: random.Random) -> Request:
        cfg = self.config
        category = rng.choices(self._cats, weights=self._cat_w)[0]
        tenant = rng.choices(self._tiers, weights=self._tier_w)[0]
        spec = self.corpus.sample(category, rng)
        true_out = spec.sample_output(
            rng, noise_sigma=cfg.output_noise_sigma, max_tokens=cfg.max_tokens
        )
        prefix_group = None
        shared = 0
        if cfg.shared_prefix_tokens > 0:
            shared = cfg.shared_prefix_tokens
            prefix_group = (tenant.label,
                            rng.randrange(max(cfg.prefix_groups_per_tenant,
                                              1)))
        return Request(
            tenant=tenant,
            category=category,
            prompt=spec.text,
            prompt_tokens=max(1, round(spec.prompt_tokens
                                       * cfg.prompt_tokens_scale)) + shared,
            max_tokens=cfg.max_tokens,
            true_output_tokens=true_out,
            prefix_group=prefix_group,
            shared_prefix_tokens=shared,
        )

    def plan(self, seed: Optional[int] = None) -> ArrivalPlan:
        """Materialise the two-burst arrival schedule."""
        cfg = self.config
        rng = random.Random(cfg.seed if seed is None else seed)

        t = 0.0
        calibration: List[Tuple[float, Request]] = []
        n_cal = min(cfg.calibration_requests, cfg.total_requests)
        for _ in range(n_cal):
            t += rng.expovariate(cfg.calibration_rate)
            calibration.append((t, self.make_request(rng)))

        t = 0.0
        stress: List[Tuple[float, Request]] = []
        for _ in range(cfg.total_requests - n_cal):
            t += rng.expovariate(cfg.stress_rate)
            stress.append((t, self.make_request(rng)))

        return ArrivalPlan(calibration=calibration, stress=stress, config=cfg)

    # ------------------------------------------------------------------
    def category_histogram(self, plan: ArrivalPlan) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for _, r in plan:
            out[r.category.value] = out.get(r.category.value, 0) + 1
        return out


# ---------------------------------------------------------------------------
# Flat-array trace representation (vectorized simulator core)
# ---------------------------------------------------------------------------
@dataclass
class VectorPlan:
    """The two-burst arrival schedule as flat numpy arrays.

    Row ``i`` is one request. The first ``n_calibration`` rows carry
    *absolute* arrival times from t=0; the remaining rows carry offsets
    relative to the stress-release instant (identical convention to
    :class:`ArrivalPlan`). Rows are in arrival order within each burst.

    Two constructors:

    * :meth:`from_plan` converts an object :class:`ArrivalPlan`
      losslessly (same requests, same ``req_id``s) — this is what the
      differential parity suite uses so both engines consume the exact
      same trace.
    * :meth:`generate` draws the trace directly into arrays with a
      ``numpy.random.Generator`` — *distribution*-equivalent to
      :class:`WorkloadGenerator` (same category/tenant mixes, corpus
      marginals, output-length law, Poisson bursts) but NOT
      bit-identical to it (different RNG stream). Use it for 10^5+
      sweeps where materialising Request objects is the bottleneck.
    """

    n_calibration: int
    arrival: np.ndarray              # float64 [n]
    tenant: np.ndarray               # int8    [n] TenantTier values
    category: np.ndarray             # int8    [n] index into CATEGORY_ORDER
    prompt_tokens: np.ndarray        # int32   [n]
    max_tokens: np.ndarray           # int32   [n]
    true_output_tokens: np.ndarray   # int32   [n]
    shared_prefix_tokens: np.ndarray  # int32  [n]
    prefix_gid: np.ndarray           # int32   [n]; -1 = no shareable prefix
    req_id: np.ndarray               # int64   [n]
    group_table: List[tuple]         # gid -> hashable prefix_group key
    config: GeneratorConfig

    def __len__(self) -> int:
        return int(self.arrival.shape[0])

    # -- lossless conversion from the object plan (parity path) --------
    @classmethod
    def from_plan(cls, plan: ArrivalPlan) -> "VectorPlan":
        rows = list(plan.calibration) + list(plan.stress)
        n = len(rows)
        groups: Dict[tuple, int] = {}
        table: List[tuple] = []
        gid = np.full(n, -1, dtype=np.int32)
        out = cls(
            n_calibration=len(plan.calibration),
            arrival=np.fromiter((t for t, _ in rows), dtype=np.float64,
                                count=n),
            tenant=np.fromiter((int(r.tenant) for _, r in rows),
                               dtype=np.int8, count=n),
            category=np.fromiter((_CAT_CODE[r.category] for _, r in rows),
                                 dtype=np.int8, count=n),
            prompt_tokens=np.fromiter((r.prompt_tokens for _, r in rows),
                                      dtype=np.int32, count=n),
            max_tokens=np.fromiter((r.max_tokens for _, r in rows),
                                   dtype=np.int32, count=n),
            true_output_tokens=np.fromiter(
                (r.true_output_tokens for _, r in rows), dtype=np.int32,
                count=n),
            shared_prefix_tokens=np.fromiter(
                (r.shared_prefix_tokens for _, r in rows), dtype=np.int32,
                count=n),
            prefix_gid=gid,
            req_id=np.fromiter((r.req_id for _, r in rows), dtype=np.int64,
                               count=n),
            group_table=table,
            config=plan.config,
        )
        for i, (_, r) in enumerate(rows):
            if r.prefix_group is not None:
                g = groups.setdefault(r.prefix_group, len(table))
                if g == len(table):
                    table.append(r.prefix_group)
                gid[i] = g
        return out

    # -- batched array generation (scale path) -------------------------
    @classmethod
    def generate(cls, config: Optional[GeneratorConfig] = None,
                 seed: Optional[int] = None,
                 corpus: Optional[Corpus] = None) -> "VectorPlan":
        cfg = config or GeneratorConfig()
        corpus = corpus or build_corpus()
        rng = np.random.default_rng(cfg.seed if seed is None else seed)
        n = cfg.total_requests
        n_cal = min(cfg.calibration_requests, n)

        cats = list(cfg.category_weights.keys())
        cat_w = np.asarray(list(cfg.category_weights.values()), dtype=float)
        tiers = list(cfg.tenant_weights.keys())
        tier_w = np.asarray(list(cfg.tenant_weights.values()), dtype=float)
        cat_pick = rng.choice(len(cats), size=n, p=cat_w / cat_w.sum())
        tier_pick = rng.choice(len(tiers), size=n, p=tier_w / tier_w.sum())

        category = np.fromiter((_CAT_CODE[c] for c in cats),
                               dtype=np.int8)[cat_pick]
        tenant = np.fromiter((int(t) for t in tiers),
                             dtype=np.int8)[tier_pick]

        # corpus entry draw: uniform within the picked category, exactly
        # like Corpus.sample, but over per-category token/verbosity arrays
        prompt_base = np.zeros(n, dtype=np.float64)
        verbosity = np.zeros(n, dtype=np.float64)
        base = np.zeros(n, dtype=np.float64)
        ref_len = np.zeros(n, dtype=np.float64)
        len_exp = np.zeros(n, dtype=np.float64)
        for k, cat in enumerate(cats):
            mask = cat_pick == k
            m = int(mask.sum())
            if m == 0:
                continue
            entries = corpus.by_category[cat]
            pts = np.asarray([p.prompt_tokens for p in entries], dtype=float)
            verbs = np.asarray([p.latent_verbosity for p in entries],
                               dtype=float)
            pick = rng.integers(0, len(entries), size=m)
            prompt_base[mask] = pts[pick]
            verbosity[mask] = verbs[pick]
            b, r, e = generation_curve(cat)
            base[mask], ref_len[mask], len_exp[mask] = b, r, e

        sigma = cfg.output_noise_sigma
        noise = np.exp(rng.normal(0.0, sigma, size=n) - 0.5 * sigma ** 2)
        raw_out = (base * verbosity
                   * (np.maximum(prompt_base, 1.0) / ref_len) ** len_exp
                   * noise)
        true_out = np.clip(np.rint(raw_out), 1,
                           cfg.max_tokens).astype(np.int32)

        shared = int(cfg.shared_prefix_tokens)
        prompt_tokens = (np.maximum(
            1, np.rint(prompt_base * cfg.prompt_tokens_scale)).astype(
                np.int32) + shared)

        gid = np.full(n, -1, dtype=np.int32)
        table: List[tuple] = []
        if shared > 0:
            g_per = max(cfg.prefix_groups_per_tenant, 1)
            g = rng.integers(0, g_per, size=n).astype(np.int32)
            gid = tenant.astype(np.int32) * g_per + g
            table = [(tier.label, j) for tier in TIER_ORDER
                     for j in range(g_per)]

        arrival = np.zeros(n, dtype=np.float64)
        if n_cal:
            arrival[:n_cal] = np.cumsum(
                rng.exponential(1.0 / cfg.calibration_rate, size=n_cal))
        if n - n_cal:
            arrival[n_cal:] = np.cumsum(
                rng.exponential(1.0 / cfg.stress_rate, size=n - n_cal))

        from ..core.request import _REQ_IDS
        req_id = np.fromiter((next(_REQ_IDS) for _ in range(n)),
                             dtype=np.int64, count=n)
        return cls(n_calibration=n_cal, arrival=arrival, tenant=tenant,
                   category=category, prompt_tokens=prompt_tokens,
                   max_tokens=np.full(n, cfg.max_tokens, dtype=np.int32),
                   true_output_tokens=true_out,
                   shared_prefix_tokens=np.full(n, shared, dtype=np.int32),
                   prefix_gid=gid, req_id=req_id, group_table=table,
                   config=cfg)

    # -- materialisation back into the object world --------------------
    def to_arrival_plan(self) -> ArrivalPlan:
        """Build the equivalent object :class:`ArrivalPlan` (fresh
        Request objects carrying this plan's ``req_id``s), so the object
        engine can run the exact same trace — the benchmark's honest
        same-input oracle arm."""
        rows: List[Tuple[float, Request]] = []
        for i in range(len(self)):
            g = int(self.prefix_gid[i])
            r = Request(
                tenant=TenantTier(int(self.tenant[i])),
                category=CATEGORY_ORDER[int(self.category[i])],
                prompt_tokens=int(self.prompt_tokens[i]),
                max_tokens=int(self.max_tokens[i]),
                true_output_tokens=int(self.true_output_tokens[i]),
                prefix_group=(self.group_table[g] if g >= 0 else None),
                shared_prefix_tokens=int(self.shared_prefix_tokens[i]),
            )
            r.req_id = int(self.req_id[i])
            rows.append((float(self.arrival[i]), r))
        return ArrivalPlan(calibration=rows[:self.n_calibration],
                           stress=rows[self.n_calibration:],
                           config=self.config)
