"""Prompt corpus (Sec. III, IV-C): ~1180 unique prompts across four
semantic workload categories — short_qa, summary, technical, report.

The corpus is generated combinatorially from templates x topics (the
paper's corpus is likewise synthetic enterprise-IT traffic). Every
prompt carries a *latent verbosity* value — a per-prompt, deterministic
draw that models how much the serving model actually says in response.
Ground-truth output lengths are produced by :meth:`PromptSpec.sample_output`,
which combines:

  * the category's systematic generation ratio (~0.81 of T_base on
    average — this is exactly the runtime token drift the paper
    measures: static estimates consistently OVER-estimate, and learned
    bias converges to 0.79-0.84, Fig. 5),
  * the prompt's latent verbosity (heavier tail for report/technical,
    which makes report split medium/long at classification time, Fig. 4),
  * mild positive correlation with prompt length (longer prompts elicit
    longer answers — what F_input models at admission time),
  * per-request sampling noise (temperature).

Nothing in this module is visible to the scheduler: the estimator sees
only (category, tenant, prompt); observed lengths reach it strictly via
post-completion feedback, as in the paper.
"""

from __future__ import annotations

import hashlib
import math
import random
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

from ..core.request import Category

# ---------------------------------------------------------------------------
# Topic inventory — enterprise-IT flavoured, mirroring the paper's examples
# ("What is DNS?", "Summarize how Kubernetes schedules pods.", ...)
# ---------------------------------------------------------------------------

_TOPICS: Sequence[str] = (
    "DNS", "Kubernetes pod scheduling", "TCP congestion control", "BGP routing",
    "TLS certificate rotation", "OAuth2 token exchange", "container image layers",
    "service mesh sidecars", "etcd consensus", "load balancer health checks",
    "GPU memory paging", "KV-cache management", "continuous batching",
    "speculative decoding", "tensor parallelism", "pipeline parallelism",
    "gradient checkpointing", "mixed precision training", "collective all-reduce",
    "parameter servers", "RDMA networking", "NVMe-oF storage", "RAID rebuild",
    "log-structured merge trees", "B-tree indexes", "write-ahead logging",
    "MVCC snapshot isolation", "two-phase commit", "Raft leader election",
    "vector clocks", "CRDT convergence", "consistent hashing", "bloom filters",
    "cache eviction policies", "memory fragmentation", "NUMA locality",
    "cgroup CPU throttling", "eBPF tracing", "syscall batching", "io_uring",
    "zero-copy networking", "QUIC streams", "HTTP/3 prioritization",
    "CDN edge caching", "rate limiting algorithms", "circuit breakers",
    "blue-green deployment", "canary rollouts", "feature flags",
    "observability pipelines", "distributed tracing spans", "metrics cardinality",
    "alert fatigue", "incident runbooks", "postmortem culture",
    "chaos engineering", "capacity planning", "autoscaling policies",
    "spot instance preemption", "serverless cold starts", "WebAssembly sandboxing",
)

# Templates per category. short_qa is terse; summary embeds a synthetic
# passage reference; technical asks for explanation; report asks for a
# long-form structured document. Prompt *length* varies within category
# so F_input has signal to exploit.

_SHORT_QA_TEMPLATES = (
    "What is {t}?",
    "How does {t} work?",
    "When should teams use {t}?",
    "Define {t} in one paragraph.",
    "What problem does {t} solve?",
)

_SUMMARY_TEMPLATES = (
    "Summarize how {t} behaves under sustained production load, covering the main failure modes operators should monitor.",
    "Summarize the design of {t} for a new on-call engineer joining the platform team this quarter.",
    "Provide a concise summary of {t}, including when it is preferred over the common alternatives in large deployments.",
    "Summarize the operational trade-offs of {t} in a multi-region, multi-tenant cloud environment with strict latency SLOs.",
    "Summarize recent best practices around {t} and the migration steps legacy systems typically require.",
)

_TECHNICAL_TEMPLATES = (
    "Explain {t} in technical depth, including the underlying data structures, protocols, and the failure scenarios that arise under contention.",
    "Explain how {t} interacts with retries, timeouts, and backpressure in a distributed system, and how to reason about its consistency guarantees.",
    "Walk through the implementation details of {t}, covering the hot path, the slow path, and the instrumentation needed to debug production regressions.",
    "Explain the performance characteristics of {t}: asymptotic behavior, constant factors, memory traffic, and the tuning knobs that matter at scale.",
    "Describe {t} for a senior engineer audience, contrasting at least two real-world implementations and their divergent design decisions under load.",
)

_REPORT_TEMPLATES = (
    "Write a detailed incident report on the {t} outage.",
    "Write a full post-incident report covering {t}.",
    "Write a detailed incident report on a network outage involving {t}, summarizing affected services, the detection timeline, root cause analysis, remediation steps, and long-term action items for the infrastructure team.",
    "Write a comprehensive design review for adopting {t} across the organization, covering current architecture, proposed changes, capacity estimates, rollout phases, risk register, and success metrics.",
    "Write a detailed quarterly reliability report focused on {t}, including SLO attainment, error budgets consumed, major incidents, trend analysis, and prioritized engineering recommendations.",
    "Produce a full migration plan document for replacing the legacy implementation of {t}, with an executive summary, dependency inventory, phased timeline, rollback strategy, and cost analysis.",
)

_TEMPLATES: Dict[Category, Sequence[str]] = {
    Category.SHORT_QA: _SHORT_QA_TEMPLATES,
    Category.SUMMARY: _SUMMARY_TEMPLATES,
    Category.TECHNICAL: _TECHNICAL_TEMPLATES,
    Category.REPORT: _REPORT_TEMPLATES,
}

# ---------------------------------------------------------------------------
# Ground-truth generation behaviour (the hidden "model")
# ---------------------------------------------------------------------------
# mean_ratio: E[T_actual / T_base] — the systematic drift the estimator
#   must learn (paper Fig. 5: converges to 0.79-0.84).
# sigma: lognormal spread of per-prompt verbosity (report/technical are
#   heavier-tailed, producing the medium/long split in Fig. 4).
# len_exp: exponent coupling prompt length to output length.
_GENERATION_PROFILE: Dict[Category, Dict[str, float]] = {
    Category.SHORT_QA: dict(mean_ratio=0.855, sigma=0.12, len_exp=0.08),
    Category.SUMMARY: dict(mean_ratio=0.815, sigma=0.15, len_exp=0.10),
    Category.TECHNICAL: dict(mean_ratio=0.795, sigma=0.20, len_exp=0.12),
    Category.REPORT: dict(mean_ratio=0.825, sigma=0.22, len_exp=0.12),
}

# Reference prompt lengths per category for the length-coupling term
# (the corpus mean, in whitespace tokens).
_REF_PROMPT_LEN: Dict[Category, float] = {
    Category.SHORT_QA: 5.9,
    Category.SUMMARY: 17.3,
    Category.TECHNICAL: 21.7,
    Category.REPORT: 27.3,
}

# T_base mirror (must match estimator.DriftConfig defaults) — used only
# to scale ground-truth outputs; the scheduler never reads this.
_T_BASE: Dict[Category, float] = {
    Category.SHORT_QA: 64.0,
    Category.SUMMARY: 288.0,
    Category.TECHNICAL: 416.0,
    Category.REPORT: 600.0,
}


def generation_curve(category: Category) -> Tuple[float, float, float]:
    """Public view of the hidden generation behaviour for one category:
    ``(base, ref_prompt_len, len_exp)`` with ``base = T_base *
    mean_ratio``, so the expected ground-truth output of a prompt of
    length P is ``base * verbosity * (max(P,1)/ref)**len_exp`` before
    sampling noise. Used by the batched array trace generator
    (``workload.generator.VectorPlan``) to reproduce
    :meth:`PromptSpec.sample_output` marginals without per-request
    objects."""
    prof = _GENERATION_PROFILE[category]
    return (_T_BASE[category] * prof["mean_ratio"],
            _REF_PROMPT_LEN[category], prof["len_exp"])


def _stable_unit(s: str) -> float:
    """Deterministic uniform(0,1) from a string (prompt-latent seed)."""
    h = hashlib.sha256(s.encode()).digest()
    return int.from_bytes(h[:8], "big") / float(1 << 64)


@dataclass(frozen=True)
class PromptSpec:
    """One corpus entry: text + hidden generation behaviour."""

    category: Category
    text: str
    prompt_tokens: int
    latent_verbosity: float  # multiplicative, lognormal around 1.0

    def sample_output(self, rng: random.Random, noise_sigma: float = 0.15,
                      max_tokens: int = 1024) -> int:
        """Draw the ground-truth generated length for one request."""
        prof = _GENERATION_PROFILE[self.category]
        base = _T_BASE[self.category] * prof["mean_ratio"]
        len_scale = (max(self.prompt_tokens, 1) / _REF_PROMPT_LEN[self.category]) ** prof["len_exp"]
        noise = math.exp(rng.gauss(0.0, noise_sigma) - 0.5 * noise_sigma ** 2)
        out = base * self.latent_verbosity * len_scale * noise
        return max(1, min(int(round(out)), max_tokens))


class Corpus:
    """Immutable prompt corpus with per-category views."""

    def __init__(self, prompts: Sequence[PromptSpec]):
        self.prompts: List[PromptSpec] = list(prompts)
        self.by_category: Dict[Category, List[PromptSpec]] = {c: [] for c in Category}
        for p in self.prompts:
            self.by_category[p.category].append(p)

    def __len__(self) -> int:
        return len(self.prompts)

    def sample(self, category: Category, rng: random.Random) -> PromptSpec:
        return rng.choice(self.by_category[category])


def build_corpus(target_size: int = 1180, pad_variants: int = 4) -> Corpus:
    """Build the ~1180-unique-prompt corpus (Sec. IV-C).

    60 topics x (5+5+5+4)=19 templates = 1140 base prompts; ``pad_variants``
    rephrased short_qa variants top it up to the target. Prompts are
    unique by construction; latent verbosity is a deterministic lognormal
    draw keyed on the prompt text, so the corpus is fully reproducible.
    """
    prompts: List[PromptSpec] = []
    seen = set()

    def add(category: Category, text: str) -> None:
        if text in seen:
            return
        seen.add(text)
        prof = _GENERATION_PROFILE[category]
        u = _stable_unit(text)
        # inverse-CDF lognormal via gauss on a second stable draw
        z = _stable_unit(text + "#z") * 2.0 - 1.0
        # Box-Muller-ish deterministic normal from two stable uniforms
        u1 = max(_stable_unit(text + "#u1"), 1e-12)
        u2 = _stable_unit(text + "#u2")
        g = math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2)
        verbosity = math.exp(prof["sigma"] * g - 0.5 * prof["sigma"] ** 2)
        prompts.append(PromptSpec(
            category=category,
            text=text,
            prompt_tokens=len(text.split()),
            latent_verbosity=verbosity,
        ))

    for topic in _TOPICS:
        for cat, templates in _TEMPLATES.items():
            for tpl in templates:
                add(cat, tpl.format(t=topic))

    # Pad with extra short_qa phrasings to reach the target corpus size.
    extra_templates = (
        "Give a one-line answer: what is {t}?",
        "Briefly, why does {t} matter?",
        "Name the main alternative to {t}.",
        "Is {t} still relevant in 2026? Answer briefly.",
    )
    for tpl in extra_templates[:pad_variants]:
        for topic in _TOPICS:
            if len(prompts) >= target_size:
                break
            add(Category.SHORT_QA, tpl.format(t=topic))

    return Corpus(prompts[:target_size])
