"""Workload generation (Sec. II-B): heterogeneous multi-tenant inference
traffic over a ~1180-unique-prompt corpus spanning four semantic
categories, with weighted probabilistic selection and burst arrival
processes capable of saturating the GPU inference queues."""

from .corpus import Corpus, PromptSpec, build_corpus
from .generator import (ArrivalPlan, GeneratorConfig, WorkloadGenerator,
                        cluster_stress_config)

__all__ = [
    "ArrivalPlan", "Corpus", "GeneratorConfig", "PromptSpec",
    "WorkloadGenerator", "build_corpus", "cluster_stress_config",
]
