"""Shared model building blocks (functional JAX, params as dicts).

Conventions:
* params are nested dicts of jnp arrays; per-layer params are stacked on
  a leading L axis and consumed via ``jax.lax.scan``;
* attention weights are stored head-split: wq [d, H, hd], wk/wv
  [d, Hk, hd], wo [H, hd, d] — so tensor-parallel sharding rules can
  target the head axis directly;
* activations flow in ``cfg.dtype`` (bf16); norms/softmax/rope in f32;
* :func:`repro.distributed.sharding.constrain` annotates the TP-critical
  intermediates (no-op off-mesh).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from ..kernels import ops
from .config import ModelConfig


def dt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# layer-stack scan (analysis tooling may force full unroll — see
# repro.xla_scan; production lowering keeps rolled loops)
# ---------------------------------------------------------------------------

from ..xla_scan import scan as scan_layers  # noqa: E402
from ..xla_scan import set_scan_unroll  # noqa: E402,F401  (re-export)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, fan_in: Optional[int] = None, dtype=jnp.bfloat16):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.bfloat16):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def init_norm(key, cfg: ModelConfig, width: Optional[int] = None) -> Dict:
    width = width or cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((width,), dt(cfg)),
                "bias": jnp.zeros((width,), dt(cfg))}
    return {"scale": jnp.zeros((width,), dt(cfg))}


def apply_norm(cfg: ModelConfig, p: Dict, x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


# ---------------------------------------------------------------------------
# rotary / sinusoidal position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., L, H, D] (or [..., H, D] with scalar-per-row positions
    broadcast); positions: int array broadcastable to x.shape[:-2]."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)                       # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., D/2]
    cos = jnp.cos(angles)[..., None, :]                # [..., 1, D/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, d_model: int,
                         offset: int = 0) -> jax.Array:
    pos = jnp.arange(offset, offset + length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10_000.0, dim / d_model)
    pe = jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)
    return pe  # [length, d_model] f32


def sinusoidal_at(pos: jax.Array, d_model: int) -> jax.Array:
    """Sinusoidal row(s) at a traced position. pos scalar or [B]."""
    posf = jnp.asarray(pos, jnp.float32)[..., None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)
    angle = posf / jnp.power(10_000.0, dim / d_model)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


# ---------------------------------------------------------------------------
# attention (self / cross), full-sequence and cached-decode paths
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, width: Optional[int] = None) -> Dict:
    width = width or cfg.d_model
    H, Hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (width, H, hd), fan_in=width, dtype=dt(cfg)),
        "wk": dense_init(k2, (width, Hk, hd), fan_in=width, dtype=dt(cfg)),
        "wv": dense_init(k3, (width, Hk, hd), fan_in=width, dtype=dt(cfg)),
        "wo": dense_init(k4, (H, hd, width), fan_in=H * hd, dtype=dt(cfg)),
    }


def qkv_project(p: Dict, x: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bld,dhk->blhk", x, p["wq"])
    k = jnp.einsum("bld,dhk->blhk", x, p["wk"])
    v = jnp.einsum("bld,dhk->blhk", x, p["wv"])
    q = constrain(q, "batch", None, "model", None)
    k = constrain(k, "batch", None, "model", None)
    v = constrain(v, "batch", None, "model", None)
    return q, k, v


def attention_block(
    cfg: ModelConfig,
    p: Dict,
    x: jax.Array,                       # [B, L, width]
    *,
    positions: Optional[jax.Array] = None,
    causal: bool = True,
    window: Optional[int] = None,
    prefix_len: int = 0,
    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,  # cross-attn
    attn_impl: str = "auto",
) -> jax.Array:
    """Full-sequence attention (training / prefill)."""
    B, L, _ = x.shape
    q, k, v = (None, None, None)
    if kv_override is None:
        q, k, v = qkv_project(p, x)
        if cfg.pos == "rope":
            pos = positions if positions is not None else jnp.arange(L)[None]
            q = apply_rope(q, jnp.broadcast_to(pos, (B, L)), cfg.rope_theta)
            k = apply_rope(k, jnp.broadcast_to(pos, (B, L)), cfg.rope_theta)
    else:
        q = jnp.einsum("bld,dhk->blhk", x, p["wq"])
        q = constrain(q, "batch", None, "model", None)
        k, v = kv_override
        causal = False

    out = ops.attention(q, k, v, causal=causal, window=window,
                        logit_softcap=cfg.logit_softcap, impl=attn_impl,
                        prefix_len=prefix_len if causal else 0)
    out = jnp.einsum("blhk,hkd->bld", out, p["wo"])
    return constrain(out, "batch", None, None)


def cross_kv(cfg: ModelConfig, p: Dict, enc_out: jax.Array):
    """Precompute cross-attention K/V from encoder output (cached once)."""
    k = jnp.einsum("bld,dhk->blhk", enc_out, p["wk"])
    v = jnp.einsum("bld,dhk->blhk", enc_out, p["wv"])
    return k, v


def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8 per-(.., head) quantisation over the head_dim."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1), 1e-8) / 127.0
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_kv(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale[..., None]


def attention_decode(
    cfg: ModelConfig,
    p: Dict,
    x: jax.Array,                       # [B, width] one token per seq
    k_cache: jax.Array,                 # [B, S, Hk, hd]
    v_cache: jax.Array,
    pos: jax.Array,                     # [] int32: current absolute position
    cache_len: jax.Array,               # [B] valid entries AFTER this write
    *,
    window: Optional[int] = None,
    cross: bool = False,
    kv_scales: Optional[Tuple[jax.Array, jax.Array]] = None,  # int8 cache
) -> Tuple[jax.Array, jax.Array, jax.Array, Optional[Tuple]]:
    """Cached single-token decode. Writes the new K/V at the ring slot
    (pos % S for windowed caches, else pos), then attends over the valid
    cache. ``pos`` may be a scalar (lockstep batch: dry-run serve_step)
    or per-sequence [B] (continuous batching: slots at different depths).
    ``kv_scales`` enables the int8-quantised cache path (the new entry
    is quantised on write; the cache is dequantised for attention — on
    TPU the paged kernel fuses the dequant in VMEM).
    Returns (out [B, width], k_cache, v_cache, kv_scales)."""
    B = x.shape[0]
    S = k_cache.shape[1]
    q = jnp.einsum("bd,dhk->bhk", x, p["wq"])
    if not cross:
        k_new = jnp.einsum("bd,dhk->bhk", x, p["wk"])
        v_new = jnp.einsum("bd,dhk->bhk", x, p["wv"])
        if cfg.pos == "rope":
            posb = jnp.broadcast_to(pos, (B,))
            q = apply_rope(q, posb, cfg.rope_theta)
            k_new = apply_rope(k_new, posb, cfg.rope_theta)
        if kv_scales is not None:
            k_new, ks_new = quantize_kv(k_new)
            v_new, vs_new = quantize_kv(v_new)
        slot = pos % S                                  # ring when S < max_len
        if getattr(slot, "ndim", 0) == 0:
            k_cache = k_cache.at[:, slot].set(k_new.astype(k_cache.dtype))
            v_cache = v_cache.at[:, slot].set(v_new.astype(v_cache.dtype))
            if kv_scales is not None:
                kv_scales = (kv_scales[0].at[:, slot].set(ks_new),
                             kv_scales[1].at[:, slot].set(vs_new))
        else:                                           # per-slot positions
            idx = jnp.arange(B)
            k_cache = k_cache.at[idx, slot].set(k_new.astype(k_cache.dtype))
            v_cache = v_cache.at[idx, slot].set(v_new.astype(v_cache.dtype))
            if kv_scales is not None:
                kv_scales = (kv_scales[0].at[idx, slot].set(ks_new),
                             kv_scales[1].at[idx, slot].set(vs_new))
    else:
        if cfg.pos == "rope":
            q = apply_rope(q, jnp.broadcast_to(pos, (B,)), cfg.rope_theta)

    if kv_scales is not None and not cross:
        k_attn = dequantize_kv(k_cache, kv_scales[0]).astype(q.dtype)
        v_attn = dequantize_kv(v_cache, kv_scales[1]).astype(q.dtype)
    else:
        k_attn, v_attn = k_cache, v_cache
    out = ops.decode_attention(
        q, k_attn, v_attn, cache_len,
        logit_softcap=cfg.logit_softcap,
        # ring caches are position-complete: every valid slot is within
        # the window by construction, so no extra window mask is needed.
        window=None,
    )
    out = jnp.einsum("bhk,hkd->bd", out, p["wo"])
    return out, k_cache, v_cache, kv_scales


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, width: Optional[int] = None) -> Dict:
    width = width or cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w1": dense_init(k1, (width, cfg.d_ff), dtype=dt(cfg)),
        "w2": dense_init(k2, (cfg.d_ff, width), dtype=dt(cfg)),
    }
    if cfg.act in ("swiglu", "geglu"):
        p["w3"] = dense_init(k3, (width, cfg.d_ff), dtype=dt(cfg))
    return p


def mlp_block(cfg: ModelConfig, p: Dict, x: jax.Array) -> jax.Array:
    h = x @ p["w1"]
    h = constrain(h, "batch", None, "model") if h.ndim == 3 else h
    if cfg.act == "swiglu":
        h = jax.nn.silu(h) * (x @ p["w3"])
    elif cfg.act == "geglu":
        h = jax.nn.gelu(h) * (x @ p["w3"])
    else:
        h = jax.nn.gelu(h)
    out = h @ p["w2"]
    return constrain(out, "batch", None, None) if out.ndim == 3 else out


# ---------------------------------------------------------------------------
# Mixture-of-Experts (grouped capacity dispatch, mesh-tf style)
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig) -> Dict:
    E, d, ff = cfg.n_experts, cfg.d_model, cfg.d_ff
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "router": dense_init(k1, (d, E), dtype=jnp.float32),
        "w1": dense_init(k2, (E, d, ff), fan_in=d, dtype=dt(cfg)),
        "w2": dense_init(k3, (E, ff, d), fan_in=ff, dtype=dt(cfg)),
    }
    if cfg.act in ("swiglu", "geglu"):
        p["w3"] = dense_init(k4, (E, d, ff), fan_in=d, dtype=dt(cfg))
    return p


def moe_block(cfg: ModelConfig, p: Dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Top-k routed experts with capacity-bounded grouped dispatch.

    Returns (out, aux_loss). FLOPs are capacity-bounded (= active-expert
    compute x capacity factor), *not* n_experts-dense — the einsum
    dispatch keeps sharding predictable: group axis on data, expert axis
    on model (EP), which lowers to an all-to-all pair on the mesh.
    """
    B, L, d = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    T = B * L
    g_size = min(cfg.moe_group_size, T)
    # pad tokens to a multiple of the group size
    n_groups = -(-T // g_size)
    pad = n_groups * g_size - T
    xt = x.reshape(T, d)
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    xg = xt.reshape(n_groups, g_size, d)
    xg = constrain(xg, "batch", None, None)

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)            # [G, T, E]

    # top-k selection
    top_p, top_e = jax.lax.top_k(probs, K)             # [G, T, K]
    top_p = top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)

    capacity = int(math.ceil(g_size * K / E * cfg.moe_capacity_factor))
    capacity = max(capacity, 4)

    # position of each (token, k) within its expert, via cumsum of one-hots
    onehot = jax.nn.one_hot(top_e, E, dtype=jnp.float32)      # [G, T, K, E]
    flat = onehot.reshape(n_groups, g_size * K, E)
    pos = jnp.cumsum(flat, axis=1) - flat                      # positions
    pos = pos.reshape(n_groups, g_size, K, E)
    in_cap = (pos < capacity)
    pos_sel = (pos * onehot).sum(-1)                           # [G, T, K]
    keep = (onehot * in_cap).sum(-1)                           # [G, T, K] 0/1

    # dispatch/combine tensors [G, T, E, C]
    cap_oh = jax.nn.one_hot(pos_sel, capacity, dtype=jnp.float32)  # [G,T,K,C]
    disp = jnp.einsum("gtke,gtkc,gtk->gtec", onehot, cap_oh, keep)
    comb = jnp.einsum("gtke,gtkc,gtk->gtec", onehot, cap_oh, keep * top_p)

    xe = jnp.einsum("gtec,gtd->gecd", disp.astype(dt(cfg)), xg)  # [G,E,C,d]
    xe = constrain(xe, "batch", "expert", None, None)
    h = jnp.einsum("gecd,edf->gecf", xe, p["w1"])
    if cfg.act in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.act == "swiglu" else jax.nn.gelu
        h = act(h) * jnp.einsum("gecd,edf->gecf", xe, p["w3"])
    else:
        h = jax.nn.gelu(h)
    ye = jnp.einsum("gecf,efd->gecd", h, p["w2"])
    ye = constrain(ye, "batch", "expert", None, None)
    out = jnp.einsum("gtec,gecd->gtd", comb.astype(dt(cfg)), ye)

    out = out.reshape(n_groups * g_size, d)[:T].reshape(B, L, d)

    # Switch-style load-balance aux loss
    density = onehot.sum(2).mean(axis=1)               # fraction routed [G, E]
    router_prob = probs.mean(axis=1)                   # [G, E]
    aux = (density * router_prob).sum(-1).mean() * E
    return constrain(out, "batch", None, None), aux.astype(jnp.float32)


def moe_block_decode(cfg: ModelConfig, p: Dict, x: jax.Array) -> jax.Array:
    """Decode-path MoE for [B, d] single tokens: single group, generous
    capacity (small-batch imbalance)."""
    out, _ = moe_block(cfg.replace(
        moe_group_size=x.shape[0], moe_capacity_factor=2.0
    ), p, x[:, None, :])
    return out[:, 0, :]


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def init_embed(key, cfg: ModelConfig) -> Dict:
    return {"table": embed_init(key, (cfg.vocab, cfg.d_model), dt(cfg))}


def embed(cfg: ModelConfig, p: Dict, tokens: jax.Array) -> jax.Array:
    x = p["table"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), dt(cfg))
    return x


def unembed(cfg: ModelConfig, p: Dict, x: jax.Array) -> jax.Array:
    logits = jnp.einsum("...d,vd->...v", x, p["table"])
    if cfg.logit_softcap is not None:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits
