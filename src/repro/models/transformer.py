"""Decoder-only transformer covering the dense, moe, and vlm families.

* params-as-scan-xs: per-layer params are stacked [L, ...] and consumed
  by ``lax.scan`` — with ZeRO/FSDP-sharded weights XLA then all-gathers
  one layer at a time inside the loop (the FSDP pattern), and compile
  time is O(1) in depth;
* remat: the layer body is wrapped in ``jax.checkpoint`` for training;
* activations between layers are sharding-constrained to
  (batch, seq, model) — Megatron-style activation partitioning that
  keeps the scan carry 1/TP of its replicated size;
* vlm (PaliGemma): a stub patch-embedding prefix is concatenated before
  the token embeddings and attended with prefix-LM masking.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from . import layers as nn
from .config import ModelConfig


def init(cfg: ModelConfig, key: jax.Array) -> Dict:
    k_embed, k_layers, k_final, k_head = jax.random.split(key, 4)

    def init_layer(k):
        ka, km, k1, k2 = jax.random.split(k, 4)
        p = {
            "ln1": nn.init_norm(k1, cfg),
            "attn": nn.init_attention(ka, cfg),
            "ln2": nn.init_norm(k2, cfg),
        }
        if cfg.family == "moe":
            p["moe"] = nn.init_moe(km, cfg)
        else:
            p["mlp"] = nn.init_mlp(km, cfg)
        return p

    params = {
        "embed": nn.init_embed(k_embed, cfg),
        "layers": jax.vmap(init_layer)(jax.random.split(k_layers, cfg.n_layers)),
        "final_norm": nn.init_norm(k_final, cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"table": nn.embed_init(k_head, (cfg.vocab, cfg.d_model), nn.dt(cfg))}
    return params


def _layer_fwd(cfg: ModelConfig, lp: Dict, h: jax.Array, *,
               prefix_len: int, attn_impl: str) -> Tuple[jax.Array, jax.Array]:
    """One decoder layer, full-sequence. Returns (h, aux_loss)."""
    h = constrain(h, "batch", None, "residual")
    attn_in = nn.apply_norm(cfg, lp["ln1"], h)
    h = h + nn.attention_block(
        cfg, lp["attn"], attn_in,
        causal=True, window=cfg.sliding_window,
        prefix_len=prefix_len, attn_impl=attn_impl,
    )
    mlp_in = nn.apply_norm(cfg, lp["ln2"], h)
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "moe":
        out, aux = nn.moe_block(cfg, lp["moe"], mlp_in)
        h = h + out
    else:
        h = h + nn.mlp_block(cfg, lp["mlp"], mlp_in)
    h = constrain(h, "batch", None, "residual")
    return h, aux


def _embed_inputs(cfg: ModelConfig, params: Dict, tokens: jax.Array,
                  patches: Optional[jax.Array]) -> jax.Array:
    x = nn.embed(cfg, params["embed"], tokens)
    if cfg.family == "vlm":
        assert patches is not None, "vlm requires stub patch embeddings"
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
    return x


def forward(cfg: ModelConfig, params: Dict, tokens: jax.Array, *,
            patches: Optional[jax.Array] = None,
            remat: bool = False, attn_impl: str = "auto",
            ) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits [B, L, V], aux_loss)."""
    x = _embed_inputs(cfg, params, tokens, patches)
    x = constrain(x, "batch", None, "residual")
    prefix = cfg.prefix_len if cfg.family == "vlm" else 0

    body = functools.partial(_layer_fwd, cfg, prefix_len=prefix,
                             attn_impl=attn_impl)

    def scan_body(h, lp):
        h2, aux = body(lp, h)
        return h2, aux

    if remat:
        scan_body = jax.checkpoint(
            scan_body, policy=jax.checkpoint_policies.nothing_saveable
        )
    x, auxs = nn.scan_layers(scan_body, x, params["layers"])
    x = nn.apply_norm(cfg, params["final_norm"], x)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = nn.unembed(cfg, head, x)
    return logits, auxs.sum()


# ---------------------------------------------------------------------------
# KV-cache serving paths
# ---------------------------------------------------------------------------

def cache_size(cfg: ModelConfig, max_len: int) -> int:
    """Sliding-window archs keep a ring buffer of the window only."""
    if cfg.sliding_window is not None:
        return min(max_len, cfg.sliding_window)
    return max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> Dict:
    S = cache_size(cfg, max_len)
    dtype = dtype or (jnp.int8 if cfg.kv_dtype == "int8" else nn.dt(cfg))
    shape = (cfg.n_layers, batch, S, cfg.n_kv_heads, cfg.d_head)
    cache = {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "lens": jnp.zeros((batch,), jnp.int32),
    }
    if dtype == jnp.int8:
        # symmetric per-(position, head) scales; 1/(2*hd) size overhead
        cache["k_scale"] = jnp.zeros(shape[:-1], jnp.float32)
        cache["v_scale"] = jnp.zeros(shape[:-1], jnp.float32)
    return cache


def prefill(cfg: ModelConfig, params: Dict, tokens: jax.Array, *,
            patches: Optional[jax.Array] = None,
            max_len: Optional[int] = None,
            attn_impl: str = "auto") -> Tuple[jax.Array, Dict]:
    """Process the full prompt, return (last-position logits, filled cache)."""
    B, Lt = tokens.shape
    x = _embed_inputs(cfg, params, tokens, patches)
    L = x.shape[1]
    S = cache_size(cfg, max_len or L)
    prefix = cfg.prefix_len if cfg.family == "vlm" else 0

    def scan_body(h, lp):
        h = constrain(h, "batch", None, "residual")
        attn_in = nn.apply_norm(cfg, lp["ln1"], h)
        q, k, v = nn.qkv_project(lp["attn"], attn_in)
        if cfg.pos == "rope":
            pos = jnp.arange(L)[None]
            q = nn.apply_rope(q, jnp.broadcast_to(pos, (B, L)), cfg.rope_theta)
            k = nn.apply_rope(k, jnp.broadcast_to(pos, (B, L)), cfg.rope_theta)
        from ..kernels import ops
        attn = ops.attention(q, k, v, causal=True, window=cfg.sliding_window,
                             logit_softcap=cfg.logit_softcap, impl=attn_impl,
                             prefix_len=prefix)
        attn = jnp.einsum("blhk,hkd->bld", attn, lp["attn"]["wo"])
        h = h + attn
        mlp_in = nn.apply_norm(cfg, lp["ln2"], h)
        if cfg.family == "moe":
            out, _ = nn.moe_block(cfg, lp["moe"], mlp_in)
            h = h + out
        else:
            h = h + nn.mlp_block(cfg, lp["mlp"], mlp_in)
        # cache the trailing S positions (ring-aligned: position p sits at
        # slot p % S once the window has wrapped; for p >= L - S that is
        # the same contiguous tail order when S divides L or L <= S).
        k_keep = k[:, -S:].astype(nn.dt(cfg))
        v_keep = v[:, -S:].astype(nn.dt(cfg))
        if cfg.sliding_window is not None and L > S:
            # roll so that slot i holds absolute position (L - S + i)
            # consistent with decode's pos % S ring indexing
            shift = L % S
            k_keep = jnp.roll(k_keep, shift, axis=1)
            v_keep = jnp.roll(v_keep, shift, axis=1)
        return h, (k_keep, v_keep)

    h, (ks, vs) = nn.scan_layers(scan_body, x, params["layers"])
    h = nn.apply_norm(cfg, params["final_norm"], h[:, -1])
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = nn.unembed(cfg, head, h)

    if L < S:
        pad = S - L
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"lens": jnp.full((B,), min(L, S), jnp.int32)}
    if cfg.kv_dtype == "int8":
        cache["k"], cache["k_scale"] = nn.quantize_kv(ks)
        cache["v"], cache["v_scale"] = nn.quantize_kv(vs)
    else:
        cache["k"], cache["v"] = ks, vs
    return logits, cache


def decode_step_paged(cfg: ModelConfig, params: Dict, pool: Dict,
                      tokens: jax.Array,        # [B] int32 current token
                      page_table: jax.Array,    # [B, pages_per_seq] int32
                      seq_lens: jax.Array,      # [B] tokens BEFORE this step
                      *,
                      max_pages: Optional[int] = None,
                      ) -> Tuple[jax.Array, Dict]:
    """One decode iteration against the vLLM-style paged KV pool
    (serving/kv_cache.py). The whole decode set goes through one batched
    paged-attention call per layer: the new token's K/V rides along as a
    fused kernel operand (so attention never reads a page aliased with a
    same-step scatter) and is scattered into the page owning slot
    ``seq_lens[b]`` only for the pool carry. ``max_pages`` statically
    trims the kernel's page grid to the deepest live sequence.

    pool: {"k": [L, n_pages, page, Hk, hd], "v": ...}.
    Returns (logits, new_pool)."""
    from ..kernels import ops
    B = tokens.shape[0]
    page_size = pool["k"].shape[2]
    x = nn.embed(cfg, params["embed"], tokens)        # [B, d]
    page_idx = seq_lens // page_size
    offset = seq_lens % page_size
    phys = jnp.take_along_axis(page_table, page_idx[:, None], axis=1)[:, 0]

    def scan_body(h, xs):
        lp, kp, vp = xs                                # [n_pages, page, Hk, hd]
        h = constrain(h, "batch", "model")
        attn_in = nn.apply_norm(cfg, lp["ln1"], h)
        q = jnp.einsum("bd,dhk->bhk", attn_in, lp["attn"]["wq"])
        k_new = jnp.einsum("bd,dhk->bhk", attn_in, lp["attn"]["wk"])
        v_new = jnp.einsum("bd,dhk->bhk", attn_in, lp["attn"]["wv"])
        if cfg.pos == "rope":
            q = nn.apply_rope(q, seq_lens, cfg.rope_theta)
            k_new = nn.apply_rope(k_new, seq_lens, cfg.rope_theta)
        attn = ops.batched_paged_decode_attention(
            q, kp, vp, page_table, seq_lens, k_new, v_new,
            max_pages=max_pages, logit_softcap=cfg.logit_softcap)
        kp = kp.at[phys, offset].set(k_new.astype(kp.dtype))
        vp = vp.at[phys, offset].set(v_new.astype(vp.dtype))
        h = h + jnp.einsum("bhk,hkd->bd", attn, lp["attn"]["wo"])
        mlp_in = nn.apply_norm(cfg, lp["ln2"], h)
        if cfg.family == "moe":
            h = h + nn.moe_block_decode(cfg, lp["moe"], mlp_in)
        else:
            h = h + nn.mlp_block(cfg, lp["mlp"], mlp_in)
        return h, (kp, vp)

    h, (ks, vs) = nn.scan_layers(
        scan_body, x, (params["layers"], pool["k"], pool["v"]))
    h = nn.apply_norm(cfg, params["final_norm"], h)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = nn.unembed(cfg, head, h)
    return logits, {"k": ks, "v": vs}


def prefill_chunk_paged(cfg: ModelConfig, params: Dict, pool: Dict,
                        tokens: jax.Array,       # [B, C] chunk token ids
                        page_table: jax.Array,   # [B, pages_per_seq] int32
                        q_offset: jax.Array,     # [B] int32 abs pos of col 0
                        ) -> Tuple[jax.Array, Dict]:
    """One prefill chunk against the paged pool, via the fused
    chunked-prefill kernel. Per layer: project the slab's Q/K/V at
    absolute positions ``[q_offset, q_offset + C)``, scatter K/V into the
    pages owning those slots, then attend the slab against *everything
    resident* — prefix-tree pages and the chunks scattered by earlier
    calls — with query-offset causal masking. Resuming from a cached
    prefix is just starting at ``q_offset > 0``.

    pool: {"k": [L, n_pages, page, Hk, hd], "v": ...}.
    Returns (last-position logits [B, V], new_pool)."""
    from ..kernels import ops
    B, C = tokens.shape
    page_size = pool["k"].shape[2]
    x = nn.embed(cfg, params["embed"], tokens)           # [B, C, d]
    positions = q_offset[:, None] + jnp.arange(C)[None]  # [B, C]
    phys = jnp.take_along_axis(page_table, positions // page_size, axis=1)
    offset = positions % page_size
    kv_lens = q_offset + C

    def scan_body(h, xs):
        lp, kp, vp = xs                                # [n_pages, page, Hk, hd]
        h = constrain(h, "batch", None, "residual")
        attn_in = nn.apply_norm(cfg, lp["ln1"], h)
        q, k, v = nn.qkv_project(lp["attn"], attn_in)  # [B, C, H/Hk, hd]
        if cfg.pos == "rope":
            q = nn.apply_rope(q, positions, cfg.rope_theta)
            k = nn.apply_rope(k, positions, cfg.rope_theta)
        kp = kp.at[phys, offset].set(k.astype(kp.dtype))
        vp = vp.at[phys, offset].set(v.astype(vp.dtype))
        attn = ops.chunked_prefill_attention(
            q, kp, vp, page_table, q_offset, kv_lens,
            logit_softcap=cfg.logit_softcap)
        h = h + jnp.einsum("blhk,hkd->bld", attn, lp["attn"]["wo"])
        mlp_in = nn.apply_norm(cfg, lp["ln2"], h)
        if cfg.family == "moe":
            out, _ = nn.moe_block(cfg, lp["moe"], mlp_in)
            h = h + out
        else:
            h = h + nn.mlp_block(cfg, lp["mlp"], mlp_in)
        return h, (kp, vp)

    h, (ks, vs) = nn.scan_layers(
        scan_body, x, (params["layers"], pool["k"], pool["v"]))
    h = nn.apply_norm(cfg, params["final_norm"], h[:, -1])
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = nn.unembed(cfg, head, h)
    return logits, {"k": ks, "v": vs}


def prefill_kv(cfg: ModelConfig, params: Dict, tokens: jax.Array, *,
               patches: Optional[jax.Array] = None,
               attn_impl: str = "auto"
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Prefill returning raw per-layer K/V [L, B, S, Hk, hd] (for
    scattering into the paged pool) plus last-position logits."""
    logits, cache = prefill(cfg, params, tokens, patches=patches,
                            max_len=tokens.shape[1], attn_impl=attn_impl)
    return logits, cache["k"], cache["v"]


def decode_step(cfg: ModelConfig, params: Dict, cache: Dict,
                tokens: jax.Array,            # [B] int32 current token
                pos: jax.Array,               # [] int32 absolute position
                ) -> Tuple[jax.Array, Dict]:
    """One decode iteration for the whole batch. Returns (logits, cache)."""
    B = tokens.shape[0]
    x = nn.embed(cfg, params["embed"], tokens)    # [B, d]
    S = cache["k"].shape[2]
    new_lens = jnp.minimum(cache["lens"] + 1, S)
    quant = "k_scale" in cache                    # int8 KV cache path

    def scan_body(h, xs):
        if quant:
            lp, kc, vc, ksc, vsc = xs
            scales = (ksc, vsc)
        else:
            lp, kc, vc = xs
            scales = None
        h = constrain(h, "batch", "model")
        attn_in = nn.apply_norm(cfg, lp["ln1"], h)
        attn, kc, vc, scales = nn.attention_decode(
            cfg, lp["attn"], attn_in, kc, vc, pos, new_lens,
            window=cfg.sliding_window, kv_scales=scales,
        )
        h = h + attn
        mlp_in = nn.apply_norm(cfg, lp["ln2"], h)
        if cfg.family == "moe":
            h = h + nn.moe_block_decode(cfg, lp["moe"], mlp_in)
        else:
            h = h + nn.mlp_block(cfg, lp["mlp"], mlp_in)
        out = (kc, vc) + (scales if quant else ())
        return h, out

    xs = (params["layers"], cache["k"], cache["v"])
    if quant:
        xs = xs + (cache["k_scale"], cache["v_scale"])
    h, saved = nn.scan_layers(scan_body, x, xs)
    h = nn.apply_norm(cfg, params["final_norm"], h)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = nn.unembed(cfg, head, h)
    new_cache = {"k": saved[0], "v": saved[1], "lens": new_lens}
    if quant:
        new_cache["k_scale"], new_cache["v_scale"] = saved[2], saved[3]
    return logits, new_cache
