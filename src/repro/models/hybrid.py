"""Zamba2-style hybrid: Mamba-2 backbone + one *shared* attention block.

The shared block (a single parameter set) is applied every
``cfg.attn_every`` Mamba layers. Its input is ``concat(h, emb0)`` — the
current hidden state concatenated with the original token embedding —
so it operates at width 2*d_model (zamba2-1.2b: 4096 = 32 heads x 128),
and its output is down-projected back to d_model and added residually.
(Zamba2's per-application LoRA deltas on the shared block are omitted —
DESIGN.md §2.)

Long-context deployments run the shared attention with a sliding window
(cfg.sliding_window), giving the hybrid a bounded decode state:
per-layer SSM states + ring KV caches for the handful of shared-block
applications.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from ..kernels import ops, ref
from . import layers as nn
from . import mamba2
from .config import ModelConfig


def _app_positions(cfg: ModelConfig) -> List[int]:
    return list(range(0, cfg.n_layers, cfg.attn_every))


def _segments(cfg: ModelConfig) -> List[Tuple[int, int]]:
    """[(start, end)) mamba-layer slices, one per shared-block application."""
    apps = _app_positions(cfg)
    bounds = apps + [cfg.n_layers]
    return [(bounds[i], bounds[i + 1]) for i in range(len(apps))]


def init(cfg: ModelConfig, key: jax.Array) -> Dict:
    k_embed, k_layers, k_shared, k_final, k_head, k_down = jax.random.split(key, 6)
    w = 2 * cfg.d_model
    ka, km, k1, k2 = jax.random.split(k_shared, 4)
    params = {
        "embed": nn.init_embed(k_embed, cfg),
        "layers": jax.vmap(functools.partial(mamba2.init_layer, cfg))(
            jax.random.split(k_layers, cfg.n_layers)),
        "shared": {
            "ln1": nn.init_norm(k1, cfg, width=w),
            "attn": nn.init_attention(ka, cfg, width=w),
            "ln2": nn.init_norm(k2, cfg, width=w),
            "mlp": nn.init_mlp(km, cfg, width=w),
            "down": nn.dense_init(k_down, (w, cfg.d_model), dtype=nn.dt(cfg)),
        },
        "final_norm": nn.init_norm(k_final, cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"table": nn.embed_init(
            k_head, (cfg.vocab, cfg.d_model), nn.dt(cfg))}
    return params


def _shared_apply(cfg: ModelConfig, sp: Dict, h: jax.Array, emb0: jax.Array,
                  *, attn_impl: str = "auto") -> jax.Array:
    """Full-sequence shared-block application."""
    u = jnp.concatenate([h, emb0], axis=-1)
    v = u + nn.attention_block(
        cfg, sp["attn"], nn.apply_norm(cfg, sp["ln1"], u),
        causal=True, window=cfg.sliding_window, attn_impl=attn_impl,
    )
    v = v + nn.mlp_block(cfg, sp["mlp"], nn.apply_norm(cfg, sp["ln2"], v))
    return h + constrain(v @ sp["down"], "batch", None, None)


def _slice_layers(params_layers, s: int, e: int):
    return jax.tree_util.tree_map(lambda a: a[s:e], params_layers)


def forward(cfg: ModelConfig, params: Dict, tokens: jax.Array, *,
            remat: bool = False, attn_impl: str = "auto",
            ) -> Tuple[jax.Array, jax.Array]:
    emb0 = nn.embed(cfg, params["embed"], tokens)
    h = constrain(emb0, "batch", None, None)

    def scan_body(carry, lp):
        return mamba2.layer_fwd(cfg, lp, carry, attn_impl=attn_impl), None

    body = scan_body
    if remat:
        body = jax.checkpoint(
            scan_body, policy=jax.checkpoint_policies.nothing_saveable
        )
    for (s, e) in _segments(cfg):
        h = _shared_apply(cfg, params["shared"], h, emb0, attn_impl=attn_impl)
        h, _ = nn.scan_layers(body, h, _slice_layers(params["layers"], s, e))

    h = nn.apply_norm(cfg, params["final_norm"], h)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return nn.unembed(cfg, head, h), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# serving paths
# ---------------------------------------------------------------------------

def _attn_cache_size(cfg: ModelConfig, max_len: int) -> int:
    if cfg.sliding_window is not None:
        return min(max_len, cfg.sliding_window)
    return max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> Dict:
    dtype = dtype or nn.dt(cfg)
    n_apps = len(_app_positions(cfg))
    S = _attn_cache_size(cfg, max_len)
    L, H, P, N = cfg.n_layers, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    return {
        "conv": jnp.zeros((L, batch, cfg.ssm_conv - 1, cfg.conv_dim), dtype),
        "ssm": jnp.zeros((L, batch, H, P, N), jnp.float32),
        "attn_k": jnp.zeros((n_apps, batch, S, cfg.n_kv_heads, cfg.d_head), dtype),
        "attn_v": jnp.zeros((n_apps, batch, S, cfg.n_kv_heads, cfg.d_head), dtype),
        "lens": jnp.zeros((batch,), jnp.int32),
    }


def prefill(cfg: ModelConfig, params: Dict, tokens: jax.Array, *,
            max_len: Optional[int] = None, attn_impl: str = "auto",
            ) -> Tuple[jax.Array, Dict]:
    B, L = tokens.shape
    S = _attn_cache_size(cfg, max_len or L)
    emb0 = nn.embed(cfg, params["embed"], tokens)
    h = emb0
    sp = params["shared"]

    attn_ks, attn_vs, conv_list, ssm_list = [], [], [], []

    def seg_scan(carry, lp):
        h2, states = mamba2._layer_prefill(cfg, lp, carry)
        return h2, states

    for (s, e) in _segments(cfg):
        # shared block with KV capture
        u = jnp.concatenate([h, emb0], axis=-1)
        attn_in = nn.apply_norm(cfg, sp["ln1"], u)
        q, k, v = nn.qkv_project(sp["attn"], attn_in)
        if cfg.pos == "rope":
            pos = jnp.arange(L)[None]
            q = nn.apply_rope(q, jnp.broadcast_to(pos, (B, L)), cfg.rope_theta)
            k = nn.apply_rope(k, jnp.broadcast_to(pos, (B, L)), cfg.rope_theta)
        attn = ops.attention(q, k, v, causal=True, window=cfg.sliding_window,
                             logit_softcap=cfg.logit_softcap, impl=attn_impl)
        attn = jnp.einsum("blhk,hkd->bld", attn, sp["attn"]["wo"])
        vv = u + attn
        vv = vv + nn.mlp_block(cfg, sp["mlp"], nn.apply_norm(cfg, sp["ln2"], vv))
        h = h + vv @ sp["down"]

        k_keep = k[:, -S:].astype(nn.dt(cfg))
        v_keep = v[:, -S:].astype(nn.dt(cfg))
        if cfg.sliding_window is not None and L > S:
            shift = L % S
            k_keep = jnp.roll(k_keep, shift, axis=1)
            v_keep = jnp.roll(v_keep, shift, axis=1)
        if L < S:
            pad = S - L
            k_keep = jnp.pad(k_keep, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v_keep = jnp.pad(v_keep, ((0, 0), (0, pad), (0, 0), (0, 0)))
        attn_ks.append(k_keep)
        attn_vs.append(v_keep)

        h, (conv_s, ssm_s) = nn.scan_layers(
            seg_scan, h, _slice_layers(params["layers"], s, e))
        conv_list.append(conv_s)
        ssm_list.append(ssm_s)

    hl = nn.apply_norm(cfg, params["final_norm"], h[:, -1])
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = nn.unembed(cfg, head, hl)
    cache = {
        "conv": jnp.concatenate(conv_list, axis=0),
        "ssm": jnp.concatenate(ssm_list, axis=0),
        "attn_k": jnp.stack(attn_ks, axis=0),
        "attn_v": jnp.stack(attn_vs, axis=0),
        "lens": jnp.full((B,), L, jnp.int32),
    }
    return logits, cache


def decode_step(cfg: ModelConfig, params: Dict, cache: Dict,
                tokens: jax.Array, pos: jax.Array) -> Tuple[jax.Array, Dict]:
    B = tokens.shape[0]
    emb0 = nn.embed(cfg, params["embed"], tokens)     # [B, d]
    h = emb0
    sp = params["shared"]
    S = cache["attn_k"].shape[2]
    attn_lens = jnp.minimum(cache["lens"] + 1, S)

    new_k, new_v = cache["attn_k"], cache["attn_v"]
    conv_all, ssm_all = cache["conv"], cache["ssm"]

    def seg_scan(carry, xs):
        lp, conv_st, ssm_st = xs
        h2, states = mamba2.decode_layer(cfg, lp, carry, conv_st, ssm_st)
        return h2, states

    for i, (s, e) in enumerate(_segments(cfg)):
        u = jnp.concatenate([h, emb0], axis=-1)
        attn_in = nn.apply_norm(cfg, sp["ln1"], u)
        attn, kc, vc, _ = nn.attention_decode(
            cfg, sp["attn"], attn_in, new_k[i], new_v[i], pos, attn_lens,
            window=cfg.sliding_window,
        )
        new_k = new_k.at[i].set(kc)
        new_v = new_v.at[i].set(vc)
        vv = u + attn
        vv = vv + nn.mlp_block(cfg, sp["mlp"], nn.apply_norm(cfg, sp["ln2"], vv))
        h = h + vv @ sp["down"]

        seg_layers = _slice_layers(params["layers"], s, e)
        h, (conv_s, ssm_s) = nn.scan_layers(
            seg_scan, h,
            (seg_layers, conv_all[s:e], ssm_all[s:e]),
        )
        conv_all = jax.lax.dynamic_update_slice_in_dim(conv_all, conv_s, s, axis=0)
        ssm_all = jax.lax.dynamic_update_slice_in_dim(ssm_all, ssm_s, s, axis=0)

    h = nn.apply_norm(cfg, params["final_norm"], h)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = nn.unembed(cfg, head, h)
    return logits, {
        "conv": conv_all, "ssm": ssm_all,
        "attn_k": new_k, "attn_v": new_v,
        "lens": cache["lens"] + 1,
    }
