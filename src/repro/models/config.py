"""Unified model configuration covering all ten assigned architectures.

One dataclass, one ``family`` switch: dense | moe | ssm | hybrid |
encdec | vlm. Family-irrelevant fields are ignored by the other
families. Exact per-arch values live in ``repro.configs.<arch>``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None         # default d_model // n_heads

    # --- attention / transformer ---
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None   # SWA (h2o-danube); also the
                                           # long-context fallback for hybrids
    norm: str = "rmsnorm"                  # rmsnorm | layernorm
    act: str = "swiglu"                    # swiglu | geglu | gelu
    pos: str = "rope"                      # rope | sinusoidal | none
    logit_softcap: Optional[float] = None
    embed_scale: bool = False              # gemma-style sqrt(d) embedding scale
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    moe_group_size: int = 1024             # dispatch group (tokens)
    router_aux_coef: float = 0.01

    # --- SSM (Mamba-2 SSD) ---
    ssm_state: int = 0                     # N
    ssm_headdim: int = 64                  # P
    ssm_ngroups: int = 1                   # G
    ssm_chunk: int = 256                   # Q
    ssm_conv: int = 4                      # depthwise conv kernel
    ssm_expand: int = 2                    # d_inner = expand * d_model

    # --- hybrid (zamba2): shared attention block every N ssm layers ---
    attn_every: int = 0

    # --- enc-dec (whisper): encoder depth + stub frontend length ---
    n_enc_layers: int = 0
    enc_seq: int = 0                       # 1500 post-conv audio frames

    # --- vlm (paligemma): stub patch-prefix length, prefix-LM masking ---
    prefix_len: int = 0

    dtype: str = "bfloat16"
    # KV-cache quantisation for serving: "bfloat16" (default) or "int8"
    # (per-entry symmetric scales; halves cache HBM traffic + capacity)
    kv_dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim is None and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def d_head(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def conv_dim(self) -> int:
        # channels that pass through the depthwise conv: x, B, C
        return self.d_inner + 2 * self.ssm_ngroups * self.ssm_state

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode state: SSM, hybrid, or sliding-window."""
        return (
            self.family in ("ssm", "hybrid")
            or self.sliding_window is not None
        )

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # --- parameter count (for 6ND model-FLOPs and memory budgeting) ---
    def param_count(self) -> int:
        d, ff, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd, H, Hk = self.d_head, self.n_heads, self.n_kv_heads

        def attn_params(width: int, heads: int, kv: int, head_dim: int) -> int:
            return width * head_dim * (heads + kv) + width * head_dim * kv + heads * head_dim * width

        def mlp_params(width: int, hidden: int, gated: bool) -> int:
            return width * hidden * (3 if gated else 2)

        gated = self.act in ("swiglu", "geglu")
        n = V * d                                     # embeddings
        if not self.tie_embeddings:
            n += V * d                                # lm_head
        if self.family in ("dense", "vlm"):
            per = attn_params(d, H, Hk, hd) + mlp_params(d, ff, gated) + 2 * d
            n += L * per
        elif self.family == "moe":
            per = attn_params(d, H, Hk, hd) + 2 * d
            per += d * self.n_experts                 # router
            per += self.n_experts * mlp_params(d, ff, gated)
            n += L * per
        elif self.family == "ssm":
            din, G, N, Hs = self.d_inner, self.ssm_ngroups, self.ssm_state, self.ssm_heads
            per = d * (2 * din + 2 * G * N + Hs)      # in_proj
            per += self.ssm_conv * self.conv_dim      # conv
            per += 3 * Hs                             # A_log, D, dt_bias
            per += din                                # gated norm
            per += din * d                            # out_proj
            per += d                                  # pre-norm
            n += L * per
        elif self.family == "hybrid":
            din, G, N, Hs = self.d_inner, self.ssm_ngroups, self.ssm_state, self.ssm_heads
            per = d * (2 * din + 2 * G * N + Hs) + self.ssm_conv * self.conv_dim
            per += 3 * Hs + din + din * d + d
            n += L * per
            # one shared attention block at width 2d + down-projection
            w = 2 * d
            n += attn_params(w, H, Hk, hd) + mlp_params(w, ff, gated) + 2 * w + w * d
        elif self.family == "encdec":
            per_enc = attn_params(d, H, Hk, hd) + mlp_params(d, ff, gated) + 2 * d
            per_dec = 2 * attn_params(d, H, Hk, hd) + mlp_params(d, ff, gated) + 3 * d
            n += self.n_enc_layers * per_enc + L * per_dec
        n += d                                        # final norm
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: selected experts only)."""
        if self.family != "moe":
            return self.param_count()
        gated = self.act in ("swiglu", "geglu")
        dense_experts = self.n_experts * self.d_model * self.d_ff * (3 if gated else 2)
        active_experts = self.experts_per_token * self.d_model * self.d_ff * (3 if gated else 2)
        return self.param_count() - self.n_layers * (dense_experts - active_experts)
