"""Encoder-decoder transformer (whisper-large-v3 backbone).

The audio conv frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings [B, enc_seq, d_model] (the output
the two conv layers would produce). Everything downstream is real:

* encoder — bidirectional self-attention stack over the frames;
* decoder — causal self-attention + cross-attention to the encoder
  output + MLP, with a KV-cached decode path (self-KV ring cache plus a
  static cross-KV computed once at prefill).

Whisper flavour: LayerNorm, GELU, sinusoidal positions, tied embeddings.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from ..kernels import ops
from . import layers as nn
from .config import ModelConfig


def _init_enc_layer(cfg: ModelConfig, key: jax.Array) -> Dict:
    ka, km, k1, k2 = jax.random.split(key, 4)
    return {
        "ln1": nn.init_norm(k1, cfg),
        "attn": nn.init_attention(ka, cfg),
        "ln2": nn.init_norm(k2, cfg),
        "mlp": nn.init_mlp(km, cfg),
    }


def _init_dec_layer(cfg: ModelConfig, key: jax.Array) -> Dict:
    ka, kx, km, k1, k2, k3 = jax.random.split(key, 6)
    return {
        "ln1": nn.init_norm(k1, cfg),
        "self_attn": {"attn": nn.init_attention(ka, cfg)},
        "lnx": nn.init_norm(k3, cfg),
        "cross_attn": {"attn": nn.init_attention(kx, cfg)},
        "ln2": nn.init_norm(k2, cfg),
        "mlp": nn.init_mlp(km, cfg),
    }


def init(cfg: ModelConfig, key: jax.Array) -> Dict:
    k_embed, k_enc, k_dec, k_fe, k_fd = jax.random.split(key, 5)
    params = {
        "embed": nn.init_embed(k_embed, cfg),
        "enc_layers": jax.vmap(functools.partial(_init_enc_layer, cfg))(
            jax.random.split(k_enc, cfg.n_enc_layers)),
        "dec_layers": jax.vmap(functools.partial(_init_dec_layer, cfg))(
            jax.random.split(k_dec, cfg.n_layers)),
        "enc_norm": nn.init_norm(k_fe, cfg),
        "final_norm": nn.init_norm(k_fd, cfg),
    }
    if not cfg.tie_embeddings:
        kh = jax.random.fold_in(k_embed, 1)
        params["lm_head"] = {"table": nn.embed_init(
            kh, (cfg.vocab, cfg.d_model), nn.dt(cfg))}
    return params


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------

def encode(cfg: ModelConfig, params: Dict, frames: jax.Array, *,
           remat: bool = False, attn_impl: str = "auto") -> jax.Array:
    """frames [B, enc_seq, d_model] (stub conv output) -> enc_out."""
    B, Le, _ = frames.shape
    pe = nn.sinusoidal_positions(Le, cfg.d_model)
    x = (frames.astype(jnp.float32) + pe).astype(nn.dt(cfg))
    x = constrain(x, "batch", None, "residual")

    def scan_body(h, lp):
        h = constrain(h, "batch", None, "residual")
        h = h + nn.attention_block(
            cfg, lp["attn"], nn.apply_norm(cfg, lp["ln1"], h),
            causal=False, attn_impl=attn_impl,
        )
        h = h + nn.mlp_block(cfg, lp["mlp"], nn.apply_norm(cfg, lp["ln2"], h))
        return h, None

    if remat:
        scan_body = jax.checkpoint(
            scan_body, policy=jax.checkpoint_policies.nothing_saveable
        )
    x, _ = nn.scan_layers(scan_body, x, params["enc_layers"])
    return nn.apply_norm(cfg, params["enc_norm"], x)


# ---------------------------------------------------------------------------
# decoder — full-sequence (training) path
# ---------------------------------------------------------------------------

def _dec_layer_fwd(cfg: ModelConfig, lp: Dict, h: jax.Array,
                   enc_out: jax.Array, *, attn_impl: str) -> jax.Array:
    h = constrain(h, "batch", None, "residual")
    h = h + nn.attention_block(
        cfg, lp["self_attn"]["attn"], nn.apply_norm(cfg, lp["ln1"], h),
        causal=True, attn_impl=attn_impl,
    )
    kx, vx = nn.cross_kv(cfg, lp["cross_attn"]["attn"], enc_out)
    h = h + nn.attention_block(
        cfg, lp["cross_attn"]["attn"], nn.apply_norm(cfg, lp["lnx"], h),
        kv_override=(kx, vx), attn_impl=attn_impl,
    )
    h = h + nn.mlp_block(cfg, lp["mlp"], nn.apply_norm(cfg, lp["ln2"], h))
    return constrain(h, "batch", None, "residual")


def forward(cfg: ModelConfig, params: Dict, tokens: jax.Array, *,
            frames: jax.Array, remat: bool = False, attn_impl: str = "auto",
            ) -> Tuple[jax.Array, jax.Array]:
    """Teacher-forced decode over the full target sequence."""
    enc_out = encode(cfg, params, frames, remat=remat, attn_impl=attn_impl)
    B, L = tokens.shape
    pe = nn.sinusoidal_positions(L, cfg.d_model)
    x = nn.embed(cfg, params["embed"], tokens)
    x = (x.astype(jnp.float32) + pe).astype(nn.dt(cfg))

    body = functools.partial(_dec_layer_fwd, cfg, enc_out=enc_out,
                             attn_impl=attn_impl)

    def scan_body(h, lp):
        return body(lp, h), None

    if remat:
        scan_body = jax.checkpoint(
            scan_body, policy=jax.checkpoint_policies.nothing_saveable
        )
    x, _ = nn.scan_layers(scan_body, x, params["dec_layers"])
    x = nn.apply_norm(cfg, params["final_norm"], x)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return nn.unembed(cfg, head, x), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# serving paths
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> Dict:
    dtype = dtype or nn.dt(cfg)
    Ld, Hk, hd = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    return {
        "k": jnp.zeros((Ld, batch, max_len, Hk, hd), dtype),
        "v": jnp.zeros((Ld, batch, max_len, Hk, hd), dtype),
        "cross_k": jnp.zeros((Ld, batch, cfg.enc_seq, Hk, hd), dtype),
        "cross_v": jnp.zeros((Ld, batch, cfg.enc_seq, Hk, hd), dtype),
        "lens": jnp.zeros((batch,), jnp.int32),
    }


def prefill(cfg: ModelConfig, params: Dict, tokens: jax.Array, *,
            frames: jax.Array, max_len: Optional[int] = None,
            attn_impl: str = "auto") -> Tuple[jax.Array, Dict]:
    """Encode + teacher-forced decoder prefill. Returns (last logits, cache)."""
    enc_out = encode(cfg, params, frames, attn_impl=attn_impl)
    B, L = tokens.shape
    S = max_len or L
    pe = nn.sinusoidal_positions(L, cfg.d_model)
    x = nn.embed(cfg, params["embed"], tokens)
    x = (x.astype(jnp.float32) + pe).astype(nn.dt(cfg))

    def scan_body(h, lp):
        h = constrain(h, "batch", None, "residual")
        attn_in = nn.apply_norm(cfg, lp["ln1"], h)
        q, k, v = nn.qkv_project(lp["self_attn"]["attn"], attn_in)
        attn = ops.attention(q, k, v, causal=True, impl=attn_impl)
        h = h + jnp.einsum("blhk,hkd->bld", attn, lp["self_attn"]["attn"]["wo"])
        kx, vx = nn.cross_kv(cfg, lp["cross_attn"]["attn"], enc_out)
        h = h + nn.attention_block(
            cfg, lp["cross_attn"]["attn"], nn.apply_norm(cfg, lp["lnx"], h),
            kv_override=(kx, vx), attn_impl=attn_impl,
        )
        h = h + nn.mlp_block(cfg, lp["mlp"], nn.apply_norm(cfg, lp["ln2"], h))
        return h, (k.astype(nn.dt(cfg)), v.astype(nn.dt(cfg)),
                   kx.astype(nn.dt(cfg)), vx.astype(nn.dt(cfg)))

    h, (ks, vs, kxs, vxs) = nn.scan_layers(scan_body, x, params["dec_layers"])
    h = nn.apply_norm(cfg, params["final_norm"], h[:, -1])
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = nn.unembed(cfg, head, h)

    if L < S:
        pad = S - L
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": ks, "v": vs, "cross_k": kxs, "cross_v": vxs,
             "lens": jnp.full((B,), L, jnp.int32)}
    return logits, cache


def decode_step(cfg: ModelConfig, params: Dict, cache: Dict,
                tokens: jax.Array, pos: jax.Array) -> Tuple[jax.Array, Dict]:
    """One decoder iteration with cached self-KV + static cross-KV."""
    B = tokens.shape[0]
    x = nn.embed(cfg, params["embed"], tokens)        # [B, d]
    pe = nn.sinusoidal_at(pos, cfg.d_model)           # position-correct PE
    x = (x.astype(jnp.float32) + pe).astype(nn.dt(cfg))
    S = cache["k"].shape[2]
    enc_len = jnp.full((B,), cfg.enc_seq, jnp.int32)
    new_lens = jnp.minimum(cache["lens"] + 1, S)

    def scan_body(h, xs):
        lp, kc, vc, kx, vx = xs
        h = constrain(h, "batch", "model")
        attn, kc, vc, _ = nn.attention_decode(
            cfg, lp["self_attn"]["attn"], nn.apply_norm(cfg, lp["ln1"], h),
            kc, vc, pos, new_lens,
        )
        h = h + attn
        xattn, _, _, _ = nn.attention_decode(
            cfg, lp["cross_attn"]["attn"], nn.apply_norm(cfg, lp["lnx"], h),
            kx, vx, pos, enc_len, cross=True,
        )
        h = h + xattn
        h = h + nn.mlp_block(cfg, lp["mlp"], nn.apply_norm(cfg, lp["ln2"], h))
        return h, (kc, vc)

    h, (ks, vs) = nn.scan_layers(
        scan_body, x,
        (params["dec_layers"], cache["k"], cache["v"],
         cache["cross_k"], cache["cross_v"]),
    )
    h = nn.apply_norm(cfg, params["final_norm"], h)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = nn.unembed(cfg, head, h)
    return logits, {"k": ks, "v": vs,
                    "cross_k": cache["cross_k"], "cross_v": cache["cross_v"],
                    "lens": new_lens}
