"""Family dispatch: a uniform model API over all six families.

Every family exposes the same five entry points; extra modality inputs
(vlm patches, encdec frames) travel in the ``batch`` dict and the
adapters route them to the family-specific keyword.

    api = get_api(cfg)
    params = api.init(cfg, key)
    logits, aux = api.forward(cfg, params, batch)          # training
    logits, cache = api.prefill(cfg, params, batch, max_len=...)
    logits, cache = api.decode_step(cfg, params, cache, tokens, pos)
    cache = api.init_cache(cfg, batch_size, max_len)
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax

from . import encdec, hybrid, mamba2, transformer
from .config import ModelConfig


@dataclass(frozen=True)
class ModelApi:
    init: Callable[[ModelConfig, jax.Array], Dict]
    forward: Callable[..., Tuple[jax.Array, jax.Array]]
    prefill: Callable[..., Tuple[jax.Array, Dict]]
    decode_step: Callable[..., Tuple[jax.Array, Dict]]
    init_cache: Callable[..., Dict]


def _tf_forward(cfg, params, batch, *, remat=False, attn_impl="auto"):
    return transformer.forward(
        cfg, params, batch["tokens"], patches=batch.get("patches"),
        remat=remat, attn_impl=attn_impl,
    )


def _tf_prefill(cfg, params, batch, *, max_len=None, attn_impl="auto"):
    return transformer.prefill(
        cfg, params, batch["tokens"], patches=batch.get("patches"),
        max_len=max_len, attn_impl=attn_impl,
    )


def _mamba_forward(cfg, params, batch, *, remat=False, attn_impl="auto"):
    return mamba2.forward(cfg, params, batch["tokens"],
                          remat=remat, attn_impl=attn_impl)


def _mamba_prefill(cfg, params, batch, *, max_len=None, attn_impl="auto"):
    return mamba2.prefill(cfg, params, batch["tokens"],
                          max_len=max_len, attn_impl=attn_impl)


def _hybrid_forward(cfg, params, batch, *, remat=False, attn_impl="auto"):
    return hybrid.forward(cfg, params, batch["tokens"],
                          remat=remat, attn_impl=attn_impl)


def _hybrid_prefill(cfg, params, batch, *, max_len=None, attn_impl="auto"):
    return hybrid.prefill(cfg, params, batch["tokens"],
                          max_len=max_len, attn_impl=attn_impl)


def _encdec_forward(cfg, params, batch, *, remat=False, attn_impl="auto"):
    return encdec.forward(cfg, params, batch["tokens"],
                          frames=batch["frames"],
                          remat=remat, attn_impl=attn_impl)


def _encdec_prefill(cfg, params, batch, *, max_len=None, attn_impl="auto"):
    return encdec.prefill(cfg, params, batch["tokens"],
                          frames=batch["frames"],
                          max_len=max_len, attn_impl=attn_impl)


_FAMILY_API: Dict[str, ModelApi] = {
    "dense": ModelApi(transformer.init, _tf_forward, _tf_prefill,
                      transformer.decode_step, transformer.init_cache),
    "moe": ModelApi(transformer.init, _tf_forward, _tf_prefill,
                    transformer.decode_step, transformer.init_cache),
    "vlm": ModelApi(transformer.init, _tf_forward, _tf_prefill,
                    transformer.decode_step, transformer.init_cache),
    "ssm": ModelApi(mamba2.init, _mamba_forward, _mamba_prefill,
                    mamba2.decode_step, mamba2.init_cache),
    "hybrid": ModelApi(hybrid.init, _hybrid_forward, _hybrid_prefill,
                       hybrid.decode_step, hybrid.init_cache),
    "encdec": ModelApi(encdec.init, _encdec_forward, _encdec_prefill,
                       encdec.decode_step, encdec.init_cache),
}


def get_api(cfg: ModelConfig) -> ModelApi:
    try:
        return _FAMILY_API[cfg.family]
    except KeyError:
        raise ValueError(f"unknown model family {cfg.family!r}") from None


def abstract_params(cfg: ModelConfig) -> Dict:
    """Parameter shapes without allocation (ShapeDtypeStructs)."""
    api = get_api(cfg)
    return jax.eval_shape(lambda k: api.init(cfg, k),
                          jax.ShapeDtypeStruct((2,), jax.numpy.uint32))
