"""Mamba-2 (SSD) model — the attention-free family (mamba2-2.7b).

Block: in_proj -> (z | xBC | dt); causal depthwise conv + SiLU on xBC;
selective SSD scan (kernels/ops.ssd) with per-head A, D skip; gated
RMSNorm; out_proj. Decode keeps O(1) state per layer: a [k-1, conv_dim]
conv ring plus the [H, P, N] SSM state — the SSM answer to a KV cache
(DESIGN.md §4: constant-size decode state is why this family runs
long_500k).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from ..kernels import ops, ref
from . import layers as nn
from .config import ModelConfig


def _split_sizes(cfg: ModelConfig):
    din = cfg.d_inner
    gn = cfg.ssm_ngroups * cfg.ssm_state
    return din, gn, cfg.ssm_heads


def init_layer(cfg: ModelConfig, key: jax.Array) -> Dict:
    """Input projections are stored SPLIT (w_z | w_x | w_b | w_c | w_dt)
    rather than as Mamba-2's fused in_proj: slicing a fused projection's
    output cuts across tensor-parallel shard boundaries and forced an
    all-gather of the full activation every layer (EXPERIMENTS.md §Perf,
    mamba2 hillclimb: 453 GB/device/step of resharding all-gathers).
    Split projections shard cleanly — w_z/w_x column-parallel on the
    model axis (d_inner % TP == 0, head-aligned), the small B/C/dt
    projections replicated. Mathematically identical, same param count;
    the depthwise conv splits per segment the same way."""
    din, gn, H = _split_sizes(cfg)
    d = cfg.d_model
    k1, k2, k3, k4, k5, k6, k7, k8 = jax.random.split(key, 8)
    # dt bias initialised so softplus(dt_bias) spans ~[1e-3, 1e-1]
    u = jax.random.uniform(k4, (H,), jnp.float32)
    dt_init = jnp.exp(u * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))  # inverse softplus

    def conv_w(key, width):
        return (jax.random.normal(key, (cfg.ssm_conv, width), jnp.float32)
                * (cfg.ssm_conv ** -0.5)).astype(nn.dt(cfg))

    return {
        "ln": nn.init_norm(k5, cfg),
        "ssm": {
            "w_z": nn.dense_init(k1, (d, din), dtype=nn.dt(cfg)),
            "w_x": nn.dense_init(k6, (d, din), dtype=nn.dt(cfg)),
            "w_b": nn.dense_init(k7, (d, gn), dtype=nn.dt(cfg)),
            "w_c": nn.dense_init(k8, (d, gn), dtype=nn.dt(cfg)),
            "w_dt": nn.dense_init(jax.random.fold_in(k1, 1), (d, H),
                                  dtype=nn.dt(cfg)),
            "conv_x_w": conv_w(k2, din),
            "conv_x_b": jnp.zeros((din,), nn.dt(cfg)),
            "conv_b_w": conv_w(jax.random.fold_in(k2, 1), gn),
            "conv_b_b": jnp.zeros((gn,), nn.dt(cfg)),
            "conv_c_w": conv_w(jax.random.fold_in(k2, 2), gn),
            "conv_c_b": jnp.zeros((gn,), nn.dt(cfg)),
            "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
            "D": jnp.ones((H,), jnp.float32),
            "dt_bias": dt_bias.astype(jnp.float32),
            "gate_norm": jnp.zeros((din,), nn.dt(cfg)),
            "out_proj": nn.dense_init(k3, (din, d), dtype=nn.dt(cfg)),
        },
    }


def init(cfg: ModelConfig, key: jax.Array) -> Dict:
    k_embed, k_layers, k_final, k_head = jax.random.split(key, 4)
    params = {
        "embed": nn.init_embed(k_embed, cfg),
        "layers": jax.vmap(functools.partial(init_layer, cfg))(
            jax.random.split(k_layers, cfg.n_layers)),
        "final_norm": nn.init_norm(k_final, cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"table": nn.embed_init(
            k_head, (cfg.vocab, cfg.d_model), nn.dt(cfg))}
    return params


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. x [B, L, C], w [k, C]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32),
        w.astype(jnp.float32)[:, None, :],          # [k, 1, C]
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1],
    )
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _ssm_pre(cfg: ModelConfig, sp: Dict, x: jax.Array):
    """Split input projections. x [B, L, d] (or [B, d]) ->
    (z, xs, b_raw, c_raw, dt_raw) — all pre-conv, shard-aligned."""
    z = x @ sp["w_z"]
    xs = x @ sp["w_x"]
    b = x @ sp["w_b"]
    c = x @ sp["w_c"]
    dt_raw = x @ sp["w_dt"]
    if x.ndim == 3:
        z = constrain(z, "batch", None, "model")
        xs = constrain(xs, "batch", None, "model")
    return z, xs, b, c, dt_raw


def _ssd_inputs(cfg: ModelConfig, sp: Dict, xs: jax.Array, b: jax.Array,
                c: jax.Array, dt_raw: jax.Array):
    """Discretise post-conv segments. Returns (xh, a, b, c, x_heads, dt)."""
    din, gn, H = _split_sizes(cfg)
    G, N, P = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_headdim
    b = b.reshape(*b.shape[:-1], G, N)
    c = c.reshape(*c.shape[:-1], G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + sp["dt_bias"])
    a = -jnp.exp(sp["A_log"]) * dt                      # [..., H] log decay
    x_heads = xs.reshape(*xs.shape[:-1], H, P)
    if x_heads.ndim == 4:
        x_heads = constrain(x_heads, "batch", None, "model", None)
    xh = (x_heads.astype(jnp.float32) * dt[..., None]).astype(xs.dtype)
    return xh, a.astype(xs.dtype), b, c, x_heads, dt


def _gated_out(cfg: ModelConfig, sp: Dict, y_heads: jax.Array, z: jax.Array,
               x_heads: jax.Array) -> jax.Array:
    """D skip + gated RMSNorm + out_proj. y/x [.., H, P], z [.., din]."""
    y = y_heads.astype(jnp.float32) + sp["D"][..., None] * x_heads.astype(jnp.float32)
    y = y.reshape(*z.shape)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = nn.rms_norm(y.astype(z.dtype), sp["gate_norm"])
    return y @ sp["out_proj"]


def layer_fwd(cfg: ModelConfig, lp: Dict, h: jax.Array, *,
              attn_impl: str = "auto") -> jax.Array:
    """One Mamba-2 block, full sequence."""
    sp = lp["ssm"]
    x = nn.apply_norm(cfg, lp["ln"], h)
    z, xs, b, c, dt_raw = _ssm_pre(cfg, sp, x)
    xs = jax.nn.silu(_causal_conv(xs, sp["conv_x_w"], sp["conv_x_b"]))
    b = jax.nn.silu(_causal_conv(b, sp["conv_b_w"], sp["conv_b_b"]))
    c = jax.nn.silu(_causal_conv(c, sp["conv_c_w"], sp["conv_c_b"]))
    xh, a, b, c, x_heads, _ = _ssd_inputs(cfg, sp, xs, b, c, dt_raw)
    y = ops.ssd(xh, a, b, c, chunk=cfg.ssm_chunk,
                impl=attn_impl if attn_impl.startswith("pallas") else "auto")
    out = _gated_out(cfg, sp, y, z, x_heads)
    return h + constrain(out, "batch", None, None)


def forward(cfg: ModelConfig, params: Dict, tokens: jax.Array, *,
            remat: bool = False, attn_impl: str = "auto",
            ) -> Tuple[jax.Array, jax.Array]:
    x = nn.embed(cfg, params["embed"], tokens)
    # residual stream replicated on d (batch-sharded only): every layer
    # then costs exactly one row-parallel all-reduce (out_proj) instead
    # of a resharding cycle (EXPERIMENTS.md §Perf, mamba2 iteration 2)
    x = constrain(x, "batch", None, None)

    def scan_body(h, lp):
        return layer_fwd(cfg, lp, h, attn_impl=attn_impl), None

    if remat:
        scan_body = jax.checkpoint(
            scan_body, policy=jax.checkpoint_policies.nothing_saveable
        )
    x, _ = nn.scan_layers(scan_body, x, params["layers"])
    x = nn.apply_norm(cfg, params["final_norm"], x)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return nn.unembed(cfg, head, x), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# serving paths — O(1) decode state
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> Dict:
    del max_len  # constant-size state
    dtype = dtype or nn.dt(cfg)
    L, H, P, N = cfg.n_layers, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    return {
        "conv": jnp.zeros((L, batch, cfg.ssm_conv - 1, cfg.conv_dim), dtype),
        "ssm": jnp.zeros((L, batch, H, P, N), jnp.float32),
        "lens": jnp.zeros((batch,), jnp.int32),
    }


def _layer_prefill(cfg: ModelConfig, lp: Dict, h: jax.Array):
    """Layer forward that also returns (conv_state, ssm_state). The conv
    ring stores the pre-conv xs|b|c segments concatenated (one cache
    tensor, decode re-splits at the fixed segment offsets)."""
    sp = lp["ssm"]
    x = nn.apply_norm(cfg, lp["ln"], h)
    z, xs, b, c, dt_raw = _ssm_pre(cfg, sp, x)
    tail = slice(-(cfg.ssm_conv - 1), None)
    conv_state = jnp.concatenate(
        [xs[:, tail], b[:, tail], c[:, tail]], axis=-1).astype(nn.dt(cfg))
    xs = jax.nn.silu(_causal_conv(xs, sp["conv_x_w"], sp["conv_x_b"]))
    b = jax.nn.silu(_causal_conv(b, sp["conv_b_w"], sp["conv_b_b"]))
    c = jax.nn.silu(_causal_conv(c, sp["conv_c_w"], sp["conv_c_b"]))
    xh, a, b, c, x_heads, _ = _ssd_inputs(cfg, sp, xs, b, c, dt_raw)
    y, state = ref.ssd_chunked(
        xh, a, b, c, chunk=cfg.ssm_chunk, return_final_state=True
    )
    out = _gated_out(cfg, sp, y, z, x_heads)
    return h + out, (conv_state, state)


def prefill(cfg: ModelConfig, params: Dict, tokens: jax.Array, *,
            max_len: Optional[int] = None, attn_impl: str = "auto",
            ) -> Tuple[jax.Array, Dict]:
    B, L = tokens.shape
    # ssd_chunked zero-pads ragged chunks internally (exactly: pad
    # tokens carry x=0, a=0, so the final state is untouched)
    x = nn.embed(cfg, params["embed"], tokens)

    def scan_body(h, lp):
        h2, states = _layer_prefill(cfg, lp, h)
        return h2, states

    x, (conv_s, ssm_s) = nn.scan_layers(scan_body, x, params["layers"])
    x = nn.apply_norm(cfg, params["final_norm"], x[:, L - 1])
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = nn.unembed(cfg, head, x)
    cache = {"conv": conv_s, "ssm": ssm_s,
             "lens": jnp.full((B,), L, jnp.int32)}
    return logits, cache


def decode_layer(cfg: ModelConfig, lp: Dict, h: jax.Array,
                 conv_st: jax.Array, ssm_st: jax.Array):
    """Single-token Mamba block step (shared with the hybrid family).
    conv_st: [B, k-1, conv_dim] ring of pre-conv xs|b|c segments."""
    B = h.shape[0]
    din, gn, H = _split_sizes(cfg)
    G, N, P = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_headdim
    sp = lp["ssm"]
    xn = nn.apply_norm(cfg, lp["ln"], h)
    z, xs_t, b_t, c_t, dt_raw = _ssm_pre(cfg, sp, xn)
    seg_t = jnp.concatenate([xs_t, b_t, c_t], axis=-1)   # [B, conv_dim]
    win = jnp.concatenate([conv_st.astype(jnp.float32),
                           seg_t[:, None, :].astype(jnp.float32)], axis=1)
    conv_w = jnp.concatenate([sp["conv_x_w"], sp["conv_b_w"],
                              sp["conv_c_w"]], axis=-1).astype(jnp.float32)
    conv_b = jnp.concatenate([sp["conv_x_b"], sp["conv_b_b"],
                              sp["conv_c_b"]], axis=-1).astype(jnp.float32)
    conv_out = jnp.einsum("bkc,kc->bc", win, conv_w) + conv_b
    seg = jax.nn.silu(conv_out).astype(xn.dtype)         # [B, conv_dim]
    new_conv = win[:, 1:].astype(conv_st.dtype)

    xs_ = seg[..., :din]
    b = seg[..., din:din + gn].reshape(B, G, N)
    c = seg[..., din + gn:].reshape(B, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + sp["dt_bias"])
    a = -jnp.exp(sp["A_log"]) * dt                       # [B, H]
    x_heads = xs_.reshape(B, H, P)
    xh = (x_heads.astype(jnp.float32) * dt[..., None]).astype(xs_.dtype)
    y, new_ssm = ops.ssm_decode_step(ssm_st, xh, a, b, c)
    out = _gated_out(cfg, sp, y, z, x_heads)
    return h + out, (new_conv, new_ssm)


def decode_step(cfg: ModelConfig, params: Dict, cache: Dict,
                tokens: jax.Array, pos: jax.Array) -> Tuple[jax.Array, Dict]:
    B = tokens.shape[0]
    x = nn.embed(cfg, params["embed"], tokens)        # [B, d]

    def scan_body(h, xs):
        lp, conv_st, ssm_st = xs
        h2, states = decode_layer(cfg, lp, h, conv_st, ssm_st)
        return h2, states

    h, (conv_s, ssm_s) = nn.scan_layers(
        scan_body, x, (params["layers"], cache["conv"], cache["ssm"])
    )
    h = nn.apply_norm(cfg, params["final_norm"], h)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = nn.unembed(cfg, head, h)
    return logits, {"conv": conv_s, "ssm": ssm_s, "lens": cache["lens"] + 1}
