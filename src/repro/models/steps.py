"""Train / prefill / serve step functions — the units the launcher jits.

* ``loss_fn``       — next-token cross-entropy (f32 logsumexp over the
  possibly vocab-sharded logits) + MoE aux loss;
* ``make_train_step`` — value_and_grad + optimizer update, full remat;
* ``make_prefill_step`` / ``make_serve_step`` — the serving iteration
  units: prefill the prompt / advance every active decode slot one
  token (greedy or temperature sampling).

All steps take ``batch`` dicts (tokens, labels, and optional modality
stubs: vlm patches / encdec frames) so one dry-run driver covers every
family.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from .config import ModelConfig
from .registry import get_api


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token cross-entropy. logits [B, L, V] (any dtype),
    labels [B, L] int32. Computed in f32; works with vocab-sharded
    logits (logsumexp lowers to a partial reduce + all-reduce)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)                        # [B, L]
    true_logit = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - true_logit
    if mask is not None:
        m = mask.astype(jnp.float32)
        return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)
    return nll.mean()


def loss_fn(cfg: ModelConfig, params: Dict, batch: Dict, *,
            remat: bool = True, attn_impl: str = "auto") -> Tuple[jax.Array, Dict]:
    api = get_api(cfg)
    logits, aux = api.forward(cfg, params, batch, remat=remat,
                              attn_impl=attn_impl)
    labels = batch["labels"]
    # vlm: logits cover [prefix + tokens]; score the token tail only.
    L = labels.shape[1]
    if logits.shape[1] != L:
        logits = logits[:, -L:]
    logits = constrain(logits, "batch", None, "model")
    ce = cross_entropy(logits, labels, batch.get("loss_mask"))
    total = ce + cfg.router_aux_coef * aux
    return total, {"loss": total, "ce": ce, "aux": aux}


def make_train_step(cfg: ModelConfig, optimizer, *,
                    remat: bool = True, attn_impl: str = "auto") -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). ``optimizer`` is a repro.distributed.optimizer.Optimizer."""

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, remat=remat, attn_impl=attn_impl),
            has_aux=True,
        )(params)
        params, opt_state, opt_metrics = optimizer.update(
            params, grads, opt_state
        )
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# serving steps
# ---------------------------------------------------------------------------

def sample_logits(logits: jax.Array, rng: Optional[jax.Array],
                  temperature: float = 0.0) -> jax.Array:
    """Greedy (temperature=0) or temperature sampling. logits [B, V]."""
    if temperature <= 0.0 or rng is None:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        rng, logits.astype(jnp.float32) / temperature, axis=-1
    ).astype(jnp.int32)


def make_prefill_step(cfg: ModelConfig, *, max_len: int,
                      attn_impl: str = "auto",
                      temperature: float = 0.0) -> Callable:
    """prefill_step(params, batch, rng) -> (first_tokens, cache)."""
    api = get_api(cfg)

    def prefill_step(params, batch, rng):
        logits, cache = api.prefill(cfg, params, batch, max_len=max_len,
                                    attn_impl=attn_impl)
        toks = sample_logits(logits, rng, temperature)
        return toks, cache

    return prefill_step


def make_serve_step(cfg: ModelConfig, *, attn_impl: str = "auto",
                    temperature: float = 0.0) -> Callable:
    """serve_step(params, cache, tokens, pos, rng) -> (next_tokens, cache).

    One new token per active sequence against the KV/SSM cache — the
    unit the decode_32k / long_500k dry-run cells lower.
    """
    api = get_api(cfg)
    del attn_impl  # decode paths dispatch internally

    def serve_step(params, cache, tokens, pos, rng):
        logits, cache = api.decode_step(cfg, params, cache, tokens, pos)
        toks = sample_logits(logits, rng, temperature)
        return toks, cache

    return serve_step
