"""Public kernel ops with platform dispatch.

The models call these — never the kernels or refs directly. Dispatch:

* ``impl='auto'`` (default): Pallas kernels on TPU; on CPU/GPU the
  chunked-jnp forms (identical math, bounded memory) so the whole system
  — including the 512-device dry-run — runs everywhere. The chunked
  forms are also what the dry-run lowers, so roofline FLOPs match the
  kernel's algorithm, not a naive O(L^2)-materialising fallback.
* ``impl='pallas'`` / ``'pallas_interpret'`` / ``'reference'`` force a
  path (tests use ``pallas_interpret`` to execute kernel bodies on CPU).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .chunked_prefill import chunked_prefill_attention as _chunked_prefill_pallas
from .flash_attention import flash_attention as _flash_pallas
from .paged_attention import (
    batched_paged_decode_attention as _batched_paged_pallas,
    paged_decode_attention as _paged_pallas,
)
from .ssd_scan import ssd_scan as _ssd_pallas

Impl = str  # 'auto' | 'pallas' | 'pallas_interpret' | 'reference'


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention(
    q: jax.Array,            # [B, Lq, H, D]
    k: jax.Array,            # [B, Lk, Hk, D]
    v: jax.Array,            # [B, Lk, Hk, D]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    prefix_len: int = 0,
    impl: Impl = "auto",
    block_q: int = 128,
    block_kv: int = 128,
) -> jax.Array:
    """Batched multi-head (GQA) attention — prefill / training path."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "reference"
    if impl in ("pallas", "pallas_interpret"):
        return _flash_pallas(
            q, k, v, causal=causal, window=window, logit_softcap=logit_softcap,
            prefix_len=prefix_len, block_q=block_q, block_kv=block_kv,
            interpret=(impl == "pallas_interpret"),
        )
    # chunked-jnp: same online-softmax algorithm, XLA-compiled
    return ref.flash_attention_chunked(
        q, k, v, causal=causal, window=window, logit_softcap=logit_softcap,
        prefix_len=prefix_len, block_kv=max(block_kv, 512),
    )


def decode_attention(
    q: jax.Array,            # [B, H, D]
    k_cache: jax.Array,      # [B, S, Hk, D]
    v_cache: jax.Array,      # [B, S, Hk, D]
    cache_len: jax.Array,    # [B] int32
    *,
    window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    impl: Impl = "auto",
) -> jax.Array:
    """Single-token decode against a contiguous per-sequence cache.

    This is a pure memory-bound gather+GEMV; XLA handles it well on all
    platforms, so there is no Pallas variant — the paged-pool variant
    below is the kernelised decode path."""
    del impl
    return ref.decode_attention_ref(
        q, k_cache, v_cache, cache_len, window=window, logit_softcap=logit_softcap
    )


def paged_decode_attention(
    q: jax.Array,            # [B, H, D]
    k_pages: jax.Array,      # [n_pages, page_size, Hk, D]
    v_pages: jax.Array,      # [n_pages, page_size, Hk, D]
    page_table: jax.Array,   # [B, pages_per_seq] int32
    seq_lens: jax.Array,     # [B] int32
    *,
    logit_softcap: Optional[float] = None,
    impl: Impl = "auto",
) -> jax.Array:
    """Decode attention over the vLLM-style paged KV pool."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "reference"
    if impl in ("pallas", "pallas_interpret"):
        return _paged_pallas(
            q, k_pages, v_pages, page_table, seq_lens,
            logit_softcap=logit_softcap,
            interpret=(impl == "pallas_interpret"),
        )
    return ref.paged_decode_attention_ref(
        q, k_pages, v_pages, page_table, seq_lens, logit_softcap=logit_softcap
    )


def batched_paged_decode_attention(
    q: jax.Array,            # [B, H, D]
    k_pages: jax.Array,      # [n_pages, page_size, Hk, D]
    v_pages: jax.Array,      # [n_pages, page_size, Hk, D]
    page_table: jax.Array,   # [B, pages_per_seq] int32
    seq_lens: jax.Array,     # [B] int32 tokens resident BEFORE this step
    k_new: jax.Array,        # [B, Hk, D] this iteration's key (not in pool)
    v_new: jax.Array,        # [B, Hk, D]
    *,
    max_pages: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    impl: Impl = "auto",
) -> jax.Array:
    """One engine iteration's whole decode set in a single call: paged
    decode with the current token's K/V fused as a virtual trailing page
    (see ``paged_attention.batched_paged_decode_attention``)."""
    # the fused new-token K/V must see pool dtype so results are
    # bit-consistent with scatter-then-read on every impl
    k_new = k_new.astype(k_pages.dtype)
    v_new = v_new.astype(v_pages.dtype)
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "reference"
    if impl in ("pallas", "pallas_interpret"):
        return _batched_paged_pallas(
            q, k_pages, v_pages, page_table, seq_lens, k_new, v_new,
            max_pages=max_pages, logit_softcap=logit_softcap,
            interpret=(impl == "pallas_interpret"),
        )
    del max_pages  # a DMA-trim hint; the gather oracle reads every page
    return ref.batched_paged_decode_attention_ref(
        q, k_pages, v_pages, page_table, seq_lens, k_new, v_new,
        logit_softcap=logit_softcap,
    )


def chunked_prefill_attention(
    q: jax.Array,            # [B, chunk, H, D] query slab
    k_pages: jax.Array,      # [n_pages, page_size, Hk, D]
    v_pages: jax.Array,      # [n_pages, page_size, Hk, D]
    page_table: jax.Array,   # [B, pages_per_seq] int32
    q_offsets: jax.Array,    # [B] int32 absolute position of q[:, 0]
    kv_lens: jax.Array,      # [B] int32 resident tokens incl. this slab
    *,
    logit_softcap: Optional[float] = None,
    impl: Impl = "auto",
) -> jax.Array:
    """Fused chunked-prefill attention over the paged KV pool: one
    prefill slab vs every resident page (cached prefix + prior chunks +
    itself), query-offset causal masked."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "reference"
    if impl in ("pallas", "pallas_interpret"):
        return _chunked_prefill_pallas(
            q, k_pages, v_pages, page_table, q_offsets, kv_lens,
            logit_softcap=logit_softcap,
            interpret=(impl == "pallas_interpret"),
        )
    return ref.chunked_prefill_attention_ref(
        q, k_pages, v_pages, page_table, q_offsets, kv_lens,
        logit_softcap=logit_softcap,
    )


def ssd(
    x: jax.Array,            # [B, L, H, P] dt-scaled
    a: jax.Array,            # [B, L, H]    log decays
    b: jax.Array,            # [B, L, G, N]
    c: jax.Array,            # [B, L, G, N]
    *,
    chunk: int = 256,
    impl: Impl = "auto",
) -> jax.Array:
    """Mamba-2 SSD chunked scan."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "reference"
    if impl in ("pallas", "pallas_interpret"):
        return _ssd_pallas(
            x, a, b, c, chunk=chunk, interpret=(impl == "pallas_interpret")
        )
    return ref.ssd_chunked(x, a, b, c, chunk=chunk)


def ssm_decode_step(h, x_t, a_t, b_t, c_t):
    """Single-token SSM state update (decode)."""
    return ref.ssm_decode_step_ref(h, x_t, a_t, b_t, c_t)
