"""Pallas TPU fused chunked-prefill attention over the paged KV pool.

The engine prefills a request in ``chunk_prefill_tokens`` slabs that share
each iteration's token budget with decode (Sarathi-style piggybacking, the
serving contract DESIGN.md §4 models). Each slab's K/V is scattered into
the sequence's pages *first* (the caller owns the scatter, exactly like
the decode path); this kernel then attends the query slab against every
resident page — the chunks written by slabs ``0..N-1`` *and* the prefix
pages matched in the radix tree — with query-offset causal masking:

* query row ``i`` of the slab sits at absolute position
  ``q_offset + i // group`` (rows are the flattened ``[chunk, group]``
  GQA tile, so one page fetch feeds all of a kv head's q-heads);
* key column ``j`` of page ``p`` sits at ``p * page_size + j``;
* a score survives iff ``k_pos <= q_pos`` and both fall inside
  ``kv_len`` — so resuming from a cached prefix is just ``q_offset > 0``
  with the prefix pages resident in the table.

Grid = (batch, kv_heads, pages_per_seq); the page axis is last
(sequential), so the online-softmax scratch — one ``[chunk * group, D]``
accumulator per (b, kv_head) — persists across pages. Pages wholly above
the slab's causal frontier or past ``kv_len`` are skipped via ``pl.when``
(index map clamped by ``safe_page_index``, as in the decode kernel).

Oracle: ``ref.chunked_prefill_attention_ref``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .paged_attention import NEG_INF, safe_page_index


def _chunked_prefill_kernel(
    # scalar-prefetch operands
    page_table_ref,                 # [B, pages_per_seq] int32 (SMEM)
    q_offsets_ref,                  # [B] int32 (SMEM)
    kv_lens_ref,                    # [B] int32 (SMEM)
    # array operands
    q_ref,                          # [1, 1, chunk * group, D]
    k_ref,                          # [1, page_size, 1, D]
    v_ref,                          # [1, page_size, 1, D]
    o_ref,                          # [1, 1, chunk * group, D]
    acc_ref, m_ref, l_ref,          # VMEM scratch
    *,
    scale: float,
    logit_softcap: Optional[float],
    page_size: int,
    pages_per_seq: int,
    chunk: int,
    group: int,
):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_off = q_offsets_ref[b]
    kv_len = kv_lens_ref[b]
    page_start = p * page_size
    valid = kv_len - page_start              # tokens of this page in use

    # skip pages past the sequence end AND pages wholly above the slab's
    # causal frontier (a resumed chunk never looks past q_off + chunk - 1)
    @pl.when((valid > 0) & (page_start < q_off + chunk))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale   # [chunk * group, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)     # [page, D]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                             # [chunk * group, page]
        if logit_softcap is not None:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        q_pos = q_off + rows // group                 # absolute query pos
        k_pos = page_start + cols                     # absolute key pos
        mask = (k_pos <= q_pos) & (k_pos < kv_len) & (q_pos < kv_len)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        pexp = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + pexp.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[:, 0] = m_new

    @pl.when(p == pages_per_seq - 1)
    def _finalize():
        l = l_ref[:, 0]
        l = jnp.where(l == 0.0, 1.0, l)   # rows past kv_len -> zeros
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def chunked_prefill_attention(
    q: jax.Array,            # [B, chunk, H, D] query slab
    k_pages: jax.Array,      # [n_pages, page_size, Hk, D]
    v_pages: jax.Array,      # [n_pages, page_size, Hk, D]
    page_table: jax.Array,   # [B, pages_per_seq] int32
    q_offsets: jax.Array,    # [B] int32 absolute position of q[:, 0]
    kv_lens: jax.Array,      # [B] int32 resident tokens incl. this slab
    *,
    logit_softcap: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    """Attend a prefill slab against paged KV it was just scattered into.

    The caller must have written the slab's K/V to the pages covering
    positions ``[q_offsets, q_offsets + chunk)`` before the call;
    ``kv_lens`` counts everything resident (cached prefix + prior chunks
    + this slab), i.e. normally ``q_offsets + chunk``.
    """
    B, chunk, H, D = q.shape
    n_pages, page_size, Hk, _ = k_pages.shape
    pages_per_seq = page_table.shape[1]
    assert H % Hk == 0
    group = H // Hk
    # flatten to the [chunk * group, D] MXU tile per (b, kv head);
    # row r is chunk position r // group, q-head (r % group) of kv head h
    q_r = (q.reshape(B, chunk, Hk, group, D)
            .transpose(0, 2, 1, 3, 4)
            .reshape(B, Hk, chunk * group, D))

    def k_index(b, h, p, page_table, q_offsets, kv_lens):
        page = safe_page_index(page_table, kv_lens, b, p, page_size)
        return (page, 0, h, 0)

    def q_index(b, h, p, page_table, q_offsets, kv_lens):
        return (b, h, 0, 0)

    kernel = functools.partial(
        _chunked_prefill_kernel,
        scale=D ** -0.5,
        logit_softcap=logit_softcap,
        page_size=page_size,
        pages_per_seq=pages_per_seq,
        chunk=chunk,
        group=group,
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(B, Hk, pages_per_seq),
            in_specs=[
                pl.BlockSpec((1, 1, chunk * group, D), q_index),
                pl.BlockSpec((1, page_size, 1, D), k_index),
                pl.BlockSpec((1, page_size, 1, D), k_index),
            ],
            out_specs=pl.BlockSpec((1, 1, chunk * group, D), q_index),
            scratch_shapes=[
                pltpu.VMEM((chunk * group, D), jnp.float32),
                pltpu.VMEM((chunk * group, 1), jnp.float32),
                pltpu.VMEM((chunk * group, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hk, chunk * group, D), q.dtype),
        interpret=interpret,
    )(page_table, q_offsets, kv_lens, q_r, k_pages, v_pages)
    return (out.reshape(B, Hk, chunk, group, D)
               .transpose(0, 2, 1, 3, 4)
               .reshape(B, chunk, H, D))
