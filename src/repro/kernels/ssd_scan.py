"""Pallas TPU kernel for the Mamba-2 SSD (state-space duality) scan.

The SSD insight: a selective-SSM over a chunk decomposes into (a) an
intra-chunk *quadratic* term — structurally a masked attention matmul,
ideal for the MXU — and (b) an inter-chunk rank-N recurrent state carry.
On TPU we map:

* grid = (batch, heads, chunks) with the chunk axis last (sequential),
  so the [P, N] recurrent state lives in VMEM scratch across chunks —
  the chunked scan never round-trips the state through HBM;
* the intra-chunk [Q, Q] decay-masked score matrix and the [Q, P]/[P, N]
  products are MXU matmuls (Q = 128/256 aligned);
* B/C group mapping (G groups shared across H heads) handled in index
  maps, mirroring GQA folding.

Inputs are pre-discretised (x already dt-scaled, ``a`` = per-step log
decay <= 0) so every exp() in the kernel is of a non-positive number —
numerically safe without max-subtraction.

Oracle: ``ref.ssd_naive`` (quadratic form) / ``ref.ssd_chunked``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ssd_kernel(
    x_ref,                   # [1, Q, 1, P]
    a_ref,                   # [1, Q, 1]
    b_ref,                   # [1, Q, 1, N]
    c_ref,                   # [1, Q, 1, N]
    y_ref,                   # [1, Q, 1, P]
    state_ref,               # VMEM scratch [P, N] f32
    *,
    chunk: int,
):
    n = pl.program_id(2)

    @pl.when(n == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)                 # [Q, P]
    a = a_ref[0, :, 0].astype(jnp.float32)                    # [Q]
    b = b_ref[0, :, 0, :].astype(jnp.float32)                 # [Q, N]
    c = c_ref[0, :, 0, :].astype(jnp.float32)                 # [Q, N]

    a_cs = jnp.cumsum(a)                                      # [Q], <= 0, decreasing
    # intra-chunk decay mask: L[i, j] = exp(a_cs[i] - a_cs[j]) for i >= j
    li = a_cs[:, None] - a_cs[None, :]
    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    lmat = jnp.where(row >= col, jnp.exp(li), 0.0)            # [Q, Q]

    # (a) intra-chunk quadratic term (MXU): (C B^T * L) X
    scores = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * lmat                                                  # [Q, Q]
    y = jax.lax.dot_general(
        scores, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                          # [Q, P]

    # (b) inter-chunk: contribution of the carried state
    c_in = c * jnp.exp(a_cs)[:, None]                          # [Q, N]
    y = y + jax.lax.dot_general(
        c_in, state_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                          # [Q, N] x [P, N]^T -> [Q, P]

    # state update: h' = e^{sum a} h + sum_i e^{a_cs[-1]-a_cs[i]} x_i b_i^T
    w = jnp.exp(a_cs[-1] - a_cs)                               # [Q]
    xw = x * w[:, None]                                        # [Q, P]
    state_ref[...] = state_ref[...] * jnp.exp(a_cs[-1]) + jax.lax.dot_general(
        xw, b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                          # [P, N]

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)


def ssd_scan(
    x: jax.Array,            # [B, L, H, P]   dt-scaled inputs
    a: jax.Array,            # [B, L, H]      per-step log decay (<= 0)
    b: jax.Array,            # [B, L, G, N]
    c: jax.Array,            # [B, L, G, N]
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, L, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    assert H % G == 0, (H, G)
    rep = H // G
    assert L % chunk == 0, (L, chunk)
    nc = L // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda bt, h, n: (bt, n, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bt, h, n: (bt, n, h)),
            pl.BlockSpec((1, chunk, 1, N), lambda bt, h, n: (bt, n, h // rep, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda bt, h, n: (bt, n, h // rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, P), lambda bt, h, n: (bt, n, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, L, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, a, b, c)
