"""Pallas TPU kernels for the serving hot spots (DESIGN.md §5):
flash_attention (prefill/train), chunked_prefill_attention (prefill
slabs against the paged KV pool), paged_decode_attention /
batched_paged_decode_attention (decode against the paged KV pool),
ssd_scan (Mamba-2 state-space duality). ops.py is the public dispatch
layer; ref.py holds the pure-jnp oracles."""

from . import ops, ref
from .chunked_prefill import chunked_prefill_attention
from .flash_attention import flash_attention
from .paged_attention import (
    batched_paged_decode_attention,
    paged_decode_attention,
)
from .ssd_scan import ssd_scan

__all__ = [
    "ops",
    "ref",
    "batched_paged_decode_attention",
    "chunked_prefill_attention",
    "flash_attention",
    "paged_decode_attention",
    "ssd_scan",
]
