"""Pallas TPU kernels for the serving hot spots (DESIGN.md §5):
flash_attention (prefill/train), paged_decode_attention (decode against
the paged KV pool), ssd_scan (Mamba-2 state-space duality). ops.py is
the public dispatch layer; ref.py holds the pure-jnp oracles."""

from . import ops, ref
from .flash_attention import flash_attention
from .paged_attention import paged_decode_attention
from .ssd_scan import ssd_scan

__all__ = ["ops", "ref", "flash_attention", "paged_decode_attention", "ssd_scan"]
