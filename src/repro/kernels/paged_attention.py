"""Pallas TPU paged-attention decode kernel.

TPU-native adaptation of vLLM's PagedAttention (the paper's serving
runtime): the GPU kernel's warp-level gather over 16-token pages becomes
explicit page-granular DMA — the page table is a *scalar-prefetch*
operand, so Pallas issues the HBM->VMEM copy for page
``page_table[b, p]`` ahead of the grid step that consumes it
(double-buffered by the pipeline), which is the TPU idiom for
data-dependent addressing.

* grid = (batch, kv_heads, pages_per_seq); the page axis is last
  (sequential), so the online-softmax scratch persists per (b, kv_head);
* the GQA query-head group for one kv head — a [group, D] tile — is the
  MXU operand, so all of a kv head's q-heads amortise one page fetch
  (GQA folding, DESIGN.md §5);
* pages past ``ceil(seq_len / page_size)`` are skipped via ``pl.when``
  (their DMA still lands in VMEM but no FLOPs are spent; index_map clamps
  to a valid page id);
* one new token per sequence (decode); memory-bound by design.

Oracle: ``ref.paged_decode_attention_ref``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def safe_page_index(page_table, seq_lens, b, p, page_size: int):
    """Physical page for grid step ``p`` of sequence ``b``, clamped to the
    sequence's last valid page. Steps past ``ceil(seq_len / page_size)``
    spend no FLOPs (the kernel body is skipped) but their block DMA still
    executes, so the index map must never read a stale/poisoned tail entry
    of the page table — those slots are allocator garbage."""
    n_valid = jnp.maximum(pl.cdiv(seq_lens[b], page_size), 1)
    return page_table[b, jnp.minimum(p, n_valid - 1)]


def _paged_kernel(
    # scalar-prefetch operands
    page_table_ref,                 # [B, pages_per_seq] int32 (SMEM)
    seq_lens_ref,                   # [B] int32 (SMEM)
    # array operands
    q_ref,                          # [1, 1, group, D]
    k_ref,                          # [1, page_size, 1, D]
    v_ref,                          # [1, page_size, 1, D]
    o_ref,                          # [1, 1, group, D]
    acc_ref, m_ref, l_ref,          # VMEM scratch
    *,
    scale: float,
    logit_softcap: Optional[float],
    page_size: int,
    pages_per_seq: int,
):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    seq_len = seq_lens_ref[b]
    valid = seq_len - p * page_size          # tokens of this page in use

    @pl.when(valid > 0)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale           # [group, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)             # [page, D]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                      # [group, page]
        if logit_softcap is not None:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        pos = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = pos < valid
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        pexp = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + pexp.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[:, 0] = m_new

    @pl.when(p == pages_per_seq - 1)
    def _finalize():
        l = l_ref[:, 0]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def paged_decode_attention(
    q: jax.Array,            # [B, H, D]
    k_pages: jax.Array,      # [n_pages, page_size, Hk, D]
    v_pages: jax.Array,      # [n_pages, page_size, Hk, D]
    page_table: jax.Array,   # [B, pages_per_seq] int32
    seq_lens: jax.Array,     # [B] int32
    *,
    logit_softcap: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    B, H, D = q.shape
    n_pages, page_size, Hk, _ = k_pages.shape
    pages_per_seq = page_table.shape[1]
    assert H % Hk == 0
    group = H // Hk
    q_r = q.reshape(B, Hk, group, D)

    def k_index(b, h, p, page_table, seq_lens):
        # clamp to a valid page id when past the sequence end; the body
        # is skipped there, the DMA just needs a legal source.
        page = safe_page_index(page_table, seq_lens, b, p, page_size)
        return (page, 0, h, 0)

    def q_index(b, h, p, page_table, seq_lens):
        return (b, h, 0, 0)

    kernel = functools.partial(
        _paged_kernel,
        scale=D ** -0.5,
        logit_softcap=logit_softcap,
        page_size=page_size,
        pages_per_seq=pages_per_seq,
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, Hk, pages_per_seq),
            in_specs=[
                pl.BlockSpec((1, 1, group, D), q_index),
                pl.BlockSpec((1, page_size, 1, D), k_index),
                pl.BlockSpec((1, page_size, 1, D), k_index),
            ],
            out_specs=pl.BlockSpec((1, 1, group, D), q_index),
            scratch_shapes=[
                pltpu.VMEM((group, D), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hk, group, D), q.dtype),
        interpret=interpret,
    )(page_table, seq_lens, q_r, k_pages, v_pages)
    return out.reshape(B, H, D)


def _batched_kernel(
    # scalar-prefetch operands
    page_table_ref,                 # [B, pages_per_seq] int32 (SMEM)
    seq_lens_ref,                   # [B] int32 (SMEM)
    # array operands
    q_ref,                          # [1, 1, group, D]
    k_ref,                          # [1, page_size, 1, D]
    v_ref,                          # [1, page_size, 1, D]
    k_new_ref,                      # [1, 1, D]
    v_new_ref,                      # [1, 1, D]
    o_ref,                          # [1, 1, group, D]
    acc_ref, m_ref, l_ref,          # VMEM scratch
    *,
    scale: float,
    logit_softcap: Optional[float],
    page_size: int,
    n_page_steps: int,
):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    seq_len = seq_lens_ref[b]
    valid = seq_len - p * page_size          # tokens of this page in use
    q = q_ref[0, 0].astype(jnp.float32) * scale               # [group, D]

    def _softcap(s):
        if logit_softcap is not None:
            return logit_softcap * jnp.tanh(s / logit_softcap)
        return s

    def _accumulate(s, mask, values):
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        pexp = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + pexp.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            pexp, values, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[:, 0] = m_new

    @pl.when((p < n_page_steps) & (valid > 0))
    def _page_body():
        k = k_ref[0, :, 0, :].astype(jnp.float32)             # [page, D]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = _softcap(jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ))                                                     # [group, page]
        pos = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        _accumulate(s, pos < valid, v)

    @pl.when(p == n_page_steps)
    def _new_token_body():
        # the current iteration's own K/V — not yet resident in the pool,
        # fused here so the kernel never reads a page it aliases with a
        # same-step scatter (position seq_len always attends to itself)
        k1 = k_new_ref[0].astype(jnp.float32)                  # [1, D]
        v1 = v_new_ref[0].astype(jnp.float32)
        s = _softcap(jax.lax.dot_general(
            q, k1, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ))                                                     # [group, 1]
        _accumulate(s, jnp.ones_like(s, dtype=jnp.bool_), v1)
        l = l_ref[:, 0]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def batched_paged_decode_attention(
    q: jax.Array,            # [B, H, D]
    k_pages: jax.Array,      # [n_pages, page_size, Hk, D]
    v_pages: jax.Array,      # [n_pages, page_size, Hk, D]
    page_table: jax.Array,   # [B, pages_per_seq] int32
    seq_lens: jax.Array,     # [B] int32 tokens resident BEFORE this step
    k_new: jax.Array,        # [B, Hk, D] this iteration's key (not in pool)
    v_new: jax.Array,        # [B, Hk, D]
    *,
    max_pages: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    """One engine iteration's whole decode set in a single ``pallas_call``.

    Extends :func:`paged_decode_attention` two ways, matching the engine's
    continuous-batching loop:

    * the current token's K/V ride along as operands and are folded in as
      a virtual trailing grid step, so attention covers ``seq_lens + 1``
      tokens without first scattering into the pool (the scatter still
      happens for the pool carry, but the kernel no longer reads pages it
      aliases — XLA needn't sequence a full-pool copy before the call);
    * ``max_pages`` statically trims the page grid to the deepest live
      sequence (the engine rounds to a power of two to bound recompiles),
      so a mostly-shallow batch doesn't stream ``pages_per_seq`` pages.

    Numerics match scatter-then-``paged_decode_attention(seq_lens + 1)``
    when ``k_new``/``v_new`` are pre-cast to the pool dtype.
    Oracle: ``ref.batched_paged_decode_attention_ref``.
    """
    B, H, D = q.shape
    n_pages, page_size, Hk, _ = k_pages.shape
    pages_per_seq = page_table.shape[1]
    n_page_steps = pages_per_seq if max_pages is None else max_pages
    assert 1 <= n_page_steps <= pages_per_seq, (n_page_steps, pages_per_seq)
    assert H % Hk == 0
    group = H // Hk
    q_r = q.reshape(B, Hk, group, D)

    def k_index(b, h, p, page_table, seq_lens):
        page = safe_page_index(page_table, seq_lens, b, p, page_size)
        return (page, 0, h, 0)

    def q_index(b, h, p, page_table, seq_lens):
        return (b, h, 0, 0)

    def new_index(b, h, p, page_table, seq_lens):
        return (b, h, 0)

    kernel = functools.partial(
        _batched_kernel,
        scale=D ** -0.5,
        logit_softcap=logit_softcap,
        page_size=page_size,
        n_page_steps=n_page_steps,
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            # one extra (virtual) grid step folds in the new token
            grid=(B, Hk, n_page_steps + 1),
            in_specs=[
                pl.BlockSpec((1, 1, group, D), q_index),
                pl.BlockSpec((1, page_size, 1, D), k_index),
                pl.BlockSpec((1, page_size, 1, D), k_index),
                pl.BlockSpec((1, 1, D), new_index),
                pl.BlockSpec((1, 1, D), new_index),
            ],
            out_specs=pl.BlockSpec((1, 1, group, D), q_index),
            scratch_shapes=[
                pltpu.VMEM((group, D), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hk, group, D), q.dtype),
        interpret=interpret,
    )(page_table, seq_lens, q_r, k_pages, v_pages, k_new, v_new)
    return out.reshape(B, H, D)
