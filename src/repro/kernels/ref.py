"""Pure-jnp oracles for every Pallas kernel.

Each function here is the semantic ground truth the kernels are tested
against (tests/test_kernels.py sweeps shapes/dtypes and asserts
allclose). Two tiers:

* ``*_naive``   — the textbook O(L^2)-materialising forms; used only as
  oracles on small shapes.
* ``*_chunked`` — jnp/lax.scan blockwise forms with identical math but
  bounded memory; these are what the models lower on non-TPU backends
  (and therefore what the dry-run's HLO contains), and they are
  themselves validated against the naive forms.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..xla_scan import scan as _scan


# ---------------------------------------------------------------------------
# Attention oracles
# ---------------------------------------------------------------------------

def mha_naive(
    q: jax.Array,            # [B, Lq, H, D]
    k: jax.Array,            # [B, Lk, Hk, D]
    v: jax.Array,            # [B, Lk, Hk, D]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    prefix_len: int = 0,
) -> jax.Array:
    """Textbook GQA attention, materialising the full score matrix.

    ``prefix_len`` > 0 gives prefix-LM masking: the first ``prefix_len``
    key positions are visible to every query (PaliGemma image prefix)."""
    B, Lq, H, D = q.shape
    _, Lk, Hk, _ = k.shape
    assert H % Hk == 0, (H, Hk)
    group = H // Hk
    qf = q.astype(jnp.float32) * (D ** -0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # expand kv heads to q heads
    kf = jnp.repeat(kf, group, axis=2)
    vf = jnp.repeat(vf, group, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    if logit_softcap is not None:
        scores = logit_softcap * jnp.tanh(scores / logit_softcap)
    # causal + sliding-window masking over absolute positions; when
    # Lq < Lk the queries are assumed to be the *last* Lq positions
    # (decode-style alignment).
    q_pos = jnp.arange(Lq)[:, None] + (Lk - Lq)
    k_pos = jnp.arange(Lk)[None, :]
    mask = jnp.ones((Lq, Lk), dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    if prefix_len:
        mask |= k_pos < prefix_len
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    return out.astype(q.dtype)


def flash_attention_chunked(
    q: jax.Array,            # [B, Lq, H, D]
    k: jax.Array,            # [B, Lk, Hk, D]
    v: jax.Array,            # [B, Lk, Hk, D]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    prefix_len: int = 0,
    block_kv: int = 1024,
) -> jax.Array:
    """Online-softmax attention scanning over KV blocks.

    Memory is O(Lq * block_kv) instead of O(Lq * Lk); this is the form
    the 32k-prefill cells lower on CPU, and the jnp mirror of the Pallas
    flash kernel's math.
    """
    B, Lq, H, D = q.shape
    _, Lk, Hk, _ = k.shape
    group = H // Hk
    n_blocks = -(-Lk // block_kv)
    pad = n_blocks * block_kv - Lk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qf = q.astype(jnp.float32) * (D ** -0.5)
    kf = k.astype(jnp.float32).reshape(B, n_blocks, block_kv, Hk, D)
    vf = v.astype(jnp.float32).reshape(B, n_blocks, block_kv, Hk, D)

    q_pos = jnp.arange(Lq)[:, None] + (Lk - Lq)          # [Lq, 1]

    def body(carry, blk):
        m, l, acc = carry                                 # [B,H,Lq], [B,H,Lq], [B,Lq,H,D]
        kb, vb, j = blk                                   # [B,block,Hk,D] x2, scalar
        kb = jnp.repeat(kb, group, axis=2)
        vb = jnp.repeat(vb, group, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb)         # [B,H,Lq,block]
        if logit_softcap is not None:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        k_pos = j * block_kv + jnp.arange(block_kv)[None, :]
        mask = k_pos < Lk                                  # padding
        inner = jnp.ones_like(mask)
        if causal:
            inner &= q_pos >= k_pos
        if window is not None:
            inner &= q_pos - k_pos < window
        if prefix_len:
            inner |= k_pos < prefix_len
        mask &= inner
        s = jnp.where(mask[None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # guard fully-masked rows (m_new == -inf)
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - safe_m[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        scale = jnp.where(jnp.isfinite(m), jnp.exp(m - safe_m), 0.0)
        l_new = l * scale + p.sum(axis=-1)
        acc = acc * scale.transpose(0, 2, 1)[..., None] + jnp.einsum(
            "bhqk,bkhd->bqhd", p, vb
        )
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, H, Lq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, Lq), jnp.float32)
    acc0 = jnp.zeros((B, Lq, H, D), jnp.float32)
    (m, l, acc), _ = _scan(
        body, (m0, l0, acc0),
        (jnp.moveaxis(kf, 1, 0), jnp.moveaxis(vf, 1, 0), jnp.arange(n_blocks)),
    )
    l = jnp.where(l == 0.0, 1.0, l)                       # fully-masked rows -> 0 out
    out = acc / l.transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,            # [B, H, D]       one new token per sequence
    k_cache: jax.Array,      # [B, S, Hk, D]
    v_cache: jax.Array,      # [B, S, Hk, D]
    cache_len: jax.Array,    # [B] int32       valid prefix length per seq
    *,
    logit_softcap: Optional[float] = None,
    window: Optional[int] = None,
) -> jax.Array:
    """Single-token decode attention against a contiguous KV cache."""
    B, S, Hk, D = k_cache.shape
    H = q.shape[1]
    group = H // Hk
    qf = q.astype(jnp.float32) * (D ** -0.5)
    qf = qf.reshape(B, Hk, group, D)
    s = jnp.einsum("bhgd,bshd->bhgs", qf, k_cache.astype(jnp.float32))
    if logit_softcap is not None:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    pos = jnp.arange(S)[None, :]                          # [1, S]
    mask = pos < cache_len[:, None]
    if window is not None:
        mask &= pos >= (cache_len[:, None] - window)
    s = jnp.where(mask[:, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)


def paged_decode_attention_ref(
    q: jax.Array,            # [B, H, D]
    k_pages: jax.Array,      # [n_pages, page_size, Hk, D]  global page pool
    v_pages: jax.Array,      # [n_pages, page_size, Hk, D]
    page_table: jax.Array,   # [B, pages_per_seq] int32     physical page ids
    seq_lens: jax.Array,     # [B] int32
    *,
    logit_softcap: Optional[float] = None,
) -> jax.Array:
    """Decode attention over a vLLM-style paged KV pool (oracle).

    Gathers each sequence's pages into a contiguous view, then defers to
    :func:`decode_attention_ref`.
    """
    B, H, D = q.shape
    n_pages, page_size, Hk, _ = k_pages.shape
    pages_per_seq = page_table.shape[1]
    k = k_pages[page_table].reshape(B, pages_per_seq * page_size, Hk, D)
    v = v_pages[page_table].reshape(B, pages_per_seq * page_size, Hk, D)
    return decode_attention_ref(
        q, k, v, seq_lens, logit_softcap=logit_softcap
    )


def batched_paged_decode_attention_ref(
    q: jax.Array,            # [B, H, D]
    k_pages: jax.Array,      # [n_pages, page_size, Hk, D]
    v_pages: jax.Array,      # [n_pages, page_size, Hk, D]
    page_table: jax.Array,   # [B, pages_per_seq] int32
    seq_lens: jax.Array,     # [B] int32 tokens resident BEFORE this step
    k_new: jax.Array,        # [B, Hk, D]
    v_new: jax.Array,        # [B, Hk, D]
    *,
    logit_softcap: Optional[float] = None,
) -> jax.Array:
    """Oracle for the batched decode kernel: scatter the new token into
    the gathered contiguous view at position ``seq_lens[b]``, then attend
    over ``seq_lens + 1`` tokens."""
    B, H, D = q.shape
    n_pages, page_size, Hk, _ = k_pages.shape
    pages_per_seq = page_table.shape[1]
    S = pages_per_seq * page_size
    k = k_pages[page_table].reshape(B, S, Hk, D)
    v = v_pages[page_table].reshape(B, S, Hk, D)
    rows = jnp.arange(B)
    k = k.at[rows, seq_lens].set(k_new.astype(k.dtype))
    v = v.at[rows, seq_lens].set(v_new.astype(v.dtype))
    return decode_attention_ref(
        q, k, v, seq_lens + 1, logit_softcap=logit_softcap
    )


def chunked_prefill_attention_ref(
    q: jax.Array,            # [B, chunk, H, D] query slab
    k_pages: jax.Array,      # [n_pages, page_size, Hk, D]
    v_pages: jax.Array,      # [n_pages, page_size, Hk, D]
    page_table: jax.Array,   # [B, pages_per_seq] int32
    q_offsets: jax.Array,    # [B] int32 absolute position of q[:, 0]
    kv_lens: jax.Array,      # [B] int32 resident tokens incl. this slab
    *,
    logit_softcap: Optional[float] = None,
) -> jax.Array:
    """Oracle for the fused chunked-prefill kernel: gather each
    sequence's pages into a contiguous view and apply query-offset causal
    masking at absolute positions (query row i sits at position
    ``q_offsets[b] + i``; rows past ``kv_lens`` come back as zeros)."""
    B, chunk, H, D = q.shape
    n_pages, page_size, Hk, _ = k_pages.shape
    pages_per_seq = page_table.shape[1]
    S = pages_per_seq * page_size
    group = H // Hk
    k = k_pages[page_table].reshape(B, S, Hk, D).astype(jnp.float32)
    v = v_pages[page_table].reshape(B, S, Hk, D).astype(jnp.float32)
    qf = (q.astype(jnp.float32) * (D ** -0.5)).reshape(B, chunk, Hk, group, D)
    s = jnp.einsum("bqhgd,bshd->bhgqs", qf, k)            # [B,Hk,g,chunk,S]
    if logit_softcap is not None:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    q_pos = q_offsets[:, None] + jnp.arange(chunk)[None, :]      # [B, chunk]
    k_pos = jnp.arange(S)[None, :]                               # [1, S]
    mask = (k_pos[:, None, :] <= q_pos[:, :, None])              # causal
    mask &= k_pos[:, None, :] < kv_lens[:, None, None]
    mask &= q_pos[:, :, None] < kv_lens[:, None, None]
    s = jnp.where(mask[:, None, None], s, -jnp.inf)
    # safe softmax: fully-masked rows (q_pos >= kv_len) -> zeros
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.where(jnp.isfinite(s), jnp.exp(s - jnp.where(
        jnp.isfinite(m), m, 0.0)), 0.0)
    l = e.sum(axis=-1, keepdims=True)
    p = e / jnp.where(l == 0.0, 1.0, l)
    out = jnp.einsum("bhgqs,bshd->bqhgd", p, v)
    return out.reshape(B, chunk, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Mamba2 SSD (state-space duality) oracles
# ---------------------------------------------------------------------------

def _segsum(a: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{j < t <= i} a[..., t].

    (the log-decay matrix of the SSD intra-chunk term; -inf above the
    diagonal)."""
    T = a.shape[-1]
    csum = jnp.cumsum(a, axis=-1)
    out = csum[..., :, None] - csum[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), dtype=bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_naive(
    x: jax.Array,            # [B, L, H, P]   (already dt-scaled)
    a: jax.Array,            # [B, L, H]      log decay per step (<= 0)
    b: jax.Array,            # [B, L, G, N]
    c: jax.Array,            # [B, L, G, N]
) -> jax.Array:
    """Quadratic "attention form" of SSD: y_i = sum_{j<=i} C_i^T B_j
    exp(sum_{j<t<=i} a_t) x_j. Oracle for small L."""
    B_, L, H, P = x.shape
    G = b.shape[2]
    rep = H // G
    bf = jnp.repeat(b.astype(jnp.float32), rep, axis=2)   # [B, L, H, N]
    cf = jnp.repeat(c.astype(jnp.float32), rep, axis=2)
    af = a.astype(jnp.float32)
    Lmat = jnp.exp(_segsum(af.transpose(0, 2, 1)))        # [B, H, L, L]
    scores = jnp.einsum("blhn,bshn->bhls", cf, bf) * Lmat
    y = jnp.einsum("bhls,bshp->blhp", scores, x.astype(jnp.float32))
    return y.astype(x.dtype)


def ssd_chunked(
    x: jax.Array,            # [B, L, H, P]
    a: jax.Array,            # [B, L, H]
    b: jax.Array,            # [B, L, G, N]
    c: jax.Array,            # [B, L, G, N]
    *,
    chunk: int = 256,
    return_final_state: bool = False,
):
    """SSD chunked scan (Mamba-2 paper ssd_minimal): intra-chunk quadratic
    term + inter-chunk recurrent state carry. Linear memory in L.

    Sequences that do not divide the chunk are zero-padded: pad tokens
    have x=0 (no state injection) and a=0 (decay exp(0)=1, state
    unchanged), so outputs at valid positions and the carried state are
    exact."""
    B_, L, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    chunk = max(1, min(chunk, L))
    pad = (-L) % chunk
    L_orig = L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        L = L + pad
    nc = L // chunk

    xf = x.astype(jnp.float32).reshape(B_, nc, chunk, H, P)
    af = a.astype(jnp.float32).reshape(B_, nc, chunk, H)
    bf = b.astype(jnp.float32).reshape(B_, nc, chunk, G, N)
    cf = c.astype(jnp.float32).reshape(B_, nc, chunk, G, N)
    bf = jnp.repeat(bf, rep, axis=3)                      # [B,nc,Q,H,N]
    cf = jnp.repeat(cf, rep, axis=3)

    a_t = af.transpose(0, 1, 3, 2)                        # [B,nc,H,Q]
    a_cs = jnp.cumsum(a_t, axis=-1)                       # inclusive cumsum
    Lmat = jnp.exp(_segsum(a_t))                          # [B,nc,H,Q,Q]

    # 1) intra-chunk (diagonal blocks)
    scores = jnp.einsum("bnqhk,bnshk->bnhqs", cf, bf) * Lmat
    y_diag = jnp.einsum("bnhqs,bnshp->bnqhp", scores, xf)

    # 2) per-chunk final states: state_n = sum_i exp(a_cs[-1]-a_cs[i]) B_i x_i^T
    decay_states = jnp.exp(a_cs[..., -1:] - a_cs)         # [B,nc,H,Q]
    states = jnp.einsum(
        "bnhq,bnqhk,bnqhp->bnhpk", decay_states, bf, xf
    )                                                      # [B,nc,H,P,N]

    # 3) inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(a_cs[..., -1])                  # [B,nc,H]

    def scan_body(h, inp):
        st, dec = inp                                      # [B,H,P,N], [B,H]
        h_new = h * dec[..., None, None] + st
        return h_new, h                                    # emit state *before* chunk

    h0 = jnp.zeros((B_, H, P, N), jnp.float32)
    h_final, h_prev = _scan(
        scan_body, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_prev = jnp.moveaxis(h_prev, 0, 1)                   # [B,nc,H,P,N]

    # 4) inter-chunk contribution: y_i += C_i^T (exp(a_cs[i]) * h_prev)
    in_decay = jnp.exp(a_cs)                              # [B,nc,H,Q]
    y_off = jnp.einsum(
        "bnqhk,bnhpk,bnhq->bnqhp", cf, h_prev, in_decay
    )

    y = (y_diag + y_off).reshape(B_, L, H, P)[:, :L_orig].astype(x.dtype)
    if return_final_state:
        return y, h_final
    return y


def ssm_decode_step_ref(
    h: jax.Array,            # [B, H, P, N] recurrent state
    x_t: jax.Array,          # [B, H, P]    dt-scaled input
    a_t: jax.Array,          # [B, H]       log decay this step
    b_t: jax.Array,          # [B, G, N]
    c_t: jax.Array,          # [B, G, N]
) -> Tuple[jax.Array, jax.Array]:
    """Single-token SSM recurrence (decode path): h' = e^a h + B x^T,
    y = C . h'. Constant memory — the SSM answer to a KV cache."""
    B_, H, P, N = h.shape
    G = b_t.shape[1]
    rep = H // G
    bf = jnp.repeat(b_t.astype(jnp.float32), rep, axis=1)  # [B,H,N]
    cf = jnp.repeat(c_t.astype(jnp.float32), rep, axis=1)
    h_new = h * jnp.exp(a_t.astype(jnp.float32))[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", x_t.astype(jnp.float32), bf
    )
    y = jnp.einsum("bhpn,bhn->bhp", h_new, cf)
    return y.astype(x_t.dtype), h_new
