"""Pallas TPU flash-attention (prefill/training hot spot).

TPU-native adaptation of the FlashAttention blocking scheme:

* grid = (batch, q_heads, q_blocks, kv_blocks) — the kv dimension is the
  *last* (sequential) grid axis, so VMEM scratch (accumulator, running
  max/denominator) persists across kv iterations of one q block;
* BlockSpecs stage 128-aligned q/k/v tiles HBM->VMEM; the [block_q,
  block_kv] score tile and the [block_q, D] accumulator live in VMEM and
  feed the MXU directly;
* online softmax in f32 VREGs; output written once on the final kv step;
* GQA is handled in the index map (kv block index = q_head // group) —
  no repeated-KV materialisation in HBM;
* causal + sliding-window masking skips kv blocks that are entirely
  masked (``pl.when`` around the whole body), so the causal case does
  ~L^2/2 work and the windowed case O(L * window).

Oracle: ``ref.mha_naive`` / ``ref.flash_attention_chunked`` (identical
math, same masking semantics).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_KV = 128
NEG_INF = -1e30  # avoids -inf NaN propagation inside exp on fully-masked rows


def _flash_kernel(
    q_ref, k_ref, v_ref,            # VMEM tiles
    o_ref,                          # output tile
    acc_ref, m_ref, l_ref,          # VMEM scratch
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    logit_softcap: Optional[float],
    prefix_len: int,
    q_offset: int,
    lk_valid: int,
    block_q: int,
    block_kv: int,
    n_kv_blocks: int,
):
    i = pl.program_id(2)            # q block
    j = pl.program_id(3)            # kv block

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # absolute positions of this tile
    q_start = i * block_q + q_offset
    k_start = j * block_kv

    # block-level skip: entirely-future (causal) or entirely-expired (window)
    run = k_start < lk_valid
    if causal:
        vis = k_start <= q_start + block_q - 1
        if prefix_len:
            vis = jnp.logical_or(vis, k_start < prefix_len)
        run = jnp.logical_and(run, vis)
    if window is not None:
        # newest query in tile: q_start + block_q - 1; oldest visible key:
        # q_pos - window + 1. Tile's newest key is k_start + block_kv - 1.
        vis = k_start + block_kv - 1 >= q_start - window + 1
        if prefix_len:
            vis = jnp.logical_or(vis, k_start < prefix_len)
        run = jnp.logical_and(run, vis)

    @pl.when(run)
    def _body():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale      # [bq, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)              # [bkv, D]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                      # [bq, bkv]
        if logit_softcap is not None:
            s = logit_softcap * jnp.tanh(s / logit_softcap)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        inner = jnp.ones((block_q, block_kv), dtype=jnp.bool_)
        if causal:
            inner = jnp.logical_and(inner, q_pos >= k_pos)
        if window is not None:
            inner = jnp.logical_and(inner, q_pos - k_pos < window)
        if prefix_len:
            inner = jnp.logical_or(inner, k_pos < prefix_len)
        mask = jnp.logical_and(k_pos < lk_valid, inner)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]                                   # [bq]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[:, 0] = m_new

    @pl.when(j == n_kv_blocks - 1)
    def _finalize():
        l = l_ref[:, 0]
        l = jnp.where(l == 0.0, 1.0, l)                        # fully-masked rows
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,            # [B, Lq, H, D]
    k: jax.Array,            # [B, Lk, Hk, D]
    v: jax.Array,            # [B, Lk, Hk, D]
    *,
    causal: bool = True,
    window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    prefix_len: int = 0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
    interpret: bool = False,
) -> jax.Array:
    """GQA flash attention. Queries are aligned to the *end* of the key
    sequence when Lq < Lk (decode-style)."""
    B, Lq, H, D = q.shape
    _, Lk, Hk, _ = k.shape
    assert H % Hk == 0, (H, Hk)
    group = H // Hk

    block_q = min(block_q, max(Lq, 8))
    block_kv = min(block_kv, max(Lk, 8))
    nq = -(-Lq // block_q)
    nk = -(-Lk // block_kv)
    pad_q = nq * block_q - Lq
    pad_k = nk * block_kv - Lk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    kernel = functools.partial(
        _flash_kernel,
        scale=D ** -0.5,
        causal=causal,
        window=window,
        logit_softcap=logit_softcap,
        prefix_len=prefix_len,
        q_offset=Lk - Lq,
        lk_valid=Lk,
        block_q=block_q,
        block_kv=block_kv,
        n_kv_blocks=nk,
    )

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, block_kv, 1, D), lambda b, h, i, j: (b, j, h // group, 0)),
            pl.BlockSpec((1, block_kv, 1, D), lambda b, h, i, j: (b, j, h // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, D), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nq * block_q, H, D), q.dtype),
        scratch_shapes=[
            # f32 VMEM scratch persisted across the sequential kv axis
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Lq]
