"""Distribution substrate: sharding rules, optimizer, compression,
checkpointing, elastic re-scale."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import smoke_config
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.fault_tolerance import (HeartbeatMonitor,
                                               StragglerDetector,
                                               elastic_plan)
from repro.distributed.optimizer import (Optimizer, OptimizerConfig,
                                         compressed_psum, dequantize_int8,
                                         lr_schedule, quantize_int8)
from repro.distributed.sharding import logical_to_spec, param_shardings
from repro.models.registry import abstract_params, get_api


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_logical_rules_divisibility_fallback():
    mesh = _FakeMesh({"data": 16, "model": 16})
    # divisible: sharded
    assert logical_to_spec(["batch", None, "model"], (256, 10, 4096),
                           mesh) == P("data", None, "model")
    # 9 heads % 16 != 0 -> replicated on that dim
    assert logical_to_spec([None, "model", None], (576, 9, 64),
                           mesh) == P(None, None, None)
    # batch == 32 divides data=16 but not pod*data
    mesh3 = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    spec = logical_to_spec(["batch"], (32,), mesh3)
    assert spec == P(("pod", "data"))


def test_param_shardings_cover_whole_tree():
    cfg = smoke_config("minitron-8b")
    aparams = abstract_params(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shard, by_path = param_shardings(aparams, mesh)
    n_leaves = len(jax.tree_util.tree_leaves(aparams))
    assert len(jax.tree_util.tree_leaves(shard)) == n_leaves
    assert len(by_path) == n_leaves


def test_optimizer_converges_quadratic():
    """AdamW drives a toy quadratic to its minimum."""
    opt = Optimizer(OptimizerConfig(lr=0.05, weight_decay=0.0,
                                    warmup_steps=1, decay_steps=10_000))
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    target = jnp.array([1.0, 2.0, -1.0])
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        grads = {"w": 2 * (params["w"] - target)}
        return opt.update(params, grads, state)

    for _ in range(400):
        params, state, metrics = step(params, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)
    assert int(state["step"]) == 400


def test_lr_schedule_warmup_and_decay():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, decay_steps=100,
                          min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in
           (1, 5, 10, 50, 100, 1000)]
    assert lrs[0] < lrs[1] < lrs[2]              # warmup rises
    assert lrs[2] >= lrs[3] >= lrs[4]            # cosine decays
    assert lrs[-1] == pytest.approx(0.1, rel=1e-3)


def test_grad_clip_bounds_update():
    opt = Optimizer(OptimizerConfig(grad_clip=1.0))
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    _, _, metrics = opt.update(params, {"w": jnp.full((4,), 1e6)}, state)
    assert float(metrics["grad_norm"]) > 1e5     # raw norm reported


def test_int8_quantization_roundtrip_error_bounded():
    x = jnp.asarray(np.random.RandomState(0).randn(1000).astype(np.float32))
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
    assert err.max() <= float(scale) * 0.5 + 1e-7


def test_compressed_psum_error_feedback_is_unbiased():
    """Across steps, error feedback recovers what quantisation drops:
    cumulative compressed sum -> cumulative true sum."""
    import functools
    g = jnp.asarray(np.random.RandomState(1).randn(64).astype(np.float32))

    def run(n_steps):
        err = jnp.zeros_like(g)
        total_comp = jnp.zeros_like(g)
        for _ in range(n_steps):
            out = jax.experimental.shard_map.shard_map(
                lambda gg, ee: compressed_psum(gg, ee, "data"),
                mesh=jax.make_mesh((1,), ("data",)),
                in_specs=(P(), P()), out_specs=(P(), P()),
            )(g, err)
            red, err = out
            total_comp = total_comp + red
        return total_comp

    n = 50
    got = np.asarray(run(n))
    expect = np.asarray(g) * n
    # relative error shrinks ~1/n thanks to error feedback
    assert np.abs(got - expect).max() / np.abs(expect).max() < 0.01


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "opt": {"m": jnp.ones((2, 3))}}
    mgr.save(10, state, scheduler_state={"policy": "fifo", "bias": {}})
    mgr.save(20, state, scheduler_state={"policy": "fifo", "bias": {}})
    step, restored, sched = mgr.restore(state)
    assert step == 20
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    assert sched["policy"] == "fifo"


def test_checkpoint_gc_keeps_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    state = {"x": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.list_steps() == [3, 4]


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(1, {"x": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        mgr.restore({"x": jnp.zeros((3, 3))})


def test_checkpoint_resume_mid_experiment(tmp_path):
    """Scheduler state restores and the experiment continues."""
    from repro.core.scheduler import DriftScheduler
    s = DriftScheduler("weighted")
    from repro.core.request import Category, Request, TenantTier
    for i in range(8):
        r = s.submit(Request(tenant=TenantTier.STANDARD,
                             category=Category.SUMMARY, prompt="a b c"),
                     now=float(i))
        s.dispatch(float(i))
        s.complete(r, 200 + i, float(i) + 1)
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(8, {"dummy": jnp.zeros(1)}, scheduler_state=s.state_dict())
    _, _, sched_state = mgr.restore({"dummy": jnp.zeros(1)})
    s2 = DriftScheduler("weighted")
    s2.load_state_dict(sched_state)
    assert s2.bias_store.snapshot() == s.bias_store.snapshot()


def test_heartbeat_detects_dead_worker():
    hb = HeartbeatMonitor(timeout=5.0)
    hb.beat(0, 0.0)
    hb.beat(1, 0.0)
    hb.beat(0, 8.0)
    assert hb.dead_workers(10.0) == [1]
    assert hb.alive(10.0) == [0]
    hb.beat(1, 11.0)                      # rejoin
    assert hb.dead_workers(12.0) == []


def test_straggler_detector_flags_slow_worker():
    det = StragglerDetector(threshold=1.5)
    for _ in range(5):
        det.observe(0, 1.0)
        det.observe(1, 1.1)
        det.observe(2, 5.0)
    assert det.stragglers() == [2]
    assert det.should_hedge(wait_time=10.0, p99_expected=4.0)
    assert not det.should_hedge(wait_time=2.0, p99_expected=4.0)


def test_elastic_plan_keeps_tp_when_possible():
    plan = elastic_plan(240, model_parallel=16)
    assert plan.mesh_shape == (15, 16)
    assert plan.dropped_chips == 0
    plan2 = elastic_plan(10, model_parallel=16)  # less than one TP group
    assert plan2.mesh_shape[1] <= 8
