"""Differential engine↔simulator parity suite.

Pins the real JAX ``ServingEngine``'s iteration-level execution to the
discrete-event step engine's semantics (``serving/simulator.py``), so
everything validated on the simulator — chunked prefill, continuous
joins, shared-prefix reuse, per-step admission caps — provably
transfers to engine-backed runs.

Two layers of contract:

* **Legacy lock.** With ``chunk_prefill_tokens=None`` and no prefix
  cache, the engine must reproduce the pre-chunking whole-bucket
  engine bit-for-bit. The goldens below were recorded from that code
  (completion order by submission index, observed tokens, completion
  step) — they depend only on oracle-EOS targets and scheduling, never
  on sampled token values, so they are platform-stable.
* **Differential parity.** The same seeded workload through both
  executors with matched configs (simulator ``prefix_page_tokens`` ==
  engine ``page_size``, ``batch_capacity`` == ``n_slots``, zero cost
  jitter — the cost model is the simulator's only clock) must agree on
  per-request completion order, cached-token counts, observed lengths,
  and TTFT ordering. Comparisons are *iteration-rank* level (sequences
  of same-iteration tie groups): the engine clocks iterations in
  ``dt`` units while the simulator prices them, and the engine's
  slot-ring legacy emits one extra token in the prefill-completion
  step — a uniform one-iteration shift that preserves ordering.

This suite intentionally imports jax unconditionally: CI treats a
skip of these tests as a failure (a silent JAX-import skip would make
the parity contract vacuous).
"""

import math

import jax
import pytest

from repro.cluster.driver import make_engine_cluster
from repro.cluster.replica import ReplicaRole
from repro.cluster.simulator import ClusterConfig, ClusterSimulator
from repro.configs import smoke_config
from repro.core.scheduler import DriftScheduler
from repro.models.registry import get_api
from repro.serving.cost_model import L4_QWEN_1_8B
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.simulator import SimConfig, WorkerSimulator
from repro.workload.generator import (ArrivalPlan, GeneratorConfig,
                                      WorkloadGenerator)

from dataclasses import replace

CFG = smoke_config("smollm-135m")
PARAMS = get_api(CFG).init(CFG, jax.random.PRNGKey(0))

#: matched-config constants: engine bucket/page vs simulator page
BUCKET = 64
PAGE = 8
SLOTS = 4
MAX_TOKENS = 24          # target cap; <= max_len - BUCKET - 2


def _requests(n, seed, *, shared=0, groups=2, max_tokens=MAX_TOKENS):
    """Seeded workload, arrival-ordered. Output lengths are bumped to
    >= 2: the engine's slot-ring legacy decodes once in the prefill
    step, so a one-token request completes an iteration earlier there
    than on the simulator — the only intentional semantic gap."""
    gen = WorkloadGenerator(GeneratorConfig(
        total_requests=n, calibration_requests=n, max_tokens=max_tokens,
        seed=seed, shared_prefix_tokens=shared,
        prefix_groups_per_tenant=groups))
    reqs = [r for _, r in gen.plan(seed=seed).calibration]
    for r in reqs:
        r.true_output_tokens = max(r.true_output_tokens, 2)
        assert r.prompt_tokens <= BUCKET, "parity needs prompts in-bucket"
    return reqs


def _run_engine(reqs, *, paged=True, chunk=None, prefix=False,
                policy="fifo", max_new=None, cache_pages=64):
    sched = DriftScheduler(policy=policy, max_new_per_step=max_new)
    eng = ServingEngine(CFG, PARAMS, sched,
                        EngineConfig(n_slots=SLOTS, max_len=96,
                                     prompt_buckets=(BUCKET,),
                                     paged=paged, page_size=PAGE,
                                     chunk_prefill_tokens=chunk,
                                     prefix_cache=prefix,
                                     prefix_cache_pages=cache_pages))
    for i, r in enumerate(reqs):
        sched.submit(r, 1e-6 * i)
    m = eng.run_until_drained(max_steps=20_000)
    assert m.n_completed == len(reqs)
    return sched, eng


def _run_sim(reqs, *, chunk=None, prefix=False, policy="fifo",
             max_new=None, cache_pages=512):
    sched = DriftScheduler(policy=policy, max_new_per_step=max_new)
    plan = ArrivalPlan(
        calibration=[(1e-6 * i, r) for i, r in enumerate(reqs)],
        stress=[],
        config=GeneratorConfig(total_requests=len(reqs),
                               calibration_requests=len(reqs)))
    sim = WorkerSimulator(
        sched, plan,
        SimConfig(step_engine=True, continuous_joins=True,
                  chunk_prefill_tokens=chunk,
                  batch_capacity=SLOTS, prefix_cache=prefix,
                  prefix_cache_pages=cache_pages,
                  prefix_page_tokens=PAGE, seed=0),
        cost_model=replace(L4_QWEN_1_8B, jitter_sigma=0.0))
    m = sim.run()
    assert m.n_completed == len(reqs)
    return sched, sim


def _groups(reqs, completed, stamp):
    """Same-iteration tie groups, in time order, as frozensets of
    submission indices."""
    idx = {r.req_id: i for i, r in enumerate(reqs)}
    out, seen = [], {}
    for r in completed:
        t = stamp(r)
        if t not in seen:
            seen[t] = frozenset()
            out.append(t)
        seen[t] = seen[t] | {idx[r.req_id]}
    return [seen[t] for t in out]


def _completion_groups(reqs, sched):
    return _groups(reqs, sched.completed, lambda r: r.exec_end)


def _ttft_groups(reqs, sched):
    done = sorted(sched.completed, key=lambda r: r.prefill_end)
    return _groups(reqs, done, lambda r: r.prefill_end)


# ----------------------------------------------------------------------
# Legacy lock: chunk-∞ / cache-off reproduces the pre-chunking engine
# ----------------------------------------------------------------------
# Recorded from the whole-bucket engine at commit ef0e5fb:
# smollm-135m smoke, n_slots=3, max_len=96, buckets=(16,), page_size=8,
# 14 requests (seed 7, max_tokens=64, generator-native outputs),
# dt=1.0. Tuples: (submission index, observed tokens, completion step).
_GOLD_FIFO = [(0, 50, 48.0), (2, 57, 55.0), (1, 64, 62.0), (3, 64, 111.0),
              (4, 64, 118.0), (5, 64, 125.0), (7, 48, 165.0),
              (6, 64, 174.0), (8, 64, 188.0), (10, 53, 226.0),
              (9, 64, 228.0), (11, 57, 244.0), (12, 53, 278.0),
              (13, 64, 291.0)]
_GOLD_SJF = [(12, 53, 51.0), (11, 57, 55.0), (9, 64, 62.0), (0, 50, 100.0),
             (7, 48, 102.0), (10, 53, 114.0), (2, 57, 156.0),
             (8, 64, 165.0), (4, 64, 177.0), (5, 64, 219.0),
             (6, 64, 228.0), (3, 64, 240.0), (13, 64, 282.0),
             (1, 64, 291.0)]
_GOLD_CAP1 = [(0, 50, 48.0), (2, 57, 57.0), (1, 64, 63.0), (3, 64, 111.0),
              (4, 64, 120.0), (5, 64, 126.0), (7, 48, 167.0),
              (6, 64, 174.0), (8, 64, 189.0), (10, 53, 226.0),
              (9, 64, 230.0), (11, 57, 245.0), (12, 53, 278.0),
              (13, 64, 293.0)]


def _legacy_run(*, paged, policy="fifo", max_new=None):
    sched = DriftScheduler(policy=policy, max_new_per_step=max_new)
    eng = ServingEngine(CFG, PARAMS, sched,
                        EngineConfig(n_slots=3, max_len=96,
                                     prompt_buckets=(16,),
                                     paged=paged, page_size=8))
    gen = WorkloadGenerator(GeneratorConfig(
        total_requests=14, calibration_requests=14, max_tokens=64, seed=7))
    plan = gen.plan(seed=7)
    idx = {r.req_id: i for i, (_, r) in enumerate(plan.calibration)}
    for t, r in plan.calibration:
        sched.submit(r, t)
    eng.run_until_drained(max_steps=5000)
    return [(idx[r.req_id], r.observed_output_tokens, r.exec_end)
            for r in sched.completed], eng


def test_legacy_golden_fifo_contiguous():
    rec, _ = _legacy_run(paged=False)
    assert rec == _GOLD_FIFO


def test_legacy_golden_fifo_paged():
    rec, eng = _legacy_run(paged=True)
    assert rec == _GOLD_FIFO
    assert eng.alloc.free_pages == eng.alloc.n_pages   # fully drained


def test_legacy_golden_sjf():
    rec, _ = _legacy_run(paged=False, policy="sjf")
    assert rec == _GOLD_SJF


def test_legacy_golden_max_new_per_step():
    rec, _ = _legacy_run(paged=True, max_new=1)
    assert rec == _GOLD_CAP1


# ----------------------------------------------------------------------
# Differential parity: engine vs simulator step engine
# ----------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [None, 16])
def test_parity_completion_order(chunk):
    """Per-request completion order (same-iteration ties preserved)
    and observed lengths agree between the executors, with and without
    a chunk budget."""
    e_sched, _ = _run_engine(_requests(18, seed=11), chunk=chunk)
    s_sched, _ = _run_sim(_requests(18, seed=11), chunk=chunk)
    e_reqs = sorted(e_sched.completed, key=lambda r: r.req_id)
    s_reqs = sorted(s_sched.completed, key=lambda r: r.req_id)
    assert [r.observed_output_tokens for r in e_reqs] == \
        [r.observed_output_tokens for r in s_reqs]
    assert _completion_groups([r for r in e_reqs], e_sched) == \
        _completion_groups([r for r in s_reqs], s_sched)


def test_parity_completion_order_contiguous_engine():
    """Chunking is execution-agnostic: the slot-ring (non-paged)
    engine obeys the same iteration semantics."""
    e_sched, _ = _run_engine(_requests(14, seed=3), paged=False, chunk=16)
    s_sched, _ = _run_sim(_requests(14, seed=3), chunk=16)
    e_reqs = sorted(e_sched.completed, key=lambda r: r.req_id)
    s_reqs = sorted(s_sched.completed, key=lambda r: r.req_id)
    assert _completion_groups(e_reqs, e_sched) == \
        _completion_groups(s_reqs, s_sched)


def test_parity_sjf_policy():
    """Policy-driven dispatch order survives the executor swap."""
    e_sched, _ = _run_engine(_requests(16, seed=5), chunk=16, policy="sjf")
    s_sched, _ = _run_sim(_requests(16, seed=5), chunk=16, policy="sjf")
    e_reqs = sorted(e_sched.completed, key=lambda r: r.req_id)
    s_reqs = sorted(s_sched.completed, key=lambda r: r.req_id)
    assert _completion_groups(e_reqs, e_sched) == \
        _completion_groups(s_reqs, s_sched)


def test_parity_max_new_per_step():
    """The per-iteration admission cap interleaves identically."""
    e_sched, _ = _run_engine(_requests(14, seed=9), chunk=16, max_new=1)
    s_sched, _ = _run_sim(_requests(14, seed=9), chunk=16, max_new=1)
    e_reqs = sorted(e_sched.completed, key=lambda r: r.req_id)
    s_reqs = sorted(s_sched.completed, key=lambda r: r.req_id)
    assert _completion_groups(e_reqs, e_sched) == \
        _completion_groups(s_reqs, s_sched)


def test_parity_ttft_rank_order():
    """Honest TTFT: both executors stamp ``prefill_end`` at the
    prefill-completing iteration — the tie-group sequences agree
    exactly (no one-iteration shift here: the first token lands at the
    same iteration on both sides)."""
    e_sched, _ = _run_engine(_requests(16, seed=13), chunk=12)
    s_sched, _ = _run_sim(_requests(16, seed=13), chunk=12)
    e_reqs = sorted(e_sched.completed, key=lambda r: r.req_id)
    s_reqs = sorted(s_sched.completed, key=lambda r: r.req_id)
    assert all(r.prefill_end is not None for r in e_reqs)
    assert _ttft_groups(e_reqs, e_sched) == _ttft_groups(s_reqs, s_sched)


def test_parity_cached_tokens_shared_prefix():
    """Shared-prefix workload: per-request realized cached-token
    counts (and the aggregate hit/miss/saved counters) agree — the
    engine's page-donation radix cache and the simulator's accounting
    cache converge on the same residency."""
    e_sched, eng = _run_engine(
        _requests(24, seed=17, shared=16, groups=2), chunk=16, prefix=True)
    s_sched, sim = _run_sim(
        _requests(24, seed=17, shared=16, groups=2), chunk=16, prefix=True)
    e_reqs = sorted(e_sched.completed, key=lambda r: r.req_id)
    s_reqs = sorted(s_sched.completed, key=lambda r: r.req_id)
    assert [r.cached_prompt_tokens for r in e_reqs] == \
        [r.cached_prompt_tokens for r in s_reqs]
    assert sum(r.cached_prompt_tokens for r in e_reqs) > 0
    e_stats, s_stats = eng.prefix_cache_stats(), sim.prefix_cache_stats()
    for k in ("hits", "misses", "tokens_saved"):
        assert e_stats[k] == s_stats[k], k


def test_parity_completion_order_shared_prefix():
    """Cache hits shorten prefill identically on both sides: the
    completion order still matches with the prefix cache on."""
    e_sched, _ = _run_engine(
        _requests(24, seed=17, shared=16, groups=2), chunk=16, prefix=True)
    s_sched, _ = _run_sim(
        _requests(24, seed=17, shared=16, groups=2), chunk=16, prefix=True)
    e_reqs = sorted(e_sched.completed, key=lambda r: r.req_id)
    s_reqs = sorted(s_sched.completed, key=lambda r: r.req_id)
    assert _completion_groups(e_reqs, e_sched) == \
        _completion_groups(s_reqs, s_sched)


def test_engine_prefix_page_conservation_after_drain():
    """Engine-side page accounting: after a shared-prefix run drains,
    every page is either free, or resident in the tree with zero
    refcount (no slot left a stranded pin)."""
    _, eng = _run_engine(
        _requests(20, seed=19, shared=16, groups=2), chunk=16, prefix=True)
    assert eng.alloc.free_pages + eng.ledger.owned_pages() \
        + eng.prefix_tree.total_pages() == eng.alloc.n_pages
    assert eng.ledger.owned_pages() == 0
    assert all(n.refcount == 0 for n in eng.prefix_tree._nodes())
    # the cache survives the drain (that is the point): clearing it
    # returns the pool to fully free
    eng.prefix_tree.clear()
    assert eng.alloc.free_pages == eng.alloc.n_pages


# ----------------------------------------------------------------------
# Per-chunk device execution: the fused chunked-prefill kernel runs
# every budget grant the iteration it lands (no single-shot remainder)
# ----------------------------------------------------------------------

def test_engine_prefill_executes_per_chunk():
    """A paged engine with a chunk budget launches one device prefill
    per consumed chunk — ``ceil(prompt/chunk)`` launches for a lone
    request (the final launch extends through the bucket padding so
    the whole bucket is resident for decode), never a single deferred
    whole-bucket prefill."""
    reqs = _requests(1, seed=43)
    prompt = reqs[0].prompt_tokens
    assert prompt > 16, "need a multi-chunk prompt"
    sched, eng = _run_engine(reqs, chunk=16)
    assert eng.n_prefill_launches == math.ceil(prompt / 16)
    slots = {s for s, _ in eng.prefill_chunk_log}
    assert len(slots) == 1
    # the executed chunk lengths tile the bucket exactly
    assert sum(n for _, n in eng.prefill_chunk_log) == BUCKET


def test_engine_prefill_chunk_launch_accounting_multislot():
    """Concurrent prefills: every request's executed chunks tile its
    bucket, and the launch count is the per-chunk total — strictly more
    launches than requests (per-chunk execution, not one-shot)."""
    reqs = _requests(12, seed=31)
    assert any(r.prompt_tokens > 16 for r in reqs)
    sched, eng = _run_engine(reqs, chunk=16)
    assert eng.n_prefill_launches == len(eng.prefill_chunk_log)
    assert eng.n_prefill_launches > len(reqs)
    assert sum(n for _, n in eng.prefill_chunk_log) == BUCKET * len(reqs)


def test_engine_chunk_budget_conserves_tokens():
    """Chunked prefill consumes exactly the uncached prompt: realized
    cache credit + chunked prefill == prompt for every request, and a
    finite budget produces prefill-only iterations (busy steps grow)
    without changing completions."""
    reqs_a = _requests(12, seed=23, shared=16, groups=2)
    reqs_b = _requests(12, seed=23, shared=16, groups=2)
    sched_a, eng_a = _run_engine(reqs_a, chunk=None, prefix=True)
    sched_b, eng_b = _run_engine(reqs_b, chunk=4, prefix=True)
    obs_a = sorted((r.observed_output_tokens) for r in sched_a.completed)
    obs_b = sorted((r.observed_output_tokens) for r in sched_b.completed)
    assert obs_a == obs_b
    assert eng_b.step_count > eng_a.step_count     # budget stretches prefill
    for r in sched_b.completed:
        assert r.prefill_end is not None
        assert r.prefill_end <= r.exec_end


# ----------------------------------------------------------------------
# P/D disaggregation: engine cluster vs cluster simulator
# ----------------------------------------------------------------------
# Matched two-replica pool (one prefill + one decode engine) so the
# stage-2 placement has a single destination — routing-load feedback
# cannot diverge and parity isolates the handoff protocol itself. The
# KV delay is constant (per-token cost zero) so transfer arrival order
# equals prefill completion order on both executors.
#
# Completion *tie groups* are not comparable across executors here:
# the engine steps every replica on one lockstep ``dt`` clock while
# the simulator prices prefill and decode iterations at very different
# durations, so handed-off work joins the decode replica in different
# cohort sizes. The order-bearing P/D signals — TTFT anchors (stamped
# at the prefill-completing iteration) and handoff arrival order — are
# compared tie-exact; full completion order is compared on a capped
# workload where it is cohort-independent.

def _run_engine_pd(reqs, *, chunk=16, kv_base=0.002):
    drv = make_engine_cluster(
        CFG, PARAMS, 2, policy="fifo", routing="pd_disaggregated",
        engine_config=EngineConfig(n_slots=SLOTS, max_len=96,
                                   prompt_buckets=(BUCKET,),
                                   paged=True, page_size=PAGE,
                                   chunk_prefill_tokens=chunk),
        n_prefill_replicas=1,
        kv_transfer_base=kv_base, kv_transfer_per_token=0.0)
    for i, r in enumerate(reqs):
        assert drv.submit(r, 1e-6 * i)
    m = drv.run_until_drained(max_steps=20_000)
    assert m.n_completed == len(reqs)
    return drv


def _run_sim_pd(reqs, *, chunk=16, kv_base=0.002):
    plan = ArrivalPlan(
        calibration=[(1e-6 * i, r) for i, r in enumerate(reqs)],
        stress=[],
        config=GeneratorConfig(total_requests=len(reqs),
                               calibration_requests=len(reqs)))
    sim = ClusterSimulator(plan, ClusterConfig(
        n_replicas=2, routing="pd_disaggregated", n_prefill_replicas=1,
        scheduler_policy="fifo", batch_capacity=SLOTS, step_engine=True,
        continuous_joins=True, chunk_prefill_tokens=chunk,
        prefix_page_tokens=PAGE,
        kv_transfer_base=kv_base, kv_transfer_per_token=0.0, seed=0),
        cost_model=replace(L4_QWEN_1_8B, jitter_sigma=0.0))
    m = sim.run()
    assert m.run.n_completed == len(reqs)
    return sim


def _pd_done(reqs, replicas):
    idx = {r.req_id: i for i, r in enumerate(reqs)}
    done = [r for rep in replicas for r in rep.sched.completed]
    assert len(done) == len(reqs)
    return idx, done


def _stamp_groups(idx, done, stamp):
    out, seen = [], {}
    for r in sorted(done, key=lambda r: (stamp(r), idx[r.req_id])):
        t = stamp(r)
        if t not in seen:
            seen[t] = set()
            out.append(t)
        seen[t].add(idx[r.req_id])
    return [frozenset(seen[t]) for t in out]


def test_pd_parity_ttft_and_handoff_anchors():
    """Engine-backed P/D vs the cluster simulator at a matched seed:
    observed lengths agree per request, every request prefills on the
    prefill replica and decodes on the decode replica, and both TTFT
    anchors (prefill-completing iteration) and KV-arrival order match
    tie-exact."""
    def mixed(reqs):
        # plant varied oracle lengths (the generator's calibration
        # outputs all hit the cap) — identical on both sides
        for i, r in enumerate(reqs):
            r.true_output_tokens = 3 + (5 * i) % 20
        return reqs
    reqs_e = mixed(_requests(16, seed=11))
    reqs_s = mixed(_requests(16, seed=11))
    drv = _run_engine_pd(reqs_e)
    sim = _run_sim_pd(reqs_s)
    assert drv.n_handoffs == sim.n_handoffs == 16
    ie, de = _pd_done(reqs_e, drv.replicas)
    is_, ds = _pd_done(reqs_s, sim.replicas)
    assert sorted((ie[r.req_id], r.observed_output_tokens) for r in de) == \
        sorted((is_[r.req_id], r.observed_output_tokens) for r in ds)
    for idx, done in ((ie, de), (is_, ds)):
        assert all(r.prefill_rid == 0 and r.decode_rid == 1 for r in done)
        assert all(r.handoff_time is not None
                   and r.handoff_time >= r.prefill_end for r in done)
        assert all(r.ttft < r.e2e_latency for r in done)
    assert _stamp_groups(ie, de, lambda r: r.prefill_end) == \
        _stamp_groups(is_, ds, lambda r: r.prefill_end)
    assert _stamp_groups(ie, de, lambda r: r.handoff_time) == \
        _stamp_groups(is_, ds, lambda r: r.handoff_time)


def test_pd_parity_completion_order_capped():
    """On a target-capped workload (completion order is decided by
    handoff order, independent of join-cohort sizes) the end-to-end
    completion order matches the simulator exactly."""
    reqs_e = _requests(16, seed=11)          # MAX_TOKENS caps every target
    reqs_s = _requests(16, seed=11)
    assert all(min(r.true_output_tokens, r.max_tokens) == MAX_TOKENS
               for r in reqs_e)
    drv = _run_engine_pd(reqs_e)
    sim = _run_sim_pd(reqs_s)
    ie, de = _pd_done(reqs_e, drv.replicas)
    is_, ds = _pd_done(reqs_s, sim.replicas)
    order_e = [ie[r.req_id]
               for r in sorted(de, key=lambda r: (r.exec_end, ie[r.req_id]))]
    order_s = [is_[r.req_id]
               for r in sorted(ds, key=lambda r: (r.exec_end, is_[r.req_id]))]
    assert order_e == order_s


def test_pd_engine_page_movement_and_conservation():
    """The handoff moves real pages: prefill happens only on the
    prefill engine (its chunk launches cover every prompt), decode-side
    pages are injected (zero prefill launches there), drift feedback
    fires exactly once per request attributed to the decode phase, and
    after the drain every page on every engine is back in its free
    pool."""
    reqs = _requests(16, seed=13)
    drv = _run_engine_pd(reqs)
    pre, dec = drv.replicas
    assert pre.role is ReplicaRole.PREFILL
    assert dec.role is ReplicaRole.DECODE
    # prefill ran (per-chunk) only on the prefill engine
    assert pre.engine.n_prefill_launches > 0
    assert dec.engine.n_prefill_launches == 0
    assert sum(n for _, n in pre.engine.prefill_chunk_log) == \
        BUCKET * len(reqs)
    # the prefill engine never completes anything; the decode engine
    # completes everything
    assert len(pre.sched.completed) == 0
    assert len(dec.sched.completed) == len(reqs)
    assert pre.n_handoffs_out == dec.n_handoffs_in == len(reqs)
    # at-most-once drift feedback, attributed to decode
    phases = {}
    for rep in drv.replicas:
        for k, v in rep.sched.phase_feedback_counts.items():
            phases[k] = phases.get(k, 0) + v
    assert phases == {"decode": len(reqs)}
    # page conservation: both pools fully free, no transfer stranded
    assert not drv._in_transit
    for rep in drv.replicas:
        assert rep.engine.alloc.free_pages == rep.engine.alloc.n_pages
        assert rep.engine.ledger.owned_pages() == 0


def test_pd_engine_failure_reprefill():
    """Failure-safe re-prefill over live engines: killing the decode
    engine mid-run loses its injected pages; stranded requests reset to
    the pre-prefill state, reroute through stage-1 routing, prefill
    again, and every request still completes with exactly one drift
    feedback."""
    reqs = _requests(14, seed=17)
    drv = make_engine_cluster(
        CFG, PARAMS, 3, policy="fifo", routing="pd_disaggregated",
        engine_config=EngineConfig(n_slots=SLOTS, max_len=96,
                                   prompt_buckets=(BUCKET,),
                                   paged=True, page_size=PAGE,
                                   chunk_prefill_tokens=16),
        n_prefill_replicas=1,
        kv_transfer_base=0.002, kv_transfer_per_token=0.0)
    for i, r in enumerate(reqs):
        assert drv.submit(r, 1e-6 * i)
    now, steps = 0.0, 0
    while not drv.replicas[1].engine.active_slots():
        drv.step(now)
        now += 1.0
        steps += 1
        assert steps < 1000, "decode replica never became active"
    drv.fail_replica(1, now)
    assert drv.n_rerouted > 0
    while not drv._drained():
        drv.step(now)
        now += 1.0
        steps += 1
        assert steps < 20_000, "cluster failed to drain after failure"
    done = [r for rep in drv.replicas for r in rep.sched.completed]
    assert len(done) == len(reqs)
    # work that died on the decode engine prefilled twice -> extra
    # handoffs beyond one per request
    assert drv.n_handoffs > len(reqs)
    assert all(r.decode_rid == 2 for r in done
               if r.handoff_time is not None)
    phases = {}
    for rep in drv.replicas:
        for k, v in rep.sched.phase_feedback_counts.items():
            phases[k] = phases.get(k, 0) + v
    assert sum(phases.values()) == len(reqs)
    for rep in drv.replicas:
        if rep.rid != 1:        # the dead pool keeps its last state
            assert rep.engine.alloc.free_pages == rep.engine.alloc.n_pages


def test_pd_engine_transfer_loss_on_source_failure():
    """A KV transfer in flight when its source prefill engine dies is
    lost (the payload pages existed only there): the request re-runs
    prefill on the surviving prefill engine and completes."""
    reqs = _requests(8, seed=19)
    drv = make_engine_cluster(
        CFG, PARAMS, 4, policy="fifo", routing="pd_disaggregated",
        engine_config=EngineConfig(n_slots=SLOTS, max_len=96,
                                   prompt_buckets=(BUCKET,),
                                   paged=True, page_size=PAGE,
                                   chunk_prefill_tokens=16),
        n_prefill_replicas=2,
        kv_transfer_base=50.0, kv_transfer_per_token=0.0)  # long flight
    for i, r in enumerate(reqs):
        assert drv.submit(r, 1e-6 * i)
    now, steps = 0.0, 0
    while not any(t.src_rid == 0 for t in drv._in_transit.values()):
        drv.step(now)
        now += 1.0
        steps += 1
        assert steps < 1000, "no transfer ever departed replica 0"
    drv.fail_replica(0, now)
    assert drv.n_handoffs_lost > 0
    while not drv._drained():
        drv.step(now)
        now += 1.0
        steps += 1
        assert steps < 20_000
    done = [r for rep in drv.replicas for r in rep.sched.completed]
    assert len(done) == len(reqs)
    # every completed request decoded from a transfer that survived:
    # its prefill ran on the surviving prefill engine (rid 1) if its
    # original KV was lost
    assert all(r.prefill_rid in (0, 1) for r in done)
    assert any(r.prefill_rid == 1 for r in done)


def test_pd_engine_work_stealing_retransfers_kv():
    """Decode-ready work stolen off a backlogged decode engine pays a
    fresh KV transfer: the payload detaches from the victim queue and
    lands on the thief, which completes it."""
    reqs = _requests(16, seed=23)
    drv = make_engine_cluster(
        CFG, PARAMS, 3, policy="fifo", routing="pd_disaggregated",
        engine_config=EngineConfig(n_slots=2, max_len=96,
                                   prompt_buckets=(BUCKET,),
                                   paged=True, page_size=PAGE,
                                   chunk_prefill_tokens=16),
        n_prefill_replicas=1,
        kv_transfer_base=0.002, kv_transfer_per_token=0.0,
        work_stealing=True, steal_min_depth=2)
    # hold one decode engine out of the pool so every handoff piles
    # onto the other, then bring it back as an idle thief
    drv.fail_replica(2, 0.0)
    for i, r in enumerate(reqs):
        assert drv.submit(r, 1e-6 * i)
    now, steps = 0.0, 0
    while drv.replicas[1].queue_depth() < 4:
        drv.step(now)
        now += 1.0
        steps += 1
        assert steps < 2000, "victim queue never built a backlog"
    drv.recover_replica(2, now)
    while not drv._drained():
        drv.step(now)
        now += 1.0
        steps += 1
        assert steps < 20_000
    done = [r for rep in drv.replicas for r in rep.sched.completed]
    assert len(done) == len(reqs)
    assert drv.n_stolen > 0
    thief = drv.replicas[2]
    assert thief.n_stolen_in > 0
    stolen_done = [r for r in done if r.n_steals > 0]
    assert stolen_done
    assert all(r.decode_rid == 2 for r in stolen_done)
    for rep in drv.replicas:
        assert rep.engine.alloc.free_pages == rep.engine.alloc.n_pages


def test_pd_engine_cluster_determinism():
    """Two identical engine-cluster P/D runs produce identical
    completion stamps."""
    def one():
        reqs = _requests(12, seed=29)
        drv = _run_engine_pd(reqs)
        idx, done = _pd_done(reqs, drv.replicas)
        return sorted((idx[r.req_id], r.observed_output_tokens,
                       r.prefill_end, r.handoff_time, r.exec_end)
                      for r in done)
    assert one() == one()
