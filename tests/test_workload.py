"""Workload corpus + generator (Sec. II-B, IV-C)."""

import random

from repro.core.request import Category, TenantTier
from repro.workload.corpus import build_corpus
from repro.workload.generator import GeneratorConfig, WorkloadGenerator


def test_corpus_size_and_uniqueness():
    corpus = build_corpus()
    texts = [p.text for p in corpus.prompts]
    assert len(texts) == len(set(texts))
    assert 1100 <= len(texts) <= 1200            # paper: ~1180 unique
    for cat in Category:
        assert len(corpus.by_category[cat]) > 50


def test_corpus_deterministic():
    a = build_corpus()
    b = build_corpus()
    assert [p.text for p in a.prompts] == [p.text for p in b.prompts]
    assert [p.latent_verbosity for p in a.prompts] == \
        [p.latent_verbosity for p in b.prompts]


def test_output_sampling_bounded_and_seeded():
    corpus = build_corpus()
    spec = corpus.by_category[Category.REPORT][0]
    r1 = spec.sample_output(random.Random(1), max_tokens=512)
    r2 = spec.sample_output(random.Random(1), max_tokens=512)
    assert r1 == r2
    assert 1 <= r1 <= 512


def test_plan_structure_and_mix():
    cfg = GeneratorConfig(seed=3)
    gen = WorkloadGenerator(cfg)
    plan = gen.plan()
    assert len(plan.calibration) == 1000
    assert len(plan.stress) == 2000
    hist = gen.category_histogram(plan)
    # weighted mix ~ 35/25/25/15 within sampling noise
    assert 0.30 < hist["short_qa"] / 3000 < 0.40
    assert 0.10 < hist["report"] / 3000 < 0.20
    tenants = [r.tenant for _, r in plan]
    for t in TenantTier:
        assert tenants.count(t) > 500


def test_plan_deterministic_per_seed():
    gen = WorkloadGenerator(GeneratorConfig())
    p1, p2 = gen.plan(seed=5), gen.plan(seed=5)
    assert [(t, r.prompt, r.true_output_tokens) for t, r in p1] == \
        [(t, r.prompt, r.true_output_tokens) for t, r in p2]
    p3 = gen.plan(seed=6)
    assert [r.prompt for _, r in p1] != [r.prompt for _, r in p3]


def test_ground_truth_hidden_from_estimates():
    """Drift exists: static estimates over-predict observed outputs."""
    gen = WorkloadGenerator(GeneratorConfig(seed=0))
    plan = gen.plan()
    from repro.core.estimator import AdaptiveTokenEstimator, DriftConfig
    est = AdaptiveTokenEstimator(DriftConfig(bias_enabled=False))
    over = 0
    reqs = [r for _, r in plan]
    for r in reqs:
        e = est.estimate(r.category, r.tenant, r.prompt_tokens)
        if e.est_output_tokens > r.true_output_tokens:
            over += 1
    assert over / len(reqs) > 0.7    # systematic over-estimation
