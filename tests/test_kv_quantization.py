"""int8 KV cache (beyond-paper serving feature): quantisation parity
with the bf16 cache path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models.layers import dequantize_kv, quantize_kv
from repro.models.registry import get_api


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 32), jnp.float32)
    q, scale = quantize_kv(x)
    assert q.dtype == jnp.int8
    back = dequantize_kv(q, scale)
    # symmetric per-head scales: max error <= scale/2
    err = jnp.abs(back - x)
    assert float((err - 0.5 * scale[..., None]).max()) < 1e-6


@pytest.mark.parametrize("arch", ["minitron-8b", "grok-1-314b"])
def test_int8_cache_decode_parity(arch):
    cfg = smoke_config(arch)
    cfg8 = cfg.replace(kv_dtype="int8")
    api = get_api(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init(cfg, key)
    toks = jax.random.randint(key, (2, 14), 0, cfg.vocab)

    full, _ = api.forward(cfg, params, {"tokens": toks})
    logits, cache = api.prefill(cfg8, params, {"tokens": toks[:, :10]},
                                max_len=24)
    assert cache["k"].dtype == jnp.int8
    assert "k_scale" in cache and "v_scale" in cache
    for i in range(4):
        logits, cache = api.decode_step(cfg8, params, cache, toks[:, 10 + i],
                                        jnp.asarray(10 + i, jnp.int32))
        err = float(jnp.abs(logits.astype(jnp.float32)
                            - full[:, 10 + i].astype(jnp.float32)).max())
        assert err < 0.3, (arch, i, err)   # quantisation-level error only


def test_int8_cache_halves_bytes():
    cfg = smoke_config("minitron-8b")
    api = get_api(cfg)
    c16 = api.init_cache(cfg, 2, 64)
    c8 = api.init_cache(cfg.replace(kv_dtype="int8"), 2, 64)
    b16 = c16["k"].nbytes + c16["v"].nbytes
    b8 = sum(c8[k].nbytes for k in ("k", "v", "k_scale", "v_scale"))
    # int8 + f32 scale per head: 1/2 + 4/(2*head_dim) of the bf16 bytes
    # (smoke head_dim=16 -> 0.625; the full configs' hd=128 -> 0.52)
    assert b8 <= (0.5 + 4 / (2 * cfg.d_head)) * b16 + 1
