"""Scheduling-policy behaviour tests (Sec. II-F)."""

import pytest

from repro.core.estimator import AdaptiveTokenEstimator, DriftConfig
from repro.core.policies import make_policy
from repro.core.queues import TenantQueueManager
from repro.core.request import Category, Request, TenantTier
from repro.core.admission import AdmissionController


def _manager_with(reqs, now=0.0):
    mgr = TenantQueueManager()
    adm = AdmissionController(AdaptiveTokenEstimator(DriftConfig()), mgr)
    for i, r in enumerate(reqs):
        adm.admit(r, now + i * 0.001)
    return mgr


def _req(tenant=TenantTier.STANDARD, category=Category.SHORT_QA,
         prompt="what is x?"):
    return Request(tenant=tenant, category=category, prompt=prompt)


def test_fifo_is_arrival_order_across_tenants():
    reqs = [_req(TenantTier.BATCH), _req(TenantTier.PREMIUM),
            _req(TenantTier.STANDARD), _req(TenantTier.BATCH)]
    mgr = _manager_with(reqs)
    pol = make_policy("fifo")
    order = [pol.select(mgr, 1.0) for _ in range(4)]
    assert [r.req_id for r in order] == [r.req_id for r in reqs]


def test_priority_tiers_then_fifo_within_tier():
    b1, p1, s1, b2, p2 = (_req(TenantTier.BATCH), _req(TenantTier.PREMIUM),
                          _req(TenantTier.STANDARD), _req(TenantTier.BATCH),
                          _req(TenantTier.PREMIUM))
    mgr = _manager_with([b1, p1, s1, b2, p2])
    pol = make_policy("priority")
    order = [pol.select(mgr, 1.0) for _ in range(5)]
    assert [r.req_id for r in order] == [p1.req_id, p2.req_id, s1.req_id,
                                         b1.req_id, b2.req_id]


def test_sjf_orders_by_estimated_budget():
    long_r = _req(category=Category.REPORT)
    short_r = _req(category=Category.SHORT_QA)
    med_r = _req(category=Category.SUMMARY)
    mgr = _manager_with([long_r, med_r, short_r])
    pol = make_policy("sjf")
    order = [pol.select(mgr, 1.0) for _ in range(3)]
    assert [r.req_id for r in order] == [short_r.req_id, med_r.req_id,
                                         long_r.req_id]
    budgets = [r.t_budget for r in order]
    assert budgets == sorted(budgets)


def test_weighted_follows_ratio_when_all_queues_full():
    reqs = ([_req(TenantTier.PREMIUM) for _ in range(10)]
            + [_req(TenantTier.STANDARD) for _ in range(10)]
            + [_req(TenantTier.BATCH) for _ in range(10)])
    mgr = _manager_with(reqs)
    pol = make_policy("weighted", ratio=(5, 3, 2))
    picks = [pol.select(mgr, 1.0).tenant for _ in range(10)]
    assert picks.count(TenantTier.PREMIUM) == 5
    assert picks.count(TenantTier.STANDARD) == 3
    assert picks.count(TenantTier.BATCH) == 2


def test_weighted_skips_empty_classes():
    reqs = [_req(TenantTier.BATCH) for _ in range(3)]
    mgr = _manager_with(reqs)
    pol = make_policy("weighted")
    assert all(pol.select(mgr, 1.0) is not None for _ in range(3))
    assert pol.select(mgr, 1.0) is None


def test_aging_promotes_long_waiting_batch_request():
    batch_r = _req(TenantTier.BATCH)
    mgr = _manager_with([batch_r])
    prem_r = _req(TenantTier.PREMIUM)
    # premium arrives much later; batch has aged past 2*threshold
    adm = AdmissionController(AdaptiveTokenEstimator(DriftConfig()), mgr)
    adm.admit(prem_r, 1000.0)
    pol = make_policy("aging", aging_threshold=100.0)
    first = pol.select(mgr, 1000.0)
    assert first.req_id == batch_r.req_id  # aged batch outranks fresh premium


def test_aging_close_to_priority_for_fresh_queues():
    b, p = _req(TenantTier.BATCH), _req(TenantTier.PREMIUM)
    mgr = _manager_with([b, p])
    pol = make_policy("aging", aging_threshold=100.0)
    assert pol.select(mgr, 0.01).req_id == p.req_id


def test_policies_return_none_on_empty():
    mgr = TenantQueueManager()
    for name in ("fifo", "priority", "sjf", "weighted", "aging"):
        assert make_policy(name).select(mgr, 0.0) is None


def test_unknown_policy_raises():
    with pytest.raises(ValueError):
        make_policy("lottery")
