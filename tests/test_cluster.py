"""Cluster serving layer: routing, admission, autoscaling, failure
rerouting, P/D disaggregation, work stealing, and end-to-end
determinism (jax-free — simulator only)."""

import pytest

from repro.cluster import (AdmissionConfig, Autoscaler, AutoscalerConfig,
                           ClusterConfig, ClusterRouter, ClusterSimulator,
                           GlobalAdmission, ReplicaRole, ReplicaState,
                           RoleAutoscaler, RoleAutoscalerConfig,
                           TokenBucket, make_routing_policy)
from repro.cluster.simulator import SimReplica
from repro.core.estimator import AdaptiveTokenEstimator, DriftConfig
from repro.core.request import Category, Request, TenantTier
from repro.core.scheduler import DriftScheduler
from repro.serving.cost_model import L4_MAX_DRIVEN
from repro.serving.kv_cache import prefix_page_key
from repro.serving.simulator import SimConfig, WorkerSimulator
from repro.workload.generator import WorkloadGenerator, cluster_stress_config


def _req(tenant=TenantTier.STANDARD, category=Category.SUMMARY,
         prompt="summarize the incident report for the oncall"):
    return Request(tenant=tenant, category=category, prompt=prompt,
                   true_output_tokens=200)


def _replicas(n, estimator=None):
    est = estimator or AdaptiveTokenEstimator(DriftConfig())
    reps = []
    for i in range(n):
        sched = DriftScheduler(estimator=est)
        sim = WorkerSimulator(sched, config=SimConfig(),
                              sink=lambda *a: None)
        reps.append(SimReplica(i, sched, sim))
    return est, reps


def _mkplan(seed, n=4, total=300):
    gen = WorkloadGenerator(cluster_stress_config(n, seed=seed,
                                                  total_requests=total))
    return gen.plan(seed=seed)


def _run(seed=1, n=4, total=300, **kw):
    cfg = kw.pop("config", None) or ClusterConfig(n_replicas=n, seed=seed)
    sim = ClusterSimulator(plan=_mkplan(seed, n, total), config=cfg,
                           cost_model=L4_MAX_DRIVEN, **kw)
    return sim, sim.run()


# --- routing policies --------------------------------------------------

def test_round_robin_cycles_deterministically():
    est, reps = _replicas(3)
    router = ClusterRouter("round_robin", est)
    picks = [router.route(reps, _req(), now=0.0).rid for _ in range(6)]
    assert picks == [0, 1, 2, 0, 1, 2]


def test_least_loaded_picks_min_token_mass():
    est, reps = _replicas(3)
    router = ClusterRouter("least_loaded", est)
    # preload replica 0 and 1 with queued work
    for rid in (0, 0, 1):
        reps[rid].sched.submit(_req(), now=0.0)
    assert router.route(reps, _req(), now=0.0).rid == 2


def test_drift_aware_segregates_sizes_and_spills():
    est, reps = _replicas(2)
    router = ClusterRouter("drift_aware", est)
    light = _req(category=Category.SHORT_QA, prompt="what is dns")
    heavy = _req(category=Category.REPORT,
                 prompt="write a full postmortem with timeline and actions")
    # seed the histogram with both sizes, then check band placement
    for _ in range(10):
        router.price(light), router.price(heavy)
        router.policy._weight[router.policy._bucket(router.price(light))] += \
            router.price(light)
        router.policy._weight[router.policy._bucket(router.price(heavy))] += \
            router.price(heavy)
    assert router.route(reps, light, now=0.0).rid == 0   # light band
    assert router.route(reps, heavy, now=0.0).rid == 1   # heavy band
    # overload the heavy band far past the spill threshold -> spills
    for _ in range(80):
        reps[1].sched.submit(_req(category=Category.REPORT), now=0.0)
    assert router.route(reps, heavy, now=0.0).rid == 0


def test_tenant_affinity_sticks_then_spills():
    est, reps = _replicas(3)
    router = ClusterRouter("tenant_affinity", est)
    prem = _req(tenant=TenantTier.PREMIUM)
    warm = router.route(reps, prem, now=0.0)
    assert warm.rid == int(TenantTier.PREMIUM) % 3
    for _ in range(50):   # overload the warm replica -> spill elsewhere
        warm.sched.submit(_req(tenant=TenantTier.PREMIUM), now=0.0)
    spilled = router.route(reps, _req(tenant=TenantTier.PREMIUM), now=0.0)
    assert spilled.rid != warm.rid


def test_router_skips_unroutable_replicas():
    est, reps = _replicas(3)
    router = ClusterRouter("round_robin", est)
    reps[0].state = ReplicaState.FAILED
    reps[2].state = ReplicaState.DRAINING
    for _ in range(4):
        assert router.route(reps, _req(), now=0.0).rid == 1
    reps[1].state = ReplicaState.STOPPED
    assert router.route(reps, _req(), now=0.0) is None


def test_unknown_routing_policy_rejected():
    with pytest.raises(ValueError):
        make_routing_policy("warp_speed")


# --- global admission --------------------------------------------------

def test_token_bucket_boundary_and_refill():
    b = TokenBucket(capacity=100.0, rate=10.0)
    assert b.try_consume(100.0, now=0.0)      # exactly-full boundary
    assert not b.try_consume(0.1, now=0.0)    # empty
    assert not b.try_consume(50.0, now=4.0)   # refilled only 40
    assert b.try_consume(50.0, now=5.0)       # refilled to exactly 50


def test_admission_rate_limit_sheds_per_tier():
    cfg = AdmissionConfig(
        bucket_capacity={t: 500.0 for t in TenantTier},
        refill_rate={t: 0.0 for t in TenantTier})
    adm = GlobalAdmission(cfg)
    ok1, _ = adm.offer(_req(), 400.0, now=0.0, cluster_token_mass=0.0)
    ok2, reason = adm.offer(_req(), 400.0, now=0.0, cluster_token_mass=0.0)
    assert ok1 and not ok2 and reason == "rate_limited"
    assert adm.n_accepted(TenantTier.STANDARD) == 1
    assert adm.shed[TenantTier.STANDARD] == {"rate_limited": 1}
    assert adm.shed_rate(TenantTier.STANDARD) == pytest.approx(0.5)
    assert adm.shed_rate(TenantTier.PREMIUM) == 0.0


def test_admission_no_replica_shed_refunds_bucket():
    cfg = AdmissionConfig(
        bucket_capacity={t: 1000.0 for t in TenantTier},
        refill_rate={t: 0.0 for t in TenantTier})
    adm = GlobalAdmission(cfg)
    r = _req()
    ok, _ = adm.offer(r, 600.0, now=0.0, cluster_token_mass=0.0)
    assert ok
    adm.shed_no_replica(r, 600.0, now=0.0)   # total outage after admit
    # outage must not also charge the tenant's rate limit
    assert adm.buckets[TenantTier.STANDARD].level == pytest.approx(1000.0)
    assert adm.n_accepted(TenantTier.STANDARD) == 0
    assert adm.shed[TenantTier.STANDARD] == {"no_replica": 1}


def test_tenant_affinity_warm_replica_stable_across_membership():
    est, reps = _replicas(4)
    router = ClusterRouter("tenant_affinity", est)
    warm_std = router.route(reps, _req(tenant=TenantTier.STANDARD), now=0.0)
    assert warm_std.rid == int(TenantTier.STANDARD)
    # an unrelated replica failing must not remap STANDARD's warm home
    reps[3].state = ReplicaState.FAILED
    assert router.route(reps, _req(tenant=TenantTier.STANDARD),
                        now=0.0).rid == warm_std.rid
    # STANDARD's own replica failing remaps only that tenant (ring: next rid)
    reps[3].state = ReplicaState.ACTIVE
    reps[warm_std.rid].state = ReplicaState.FAILED
    assert router.route(reps, _req(tenant=TenantTier.STANDARD),
                        now=0.0).rid == warm_std.rid + 1
    assert router.route(reps, _req(tenant=TenantTier.PREMIUM),
                        now=0.0).rid == int(TenantTier.PREMIUM)


def test_admission_backpressure_precedes_buckets():
    adm = GlobalAdmission(AdmissionConfig(max_cluster_token_mass=1000.0))
    ok, reason = adm.offer(_req(), 600.0, now=0.0, cluster_token_mass=500.0)
    assert not ok and reason == "backpressure"
    # bucket untouched by a backpressure shed
    assert adm.buckets[TenantTier.STANDARD].level == \
        adm.cfg.bucket_capacity[TenantTier.STANDARD]


# --- autoscaler --------------------------------------------------------

def test_autoscaler_hysteresis_and_cooldown():
    cfg = AutoscalerConfig(min_replicas=1, max_replicas=4,
                           up_queue_mass_per_replica=1000.0,
                           down_queue_mass_per_replica=100.0,
                           down_utilization=0.5, cooldown=10.0)
    scaler = Autoscaler(cfg)
    est, reps = _replicas(2)
    for _ in range(20):                      # heavy backlog on both
        reps[0].sched.submit(_req(), now=0.0)
        reps[1].sched.submit(_req(), now=0.0)
    assert scaler.decide(0.0, reps) == "up"
    assert scaler.decide(5.0, reps) is None          # cooldown
    assert scaler.decide(10.0, reps) == "up"         # cooldown expired
    # empty the queues -> below the down thresholds, but cooling down
    for r in reps:
        r.sched.queues.drain()
    assert scaler.decide(15.0, reps) is None         # cooldown
    assert scaler.decide(20.0, reps) == "down"
    assert scaler.decide(25.0, reps) is None         # cooldown again
    assert [e.action for e in scaler.events] == ["up", "up", "down"]


def test_autoscaler_respects_min_max():
    cfg = AutoscalerConfig(min_replicas=2, max_replicas=2,
                           up_queue_mass_per_replica=10.0, cooldown=0.0)
    scaler = Autoscaler(cfg)
    est, reps = _replicas(2)
    for _ in range(50):
        reps[0].sched.submit(_req(), now=0.0)
    assert scaler.decide(0.0, reps) is None           # at max
    for r in reps:
        r.sched.queues.drain()
    assert scaler.decide(100.0, reps) is None         # at min


# --- cluster simulator end-to-end --------------------------------------

def test_cluster_completes_everything_and_shares_estimator():
    sim, m = _run(seed=1, n=4, total=300)
    assert m.run.n_completed == 300
    # one shared bias store: per-replica schedulers all see every update
    stores = {id(rep.sched.estimator.bias_store) for rep in sim.replicas}
    assert len(stores) == 1
    assert sum(sim.estimator.bias_store.update_counts().values()) == 300


def test_cluster_determinism_same_seed_same_numbers():
    _, a = _run(seed=3, n=4, total=300)
    _, b = _run(seed=3, n=4, total=300)
    assert a.as_dict() == b.as_dict()


def test_replica_failure_reroutes_without_double_feedback():
    cfg = ClusterConfig(n_replicas=4, seed=1, fail_events=((10.0, 0),),
                        repair_time=20.0)
    sim, m = _run(seed=1, n=4, total=300, config=cfg)
    assert m.run.n_completed == 300                  # nothing lost
    assert m.n_rerouted > 0                          # queue moved off rid 0
    # at-most-once bias feedback: one update per completed request,
    # regardless of retries/reroutes
    assert sum(sim.estimator.bias_store.update_counts().values()) == 300
    retried = [r for rep in sim.replicas for r in rep.sched.completed
               if r.retries > 0]
    assert m.run.n_failed_dispatches == 0 or retried or m.n_rerouted


def test_failed_replica_rejoins_after_repair():
    cfg = ClusterConfig(n_replicas=2, seed=1, fail_events=((5.0, 0),),
                        repair_time=10.0)
    sim, m = _run(seed=1, n=2, total=300, config=cfg)
    assert m.run.n_completed == 300
    assert sim.replicas[0].state is ReplicaState.ACTIVE  # rejoined
    assert len(sim.replicas[0].sched.completed) > 0      # served post-repair


def test_cluster_autoscales_up_under_burst():
    scaler = Autoscaler(AutoscalerConfig(
        min_replicas=2, max_replicas=6,
        up_queue_mass_per_replica=10_000.0, cooldown=5.0,
        startup_delay=2.0))
    sim, m = _run(seed=1, n=2, total=400, autoscaler=scaler)
    assert m.run.n_completed == 400
    assert any(e["action"] == "up" for e in m.scale_events)
    assert len(sim.replicas) > 2                     # pool actually grew
    grown = [r for r in sim.replicas if r.rid >= 2]
    assert sum(len(r.sched.completed) for r in grown) > 0  # and served


def test_cluster_admission_sheds_and_accounts():
    adm = GlobalAdmission(AdmissionConfig(
        bucket_capacity={t: 15_000.0 for t in TenantTier},
        refill_rate={t: 400.0 for t in TenantTier}))
    sim, m = _run(seed=1, n=2, total=300, admission=adm)
    assert 0 < m.shed_rate < 1
    n_shed = sum(sum(v.values()) for v in adm.shed.values())
    assert m.run.n_completed + n_shed == 300
    # shed requests were never admitted anywhere
    assert all(rec.reason in ("rate_limited", "backpressure")
               for rec in adm.shed_log)


# --- P/D disaggregation ------------------------------------------------

def _pd_run(seed=1, n=4, total=300, **cfg_kw):
    cfg_kw.setdefault("routing", "pd_disaggregated")
    cfg = ClusterConfig(n_replicas=n, seed=seed, **cfg_kw)
    return _run(seed=seed, n=n, total=total, config=cfg)


def test_pd_two_stage_lifecycle_completes_everything():
    sim, m = _pd_run()
    assert m.run.n_completed == 300
    roles = [r.role for r in sim.replicas]
    assert roles.count(ReplicaRole.PREFILL) == 1      # 25% of 4, min 1
    assert roles.count(ReplicaRole.DECODE) == 3
    # every request prefilled on a prefill replica and decoded elsewhere
    assert m.n_handoffs == 300
    done = [r for rep in sim.replicas for r in rep.sched.completed]
    assert all(r.prefill_end is not None and r.handoff_time is not None
               and r.prefill_rid != r.decode_rid for r in done)
    # TTFT is the prefill-phase anchor: strictly before completion
    assert all(r.ttft < r.e2e_latency for r in done)
    # KV transfer delay is the modeled base + per-prompt-token cost
    r = done[0]
    assert r.kv_transfer_latency == pytest.approx(
        sim.cfg.kv_transfer_base
        + sim.cfg.kv_transfer_per_token * r.prompt_tokens)


def test_pd_feedback_fires_once_attributed_to_decode():
    sim, m = _pd_run()
    # at-most-once: one bias update per completed request
    assert sum(sim.estimator.bias_store.update_counts().values()) == 300
    phases = {}
    for rep in sim.replicas:
        for k, v in rep.sched.phase_feedback_counts.items():
            phases[k] = phases.get(k, 0) + v
    assert phases == {"decode": 300}
    # drift samples carry the observing phase too
    samples = [s for rep in sim.replicas for s in rep.sched.drift.samples]
    assert len(samples) == 300
    assert all(s.phase == "decode" for s in samples)


def test_pd_prefill_failure_mid_handoff_no_double_feedback():
    """Kill the (single) prefill replica while KV transfers are in
    flight: the lost transfers re-run prefill elsewhere, nothing is
    lost, and bias feedback still fires exactly once per request."""
    sim, m = _pd_run(kv_transfer_base=3.0,      # widen the in-flight window
                     fail_events=((2.0, 0),), repair_time=20.0)
    assert sim.replicas[0].role is ReplicaRole.PREFILL
    assert m.run.n_completed == 300
    assert m.n_handoffs_lost > 0                 # transfers actually died
    assert sum(sim.estimator.bias_store.update_counts().values()) == 300
    # the re-prefilled requests record the recovery (retries reset path)
    done = [r for rep in sim.replicas for r in rep.sched.completed]
    assert any(r.retries > 0 for r in done)


def test_pd_decode_failure_reprefills_stranded_kv():
    """A failed decode replica takes its KV pages with it: stranded
    decode-ready work resets to the pre-prefill state and re-enters
    stage-1 routing. More handoffs than requests prove the re-runs."""
    sim, m = _pd_run(fail_events=((15.0, 2),), repair_time=25.0)
    assert sim.replicas[2].role is ReplicaRole.DECODE
    assert m.run.n_completed == 300
    assert m.n_rerouted > 0
    assert m.n_handoffs > 300
    assert sum(sim.estimator.bias_store.update_counts().values()) == 300


def test_pd_determinism_same_seed_same_numbers():
    _, a = _pd_run(seed=3, work_stealing=True)
    _, b = _pd_run(seed=3, work_stealing=True)
    assert a.as_dict() == b.as_dict()


# --- work stealing ------------------------------------------------------

def test_plan_steals_pairs_idle_thief_with_loaded_victim():
    est, reps = _replicas(3)
    router = ClusterRouter("least_loaded", est)
    for _ in range(8):
        reps[0].sched.submit(_req(), now=0.0)
    plans = router.plan_steals(reps, now=0.0, min_victim_depth=4)
    # two idle thieves, one victim: only the first thief gets the plan
    assert len(plans) == 1
    assert plans[0].victim_rid == 0 and plans[0].thief_rid == 1
    assert plans[0].n == 4                      # half the queue
    # below the depth floor: no stealing
    est2, reps2 = _replicas(2)
    router2 = ClusterRouter("least_loaded", est2)
    for _ in range(3):
        reps2[0].sched.submit(_req(), now=0.0)
    assert router2.plan_steals(reps2, now=0.0, min_victim_depth=4) == []


def test_steals_respect_roles():
    est, reps = _replicas(3)
    reps[0].role = ReplicaRole.DECODE           # victim holds decode work
    reps[1].role = ReplicaRole.PREFILL          # cannot take decode work
    reps[2].role = ReplicaRole.DECODE
    router = ClusterRouter("least_loaded", est)
    for _ in range(8):
        reps[0].sched.submit(_req(), now=0.0)
    plans = router.plan_steals(reps, now=0.0, min_victim_depth=4)
    assert [p.thief_rid for p in plans] == [2]


def test_steals_refuse_to_move_resident_prefix_work():
    """Prefix-cache-aware stealing: not-yet-prefilled work whose shared
    prefix is resident on the victim — and whose admission estimate was
    priced with that discount — is NOT dragged to a cold thief when the
    forfeited cache discount exceeds the queue-imbalance gain (the
    request's own budget mass). Cold work on the same victim still
    steals exactly as before."""
    est = AdaptiveTokenEstimator(DriftConfig())
    reps = []
    for i in range(2):
        sched = DriftScheduler(estimator=est)
        sim = WorkerSimulator(
            sched,
            config=SimConfig(step_engine=True, prefix_cache=True,
                             prefix_cache_pages=64),
            sink=lambda *a: None)
        reps.append(SimReplica(i, sched, sim))
    victim, thief = reps
    # a 4096-token tenant prefix resident on the victim only
    group = ("standard", 0)
    key = prefix_page_key(group, 4096, 128)
    victim.sim.prefix_tree.insert(key, 0.0)
    router = ClusterRouter("prefix_aware", est)

    def warm_req():
        r = _req()
        r.prompt_tokens = 4200
        r.prefix_group = group
        r.shared_prefix_tokens = 4096
        # priced at placement on the warm replica (the cluster stamps
        # the chosen replica's overlap): the queued budget is only the
        # uncached remainder — which the discount dwarfs
        r.expected_cached_tokens = 4096
        return r

    for _ in range(8):
        victim.sched.submit(warm_req(), now=0.0)
    assert victim.prefix_cached_tokens(victim.queued_requests()[0]) == 4096
    assert thief.prefix_cached_tokens(victim.queued_requests()[0]) == 0
    # every steal-tail candidate is residency-vetoed: no plan at all
    assert router.plan_steals(reps, now=0.0, min_victim_depth=4) == []

    # control: pile cold (no shareable prefix) work behind the warm
    # stream — the tail is now cold and steals normally, the warm head
    # stays pinned to its resident replica
    cold = [_req() for _ in range(8)]
    for r in cold:
        victim.sched.submit(r, now=0.0)
    plans = router.plan_steals(reps, now=0.0, min_victim_depth=4)
    assert len(plans) == 1
    assert plans[0].victim_rid == 0 and plans[0].thief_rid == 1
    assert set(plans[0].req_ids) == {r.req_id for r in cold}
    assert plans[0].n == len(plans[0].req_ids) == 8


def test_stealing_preserves_estimates_and_order_metadata():
    sim, m = _pd_run(work_stealing=True, steal_min_depth=2,
                     fail_events=((15.0, 2),), repair_time=25.0)
    assert m.run.n_completed == 300
    assert m.n_stolen > 0
    done = [r for rep in sim.replicas for r in rep.sched.completed]
    stolen = [r for r in done if r.n_steals > 0]
    assert stolen
    # stealing must not re-price: the admission estimate survived the
    # move (estimates are assigned exactly once, at admission)
    assert all(r.estimate is not None for r in stolen)
    assert sum(sim.estimator.bias_store.update_counts().values()) == 300
    # flow conservation on the counters
    assert sum(rep.n_stolen_in for rep in sim.replicas) == \
        sum(rep.n_stolen_away for rep in sim.replicas) == m.n_stolen


# --- role-aware autoscaler ----------------------------------------------

def test_role_autoscaler_scales_overloaded_role_up():
    cfg = RoleAutoscalerConfig(min_replicas=2, max_replicas=6,
                               up_queue_mass_per_replica=1000.0,
                               down_queue_mass_per_replica=100.0,
                               cooldown=10.0)
    scaler = RoleAutoscaler(cfg)
    est, reps = _replicas(3)
    reps[0].role = ReplicaRole.PREFILL
    reps[1].role = ReplicaRole.DECODE
    reps[2].role = ReplicaRole.DECODE
    for _ in range(20):                          # decode pool backlogged
        reps[1].sched.submit(_req(), now=0.0)
    assert scaler.decide_role(0.0, reps) == ("up", ReplicaRole.DECODE)
    assert scaler.decide_role(5.0, reps) is None          # cooldown
    assert scaler.events[-1].role == "decode"
    # drain the queues -> the over-target pool gives a replica back
    for r in reps:
        r.sched.queues.drain()
    assert scaler.decide_role(20.0, reps) == ("down", ReplicaRole.DECODE)


def test_role_autoscaler_keeps_one_replica_per_role():
    cfg = RoleAutoscalerConfig(min_replicas=1, max_replicas=8,
                               up_queue_mass_per_replica=1e9,
                               down_queue_mass_per_replica=1e9,
                               down_utilization=1.0, cooldown=0.0)
    scaler = RoleAutoscaler(cfg)
    est, reps = _replicas(2)
    reps[0].role = ReplicaRole.PREFILL
    reps[1].role = ReplicaRole.DECODE
    # both pools idle and "calm", but neither can shrink below 1
    assert scaler.decide_role(0.0, reps) is None
    assert scaler.pick_drain_target(reps, role=ReplicaRole.PREFILL) is None


def test_pd_cluster_autoscales_decode_pool_under_burst():
    scaler = RoleAutoscaler(RoleAutoscalerConfig(
        min_replicas=2, max_replicas=8,
        up_queue_mass_per_replica=10_000.0, cooldown=5.0,
        startup_delay=2.0))
    cfg = ClusterConfig(n_replicas=4, seed=1, routing="pd_disaggregated")
    sim2 = ClusterSimulator(plan=_mkplan(1, 4, 400), config=cfg,
                            cost_model=L4_MAX_DRIVEN, autoscaler=scaler)
    m2 = sim2.run()
    assert m2.run.n_completed == 400
    ups = [e for e in m2.scale_events if e["action"] == "up"]
    assert ups and all(e["role"] in ("prefill", "decode") for e in ups)
    grown = [r for r in sim2.replicas if r.rid >= 4]
    assert grown and all(r.role in (ReplicaRole.PREFILL, ReplicaRole.DECODE)
                         for r in grown)


def test_drift_aware_beats_round_robin_on_p99():
    """The acceptance-criterion property at 4 replicas, heterogeneous
    stress workload, batch-walk cost regime."""
    p99 = {}
    for routing in ("round_robin", "drift_aware"):
        cfg = ClusterConfig(n_replicas=4, routing=routing, seed=1)
        _, m = _run(seed=1, n=4, total=600, config=cfg)
        assert m.run.n_completed == 600
        p99[routing] = m.run.e2e.p99
    assert p99["drift_aware"] < p99["round_robin"]
