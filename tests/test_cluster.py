"""Cluster serving layer: routing, admission, autoscaling, failure
rerouting, and end-to-end determinism (jax-free — simulator only)."""

import pytest

from repro.cluster import (AdmissionConfig, Autoscaler, AutoscalerConfig,
                           ClusterConfig, ClusterRouter, ClusterSimulator,
                           GlobalAdmission, ReplicaState, TokenBucket,
                           make_routing_policy)
from repro.cluster.simulator import SimReplica
from repro.core.estimator import AdaptiveTokenEstimator, DriftConfig
from repro.core.request import Category, Request, TenantTier
from repro.core.scheduler import DriftScheduler
from repro.serving.cost_model import L4_MAX_DRIVEN
from repro.serving.simulator import SimConfig, WorkerSimulator
from repro.workload.generator import WorkloadGenerator, cluster_stress_config


def _req(tenant=TenantTier.STANDARD, category=Category.SUMMARY,
         prompt="summarize the incident report for the oncall"):
    return Request(tenant=tenant, category=category, prompt=prompt,
                   true_output_tokens=200)


def _replicas(n, estimator=None):
    est = estimator or AdaptiveTokenEstimator(DriftConfig())
    reps = []
    for i in range(n):
        sched = DriftScheduler(estimator=est)
        sim = WorkerSimulator(sched, config=SimConfig(),
                              sink=lambda *a: None)
        reps.append(SimReplica(i, sched, sim))
    return est, reps


def _mkplan(seed, n=4, total=300):
    gen = WorkloadGenerator(cluster_stress_config(n, seed=seed,
                                                  total_requests=total))
    return gen.plan(seed=seed)


def _run(seed=1, n=4, total=300, **kw):
    cfg = kw.pop("config", None) or ClusterConfig(n_replicas=n, seed=seed)
    sim = ClusterSimulator(plan=_mkplan(seed, n, total), config=cfg,
                           cost_model=L4_MAX_DRIVEN, **kw)
    return sim, sim.run()


# --- routing policies --------------------------------------------------

def test_round_robin_cycles_deterministically():
    est, reps = _replicas(3)
    router = ClusterRouter("round_robin", est)
    picks = [router.route(reps, _req(), now=0.0).rid for _ in range(6)]
    assert picks == [0, 1, 2, 0, 1, 2]


def test_least_loaded_picks_min_token_mass():
    est, reps = _replicas(3)
    router = ClusterRouter("least_loaded", est)
    # preload replica 0 and 1 with queued work
    for rid in (0, 0, 1):
        reps[rid].sched.submit(_req(), now=0.0)
    assert router.route(reps, _req(), now=0.0).rid == 2


def test_drift_aware_segregates_sizes_and_spills():
    est, reps = _replicas(2)
    router = ClusterRouter("drift_aware", est)
    light = _req(category=Category.SHORT_QA, prompt="what is dns")
    heavy = _req(category=Category.REPORT,
                 prompt="write a full postmortem with timeline and actions")
    # seed the histogram with both sizes, then check band placement
    for _ in range(10):
        router.price(light), router.price(heavy)
        router.policy._weight[router.policy._bucket(router.price(light))] += \
            router.price(light)
        router.policy._weight[router.policy._bucket(router.price(heavy))] += \
            router.price(heavy)
    assert router.route(reps, light, now=0.0).rid == 0   # light band
    assert router.route(reps, heavy, now=0.0).rid == 1   # heavy band
    # overload the heavy band far past the spill threshold -> spills
    for _ in range(80):
        reps[1].sched.submit(_req(category=Category.REPORT), now=0.0)
    assert router.route(reps, heavy, now=0.0).rid == 0


def test_tenant_affinity_sticks_then_spills():
    est, reps = _replicas(3)
    router = ClusterRouter("tenant_affinity", est)
    prem = _req(tenant=TenantTier.PREMIUM)
    warm = router.route(reps, prem, now=0.0)
    assert warm.rid == int(TenantTier.PREMIUM) % 3
    for _ in range(50):   # overload the warm replica -> spill elsewhere
        warm.sched.submit(_req(tenant=TenantTier.PREMIUM), now=0.0)
    spilled = router.route(reps, _req(tenant=TenantTier.PREMIUM), now=0.0)
    assert spilled.rid != warm.rid


def test_router_skips_unroutable_replicas():
    est, reps = _replicas(3)
    router = ClusterRouter("round_robin", est)
    reps[0].state = ReplicaState.FAILED
    reps[2].state = ReplicaState.DRAINING
    for _ in range(4):
        assert router.route(reps, _req(), now=0.0).rid == 1
    reps[1].state = ReplicaState.STOPPED
    assert router.route(reps, _req(), now=0.0) is None


def test_unknown_routing_policy_rejected():
    with pytest.raises(ValueError):
        make_routing_policy("warp_speed")


# --- global admission --------------------------------------------------

def test_token_bucket_boundary_and_refill():
    b = TokenBucket(capacity=100.0, rate=10.0)
    assert b.try_consume(100.0, now=0.0)      # exactly-full boundary
    assert not b.try_consume(0.1, now=0.0)    # empty
    assert not b.try_consume(50.0, now=4.0)   # refilled only 40
    assert b.try_consume(50.0, now=5.0)       # refilled to exactly 50


def test_admission_rate_limit_sheds_per_tier():
    cfg = AdmissionConfig(
        bucket_capacity={t: 500.0 for t in TenantTier},
        refill_rate={t: 0.0 for t in TenantTier})
    adm = GlobalAdmission(cfg)
    ok1, _ = adm.offer(_req(), 400.0, now=0.0, cluster_token_mass=0.0)
    ok2, reason = adm.offer(_req(), 400.0, now=0.0, cluster_token_mass=0.0)
    assert ok1 and not ok2 and reason == "rate_limited"
    assert adm.n_accepted(TenantTier.STANDARD) == 1
    assert adm.shed[TenantTier.STANDARD] == {"rate_limited": 1}
    assert adm.shed_rate(TenantTier.STANDARD) == pytest.approx(0.5)
    assert adm.shed_rate(TenantTier.PREMIUM) == 0.0


def test_admission_no_replica_shed_refunds_bucket():
    cfg = AdmissionConfig(
        bucket_capacity={t: 1000.0 for t in TenantTier},
        refill_rate={t: 0.0 for t in TenantTier})
    adm = GlobalAdmission(cfg)
    r = _req()
    ok, _ = adm.offer(r, 600.0, now=0.0, cluster_token_mass=0.0)
    assert ok
    adm.shed_no_replica(r, 600.0, now=0.0)   # total outage after admit
    # outage must not also charge the tenant's rate limit
    assert adm.buckets[TenantTier.STANDARD].level == pytest.approx(1000.0)
    assert adm.n_accepted(TenantTier.STANDARD) == 0
    assert adm.shed[TenantTier.STANDARD] == {"no_replica": 1}


def test_tenant_affinity_warm_replica_stable_across_membership():
    est, reps = _replicas(4)
    router = ClusterRouter("tenant_affinity", est)
    warm_std = router.route(reps, _req(tenant=TenantTier.STANDARD), now=0.0)
    assert warm_std.rid == int(TenantTier.STANDARD)
    # an unrelated replica failing must not remap STANDARD's warm home
    reps[3].state = ReplicaState.FAILED
    assert router.route(reps, _req(tenant=TenantTier.STANDARD),
                        now=0.0).rid == warm_std.rid
    # STANDARD's own replica failing remaps only that tenant (ring: next rid)
    reps[3].state = ReplicaState.ACTIVE
    reps[warm_std.rid].state = ReplicaState.FAILED
    assert router.route(reps, _req(tenant=TenantTier.STANDARD),
                        now=0.0).rid == warm_std.rid + 1
    assert router.route(reps, _req(tenant=TenantTier.PREMIUM),
                        now=0.0).rid == int(TenantTier.PREMIUM)


def test_admission_backpressure_precedes_buckets():
    adm = GlobalAdmission(AdmissionConfig(max_cluster_token_mass=1000.0))
    ok, reason = adm.offer(_req(), 600.0, now=0.0, cluster_token_mass=500.0)
    assert not ok and reason == "backpressure"
    # bucket untouched by a backpressure shed
    assert adm.buckets[TenantTier.STANDARD].level == \
        adm.cfg.bucket_capacity[TenantTier.STANDARD]


# --- autoscaler --------------------------------------------------------

def test_autoscaler_hysteresis_and_cooldown():
    cfg = AutoscalerConfig(min_replicas=1, max_replicas=4,
                           up_queue_mass_per_replica=1000.0,
                           down_queue_mass_per_replica=100.0,
                           down_utilization=0.5, cooldown=10.0)
    scaler = Autoscaler(cfg)
    est, reps = _replicas(2)
    for _ in range(20):                      # heavy backlog on both
        reps[0].sched.submit(_req(), now=0.0)
        reps[1].sched.submit(_req(), now=0.0)
    assert scaler.decide(0.0, reps) == "up"
    assert scaler.decide(5.0, reps) is None          # cooldown
    assert scaler.decide(10.0, reps) == "up"         # cooldown expired
    # empty the queues -> below the down thresholds, but cooling down
    for r in reps:
        r.sched.queues.drain()
    assert scaler.decide(15.0, reps) is None         # cooldown
    assert scaler.decide(20.0, reps) == "down"
    assert scaler.decide(25.0, reps) is None         # cooldown again
    assert [e.action for e in scaler.events] == ["up", "up", "down"]


def test_autoscaler_respects_min_max():
    cfg = AutoscalerConfig(min_replicas=2, max_replicas=2,
                           up_queue_mass_per_replica=10.0, cooldown=0.0)
    scaler = Autoscaler(cfg)
    est, reps = _replicas(2)
    for _ in range(50):
        reps[0].sched.submit(_req(), now=0.0)
    assert scaler.decide(0.0, reps) is None           # at max
    for r in reps:
        r.sched.queues.drain()
    assert scaler.decide(100.0, reps) is None         # at min


# --- cluster simulator end-to-end --------------------------------------

def test_cluster_completes_everything_and_shares_estimator():
    sim, m = _run(seed=1, n=4, total=300)
    assert m.run.n_completed == 300
    # one shared bias store: per-replica schedulers all see every update
    stores = {id(rep.sched.estimator.bias_store) for rep in sim.replicas}
    assert len(stores) == 1
    assert sum(sim.estimator.bias_store.update_counts().values()) == 300


def test_cluster_determinism_same_seed_same_numbers():
    _, a = _run(seed=3, n=4, total=300)
    _, b = _run(seed=3, n=4, total=300)
    assert a.as_dict() == b.as_dict()


def test_replica_failure_reroutes_without_double_feedback():
    cfg = ClusterConfig(n_replicas=4, seed=1, fail_events=((10.0, 0),),
                        repair_time=20.0)
    sim, m = _run(seed=1, n=4, total=300, config=cfg)
    assert m.run.n_completed == 300                  # nothing lost
    assert m.n_rerouted > 0                          # queue moved off rid 0
    # at-most-once bias feedback: one update per completed request,
    # regardless of retries/reroutes
    assert sum(sim.estimator.bias_store.update_counts().values()) == 300
    retried = [r for rep in sim.replicas for r in rep.sched.completed
               if r.retries > 0]
    assert m.run.n_failed_dispatches == 0 or retried or m.n_rerouted


def test_failed_replica_rejoins_after_repair():
    cfg = ClusterConfig(n_replicas=2, seed=1, fail_events=((5.0, 0),),
                        repair_time=10.0)
    sim, m = _run(seed=1, n=2, total=300, config=cfg)
    assert m.run.n_completed == 300
    assert sim.replicas[0].state is ReplicaState.ACTIVE  # rejoined
    assert len(sim.replicas[0].sched.completed) > 0      # served post-repair


def test_cluster_autoscales_up_under_burst():
    scaler = Autoscaler(AutoscalerConfig(
        min_replicas=2, max_replicas=6,
        up_queue_mass_per_replica=10_000.0, cooldown=5.0,
        startup_delay=2.0))
    sim, m = _run(seed=1, n=2, total=400, autoscaler=scaler)
    assert m.run.n_completed == 400
    assert any(e["action"] == "up" for e in m.scale_events)
    assert len(sim.replicas) > 2                     # pool actually grew
    grown = [r for r in sim.replicas if r.rid >= 2]
    assert sum(len(r.sched.completed) for r in grown) > 0  # and served


def test_cluster_admission_sheds_and_accounts():
    adm = GlobalAdmission(AdmissionConfig(
        bucket_capacity={t: 15_000.0 for t in TenantTier},
        refill_rate={t: 400.0 for t in TenantTier}))
    sim, m = _run(seed=1, n=2, total=300, admission=adm)
    assert 0 < m.shed_rate < 1
    n_shed = sum(sum(v.values()) for v in adm.shed.values())
    assert m.run.n_completed + n_shed == 300
    # shed requests were never admitted anywhere
    assert all(rec.reason in ("rate_limited", "backpressure")
               for rec in adm.shed_log)


def test_drift_aware_beats_round_robin_on_p99():
    """The acceptance-criterion property at 4 replicas, heterogeneous
    stress workload, batch-walk cost regime."""
    p99 = {}
    for routing in ("round_robin", "drift_aware"):
        cfg = ClusterConfig(n_replicas=4, routing=routing, seed=1)
        _, m = _run(seed=1, n=4, total=600, config=cfg)
        assert m.run.n_completed == 600
        p99[routing] = m.run.e2e.p99
    assert p99["drift_aware"] < p99["round_robin"]
