"""Shared-prefix KV reuse: radix tree semantics, cached-suffix pricing,
step-engine integration, prefix-aware routing, failure invalidation,
and the share-0 parity contract (prefix share 0 must be bit-identical
to the cache-off step engine of PR 3)."""

from dataclasses import replace

import pytest

from repro.cluster import (ClusterConfig, ClusterSimulator,
                           PrefixAwareRouting, ROUTING_POLICIES)
from repro.core.estimator import AdaptiveTokenEstimator, DriftConfig
from repro.core.request import Category, Request, TenantTier
from repro.core.scheduler import DriftScheduler
from repro.serving.cost_model import L4_QWEN_1_8B
from repro.serving.kv_cache import (OutOfPagesError, PagedAllocator,
                                    PrefixTree, prefix_page_key)
from repro.serving.simulator import (KV_PAGE_TOKENS, SimConfig,
                                     WorkerSimulator)
from repro.workload.generator import (GeneratorConfig, WorkloadGenerator,
                                      cluster_stress_config)

NOJIT = replace(L4_QWEN_1_8B, jitter_sigma=0.0)


def _key(group, n_pages):
    return tuple((group, i) for i in range(n_pages))


# --- prefix_page_key ---------------------------------------------------

def test_prefix_page_key_full_pages_only():
    assert prefix_page_key(None, 4096, 128) == ()
    assert prefix_page_key(("t", 0), 0, 128) == ()
    assert prefix_page_key(("t", 0), 127, 128) == ()       # sub-page
    assert prefix_page_key(("t", 0), 128, 128) == ((("t", 0), 0),)
    # the partial tail page is never shareable (copy-on-write boundary)
    assert len(prefix_page_key(("t", 0), 300, 128)) == 2


# --- PrefixTree semantics ----------------------------------------------

def test_tree_insert_match_and_shared_pages():
    alloc = PagedAllocator(n_pages=32, page_size=128, pages_per_seq=8)
    tree = PrefixTree(alloc)
    node, added = tree.insert(_key("a", 4), 1.0)
    assert added == 4 and tree.total_pages() == 4
    # a second insert of the same key adds nothing (pages are shared)
    _, added2 = tree.insert(_key("a", 4), 2.0)
    assert added2 == 0 and tree.total_pages() == 4
    assert tree.cached_tokens(_key("a", 4)) == 4 * 128
    # partial key matches the shared run
    assert tree.cached_tokens(_key("a", 2)) == 2 * 128
    assert tree.cached_tokens(_key("b", 2)) == 0


def test_tree_radix_split_on_divergence():
    alloc = PagedAllocator(n_pages=32, page_size=128, pages_per_seq=8)
    tree = PrefixTree(alloc)
    tree.insert(_key("a", 4), 1.0)
    diverged = _key("a", 2) + (("a", 99),)
    _, added = tree.insert(diverged, 2.0)
    assert added == 1
    # both continuations stay resident, sharing the 2-page run
    assert tree.cached_tokens(_key("a", 4)) == 4 * 128
    assert tree.cached_tokens(diverged) == 3 * 128
    assert tree.total_pages() == 5


def test_tree_lock_blocks_eviction_lru_order():
    alloc = PagedAllocator(n_pages=32, page_size=128, pages_per_seq=8)
    tree = PrefixTree(alloc)
    na, _ = tree.insert(_key("a", 2), 1.0)     # older
    nb, _ = tree.insert(_key("b", 2), 2.0)     # newer
    tree.lock(na)
    freed = tree.evict(100)
    # only the unreferenced leaf (b) may go, despite a being older
    assert freed == 2
    assert tree.cached_tokens(_key("a", 2)) == 2 * 128
    assert tree.cached_tokens(_key("b", 2)) == 0
    tree.release(na)
    assert tree.evict(100) == 2
    assert tree.total_pages() == 0
    # LRU: with no locks, the oldest last_access goes first
    tree.insert(_key("c", 2), 5.0)
    tree.insert(_key("d", 2), 6.0)
    tree.match(_key("c", 2), 7.0)              # refresh c
    tree.evict(2)
    assert tree.cached_tokens(_key("c", 2)) == 2 * 128
    assert tree.cached_tokens(_key("d", 2)) == 0


def test_tree_release_without_lock_raises():
    alloc = PagedAllocator(n_pages=8, page_size=128, pages_per_seq=8)
    tree = PrefixTree(alloc)
    node, _ = tree.insert(_key("a", 1), 1.0)
    with pytest.raises(ValueError):
        tree.release(node)


def test_tree_insert_truncates_under_locked_pressure():
    """With every resident page locked and the free list empty, insert
    cannot evict and must truncate instead of failing the caller."""
    alloc = PagedAllocator(n_pages=4, page_size=128, pages_per_seq=4)
    tree = PrefixTree(alloc)
    node, added = tree.insert(_key("a", 3), 1.0)
    assert added == 3
    tree.lock(node)
    node_b, added_b = tree.insert(_key("b", 3), 2.0)
    assert added_b == 1                        # only one page left
    assert alloc.free_pages == 0
    # with EVERY resident page locked, cow_extend has nothing to claim
    tree.lock(node_b)
    with pytest.raises(OutOfPagesError):
        tree.cow_extend(node)
    tree.release(node)
    tree.release(node_b)


def test_tree_cow_extend_allocates_private_copy():
    alloc = PagedAllocator(n_pages=8, page_size=128, pages_per_seq=4)
    tree = PrefixTree(alloc)
    node, _ = tree.insert(_key("a", 2), 1.0)
    page = tree.cow_extend(node)
    assert tree.n_cow_pages == 1
    # caller owns the copy; the shared pages are untouched
    assert tree.total_pages() == 2
    assert alloc.free_pages == 8 - 3
    alloc.free_raw([page])
    assert alloc.free_pages == 8 - 2


def test_tree_clear_returns_all_pages():
    alloc = PagedAllocator(n_pages=16, page_size=128, pages_per_seq=4)
    tree = PrefixTree(alloc)
    na, _ = tree.insert(_key("a", 3), 1.0)
    tree.insert(_key("b", 2), 2.0)
    tree.lock(na)                              # locks die with the pool
    assert tree.clear() == 5
    assert tree.total_pages() == 0 and alloc.free_pages == 16
    assert tree.cached_tokens(_key("a", 3)) == 0


def test_tree_release_after_clear_is_noop():
    """A lock holder that survives a failure wipe releases into the
    orphaned old tree without raising (the locks died with the pool)."""
    alloc = PagedAllocator(n_pages=8, page_size=128, pages_per_seq=4)
    tree = PrefixTree(alloc)
    node, _ = tree.insert(_key("a", 2), 1.0)
    tree.lock(node)
    tree.clear()
    tree.release(node)                         # must not raise
    assert tree.total_pages() == 0 and alloc.free_pages == 8


def test_tree_insert_under_pressure_never_orphans_parent():
    """Extending a resident unreferenced prefix under page pressure
    must not LRU-evict the very node the extension hangs off — that
    would leak the new pages out of both the tree and the free list."""
    alloc = PagedAllocator(n_pages=3, page_size=128, pages_per_seq=4)
    tree = PrefixTree(alloc)
    tree.insert(_key("a", 2), 1.0)
    tree.insert(_key("a", 4), 2.0)             # needs 2, only 1 free
    assert alloc.free_pages + tree.total_pages() == 3
    # whatever is resident is reachable
    assert tree.cached_tokens(_key("a", 4)) == tree.total_pages() * 128


def test_tree_state_dict_round_trip():
    alloc = PagedAllocator(n_pages=32, page_size=128, pages_per_seq=8)
    tree = PrefixTree(alloc)
    tree.insert(_key("a", 4), 1.0)
    tree.insert(_key("a", 2) + (("a", 9),), 2.0)
    tree.insert(_key("b", 3), 3.0)
    tree.evict(1)
    sd = tree.state_dict()
    other = PrefixTree(alloc)
    other.load_state_dict(sd)
    assert other.total_pages() == tree.total_pages()
    assert other.n_evicted_pages == tree.n_evicted_pages
    for key in (_key("a", 4), _key("a", 2) + (("a", 9),), _key("b", 3),
                _key("c", 1)):
        assert other.cached_tokens(key) == tree.cached_tokens(key)


# --- cached-suffix pricing ---------------------------------------------

def test_cost_model_prices_only_uncached_suffix():
    c = NOJIT
    full = c.step_time(4, 1000)
    assert c.step_time(4, 1000, cached_tokens=600) == \
        pytest.approx(c.step_time(4, 400))
    assert c.step_time(4, 1000, cached_tokens=0) == full
    # floor at zero: a cache can never make prefill negative
    assert c.step_time(4, 1000, cached_tokens=5000) == \
        pytest.approx(c.step_time(4, 0))
    reqs = [Request(tenant=TenantTier.STANDARD, category=Category.SUMMARY,
                    prompt_tokens=500, true_output_tokens=10)]
    assert c.batch_time(reqs, cached_tokens=200) == \
        pytest.approx(c.batch_time(reqs) - c.c_prefill * 200)


def test_estimator_budget_discounts_cached_tokens():
    est = AdaptiveTokenEstimator(DriftConfig())
    base = est.estimate(Category.SUMMARY, TenantTier.STANDARD, 1000)
    hit = est.estimate(Category.SUMMARY, TenantTier.STANDARD, 1000,
                       cached_tokens=512)
    # output estimate reads the FULL prompt; only T_input is discounted
    assert hit.est_output_tokens == base.est_output_tokens
    assert hit.t_budget == pytest.approx(base.t_budget - 512)
    assert hit.cached_tokens == 512
    # clamped to the prompt
    over = est.estimate(Category.SUMMARY, TenantTier.STANDARD, 100,
                        cached_tokens=512)
    assert over.cached_tokens == 100


# --- step-engine integration -------------------------------------------

def _plan(shared, *, total=160, seed=11, groups=2):
    gen = WorkloadGenerator(GeneratorConfig(
        total_requests=total, calibration_requests=total // 3, seed=seed,
        prompt_tokens_scale=8.0, shared_prefix_tokens=shared,
        prefix_groups_per_tenant=groups))
    return gen.plan(seed=seed)


def _run_worker(shared, *, prefix_cache, pages=4096, **sim_kw):
    sched = DriftScheduler(policy="fifo", config=DriftConfig())
    sim = WorkerSimulator(
        sched, _plan(shared),
        SimConfig(seed=11, step_engine=True, prefix_cache=prefix_cache,
                  prefix_cache_pages=pages, **sim_kw),
        cost_model=NOJIT)
    return sched, sim, sim.run()


def test_prefix_cache_requires_step_engine():
    with pytest.raises(ValueError, match="step_engine"):
        WorkerSimulator(DriftScheduler(), config=SimConfig(
            prefix_cache=True))


def test_worker_cache_hits_and_token_conservation():
    sched, sim, m = _run_worker(512, prefix_cache=True)
    stats = sim.prefix_cache_stats()
    assert stats["hits"] > 0
    assert stats["tokens_saved"] >= stats["hits"] * KV_PAGE_TOKENS
    assert m.n_completed == 160
    for r in sched.completed:
        prefilled, emitted = sim.token_ledger[r.req_id]
        # conservation: cached + chunk-prefilled == prompt, and the
        # realized hit recorded on the request matches the ledger
        assert sim.prefix_ledger[r.req_id] + prefilled == r.prompt_tokens
        assert sim.prefix_ledger[r.req_id] == r.cached_prompt_tokens
        assert emitted == r.observed_output_tokens


def test_worker_cache_reduces_latency_and_prefill_work():
    _, on, m_on = _run_worker(512, prefix_cache=True)
    _, off, m_off = _run_worker(512, prefix_cache=False)
    assert on.prefix_tokens_saved > 0
    prefilled_on = sum(v[0] for v in on.token_ledger.values())
    prefilled_off = sum(v[0] for v in off.token_ledger.values())
    assert prefilled_on + on.prefix_tokens_saved == prefilled_off
    assert m_on.e2e.p50 < m_off.e2e.p50


def test_share0_bit_parity_with_cache_off():
    """Prefix share 0: the cache takes no action and the run is
    bit-identical to the PR-3 step engine (same events, same floats)."""
    sa, xa, ma = _run_worker(0, prefix_cache=True)
    sb, xb, mb = _run_worker(0, prefix_cache=False)
    assert ma.as_dict() == mb.as_dict()
    ea = [lat for _, lat in sorted((r.req_id, r.e2e_latency)
                                   for r in sa.completed)]
    eb = [lat for _, lat in sorted((r.req_id, r.e2e_latency)
                                   for r in sb.completed)]
    assert ea == eb                            # exact, not approx
    stats = xa.prefix_cache_stats()
    assert stats["hits"] == stats["misses"] == stats["tokens_saved"] == 0


def test_drift_samples_attribute_cache_outcome():
    sched, sim, _ = _run_worker(512, prefix_cache=True)
    samples = sched.drift.samples
    assert any(s.cache_hit for s in samples)
    assert any(not s.cache_hit for s in samples)
    for s in samples:
        if s.cache_hit:
            assert s.cached_tokens >= KV_PAGE_TOKENS
    split = sched.drift.per_cache_outcome()
    assert split["hit"].n + split["miss"].n == len(samples)
    # calibration is cache-neutral: hit samples carry the same
    # output-drift information (non-degenerate errors), not zeros
    assert split["hit"].n > 0 and split["hit"].mae > 0


# --- routing -----------------------------------------------------------

def test_prefix_aware_registered():
    assert "prefix_aware" in ROUTING_POLICIES
    assert ROUTING_POLICIES["prefix_aware"] is PrefixAwareRouting


def _cluster(routing, shared, *, cache=True, pages=32, seed=3,
             fail_events=(), total=300):
    gen = WorkloadGenerator(cluster_stress_config(
        4, seed=seed, total_requests=total, prompt_tokens_scale=8.0,
        shared_prefix_tokens=shared, prefix_groups_per_tenant=4))
    sim = ClusterSimulator(
        plan=gen.plan(seed=seed),
        config=ClusterConfig(n_replicas=4, routing=routing,
                             step_engine=True, chunk_prefill_tokens=2048,
                             prefix_cache=cache, prefix_cache_pages=pages,
                             fail_events=fail_events, seed=seed),
        cost_model=L4_QWEN_1_8B)
    return sim, sim.run()


def test_cluster_prefix_aware_beats_least_loaded_under_pressure():
    """With the per-replica cache smaller than the group population,
    residency-following placement must out-hit load-only placement and
    cut the prefill tokens actually computed."""
    _, pa = _cluster("prefix_aware", 1024)
    _, ll = _cluster("least_loaded", 1024)
    assert pa.prefix_cache["hit_rate"] > ll.prefix_cache["hit_rate"]
    assert pa.prefix_cache["tokens_saved"] > ll.prefix_cache["tokens_saved"]
    assert pa.prefix_cache["evicted_pages"] < ll.prefix_cache["evicted_pages"]
    assert pa.run.n_completed == ll.run.n_completed == 300


def test_cluster_share0_parity_and_counters_in_dict():
    _, on = _cluster("least_loaded", 0, cache=True)
    _, off = _cluster("least_loaded", 0, cache=False)
    assert on.as_dict() == off.as_dict()
    d = on.as_dict()
    assert "prefix_cache" in d
    for k in ("hits", "misses", "hit_rate", "tokens_saved",
              "evicted_pages", "invalidations"):
        assert k in d["prefix_cache"]
    assert d["replicas"][0]["n_prefix_hits"] == 0


def test_cluster_expected_cached_tokens_price_admission():
    sim, m = _cluster("prefix_aware", 1024)
    completed = [r for rep in sim.replicas for r in rep.sched.completed]
    hits = [r for r in completed if r.estimate.cached_tokens > 0]
    assert hits, "warm placements must price the uncached suffix"
    for r in hits:
        assert r.estimate.t_budget < r.prompt_tokens + \
            r.estimate.est_output_tokens


def test_worker_failure_with_surviving_workers_completes():
    """Standalone group, 2 workers, one fails: the cache wipe must not
    crash the surviving worker's slots when they release their (now
    orphaned) prefix locks; everything still completes."""
    sched = DriftScheduler(policy="fifo", config=DriftConfig())
    sim = WorkerSimulator(
        sched, _plan(512),
        SimConfig(seed=11, step_engine=True, prefix_cache=True,
                  n_workers=2, fail_times=(4.0,), fail_worker=0),
        cost_model=NOJIT)
    m = sim.run()
    assert m.n_completed == 160
    assert sim.n_cache_invalidations >= 1


def test_reroute_reprices_cache_discount():
    """A warm placement's cached-token budget discount belongs to the
    dead replica; after a failure reroute every estimate must satisfy
    t_budget == prompt - cached + est_out against its CURRENT cached
    tokens (the surviving replica's residency, not the dead one's)."""
    sim, m = _cluster("prefix_aware", 1024, fail_events=((4.0, 0),),
                      total=240)
    assert m.run.n_completed == 240 and m.n_rerouted > 0
    for rep in sim.replicas:
        for r in rep.sched.completed:
            e = r.estimate
            assert e.t_budget == pytest.approx(
                r.prompt_tokens - e.cached_tokens + e.est_output_tokens)


def test_cluster_failure_invalidates_cache_at_most_once_feedback():
    """A replica failure wipes its resident prefixes (lost KV -> full
    re-prefill); every request still completes exactly once and fires
    feedback exactly once."""
    sim, m = _cluster("prefix_aware", 1024, fail_events=((4.0, 0),),
                      total=240)
    assert m.run.n_completed == 240
    inval = sum(rep.prefix_cache_stats()["invalidations"]
                for rep in sim.replicas)
    assert inval >= 1
    feedback = sum(sim.estimator.bias_store.update_counts().values())
    assert feedback == 240                     # at-most-once, exactly once


def test_step_engine_reports_decode_and_inter_token_stats():
    _, m = _cluster("least_loaded", 0, cache=False)
    assert m.run.decode.n == m.run.n_completed
    assert m.inter_token.n > 0
    assert m.inter_token.p50 > 0
    # inter-token gap can never exceed the whole decode span
    assert m.inter_token.p50 <= m.decode.p50
    d = m.run.as_dict()
    assert "decode" in d and "inter_token" in d
