"""Per-architecture smoke tests (assignment requirement): instantiate a
REDUCED same-family config, run one forward + one train step on CPU,
assert output shapes + no NaNs. Full configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, smoke_config
from repro.distributed.optimizer import Optimizer, OptimizerConfig
from repro.models.registry import get_api
from repro.models.steps import make_prefill_step, make_serve_step, \
    make_train_step


def _batch(cfg, key, B=2, L=16, labels=True):
    tok = jax.random.randint(key, (B, L), 0, cfg.vocab)
    batch = {"tokens": tok}
    if labels:
        batch["labels"] = jnp.roll(tok, -1, axis=1)
    if cfg.family == "vlm":
        batch["patches"] = jnp.zeros((B, cfg.prefix_len, cfg.d_model),
                                     jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = 0.01 * jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model), jnp.float32
        ).astype(jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch):
    cfg = smoke_config(arch)
    api = get_api(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init(cfg, key)
    B, L = 2, 16
    batch = _batch(cfg, key, B, L)
    logits, aux = api.forward(cfg, params, batch)
    expect_len = L + (cfg.prefix_len if cfg.family == "vlm" else 0)
    assert logits.shape == (B, expect_len, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_reduces_loss_direction(arch):
    cfg = smoke_config(arch)
    api = get_api(cfg)
    key = jax.random.PRNGKey(1)
    params = api.init(cfg, key)
    opt = Optimizer(OptimizerConfig(lr=1e-3, warmup_steps=1, decay_steps=100))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    batch = _batch(cfg, key)
    losses = []
    for _ in range(2):
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
        assert float(m["grad_norm"]) > 0
    assert losses[1] < losses[0]  # same batch: one step must improve it


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch):
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(2)
    api = get_api(cfg)
    params = api.init(cfg, key)
    B, L, S = 2, 12, 48
    batch = _batch(cfg, key, B, L, labels=False)
    pf = make_prefill_step(cfg, max_len=S)
    sv = make_serve_step(cfg)
    toks, cache = pf(params, batch, None)
    assert toks.shape == (B,) and toks.dtype == jnp.int32
    pos = jnp.asarray(L, jnp.int32)
    for _ in range(3):
        toks, cache = sv(params, cache, toks, pos, None)
        pos = pos + 1
        assert toks.shape == (B,)
        assert (np.asarray(toks) >= 0).all()
        assert (np.asarray(toks) < cfg.vocab).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The published full config loads and has plausible scale."""
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {
        "minitron-8b": 8.3e9, "smollm-135m": 1.35e8, "minitron-4b": 4.2e9,
        "h2o-danube-1.8b": 1.8e9, "whisper-large-v3": 1.5e9,
        "mamba2-2.7b": 2.7e9, "zamba2-1.2b": 1.2e9,
        "grok-1-314b": 3.14e11, "llama4-scout-17b-a16e": 1.07e11,
        "paligemma-3b": 2.6e9,  # decoder-only backbone (SigLIP stubbed)
    }[arch]
    assert 0.6 * expected < n < 1.6 * expected, (arch, n, expected)
