"""DriftScheduler lifecycle + fault-tolerance semantics."""

import pytest

from repro.core.estimator import DriftConfig
from repro.core.request import Category, Request, RequestState, TenantTier
from repro.core.scheduler import DriftScheduler


def _req(category=Category.SUMMARY, tenant=TenantTier.STANDARD):
    return Request(tenant=tenant, category=category,
                   prompt="summarize the design of X for a new engineer")


def test_lifecycle_timestamps():
    s = DriftScheduler("fifo")
    r = s.submit(_req(), now=1.0)
    assert r.state is RequestState.QUEUED and r.arrival_time == 1.0
    d = s.dispatch(now=2.5)
    assert d is r and r.dispatch_time == 2.5
    sample = s.complete(r, observed_tokens=111, now=9.0)
    assert r.state is RequestState.COMPLETED
    assert r.e2e_latency == pytest.approx(8.0)
    assert r.queue_wait == pytest.approx(1.5)
    assert sample.observed_output == 111.0


def test_complete_feeds_bias_exactly_once():
    s = DriftScheduler("fifo")
    r = s.submit(_req(), now=0.0)
    s.dispatch(now=0.0)
    n0 = s.bias_store.update_counts()["summary"]
    s.complete(r, 100, now=1.0)
    assert s.bias_store.update_counts()["summary"] == n0 + 1


def test_fail_requeues_at_head_without_feedback():
    s = DriftScheduler("fifo")
    r1 = s.submit(_req(), now=0.0)
    r2 = s.submit(_req(), now=0.1)
    d1 = s.dispatch(now=0.2)
    assert d1 is r1
    counts_before = s.bias_store.update_counts()
    s.fail(d1, now=0.5)                      # worker died mid-batch
    assert s.bias_store.update_counts() == counts_before  # no feedback
    assert d1.retries == 1
    nxt = s.dispatch(now=0.6)
    assert nxt is r1                          # head-of-queue re-admission
    assert nxt.estimate is not None           # original estimate preserved


def test_dispatch_batch_respects_capacity():
    s = DriftScheduler("fifo")
    for i in range(10):
        s.submit(_req(), now=float(i))
    batch = s.dispatch_batch(now=20.0, max_n=4)
    assert len(batch) == 4
    assert s.queue_depth() == 6


def test_checkpoint_roundtrip_preserves_bias_and_cursor():
    s = DriftScheduler("weighted")
    for i in range(6):
        r = s.submit(_req(), now=float(i))
        s.dispatch(now=float(i))
        s.complete(r, 50 + i, now=float(i) + 1)
    state = s.state_dict()

    s2 = DriftScheduler("weighted")
    s2.load_state_dict(state)
    assert s2.bias_store.snapshot() == s.bias_store.snapshot()
    assert s2.policy.state_dict() == s.policy.state_dict()
    assert s2.dispatched == s.dispatched


def test_checkpoint_restores_queued_requests():
    s = DriftScheduler("fifo")
    reqs = [s.submit(_req(), now=float(i)) for i in range(4)]
    s.dispatch(now=5.0)                       # one request leaves the queue
    state = s.state_dict()
    assert len(state["queued_req_ids"]) == 3

    s2 = DriftScheduler("fifo")
    s2.load_state_dict(state, requests={r.req_id: r for r in reqs})
    assert s2.queue_depth() == 3
    restored = [s2.dispatch(now=10.0).req_id for _ in range(3)]
    assert restored == state["queued_req_ids"]    # FIFO order preserved


def test_checkpoint_restore_drains_stale_queue():
    s = DriftScheduler("fifo")
    r = s.submit(_req(), now=0.0)
    s.dispatch(now=0.0)
    s.complete(r, 100, now=1.0)
    state = s.state_dict()                    # empty queue at checkpoint
    s2 = DriftScheduler("fifo")
    s2.submit(_req(), now=0.0)                # stale pre-restore request
    s2.load_state_dict(state)
    assert s2.queue_depth() == 0              # mirror of the checkpoint


def test_checkpoint_queued_requests_refused_without_registry():
    s = DriftScheduler("fifo")
    s.submit(_req(), now=0.0)
    state = s.state_dict()
    with pytest.raises(ValueError):
        DriftScheduler("fifo").load_state_dict(state)
    with pytest.raises(KeyError):
        DriftScheduler("fifo").load_state_dict(state, requests={})


def test_checkpoint_policy_mismatch_raises():
    s = DriftScheduler("fifo")
    with pytest.raises(ValueError):
        s.load_state_dict({"policy": "sjf"})


def test_prompt_tokens_counted_when_missing():
    s = DriftScheduler("fifo")
    r = Request(tenant=TenantTier.BATCH, category=Category.SHORT_QA,
                prompt="what is a b-tree index")
    s.submit(r, now=0.0)
    assert r.prompt_tokens == 5
