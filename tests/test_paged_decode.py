"""Paged decode path == contiguous decode path, end to end through the
allocator (the TPU PagedAttention adaptation is semantics-preserving)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import transformer
from repro.models.registry import get_api
from repro.serving.kv_cache import PagedAllocator, PagedPool


def test_paged_decode_matches_contiguous():
    cfg = smoke_config("minitron-8b")
    api = get_api(cfg)
    key = jax.random.PRNGKey(11)
    params = api.init(cfg, key)
    B, L, page = 2, 12, 8
    pages_per_seq = 6
    tokens = jax.random.randint(key, (B, L + 4), 0, cfg.vocab)

    # contiguous baseline
    _, cache = api.prefill(cfg, params, {"tokens": tokens[:, :L]},
                           max_len=page * pages_per_seq)

    # paged: allocate per-sequence pages and scatter the prefilled KV
    pool = PagedPool.create(cfg, n_pages=B * pages_per_seq + 2,
                            page_size=page)
    alloc = PagedAllocator(pool.n_pages, page, pages_per_seq)
    _, k_lv, v_lv = transformer.prefill_kv(cfg, params, tokens[:, :L])
    from repro.serving.kv_cache import write_prefill_pages
    for b in range(B):
        pages = alloc.alloc(b, L)
        pool = write_prefill_pages(
            pool, (k_lv[:, b], v_lv[:, b]), pages, L)

    for i in range(4):
        # contiguous step
        la, cache = api.decode_step(cfg, params, cache, tokens[:, L + i],
                                    jnp.asarray(L + i, jnp.int32))
        # paged step
        pt = jnp.asarray(alloc.table_array([0, 1]))
        lens = jnp.asarray(alloc.lens_array([0, 1]))
        lb, new_pool = transformer.decode_step_paged(
            cfg, params, {"k": pool.k, "v": pool.v},
            tokens[:, L + i], pt, lens)
        pool = PagedPool(k=new_pool["k"], v=new_pool["v"], page_size=page)
        for b in range(B):
            alloc.extend(b, 1)
        np.testing.assert_allclose(
            np.asarray(la, np.float32), np.asarray(lb, np.float32),
            atol=6e-2, rtol=6e-2, err_msg=f"paged step {i}")


def test_paged_decode_heterogeneous_lengths():
    """Paged slots at different depths (continuous batching) stay
    consistent with per-sequence contiguous decoding."""
    cfg = smoke_config("smollm-135m")
    api = get_api(cfg)
    key = jax.random.PRNGKey(12)
    params = api.init(cfg, key)
    page, pps = 8, 8
    lens = [5, 11]
    B = len(lens)
    toks = jax.random.randint(key, (B, 16), 0, cfg.vocab)

    pool = PagedPool.create(cfg, n_pages=B * pps + 1, page_size=page)
    alloc = PagedAllocator(pool.n_pages, page, pps)
    from repro.serving.kv_cache import write_prefill_pages
    singles = []
    for b, Lb in enumerate(lens):
        _, kb, vb = transformer.prefill_kv(cfg, params, toks[b:b+1, :Lb])
        pages = alloc.alloc(b, Lb)
        pool = write_prefill_pages(pool, (kb[:, 0], vb[:, 0]), pages, Lb)
        # per-sequence contiguous reference
        _, c = api.prefill(cfg, params, {"tokens": toks[b:b+1, :Lb]},
                           max_len=page * pps)
        singles.append(c)

    new_tok = toks[:, 15]
    pt = jnp.asarray(alloc.table_array([0, 1]))
    ln = jnp.asarray(alloc.lens_array([0, 1]))
    lp, _ = transformer.decode_step_paged(
        cfg, params, {"k": pool.k, "v": pool.v}, new_tok, pt, ln)
    for b, Lb in enumerate(lens):
        lc, _ = api.decode_step(cfg, params, singles[b], new_tok[b:b+1],
                                jnp.asarray(Lb, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(lp[b], np.float32), np.asarray(lc[0], np.float32),
            atol=6e-2, rtol=6e-2, err_msg=f"slot {b} at depth {Lb}")
