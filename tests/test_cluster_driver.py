"""Cluster router/admission over real JAX ServingEngine replicas."""

import jax
import pytest

from repro.cluster import AdmissionConfig, GlobalAdmission
from repro.cluster.driver import EngineClusterDriver, make_engine_cluster
from repro.configs import smoke_config
from repro.core.request import TenantTier
from repro.core.scheduler import DriftScheduler
from repro.models.registry import get_api
from repro.serving.engine import EngineConfig, ServingEngine
from repro.workload.generator import GeneratorConfig, WorkloadGenerator


def _cluster(n_replicas=2, routing="drift_aware", admission=None):
    cfg = smoke_config("smollm-135m")
    api = get_api(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0))
    return make_engine_cluster(
        cfg, params, n_replicas, routing=routing, admission=admission,
        engine_config=EngineConfig(n_slots=2, max_len=96,
                                   prompt_buckets=(16,)))


def _submit_n(driver, n, seed=0):
    gen = WorkloadGenerator(GeneratorConfig(
        total_requests=n, calibration_requests=n, max_tokens=48, seed=seed))
    plan = gen.plan(seed=seed)
    return sum(driver.submit(r, t) for t, r in plan.calibration)


def test_engine_cluster_routes_and_completes():
    driver = _cluster(n_replicas=2)
    accepted = _submit_n(driver, 10)
    assert accepted == 10
    m = driver.run_until_drained(max_steps=5000)
    assert m.n_completed == 10
    # work actually spread over both replicas
    assert all(rep.n_routed > 0 for rep in driver.replicas)
    # shared estimator saw every completion
    assert sum(driver.estimator.bias_store.update_counts().values()) == 10


def test_engine_cluster_rejects_unshared_estimators():
    cfg = smoke_config("smollm-135m")
    api = get_api(cfg)
    params = api.init(cfg, jax.random.PRNGKey(0))
    engines = [ServingEngine(cfg, params, DriftScheduler(),
                             EngineConfig(n_slots=2, max_len=96,
                                          prompt_buckets=(16,)))
               for _ in range(2)]
    with pytest.raises(ValueError):
        EngineClusterDriver(engines)


def test_engine_cluster_admission_sheds():
    adm = GlobalAdmission(AdmissionConfig(
        bucket_capacity={t: 400.0 for t in TenantTier},
        refill_rate={t: 0.0 for t in TenantTier}))
    driver = _cluster(n_replicas=2, admission=adm)
    accepted = _submit_n(driver, 12)
    assert 0 < accepted < 12
    assert driver.n_shed == 12 - accepted
    m = driver.run_until_drained(max_steps=5000)
    assert m.n_completed == accepted
