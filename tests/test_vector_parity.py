"""Differential oracle: the vectorized simulator core must reproduce
the object step engine bit-for-bit.

``repro.serving.vector_sim`` re-implements the iteration-level worker
simulator on flat numpy state so benchmarks can sweep 10^6-request
workloads; the object engine stays authoritative. These tests run both
engines on the *same* :class:`ArrivalPlan` (the :class:`VectorPlan`
snapshot is taken before the object run mutates its ``Request``
objects — ``req_id`` comes from a process-global counter, so two
generator calls do NOT produce comparable ids) and require exact
equality of:

* completion order (the full req_id sequence),
* every lifecycle stamp (dispatch / exec / prefill-end / completion),
* token ledgers and prefix-cache hit/miss/saved/invalidation counters,
* the entire ``RunMetrics`` dict — including ``busy_time``-derived
  ``gpu_utilization``, which locks the float accumulation order,
* telemetry samples and tenant-queue depth history.

Arms cover the exact-parity policies (fifo / priority / sjf /
weighted) crossed with chunked prefill, continuous joins, the prefix
cache, preemption (worker failure + repair) and ``max_new_per_step``,
plus the epoch-batched fast paths (single worker with jitter; many
workers jitter-free) that collapse pure-decode runs. The ``aging``
policy is order-equivalent but not bit-locked (its priority key is
algebraically shifted) and is deliberately absent.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.policies import make_policy
from repro.core.scheduler import DriftScheduler
from repro.serving.cost_model import L4_QWEN_1_8B
from repro.serving.simulator import SimConfig, WorkerSimulator, \
    make_worker_simulator
from repro.serving.vector_sim import (S_COMPLETED, S_CREATED,
                                      StepVectorizedWorkerSimulator,
                                      VectorWorkerSimulator)
from repro.workload.generator import (GeneratorConfig, VectorPlan,
                                      WorkloadGenerator)

ZERO_JIT = dataclasses.replace(L4_QWEN_1_8B, jitter_sigma=0.0)


def _eq(a, b):
    """Exact equality, except NaN == NaN (empty-class sentinel means
    the same absence on both sides)."""
    if isinstance(a, dict) and isinstance(b, dict):
        return a.keys() == b.keys() and all(_eq(a[k], b[k]) for k in a)
    if isinstance(a, (list, tuple)) and isinstance(b, (list, tuple)):
        return len(a) == len(b) and all(_eq(x, y) for x, y in zip(a, b))
    if isinstance(a, float) and isinstance(b, float) \
            and np.isnan(a) and np.isnan(b):
        return True
    return a == b

N_TOTAL, N_CAL, SEED = 96, 12, 11


def _plan():
    gen = WorkloadGenerator(GeneratorConfig(
        total_requests=N_TOTAL, calibration_requests=N_CAL,
        shared_prefix_tokens=192, prefix_groups_per_tenant=2, seed=SEED))
    return gen.plan()


def _run_pair(policy, cfg, cost=L4_QWEN_1_8B, max_new=None):
    plan = _plan()
    vplan = VectorPlan.from_plan(plan)   # snapshot before object mutates
    vec = VectorWorkerSimulator(vplan, cfg, cost, policy=policy,
                                max_new_per_step=max_new)
    mv = vec.run()
    sched = DriftScheduler(policy=make_policy(policy),
                           max_new_per_step=max_new)
    obj = WorkerSimulator(sched, plan=plan, config=cfg, cost_model=cost)
    mo = obj.run()
    return sched, obj, mo, vec, mv


def _assert_exact(sched, obj, mo, vec, mv):
    st = vec.state
    # 1. completion order: the full req_id sequence
    obj_order = [int(r.req_id) for r in sched.completed]
    vec_order = [int(st.req_id[i])
                 for i in vec.sched.completed_order.view()]
    assert obj_order == vec_order

    # 2. every lifecycle stamp, exactly (None <-> NaN)
    rows = {int(st.req_id[i]): i for i in range(len(st.req_id))}
    for r in sched.completed:
        i = rows[int(r.req_id)]
        for name, ov, vv in [
                ("arrival", r.arrival_time, st.arrival[i]),
                ("enqueue", r.enqueue_time, st.enqueue[i]),
                ("dispatch", r.dispatch_time, st.dispatch[i]),
                ("exec_start", r.exec_start, st.exec_start[i]),
                ("exec_end", r.exec_end, st.exec_end[i]),
                ("prefill_end", r.prefill_end, st.prefill_end[i]),
                ("completion", r.completion_time, st.completion[i])]:
            if ov is None:
                assert np.isnan(vv), (name, r.req_id)
            else:
                assert ov == vv, (name, r.req_id, ov, float(vv))
        assert r.observed_output_tokens == st.observed[i], r.req_id
        assert r.retries == st.retries[i], r.req_id
        assert r.cached_prompt_tokens == st.cached_prompt_tokens[i]

    # 3. token + prefix ledgers
    ol, vl = obj.token_ledger, vec.token_ledger
    assert {int(k) for k in ol} == set(vl)
    for k, v in ol.items():
        assert tuple(v) == tuple(vl[int(k)]), k
    opl = getattr(obj, "prefix_ledger", {})
    if opl:
        vpl = vec.prefix_ledger
        assert {int(k) for k in opl} == set(vpl)
        for k, v in opl.items():
            assert v == vpl[int(k)], k

    # 4. engine counters
    for a in ("n_steps", "n_joins", "n_failed_dispatches",
              "n_prefix_hits", "n_prefix_misses", "prefix_tokens_saved",
              "n_cache_invalidations"):
        assert getattr(obj, a) == getattr(vec, a), a

    # 5. the whole metrics dict — busy_time/gpu_util lock float order
    assert _eq(mo.as_dict(), mv.as_dict())

    # 6. telemetry + queue depth history
    ot = [dataclasses.astuple(s) for s in obj.telemetry]
    vt = [dataclasses.astuple(s) for s in vec.telemetry]
    assert ot == vt
    od = [tuple(d) for d in obj.sched.queues.depth_history]
    vd = [tuple(d) for d in vec.sched.depth_history()]
    assert od == vd


BASE = dict(step_engine=True, n_workers=2, batch_capacity=4, seed=SEED)

ARMS = [
    ("fifo-plain", "fifo", {}, None),
    ("priority-plain", "priority", {}, None),
    ("sjf-plain", "sjf", {}, None),
    ("weighted-plain", "weighted", {}, None),
    ("fifo-chunked", "fifo", dict(chunk_prefill_tokens=64), None),
    ("fifo-chunked-joins", "fifo",
     dict(chunk_prefill_tokens=64, continuous_joins=True), None),
    ("sjf-chunked-joins", "sjf",
     dict(chunk_prefill_tokens=64, continuous_joins=True), None),
    ("fifo-prefix", "fifo", dict(prefix_cache=True), None),
    ("weighted-prefix-joins", "weighted",
     dict(prefix_cache=True, continuous_joins=True,
          chunk_prefill_tokens=64), None),
    ("fifo-preempt", "fifo",
     dict(fail_times=(5.0,), repair_time=3.0), None),
    ("priority-preempt", "priority",
     dict(fail_times=(5.0,), repair_time=3.0), None),
    ("sjf-preempt-prefix-joins", "sjf",
     dict(fail_times=(5.0,), repair_time=3.0, prefix_cache=True,
          continuous_joins=True, chunk_prefill_tokens=64), None),
    ("sjf-max-new", "sjf", {}, 2),
    ("fifo-telemetry", "fifo", dict(telemetry_interval=0.5), None),
]


@pytest.mark.parametrize("tag,policy,extra,max_new", ARMS,
                         ids=[a[0] for a in ARMS])
def test_vector_matches_object_exactly(tag, policy, extra, max_new):
    cfg = SimConfig(**BASE, **extra)
    _assert_exact(*_run_pair(policy, cfg, max_new=max_new))


EPOCH_ARMS = [
    # single worker: jitter draws stay ordered, epochs legal under noise
    ("1w-fifo-jitter", "fifo", L4_QWEN_1_8B,
     dict(n_workers=1, batch_capacity=8)),
    ("1w-sjf-joins-jitter", "sjf", L4_QWEN_1_8B,
     dict(n_workers=1, batch_capacity=8, chunk_prefill_tokens=64,
          continuous_joins=True)),
    ("1w-prefix-preempt-jitter", "fifo", L4_QWEN_1_8B,
     dict(n_workers=1, batch_capacity=8, prefix_cache=True,
          continuous_joins=True, chunk_prefill_tokens=64,
          fail_times=(5.0,), repair_time=3.0)),
    ("1w-telemetry-jitter", "fifo", L4_QWEN_1_8B,
     dict(n_workers=1, batch_capacity=8, telemetry_interval=0.5)),
    # jitter-free cost model: epochs legal across many workers
    ("2w-fifo-zerojit", "fifo", ZERO_JIT, dict()),
    ("2w-sjf-joins-zerojit", "sjf", ZERO_JIT,
     dict(chunk_prefill_tokens=64, continuous_joins=True)),
    ("2w-preempt-zerojit", "fifo", ZERO_JIT,
     dict(fail_times=(5.0,), repair_time=3.0)),
]


@pytest.mark.parametrize("tag,policy,cost,extra", EPOCH_ARMS,
                         ids=[a[0] for a in EPOCH_ARMS])
def test_epoch_fast_path_matches_object_exactly(tag, policy, cost, extra):
    cfg = SimConfig(**{**BASE, **extra})
    pair = _run_pair(policy, cfg, cost=cost)
    vec = pair[3]
    assert vec.n_epochs > 0, "arm must exercise the epoch fast path"
    _assert_exact(*pair)


def test_cluster_vector_backend_matches_object():
    """ClusterSimulator(backend='vector') — the composed
    StepVectorizedWorkerSimulator behind every replica — reproduces the
    object cluster run exactly (jitter-free cost model so replica
    epochs actually collapse; the shared rng forbids epochs under
    noise, where the composed engine degenerates to the object path)."""
    from repro.cluster.simulator import ClusterConfig, ClusterSimulator

    def run(backend, **kw):
        plan = WorkloadGenerator(GeneratorConfig(
            total_requests=120, calibration_requests=12, seed=5)).plan()
        cfg = ClusterConfig(n_replicas=3, step_engine=True,
                            batch_capacity=4, backend=backend, seed=5,
                            **kw)
        sim = ClusterSimulator(plan, cfg, cost_model=ZERO_JIT)
        return sim, sim.run()

    for kw in ({}, dict(prefix_cache=True, continuous_joins=True,
                        chunk_prefill_tokens=64),
               dict(fail_events=((5.0, 1),), repair_time=10.0)):
        _, mo = run("object", **kw)
        sim_v, mv = run("vector", **kw)
        assert all(isinstance(rep.sim, StepVectorizedWorkerSimulator)
                   for rep in sim_v.replicas)
        assert sum(rep.sim.n_epochs for rep in sim_v.replicas) > 0
        do, dv = mo.as_dict(), mv.as_dict()
        assert do.pop("backend") == "object"
        assert dv.pop("backend") == "vector"
        assert _eq(do, dv), kw


# ---------------------------------------------------------------------
# backend selection: no silent fallback
# ---------------------------------------------------------------------

def test_worker_simulator_refuses_vector_backend_directly():
    """Constructing the *object* engine with backend='vector' must
    raise, not silently run the slow path — CI greps for this guard."""
    sched = DriftScheduler(policy=make_policy("fifo"))
    with pytest.raises(ValueError, match="vector"):
        WorkerSimulator(sched, plan=_plan(),
                        config=SimConfig(step_engine=True,
                                         backend="vector"))


def test_unknown_backend_rejected():
    sched = DriftScheduler(policy=make_policy("fifo"))
    with pytest.raises(ValueError, match="backend"):
        WorkerSimulator(sched, plan=_plan(),
                        config=SimConfig(backend="numpy"))


def test_factory_selects_backend_classes():
    cfg = SimConfig(step_engine=True, backend="vector", seed=SEED)
    # standalone (no sink): the flat-array engine
    sched = DriftScheduler(policy=make_policy("fifo"))
    sim = make_worker_simulator(sched, plan=_plan(), config=cfg)
    assert type(sim) is VectorWorkerSimulator
    # sink-driven: the composed subclass (still a WorkerSimulator)
    sched2 = DriftScheduler(policy=make_policy("fifo"))
    sim2 = make_worker_simulator(sched2, plan=None, config=cfg,
                                 sink=lambda t, k, p: None)
    assert type(sim2) is StepVectorizedWorkerSimulator
    assert isinstance(sim2, WorkerSimulator)
    # object stays object
    sched3 = DriftScheduler(policy=make_policy("fifo"))
    sim3 = make_worker_simulator(
        sched3, plan=_plan(), config=SimConfig(step_engine=True))
    assert type(sim3) is WorkerSimulator


def test_cluster_vector_backend_rejects_pd():
    from repro.cluster.simulator import ClusterConfig, ClusterSimulator
    plan = WorkloadGenerator(GeneratorConfig(
        total_requests=24, calibration_requests=4, seed=3)).plan()
    with pytest.raises(ValueError, match="pd_disaggregated"):
        ClusterSimulator(plan, ClusterConfig(
            n_replicas=3, step_engine=True, backend="vector",
            routing="pd_disaggregated"))


# ---------------------------------------------------------------------
# conservation: fixed-seed fallback for the hypothesis property
# (tests/test_properties.py carries the randomized-driver version;
# hypothesis is a CI-only dependency, so this fallback must always run)
# ---------------------------------------------------------------------

@pytest.mark.parametrize("seed,policy,extra", [
    (1, "fifo", dict(prefix_cache=True, continuous_joins=True,
                     chunk_prefill_tokens=48)),
    (2, "sjf", dict(fail_times=(4.0, 9.0), repair_time=2.0)),
    (3, "weighted", dict(n_workers=1, batch_capacity=8,
                         prefix_cache=True)),
])
def test_vector_core_conservation_fixed_seeds(seed, policy, extra):
    """Conservation laws of the flat-array core, checked at every step
    boundary of a full run: prefix-pool pages are partitioned between
    the free list and the radix tree, and every request is in exactly
    one lifecycle bucket (queued + running + done == arrived)."""
    gen = WorkloadGenerator(GeneratorConfig(
        total_requests=64, calibration_requests=8,
        shared_prefix_tokens=96, prefix_groups_per_tenant=2, seed=seed))
    vplan = VectorPlan.from_plan(gen.plan())
    cfg = SimConfig(**{**BASE, "seed": seed, **extra})
    vec = VectorWorkerSimulator(vplan, cfg, L4_QWEN_1_8B, policy=policy)

    checks = {"n": 0}
    inner = vec._finish_step

    def checked(wid, gen_, now):
        done = inner(wid, gen_, now)
        st = vec.state
        if vec.prefix_tree is not None:
            alloc = vec.prefix_tree.allocator
            assert (alloc.free_pages + vec.prefix_tree.total_pages()
                    == alloc.n_pages)
        n = len(st.req_id)
        arrived = n - int((st.state[:n] == S_CREATED).sum())
        # queued + dispatched + executing + completed — every arrived
        # request sits in exactly one lifecycle bucket (S_FAILED is
        # transient: a preempted request is immediately re-queued)
        in_buckets = int((st.state[:n] > S_CREATED).sum()
                         - (st.state[:n] == 5).sum())
        assert in_buckets == arrived
        checks["n"] += 1
        return done

    vec._finish_step = checked
    vec.run()
    assert checks["n"] > 0
    assert int((vec.state.state == S_COMPLETED).sum()) == len(vplan)
